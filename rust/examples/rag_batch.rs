//! RAG-shaped batch processing with EOS early termination on the real
//! engine — the paper's prefill-heavy arm (Fig. 12) plus the §8.1 EOS
//! mode, at `small`-model scale.
//!
//!     make artifacts && cargo run --release --example rag_batch
//!
//! RAG-12000's shape (avg 926 / max 1843 prompt, 128 generation ⇒ p:g
//! ≈ 7:1) maps to prompts avg ~42 / max 56 with g = 6 in the 64-token
//! bucket. Prefill-heavy batches have high PME (Eq. 3), so throughput in
//! *processed* tokens/s should beat the MTBench-shaped run — the same
//! contrast the paper draws between Fig. 11 and Fig. 12.

use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::Request;
use moe_lens::perfmodel::Stage1Model;
use moe_lens::config::{MachineSpec, ModelSpec};
use moe_lens::util::rng::Rng;
use moe_lens::workload::eos_gen_len;

fn main() -> anyhow::Result<()> {
    let mut cfg = EngineConfig::for_model("small");
    cfg.kv_blocks = 200;
    let mut engine = ServingEngine::load(cfg)?;
    let n_tok = engine.n_tok();
    let vocab = engine.pjrt.config.vocab;

    // RAG-shaped: long prompts, short generations, EOS stops ~half way.
    let (g_max, k) = (6usize, 48usize);
    let mut rng = Rng::new(0x1246);
    let reqs: Vec<Request> = (0..k)
        .map(|i| {
            let p = rng.range(28, n_tok - g_max - 2);
            let prompt: Vec<i32> =
                (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            // EOS mode: cap each request at its sampled effective length
            // (the engine also honors literal EOS tokens; with random
            // weights we emulate the dataset's stop statistics instead).
            let eff_g = eos_gen_len(g_max, 0.6, &mut rng);
            Request::new(i as u64, prompt, eff_g)
        })
        .collect();
    let avg_p = reqs.iter().map(|r| r.prompt.len()).sum::<usize>() as f64 / k as f64;
    let avg_g = reqs.iter().map(|r| r.max_gen).sum::<usize>() as f64 / k as f64;

    println!(
        "serving {k} RAG-shaped requests (avg p={avg_p:.1}, avg g={avg_g:.1}, EOS mode) ..."
    );
    let (_, report) = engine.run(reqs)?;
    report.print("rag_batch (small, real engine)");

    // PME contrast (Stage 1): RAG-shape vs MTBench-shape.
    let s1 = Stage1Model::new(MachineSpec::paper_testbed(), ModelSpec::small());
    println!("== PME (Eq. 3): why prefill-heavy wins ==");
    println!("  RAG-shaped     (p=42, g=6)  : {:.4}", s1.pme(42, 6));
    println!("  MTBench-shaped (p=16, g=16) : {:.4}", s1.pme(16, 16));
    println!(
        "  ratio: {:.1}x more parallel tokens per unit of KV memory",
        s1.pme(42, 6) / s1.pme(16, 16)
    );
    Ok(())
}
