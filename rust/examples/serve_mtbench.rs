//! End-to-end driver (DESIGN.md §5): serve an MTBench-shaped batch on the
//! real `small` model through the full stack — resource-aware scheduler →
//! VSLPipe (CPU attention pool overlapped with PJRT GEMMs) → contiguous
//! data mover streaming real weight bytes through the throttled link —
//! and compare measured throughput against the Stage-2 model's prediction
//! for this exact configuration.
//!
//!     make artifacts && cargo run --release --example serve_mtbench
//!
//! MTBench's (98-prompt / 32-gen) shape is scaled to the `small` model's
//! compiled 64-token bucket (prompts ~16, generation 16): the *ratio*
//! p:g ≈ 3:1 and the length spread are preserved, which is all the
//! scheduler dynamics depend on. The run is recorded in EXPERIMENTS.md.

use moe_lens::config::{MachineSpec, ModelSpec};
use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::Request;
use moe_lens::perfmodel::Stage2Model;
use moe_lens::transfer::LinkTiming;
use moe_lens::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- Deployment: small model, virtual 2 GB/s link (bandwidth
    // accounting without wall-clock sleeps), modest KV cache.
    let mut cfg = EngineConfig::for_model("small");
    cfg.kv_blocks = 160; // 160 x 16 = 2560 token slots
    cfg.timing = LinkTiming::Virtual(2e9);
    cfg.attn_threads = 2;
    let mut engine = ServingEngine::load(cfg)?;

    // --- MTBench-shaped workload at 1/6 scale: lognormal prompts with
    // avg 16 / max 48, generation capped at 16 (p:g ratio as in the
    // paper's g=32 arm); 96 requests.
    let (avg_p, max_p, g, k) = (16usize, 48usize, 16usize, 96usize);
    let n_tok = engine.n_tok();
    let vocab = engine.pjrt.config.vocab;
    let mut rng = Rng::new(20250710);
    let sigma = ((max_p as f64 / avg_p as f64).ln() / 3.0).clamp(0.1, 1.5);
    let mu = (avg_p as f64).ln() - sigma * sigma / 2.0;
    let reqs: Vec<Request> = (0..k)
        .map(|i| {
            let p = (rng.lognormal(mu, sigma).round() as usize)
                .clamp(1, (n_tok - g).min(max_p));
            let prompt: Vec<i32> =
                (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            Request::new(i as u64, prompt, g)
        })
        .collect();
    let avg_prompt =
        reqs.iter().map(|r| r.prompt.len()).sum::<usize>() as f64 / k as f64;

    println!(
        "serving {k} MTBench-shaped requests (avg p={avg_prompt:.1}, g={g}) on \
         'small' via PJRT {} ...",
        engine.pjrt.platform()
    );
    let (trace, report) = engine.run(reqs)?;
    report.print("serve_mtbench (small, real engine)");

    // --- Per-pass breakdown (Fig. 13's bottom rows, real clock).
    let n = trace.passes.len();
    let show = [0, n / 4, n / 2, 3 * n / 4, n - 1];
    // gpu/cpu columns are total busy time per lane: the exclusive span
    // plus the GPU+CPU-overlapped window (PassRecord's lanes are
    // exclusive since the attribution fix).
    println!("  pass   prefill decode  io_wait    gpu      cpu_attn  overlap  kv_blocks");
    for &i in &show {
        let p = &trace.passes[i];
        println!(
            "  {:>4}   {:>7} {:>6}  {:>7.1}ms {:>7.1}ms {:>7.1}ms {:>7.1}ms  {:>6}",
            p.pass_id,
            p.prefill_tokens,
            p.decode_tokens,
            p.io_time * 1e3,
            p.gpu_busy() * 1e3,
            p.cpu_busy() * 1e3,
            p.overlap_time * 1e3,
            p.kv_blocks_used,
        );
    }

    // --- Stage-2 prediction for this configuration, on the *link clock*
    // (the engine's IO lane is virtual; compute is real wall time, so the
    // comparable prediction is the IO-bound term with this machine's
    // constants).
    let spec = ModelSpec::small();
    let machine = MachineSpec {
        gpu: moe_lens::config::GpuSpec::a40(),
        host: moe_lens::config::HostSpec::repro_box(),
        pcie_bw: 2e9,
        gpu_mem_for_serving: 1 << 30,
    };
    let s2 = Stage2Model::new(machine, spec.clone(), 16);
    let kv_bytes = 160u64 * 16 * spec.kv_bytes_per_token();
    let pred = s2.predict(avg_prompt.round() as usize, g, kv_bytes, k as f64);
    let link_secs = engine.link().total_time().as_secs_f64();
    let measured_link_tput = report.generated_tokens as f64 / link_secs.max(1e-9);
    println!("== Stage-2 model vs link-clock measurement ==");
    println!("  predicted  : {:>8.1} gen tok/s", pred.throughput);
    println!("  measured   : {:>8.1} gen tok/s (IO lane)", measured_link_tput);
    println!(
        "  accuracy   : {:>8.1} %",
        moe_lens::util::stats::prediction_accuracy(pred.throughput, measured_link_tput)
            * 100.0
    );
    println!(
        "  link moved {:.1} MB, achieved {:.2} GB/s of 2.00 GB/s configured",
        engine.link().total_bytes() as f64 / 1e6,
        engine.link().achieved_bw() / 1e9,
    );
    Ok(())
}
