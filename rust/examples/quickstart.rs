//! Quickstart: load the AOT-compiled `tiny` model through the PJRT CPU
//! client and serve a handful of prompts end to end.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Everything on the request path is Rust: the resource-aware scheduler,
//! the VSLPipe engine, the paged BF16 KV cache, the CPU decode-attention
//! kernel, and the weight-streaming data mover. Python ran once, at
//! `make artifacts` time.

use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::Request;

fn main() -> anyhow::Result<()> {
    let mut engine = ServingEngine::load(EngineConfig::for_model("tiny"))?;
    println!(
        "loaded 'tiny' ({} layers, bucket {} tokens) on PJRT '{}'",
        engine.pjrt.config.n_layers,
        engine.n_tok(),
        engine.pjrt.platform()
    );

    // Three prompts, eight greedy tokens each.
    let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3, 4, 5], vec![9, 8, 7], vec![42; 6]];
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), 8))
        .collect();

    let (_, report) = engine.run(reqs)?;
    report.print("quickstart (tiny)");

    let mut finished = engine.sched.take_finished();
    finished.sort_by_key(|s| s.id());
    for seq in &finished {
        println!(
            "  prompt {:?} -> generated {:?}",
            seq.req.prompt, seq.generated
        );
    }
    println!(
        "  weights streamed: {:.1} MB over the data-mover link",
        engine.link().total_bytes() as f64 / 1e6
    );
    Ok(())
}
