//! Online serving: Poisson arrivals through the incremental engine, with
//! request-level latency reporting (TTFT / TPOT / e2e p50+p99, goodput).
//!
//!     make artifacts && cargo run --release --example online_serving
//!
//! Unlike the closed-batch examples, requests enter the system over time
//! (the paper's serving claims are about *continuous* operation, and the
//! MoE-Lightning comparison, arXiv:2411.11217, is request-level). The
//! engine admits each request when its arrival time passes, overlapping
//! its prefill with in-flight decodes via the resource-aware scheduler.
//!
//! Without artifacts the example falls back to the paper-scale simulator
//! (same scheduler, virtual clock) so it always demonstrates the flow.

use moe_lens::config::ModelSpec;
use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::Request;
use moe_lens::simhw::{SimConfig, SimMachine};
use moe_lens::util::rng::Rng;
use moe_lens::workload::ArrivalProcess;

fn main() -> anyhow::Result<()> {
    match ServingEngine::load(EngineConfig::for_model("small")) {
        Ok(engine) => real_engine(engine),
        Err(e) => {
            println!("real engine unavailable ({e:#});");
            println!("falling back to the paper-scale simulator\n");
            simulated();
            Ok(())
        }
    }
}

fn real_engine(mut engine: ServingEngine) -> anyhow::Result<()> {
    let n_tok = engine.n_tok();
    let vocab = engine.pjrt.config.vocab;
    let mut rng = Rng::new(0xC0FFEE);

    // MTBench-like shapes at small-model scale, arriving at ~40 req/s.
    let (k, rate) = (48usize, 40.0);
    let reqs: Vec<Request> = (0..k)
        .map(|i| {
            let p = rng.range(8, n_tok / 2);
            let g = rng.range(4, n_tok / 4);
            let prompt: Vec<i32> =
                (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            Request::new(i as u64, prompt, g)
        })
        .collect();
    let times = ArrivalProcess::Poisson { rate }.times(k, &mut rng);
    let arrivals: Vec<(f64, Request)> = times.into_iter().zip(reqs).collect();

    println!(
        "online serving: {k} requests at ~{rate} req/s (Poisson) on 'small' \
         via PJRT {}\n",
        engine.pjrt.platform()
    );
    let (_, report, latency) = engine.run_online(arrivals, 2.0)?;
    report.print("online serving (small)");
    latency.print();
    Ok(())
}

fn simulated() {
    let cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
    let mut rng = Rng::new(0xC0FFEE);
    let (k, rate) = (2000usize, 150.0);
    let times = ArrivalProcess::Poisson { rate }.times(k, &mut rng);
    let arrivals: Vec<(f64, Request)> = times
        .into_iter()
        .enumerate()
        .map(|(i, t)| (t, Request::new(i as u64, vec![1; 98], 32)))
        .collect();
    let (_, report, latency) =
        SimMachine::new(cfg).run_online(arrivals, 60.0);
    report.print("online serving (simulated Mixtral-8x7B, 70 GB KV)");
    latency.print();
}
