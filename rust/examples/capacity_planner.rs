//! Capacity planner: the paper's performance models as a deployment
//! sizing tool. Given a model and workload shape, how much CPU memory
//! does each GPU need before the GPU — not memory — becomes the
//! bottleneck? And what throughput should you expect along the way?
//!
//!     cargo run --release --example capacity_planner
//!
//! This is the Stage-1/Stage-2 machinery (Eqs. 1–14) driving the kind of
//! question §5 poses: "how much CPU memory is necessary to fully utilize
//! the GPU?" — for all three paper models and three GPUs.

use moe_lens::config::{GpuSpec, MachineSpec, ModelSpec};
use moe_lens::perfmodel::{stage2::Regime, Stage1Model, Stage2Model};
use moe_lens::util::bench::Table;

fn main() {
    let (p, g) = (98usize, 64usize); // MTBench-like shape
    println!("capacity plan for p={p}, g={g} (MTBench-like), measured-PCIe testbed\n");

    // --- Table: KV cache needed to saturate each GPU (Table 2's logic,
    // extended with the Eq. 7 overlap amplification).
    let mut t = Table::new(&[
        "model", "gpu", "tok_to_sat", "kv_to_sat_GB", "kv_eff_overlap_GB",
    ]);
    for model in [ModelSpec::mixtral_8x7b(), ModelSpec::mixtral_8x22b(), ModelSpec::dbrx()] {
        for gpu in [GpuSpec::a40(), GpuSpec::l40(), GpuSpec::a100()] {
            let machine = MachineSpec { gpu: gpu.clone(), ..MachineSpec::paper_testbed() };
            let s1 = Stage1Model::new(machine, model.clone());
            let kv_needed = s1.kv_bytes_to_saturate(p + g);
            // Eq. 7: overlap shrinks the *provisioned* bytes needed.
            let provision = kv_needed * (p as f64 + g as f64 / 2.0) / (p + g) as f64;
            t.row(&[
                model.name.to_string(),
                gpu.name.to_string(),
                format!("{:.0}", s1.tokens_to_saturate()),
                format!("{:.0}", kv_needed / 1e9),
                format!("{:.0}", provision / 1e9),
            ]);
        }
    }
    t.print();

    // --- Throughput vs provisioned CPU memory for Mixtral-8x7B on A40.
    println!("\nMixtral-8x7B on A40: predicted throughput vs KV budget (K = 5gq)");
    let model = ModelSpec::mixtral_8x7b();
    let s2 = Stage2Model::new(MachineSpec::paper_testbed(), model, 16);
    let mut t = Table::new(&["kv_GB", "gen_tok_s", "gpu_util_%", "regime"]);
    for kv_gb in [35u64, 70, 140, 210, 420, 840, 1680] {
        let kv = kv_gb << 30;
        let k = s2.default_batch(p, g, kv);
        let pred = s2.predict(p, g, kv, k);
        t.row(&[
            kv_gb.to_string(),
            format!("{:.0}", pred.throughput),
            format!("{:.1}", pred.gpu_utilization * 100.0),
            format!("{:?}", pred.regime),
        ]);
    }
    t.print();

    // --- The §5.3 back-of-envelope: CPU-side requirements at 2x-model KV.
    let s1 = Stage1Model::new(MachineSpec::paper_testbed(), ModelSpec::mixtral_8x7b());
    let kv = 2 * s1.model.model_bytes();
    println!("\nCPU-side requirements at KV = 2x model size (§5.3):");
    println!(
        "  memory bandwidth: {:.0} GB/s (socket provides {:.0} GB/s)",
        s1.cpu_mem_bw_required(kv) / 1e9,
        s1.machine.host.mem_bw / 1e9
    );
    println!(
        "  attention compute: {:.0} GFLOP/s (socket peak {:.0} GFLOP/s)",
        s1.cpu_flops_required(kv) / 1e9,
        s1.machine.host.core_flops * s1.machine.host.n_cores as f64 / 1e9
    );
    let _ = Regime::GpuCompute; // referenced for doc purposes
}
