//! The VSLPipe execution engine (§6.4): the real serving path.
//!
//! Layers compose exactly as the paper's Fig. 8 divides them:
//!
//! * **GPU Task A** (PJRT `task_a`): RMSNorm + QKV projection + RoPE;
//! * **CPU Task** (`cpuattn` thread pool): KV-cache store + decode
//!   attention over the paged BF16 cache;
//! * **GPU flash attention** (PJRT `prefill_attn`, Pallas L1): packed
//!   segment-causal attention for prefill rows;
//! * **GPU Task B** (PJRT `task_b`): O-projection + residual + MoE layer.
//!
//! Per layer, the CPU task runs on the attention pool *concurrently* with
//! the GPU-side flash attention (the paper's phase overlap), weights
//! stream through the double-buffered [`transfer::WeightBuffer`] via the
//! Contiguous Data Mover, and stage boundaries are the only CPU↔GPU sync
//! points. Python is never on this path: all five compute pieces are
//! AOT-compiled PJRT executables.
//!
//! On top of the per-layer pipeline sits the *pass* pipeline
//! (`EngineConfig::pipeline_depth`): pass N+1's planning, packing, and
//! embedding gather run on a host worker under pass N's layer loop, and
//! the LM head overlaps the next pass's layer-0 weight prefetch — see
//! the `vslpipe` module docs.

mod batch;
mod vslpipe;

pub use batch::{pack_plan, Bucket, Row, RowKind};
pub use vslpipe::{EngineConfig, PipelineStats, ServingEngine, StepResult};
