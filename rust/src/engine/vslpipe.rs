//! The serving engine: scheduler + VSLPipe pipeline over the PJRT
//! executables, the paged KV cache, the CPU attention pool, and the
//! weight-streaming path.
//!
//! The engine is *incremental*: [`ServingEngine::step`] executes exactly
//! one pass (plan → pack → run_pass → complete) and returns its
//! [`PassRecord`] plus the tokens it yielded. [`ServingEngine::run`]
//! drains a closed batch by looping `step`, and
//! [`ServingEngine::run_online`] feeds the scheduler from a timed arrival
//! stream, tracking per-request TTFT / TPOT / end-to-end latency.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::batch::{pack_plan, Bucket, RowKind};
use crate::cpuattn::{AttnShape, DecodeQuery, ThreadPool};
use crate::kvcache::{KvLayout, PagedKvCache, SeqId};
use crate::metrics::{LatencyStats, PassRecord, RequestTracker, RunReport, Stopwatch, Trace};
use crate::model::Request;
use crate::runtime::{to_f32, to_i32, Arg, Manifest, PjrtEngine};
use crate::sched::{
    AdmissionPolicy, DropReason, SchedConfig, Scheduler, ServiceModel, VictimPolicy,
};
use crate::transfer::{DataMover, LinkTiming, PcieLink, WeightBuffer, WeightFile};
use crate::workload::duplicate_id;

/// Engine deployment configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: String,
    /// Model config name ("tiny" / "small").
    pub model: String,
    /// Paged-KV geometry (CPU-memory budget in blocks).
    pub block_size: usize,
    pub kv_blocks: usize,
    /// Link clocking (unthrottled for correctness runs, throttled for
    /// timing experiments).
    pub timing: LinkTiming,
    /// Data-mover packet size (§6.5; scaled down from 100 MB for the
    /// small artifacts).
    pub packet_bytes: usize,
    /// CPU attention worker threads.
    pub attn_threads: usize,
    /// Scheduler token budget per pass (buckets of `n_tok` are opened as
    /// needed up to this).
    pub token_budget: usize,
    /// Queue admission policy (default FIFO — PR-1 behavior).
    pub admission: AdmissionPolicy,
    /// Preemption victim policy (default newest-first — PR-1 behavior).
    pub victim: VictimPolicy,
    /// Service-time estimates for the SLO/weighted policies. The default
    /// (instant) makes SLO admission shed only requests whose deadline
    /// has already passed — conservative until the engine is profiled.
    pub service: ServiceModel,
}

impl EngineConfig {
    /// Correctness-oriented defaults for a config name.
    pub fn for_model(model: &str) -> Self {
        EngineConfig {
            artifacts_dir: "artifacts".into(),
            model: model.into(),
            block_size: 16,
            kv_blocks: 256,
            timing: LinkTiming::Unthrottled,
            // §Perf iteration 2: 1 MB packets cost ~2x mover bandwidth vs
            // large packets (5.9 vs 11.5 GB/s memcpy roof); 8 MB keeps
            // §6.5's no-head-of-line-blocking property at small-model
            // scale (paper-scale default stays 100 MB).
            packet_bytes: 8 << 20,
            attn_threads: 2,
            token_budget: 0, // 0 => 2 buckets (set at load)
            admission: AdmissionPolicy::default(),
            victim: VictimPolicy::default(),
            service: ServiceModel::default(),
        }
    }
}

/// Per-pass lane timings (wall clock, mutually exclusive): `io_wait +
/// gpu + cpu + overlap` decomposes the pass body. `overlap` is the window
/// where GPU flash attention and CPU decode attention run concurrently
/// (§6.4's phase overlap); total GPU busy time is `gpu + overlap`.
#[derive(Debug, Clone, Copy, Default)]
struct PassTimes {
    io_wait: f64,
    gpu: f64,
    cpu: f64,
    overlap: f64,
}

/// The outcome of one engine pass.
#[derive(Debug, Clone)]
pub struct StepResult {
    /// Telemetry for the pass (also what `run` pushes onto the trace).
    pub record: PassRecord,
    /// `(sequence, token)` pairs yielded this pass: every decode row plus
    /// the last row of every completing prefill chunk.
    pub yielded: Vec<(SeqId, i32)>,
    /// Sequences that finished this pass.
    pub finished: Vec<SeqId>,
    /// Requests the SLO admission policy shed while planning this pass
    /// (empty under the FIFO default).
    pub dropped: Vec<(SeqId, DropReason)>,
}

/// The end-to-end serving engine.
pub struct ServingEngine {
    pub pjrt: PjrtEngine,
    pub sched: Scheduler,
    cache: PagedKvCache,
    weights: Arc<WeightFile>,
    buffer: Arc<WeightBuffer>,
    link: Arc<PcieLink>,
    mover: DataMover,
    pool: ThreadPool,
    shape: AttnShape,
    /// Host-resident non-layer weights (embedding table, final norm, LM
    /// head — the paper keeps only layer weights on the streaming path).
    embedding: Vec<f32>,
    final_norm: Vec<f32>,
    lm_head: Vec<f32>,
    /// Run-relative clock stamping `PassRecord::t_end` (reset by
    /// [`ServingEngine::begin_run`]).
    run_clock: Stopwatch,
    /// Pass counter within the current run.
    next_pass: usize,
}

impl ServingEngine {
    pub fn load(cfg: EngineConfig) -> Result<ServingEngine> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let pjrt = PjrtEngine::load(&manifest, &cfg.model)?;
        let rc = pjrt.config.clone();

        let cm = manifest.config(&cfg.model)?;
        let weights = Arc::new(WeightFile::load(&cfg.artifacts_dir, &cm.weights)?);
        anyhow::ensure!(
            weights.n_layers() == rc.n_layers,
            "weight file has {} layers, config {}",
            weights.n_layers(),
            rc.n_layers
        );
        let layer_elems = weights.layer_data(0).len();
        let buffer = Arc::new(WeightBuffer::new(layer_elems));
        let link = Arc::new(PcieLink::new(cfg.timing));
        let mover = DataMover::spawn(
            Arc::clone(&weights),
            Arc::clone(&buffer),
            Arc::clone(&link),
            cfg.packet_bytes,
        );

        let shape = AttnShape {
            n_heads: rc.n_heads,
            n_kv_heads: rc.n_kv_heads,
            head_dim: rc.head_dim,
        };
        let cache = PagedKvCache::new(
            KvLayout::new(cfg.block_size, cfg.kv_blocks),
            rc.n_layers,
            shape.kv_dim(),
        );

        let token_budget = if cfg.token_budget == 0 { 2 * rc.n_tok } else { cfg.token_budget };
        let sched = Scheduler::new(
            SchedConfig::new(token_budget, rc.n_tok)
                .atomic()
                .with_admission(cfg.admission)
                .with_victim(cfg.victim)
                .with_service(cfg.service),
        );

        let embedding = weights.tensor_data("embedding")?.to_vec();
        let final_norm = weights.tensor_data("final_norm")?.to_vec();
        let lm_head = weights.tensor_data("lm_head")?.to_vec();

        Ok(ServingEngine {
            pjrt,
            sched,
            cache,
            weights,
            buffer,
            link,
            mover,
            pool: ThreadPool::new(cfg.attn_threads),
            shape,
            embedding,
            final_norm,
            lm_head,
            run_clock: Stopwatch::start(),
            next_pass: 0,
        })
    }

    pub fn n_tok(&self) -> usize {
        self.pjrt.config.n_tok
    }

    pub fn link(&self) -> &PcieLink {
        &self.link
    }

    /// Check a request against the compiled shapes.
    fn validate(&self, r: &Request) -> Result<()> {
        anyhow::ensure!(
            r.prompt.len() + r.max_gen <= self.n_tok(),
            "request {}: prompt({}) + max_gen({}) must fit the compiled \
             bucket ({}) so preemption replay stays atomic",
            r.id,
            r.prompt.len(),
            r.max_gen,
            self.n_tok()
        );
        anyhow::ensure!(
            r.prompt.len() + r.max_gen <= self.pjrt.config.max_ctx,
            "request {} exceeds max_ctx",
            r.id
        );
        Ok(())
    }

    /// Validate and enqueue one request — online admission. The request
    /// joins the Prefill Scheduler's queue and is picked up by the next
    /// [`step`](Self::step).
    pub fn submit(&mut self, r: Request) -> Result<()> {
        self.validate(&r)?;
        self.sched.submit(r);
        Ok(())
    }

    /// Start a new run: reset the pass counter and the run-relative clock,
    /// and hand back an empty trace sized to the KV geometry.
    pub fn begin_run(&mut self) -> Trace {
        self.next_pass = 0;
        self.run_clock = Stopwatch::start();
        Trace::new(self.cache.layout().layout().n_blocks)
    }

    /// Execute exactly one pass: plan → pack → run_pass → complete.
    /// Generated tokens land in the scheduler (`self.sched.finished()` for
    /// completed sequences); the returned [`StepResult`] carries the pass
    /// telemetry and the yielded `(seq, token)` pairs.
    ///
    /// `PassRecord::t_end` and `pass_id` are relative to the last
    /// [`begin_run`](Self::begin_run) — `run`/`run_online` call it for
    /// you; a manual `submit` + `step` loop should call it once up front,
    /// otherwise timestamps count from engine load (or from the previous
    /// run's clock) and pass ids continue the previous run's numbering.
    pub fn step(&mut self) -> Result<StepResult> {
        let now = self.run_clock.elapsed().as_secs_f64();
        let plan = self.sched.plan_at(self.cache.layout_mut(), now);
        let dropped = plan.dropped.clone();
        if plan.is_empty() {
            // Planning only shed requests (SLO admission) — there is no
            // pass body to execute. Record a zero-duration pass so the
            // drop accounting still lands on the trace.
            let record = PassRecord {
                pass_id: self.next_pass,
                t_end: self.run_clock.elapsed().as_secs_f64(),
                kv_blocks_used: self.cache.layout().used_blocks(),
                active_decode: self.sched.active_decode(),
                ..Default::default()
            };
            self.next_pass += 1;
            return Ok(StepResult {
                record,
                yielded: Vec::new(),
                finished: Vec::new(),
                dropped,
            });
        }
        let buckets = pack_plan(&plan, &self.sched, self.n_tok());
        let pass_clock = Stopwatch::start();
        let (tokens, times) = self.run_pass(&buckets)?;
        let duration = pass_clock.elapsed().as_secs_f64();
        let generated = tokens.len();
        let finished = self.sched.complete(&tokens, self.cache.layout_mut());

        let record = PassRecord {
            pass_id: self.next_pass,
            t_end: self.run_clock.elapsed().as_secs_f64(),
            duration,
            prefill_tokens: plan.prefill_tokens(),
            decode_tokens: plan.decode_tokens(),
            generated,
            finished: finished.len(),
            preempted: plan.preempted.len(),
            io_time: times.io_wait,
            gpu_time: times.gpu,
            cpu_time: times.cpu,
            overlap_time: times.overlap,
            kv_blocks_used: self.cache.layout().used_blocks(),
            active_decode: self.sched.active_decode(),
        };
        self.next_pass += 1;
        Ok(StepResult { record, yielded: tokens, finished, dropped })
    }

    /// Serve a batch of requests to completion. Returns the trace and the
    /// run report; generated tokens live in `self.sched.finished()`.
    ///
    /// This is the closed-batch special case of the incremental engine:
    /// every request is admitted up front, then [`step`](Self::step) loops
    /// until the scheduler drains.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<(Trace, RunReport)> {
        let n_req = requests.len();
        for r in &requests {
            self.validate(r)?;
        }
        self.sched.submit_all(requests);

        let mut trace = self.begin_run();
        while !self.sched.is_done() {
            let step = self.step()?;
            trace.push(step.record);
        }
        let report = RunReport::from_trace(&trace, n_req);
        Ok((trace, report))
    }

    /// Serve a timed arrival stream: `(arrival_secs, request)` pairs on
    /// the run clock (0 = run start). Requests are admitted when their
    /// arrival time passes; when the system drains before the next
    /// arrival, the engine sleeps until it. Returns the trace, the run
    /// report, and per-request latency stats; `slo_e2e` is the end-to-end
    /// deadline goodput is measured against (`f64::INFINITY` for plain
    /// completed-requests-per-second).
    pub fn run_online(
        &mut self,
        mut arrivals: Vec<(f64, Request)>,
        slo_e2e: f64,
    ) -> Result<(Trace, RunReport, LatencyStats)> {
        anyhow::ensure!(
            self.sched.is_done(),
            "run_online requires a drained scheduler: sequences submitted \
             outside the arrival stream would yield tokens the latency \
             tracker has no arrival record for"
        );
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("non-NaN arrival times"));
        for (_, r) in &arrivals {
            self.validate(r)?;
        }
        if let Some(dup) = duplicate_id(&arrivals) {
            anyhow::bail!(
                "duplicate request id {dup} in arrival stream — per-request \
                 latency tracking requires unique ids"
            );
        }
        let n_req = arrivals.len();
        let mut pending: VecDeque<(f64, Request)> = arrivals.into();
        let mut tracker = RequestTracker::new();
        let mut trace = self.begin_run();

        loop {
            let now = self.run_clock.elapsed().as_secs_f64();
            while pending.front().is_some_and(|(t, _)| *t <= now) {
                let (t, r) = pending.pop_front().unwrap();
                tracker.arrived(r.id, t);
                self.sched.submit_at(r, t);
            }
            if self.sched.is_done() {
                match pending.front() {
                    Some(&(t, _)) => {
                        // Idle: nothing to serve until the next arrival.
                        let wait = t - self.run_clock.elapsed().as_secs_f64();
                        if wait > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(wait));
                        }
                        continue;
                    }
                    None => break,
                }
            }
            let step = self.step()?;
            let t_end = step.record.t_end;
            for &(id, _) in &step.yielded {
                tracker.token(id, t_end);
            }
            for &id in &step.finished {
                tracker.finished(id, t_end);
            }
            for &(id, reason) in &step.dropped {
                tracker.dropped(id, t_end, reason);
            }
            trace.push(step.record);
        }

        let report = RunReport::from_trace(&trace, n_req);
        let stats = tracker.stats(trace.wall_secs(), slo_e2e);
        Ok((trace, report, stats))
    }

    /// One VSLPipe pass over the packed buckets.
    fn run_pass(&mut self, buckets: &[Bucket]) -> Result<(Vec<(SeqId, i32)>, PassTimes)> {
        let rc = &self.pjrt.config;
        let (n_tok, q_dim, kv_dim) = (rc.n_tok, rc.q_dim(), rc.kv_dim());
        let n_layers = rc.n_layers;
        let mut times = PassTimes::default();

        // Prologue: prime the double buffer (§6.4 prologue).
        self.mover.reset();
        self.mover.request(0);
        if n_layers > 1 {
            self.mover.request(1);
        }

        // Embed every bucket.
        let mut clock = Stopwatch::start();
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
        for b in buckets {
            let outs = self
                .pjrt
                .embed
                .run(&[Arg::I32(&b.ids), Arg::F32(&self.embedding)])
                .context("embed")?;
            xs.push(to_f32(&outs[0])?);
        }
        times.gpu += clock.lap().as_secs_f64();

        for layer in 0..n_layers {
            // Stage-boundary sync: weights for this layer must be staged.
            clock.lap();
            self.mover.wait_layer(layer);
            times.io_wait += clock.lap().as_secs_f64();

            // Stage the layer's weight literals ONCE (not per bucket) and
            // outside the buffer lock — §Perf iteration 6: the big task_b
            // expert tensors dominated H2D staging when copied per bucket.
            let ta = &self.pjrt.task_a;
            let tb = &self.pjrt.task_b;
            let (a_w, b_w) = self.buffer.read(layer, |w| -> Result<_> {
                let t = |name: &str| self.weights.tensor_in_layer(layer, name, w);
                let a_w = [
                    ta.literal(2, &Arg::F32(t("ln1")?))?,
                    ta.literal(3, &Arg::F32(t("wq")?))?,
                    ta.literal(4, &Arg::F32(t("wk")?))?,
                    ta.literal(5, &Arg::F32(t("wv")?))?,
                ];
                let b_w = [
                    tb.literal(2, &Arg::F32(t("wo")?))?,
                    tb.literal(3, &Arg::F32(t("ln2")?))?,
                    tb.literal(4, &Arg::F32(t("router")?))?,
                    tb.literal(5, &Arg::F32(t("w1")?))?,
                    tb.literal(6, &Arg::F32(t("w3")?))?,
                    tb.literal(7, &Arg::F32(t("w2")?))?,
                ];
                Ok((a_w, b_w))
            })?;

            // --- GPU Task A per bucket, then KV-cache stores (CPU task's
            // store half).
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut ks: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut vs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            for (bi, b) in buckets.iter().enumerate() {
                let x_lit = ta.literal(0, &Arg::F32(&xs[bi]))?;
                let pos_lit = ta.literal(1, &Arg::I32(&b.positions))?;
                let args =
                    [&x_lit, &pos_lit, &a_w[0], &a_w[1], &a_w[2], &a_w[3]];
                let outs = ta.run_prepared(&args).context("task_a")?;
                qs.push(to_f32(&outs[0])?);
                ks.push(to_f32(&outs[1])?);
                vs.push(to_f32(&outs[2])?);
            }
            times.gpu += clock.lap().as_secs_f64();

            // Host-side KV stores + decode-query assembly (CPU lane).
            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    self.cache.write(
                        row.seq,
                        layer,
                        row.pos,
                        &ks[bi][ri * kv_dim..(ri + 1) * kv_dim],
                        &vs[bi][ri * kv_dim..(ri + 1) * kv_dim],
                    );
                }
            }
            let mut decode_refs: Vec<(usize, usize)> = Vec::new(); // (bucket, row)
            let mut queries: Vec<DecodeQuery> = Vec::new();
            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    if row.kind == RowKind::Decode {
                        decode_refs.push((bi, ri));
                        queries.push(DecodeQuery {
                            seq: row.seq,
                            q: &qs[bi][ri * q_dim..(ri + 1) * q_dim],
                        });
                    }
                }
            }
            times.cpu += clock.lap().as_secs_f64();

            // --- Phase overlap: CPU decode attention (pool) runs while the
            // GPU computes packed flash attention for the prefill rows.
            // The phase is booked as three exclusive spans so the trace
            // lanes decompose the pass: GPU-only, both-busy (overlap), and
            // the CPU tail the engine spends waiting on the attention
            // thread. (The seed booked the whole phase to the GPU lane,
            // double-counting the CPU lane in the Fig.-13 series.)
            let mut cpu_out = vec![0f32; queries.len() * q_dim];
            let cpu_nanos = AtomicU64::new(0);
            let mut prefill_attn: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut gpu_lane = 0f64;

            let phase_clock = Stopwatch::start();
            std::thread::scope(|s| -> Result<()> {
                let cache = &self.cache;
                let pool = &self.pool;
                let shape = self.shape;
                let cpu_nanos = &cpu_nanos;
                let queries_ref = &queries;
                let cpu_out_ref = &mut cpu_out;
                let handle = s.spawn(move || {
                    let t0 = std::time::Instant::now();
                    pool.decode_attention(cache, layer, shape, queries_ref, cpu_out_ref);
                    cpu_nanos.store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                });
                // GPU lane: packed flash attention per bucket. Pure-decode
                // buckets skip the kernel outright — every one of their
                // rows takes the CPU lane's result in the merge below, so
                // the packed output would be computed and then fully
                // overwritten (padding rows get zeros; task_b and the head
                // are row-independent, so real rows are unaffected).
                let gpu_clock = Stopwatch::start();
                for (bi, b) in buckets.iter().enumerate() {
                    if b.n_prefill() == 0 {
                        prefill_attn.push(vec![0f32; n_tok * q_dim]);
                        continue;
                    }
                    let outs = self
                        .pjrt
                        .prefill_attn
                        .run(&[
                            Arg::F32(&qs[bi]),
                            Arg::F32(&ks[bi]),
                            Arg::F32(&vs[bi]),
                            Arg::I32(&b.seg_ids),
                        ])
                        .context("prefill_attn")?;
                    prefill_attn.push(to_f32(&outs[0])?);
                }
                gpu_lane = gpu_clock.elapsed().as_secs_f64();
                handle.join().expect("attention thread");
                Ok(())
            })?;
            let phase_wall = phase_clock.elapsed().as_secs_f64();
            clock.lap(); // resync: the phase is accounted below
            let cpu_busy = cpu_nanos.load(Ordering::Relaxed) as f64 / 1e9;
            let both_busy = gpu_lane.min(cpu_busy);
            times.overlap += both_busy;
            times.gpu += gpu_lane - both_busy;
            times.cpu += (phase_wall - gpu_lane).max(0.0);

            // Merge: decode rows take the CPU result.
            for (qi, &(bi, ri)) in decode_refs.iter().enumerate() {
                prefill_attn[bi][ri * q_dim..(ri + 1) * q_dim]
                    .copy_from_slice(&cpu_out[qi * q_dim..(qi + 1) * q_dim]);
            }
            times.cpu += clock.lap().as_secs_f64();

            // --- GPU Task B per bucket (weights pre-staged once above).
            for (bi, _b) in buckets.iter().enumerate() {
                let attn_lit = tb.literal(0, &Arg::F32(&prefill_attn[bi]))?;
                let resid_lit = tb.literal(1, &Arg::F32(&xs[bi]))?;
                let args = [
                    &attn_lit, &resid_lit, &b_w[0], &b_w[1], &b_w[2], &b_w[3],
                    &b_w[4], &b_w[5],
                ];
                let outs = tb.run_prepared(&args).context("task_b")?;
                xs[bi] = to_f32(&outs[0])?;
            }
            times.gpu += clock.lap().as_secs_f64();

            // Stage epilogue: release the slot, prefetch layer + 2 (§6.4).
            self.mover.done_with(layer);
            if layer + 2 < n_layers {
                self.mover.request(layer + 2);
            }
        }

        // Head: greedy next-token ids; collect yielding rows. Buckets with
        // no yielding row (pure partial-prefill buckets) skip the LM-head
        // execution entirely — their logits would be discarded.
        debug_assert_eq!(self.embedding.len(), rc.vocab * rc.d_model);
        let mut tokens: Vec<(SeqId, i32)> = Vec::new();
        clock.lap();
        for (bi, b) in buckets.iter().enumerate() {
            if !b.rows.iter().any(|r| r.yields) {
                continue;
            }
            let outs = self
                .pjrt
                .head
                .run(&[
                    Arg::F32(&xs[bi]),
                    Arg::F32(&self.final_norm),
                    Arg::F32(&self.lm_head),
                ])
                .context("head")?;
            let ids = to_i32(&outs[0])?;
            debug_assert_eq!(ids.len(), n_tok);
            for (ri, row) in b.rows.iter().enumerate() {
                if row.yields {
                    tokens.push((row.seq, ids[ri]));
                }
            }
        }
        times.gpu += clock.lap().as_secs_f64();

        Ok((tokens, times))
    }
}
