//! The serving engine: scheduler + VSLPipe pipeline over the PJRT
//! executables, the paged KV cache, the CPU attention pool, and the
//! weight-streaming path.
//!
//! The engine is *incremental*: [`ServingEngine::step`] executes exactly
//! one pass (plan → pack → run_pass → complete) and returns its
//! [`PassRecord`] plus the tokens it yielded. [`ServingEngine::run`]
//! drains a closed batch by looping `step`, and
//! [`ServingEngine::run_online`] feeds the scheduler from a timed arrival
//! stream, tracking per-request TTFT / TPOT / end-to-end latency.
//!
//! # Double-buffered pass pipeline
//!
//! With [`EngineConfig::pipeline_depth`] ≥ 1 (the default), each step is
//! a two-stage software pipeline instead of a serial plan → pack → embed
//! → layers → head chain:
//!
//! * While pass N's layer loop runs (DataMover streaming + GPU GEMMs +
//!   CPU attention), a host worker speculatively plans pass N+1 on a
//!   [`Scheduler::speculate`] snapshot, packs its buckets, and gathers
//!   its embeddings from the host-resident table. Pass-N yields that the
//!   head has not produced yet enter the snapshot as placeholder tokens;
//!   their bucket rows and embedding rows are patched at commit time.
//! * The [`DataMover`] stage protocol runs across pass boundaries, so the
//!   §6.4 `+2` prefetch issued at pass N's last layers streams pass N+1's
//!   layer 0/1 *while the LM head computes*.
//!
//! The speculation commits only if pass N finished exactly the sequences
//! the budget predicted (an EOS finish invalidates it) — otherwise the
//! engine falls back to a synchronous replan. Time-dependent planning
//! always takes the replan path: SLO admission reads the clock, and
//! weighted victim selection combined with the measured-service EWMA
//! reads a model that changes every pass. Requests arriving
//! while pass N runs join planning one pass later than in the synchronous
//! engine: that one-pass admission latency is the price of planning
//! ahead, and it is what removes the exposed inter-pass host gap.
//!
//! Lane accounting: exposed host work (replans, the tail of an
//! overrunning speculative plan, commit/patch bookkeeping) lands in
//! `PassRecord::host_time` — the fifth exclusive lane — while hidden
//! speculative work is reported as `host_overlap_time` on the pass it ran
//! under. With `pipeline_depth = 0` the engine takes the exact pre-pipeline
//! code path: planning happens outside the pass body, both host lanes
//! stay zero, and traces are pass-for-pass identical to the synchronous
//! engine.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::batch::{pack_plan, Bucket, RowKind};
use crate::config::{MachineSpec, ModelSpec};
use crate::cpuattn::{AttnShape, DecodeQuery, ThreadPool};
use crate::kvcache::{KvLayout, PagedKvCache, PagedLayout, SeqId};
use crate::metrics::{LatencyStats, PassRecord, RequestTracker, RunReport, Stopwatch, Trace};
use crate::model::Request;
use crate::runtime::{to_f32, to_i32, Arg, Manifest, PjrtEngine};
use crate::sched::{
    AdmissionPolicy, DropReason, PassPlan, SchedConfig, Scheduler, ServiceEstimator,
    ServiceModel, VictimPolicy,
};
use crate::transfer::{
    DataMover, ExpertMode, LinkTiming, PcieLink, ResidencyMap, WeightBuffer, WeightFile,
};
use crate::workload::{duplicate_id, ExpertRouter, PassRouting, RoutingSpec};

/// Engine deployment configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: String,
    /// Model config name ("tiny" / "small").
    pub model: String,
    /// Paged-KV geometry (CPU-memory budget in blocks).
    pub block_size: usize,
    pub kv_blocks: usize,
    /// Link clocking (unthrottled for correctness runs, throttled for
    /// timing experiments).
    pub timing: LinkTiming,
    /// Data-mover packet size (§6.5; scaled down from 100 MB for the
    /// small artifacts).
    pub packet_bytes: usize,
    /// CPU attention worker threads (0 = size the pool from
    /// `std::thread::available_parallelism`).
    pub attn_threads: usize,
    /// Scheduler token budget per pass (buckets of `n_tok` are opened as
    /// needed up to this).
    pub token_budget: usize,
    /// Queue admission policy (default FIFO — PR-1 behavior).
    pub admission: AdmissionPolicy,
    /// Preemption victim policy (default newest-first — PR-1 behavior).
    pub victim: VictimPolicy,
    /// Service-time estimates for the SLO/weighted policies. The default
    /// (instant) makes SLO admission shed only requests whose deadline
    /// has already passed; with [`measured_service`](Self::measured_service)
    /// on, an EWMA of observed pass times replaces it as soon as the
    /// first pass completes.
    pub service: ServiceModel,
    /// Two-stage pass pipeline depth: 0 = legacy synchronous stepping,
    /// ≥ 1 = overlap pass N+1's plan/pack/embed with pass N's layer loop
    /// and the LM head with next-pass weight prefetch (see the module
    /// docs). Default on.
    pub pipeline_depth: usize,
    /// Feed an online EWMA of *measured* per-pass prefill/decode times
    /// into the scheduler's [`ServiceModel`] (ROADMAP "measured engine
    /// service model"), so SLO admission predicts real service times
    /// instead of the instant default. Only the SLO admission and
    /// weighted-victim policies read the model; the FIFO/newest defaults
    /// are unaffected.
    pub measured_service: bool,
    /// Expert-routing trace attached to this deployment (`None` =
    /// uniform routing with the default seed). Only read when
    /// [`pinned_experts`](Self::pinned_experts) is nonzero.
    pub routing: Option<RoutingSpec>,
    /// Experts pinned in HBM per layer (popularity order). `0` disables
    /// expert-granular residency entirely: the mover streams whole layers
    /// and traces are byte-identical to the pre-refactor engine.
    pub pinned_experts: usize,
    /// HBM bytes available for pinned expert weights (the residency
    /// budget the always-on assert checks). Defaults to the paper
    /// testbed's serving slice.
    pub hbm_bytes: u64,
}

impl EngineConfig {
    /// Correctness-oriented defaults for a config name.
    pub fn for_model(model: &str) -> Self {
        EngineConfig {
            artifacts_dir: "artifacts".into(),
            model: model.into(),
            block_size: 16,
            kv_blocks: 256,
            timing: LinkTiming::Unthrottled,
            // §Perf iteration 2: 1 MB packets cost ~2x mover bandwidth vs
            // large packets (5.9 vs 11.5 GB/s memcpy roof); 8 MB keeps
            // §6.5's no-head-of-line-blocking property at small-model
            // scale (paper-scale default stays 100 MB).
            packet_bytes: 8 << 20,
            attn_threads: 0,
            token_budget: 0, // 0 => 2 buckets (set at load)
            admission: AdmissionPolicy::default(),
            victim: VictimPolicy::default(),
            service: ServiceModel::default(),
            pipeline_depth: 1,
            measured_service: true,
            routing: None,
            pinned_experts: 0,
            hbm_bytes: MachineSpec::paper_testbed().gpu_mem_for_serving,
        }
    }
}

/// Per-pass lane timings (wall clock, mutually exclusive): `io_wait +
/// gpu + cpu + overlap + host` decomposes the pass body. `overlap` is the
/// window where GPU flash attention and CPU decode attention run
/// concurrently (§6.4's phase overlap); total GPU busy time is
/// `gpu + overlap`. `host` is *exposed* plan/pack/embed/commit time;
/// `host_overlap` is speculative planning hidden under the layer loop
/// (a shadow lane, excluded from the partition).
#[derive(Debug, Clone, Copy, Default)]
struct PassTimes {
    io_wait: f64,
    gpu: f64,
    cpu: f64,
    overlap: f64,
    host: f64,
    host_overlap: f64,
}

/// The outcome of one engine pass.
#[derive(Debug, Clone)]
pub struct StepResult {
    /// Telemetry for the pass (also what `run` pushes onto the trace).
    pub record: PassRecord,
    /// `(sequence, token)` pairs yielded this pass: every decode row plus
    /// the last row of every completing prefill chunk.
    pub yielded: Vec<(SeqId, i32)>,
    /// Sequences that finished this pass.
    pub finished: Vec<SeqId>,
    /// Requests the SLO admission policy shed while planning this pass
    /// (empty under the FIFO default).
    pub dropped: Vec<(SeqId, DropReason)>,
}

/// Pipeline telemetry: how often the speculative planner ran, committed,
/// and fell back to a synchronous replan.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    /// Speculative plan jobs launched.
    pub speculated: usize,
    /// Jobs whose prediction held and whose pass was reused.
    pub committed: usize,
    /// Jobs invalidated (EOS finish diverged from the budget prediction);
    /// the next pass replanned synchronously.
    pub replanned: usize,
}

/// A fully prepared next pass, produced by a committed speculation: the
/// plan is already applied to the scheduler/KV layout, buckets are packed
/// and token-patched, and embeddings are gathered. [`ServingEngine::step`]
/// executes it directly, with no exposed host work.
struct PipelinedStep {
    plan: PassPlan,
    buckets: Vec<Bucket>,
    xs: Vec<Vec<f32>>,
    /// Per-layer activated-expert sets of the plan (expert mode only) —
    /// the routing state the speculate/commit snapshot carries.
    routing: Option<PassRouting>,
}

/// Everything the speculative planner worker needs, owned (jobs are fed
/// to the long-lived [`PlannerWorker`] over a channel).
struct SpecJob {
    sched: Scheduler,
    layout: PagedLayout,
    /// Sequences the in-flight pass will yield a token for (decode rows +
    /// completing prefill chunks) — the predicted `complete` input.
    yields: Vec<SeqId>,
    now: f64,
    n_tok: usize,
    d_model: usize,
    embedding: Arc<Vec<f32>>,
    /// Routing oracle (expert mode): the worker routes the speculative
    /// plan so the snapshot carries its activated-expert sets.
    router: Option<Arc<ExpertRouter>>,
}

/// The worker's result: the speculative successor state plus the packed,
/// embedded next pass and the patch sites that still need pass-N's real
/// tokens.
struct SpecNext {
    /// Sequences predicted to finish (budget exhaustion), sorted.
    predicted_finished: Vec<SeqId>,
    /// Placeholder tokens applied to surviving yielders:
    /// `(id, generated index, logical token position)`.
    placeholders: Vec<(SeqId, usize, usize)>,
    plan: PassPlan,
    sched: Scheduler,
    layout: PagedLayout,
    buckets: Vec<Bucket>,
    xs: Vec<Vec<f32>>,
    /// `(bucket, row)` sites fed by a pass-N token (placeholder-valued
    /// until commit patches them).
    patches: Vec<(usize, usize)>,
    /// Activated-expert sets of the speculative plan (expert mode only).
    routing: Option<PassRouting>,
    /// Worker busy time (seconds) — the host work the pipeline hid.
    host_secs: f64,
}

/// The long-lived speculative-planner worker: one thread, fed one
/// [`SpecJob`] per pipelined pass over a channel (DataMover-style), so
/// the per-pass cost on the submit side is just the snapshot clone — no
/// thread spawn. Exactly one job is in flight at a time (submitted in
/// the speculate phase, received in the commit phase of the same step).
struct PlannerWorker {
    tx: Option<Sender<SpecJob>>,
    rx: Receiver<SpecNext>,
    handle: Option<JoinHandle<()>>,
}

impl PlannerWorker {
    fn spawn() -> PlannerWorker {
        let (tx, job_rx) = channel::<SpecJob>();
        let (out_tx, rx) = channel::<SpecNext>();
        let handle = std::thread::spawn(move || {
            while let Ok(job) = job_rx.recv() {
                if out_tx.send(job.run()).is_err() {
                    return;
                }
            }
        });
        PlannerWorker { tx: Some(tx), rx, handle: Some(handle) }
    }

    fn submit(&self, job: SpecJob) {
        let Some(tx) = self.tx.as_ref() else {
            panic!("planner worker not running");
        };
        if tx.send(job).is_err() {
            panic!("planner worker exited");
        }
    }

    fn recv(&self) -> SpecNext {
        match self.rx.recv() {
            Ok(next) => next,
            Err(_) => panic!("planner worker exited"),
        }
    }
}

impl Drop for PlannerWorker {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl SpecJob {
    fn run(mut self) -> SpecNext {
        let clock = Stopwatch::start();
        let (predicted_finished, placeholders) =
            self.sched.complete_speculative(&self.yields, &mut self.layout);
        let plan = self.sched.plan_at(&mut self.layout, self.now);
        let (buckets, xs, patches) = if plan.is_empty() {
            (Vec::new(), Vec::new(), Vec::new())
        } else {
            let buckets = pack_plan(&plan, &self.sched, self.n_tok);
            // Rows fed by the token pass N is still computing: every
            // decode row of a surviving yielder (its fed token is the
            // placeholder just pushed), and any replayed prefill row
            // landing exactly on the placeholder's logical position.
            let site: BTreeMap<SeqId, usize> =
                placeholders.iter().map(|&(id, _, pos)| (id, pos)).collect();
            let mut patches = Vec::new();
            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    let hit = match row.kind {
                        RowKind::Decode => site.contains_key(&row.seq),
                        RowKind::Prefill => site.get(&row.seq) == Some(&row.pos),
                    };
                    if hit {
                        patches.push((bi, ri));
                    }
                }
            }
            let xs = gather_embeddings(&self.embedding[..], self.d_model, &buckets);
            (buckets, xs, patches)
        };
        let routing = if plan.is_empty() {
            None
        } else {
            self.router.as_ref().map(|r| plan.routed(r))
        };
        SpecNext {
            predicted_finished,
            placeholders,
            plan,
            sched: self.sched,
            layout: self.layout,
            buckets,
            xs,
            patches,
            routing,
            host_secs: clock.elapsed().as_secs_f64(),
        }
    }
}

/// Host-side embedding lookup: an exact row gather from the resident
/// table, matching the PJRT `embed` executable (a pure `take`) bit for
/// bit — which is what keeps pipelined and synchronous runs
/// token-identical. Padding rows (id 0) gather row 0 exactly as the
/// compiled gather does.
fn gather_embeddings(embedding: &[f32], d_model: usize, buckets: &[Bucket]) -> Vec<Vec<f32>> {
    buckets
        .iter()
        .map(|b| {
            let mut x = vec![0f32; b.n_tok * d_model];
            for (i, &id) in b.ids.iter().enumerate() {
                let row = id as usize * d_model;
                x[i * d_model..(i + 1) * d_model]
                    .copy_from_slice(&embedding[row..row + d_model]);
            }
            x
        })
        .collect()
}

/// The expected yield set of a plan: one token per decode row and per
/// completing prefill chunk — exactly what `Scheduler::complete` will be
/// fed after the pass runs.
fn predicted_yields(plan: &PassPlan) -> Vec<SeqId> {
    plan.decode
        .iter()
        .map(|&(id, _)| id)
        .chain(plan.prefill.iter().filter(|c| c.completes).map(|c| c.id))
        .collect()
}

/// The end-to-end serving engine.
pub struct ServingEngine {
    pub pjrt: PjrtEngine,
    pub sched: Scheduler,
    cache: PagedKvCache,
    weights: Arc<WeightFile>,
    buffer: Arc<WeightBuffer>,
    link: Arc<PcieLink>,
    mover: DataMover,
    pool: ThreadPool,
    shape: AttnShape,
    /// Host-resident non-layer weights (embedding table, final norm, LM
    /// head — the paper keeps only layer weights on the streaming path).
    /// The embedding is shared with the speculative planner worker.
    embedding: Arc<Vec<f32>>,
    final_norm: Vec<f32>,
    lm_head: Vec<f32>,
    /// Run-relative clock stamping `PassRecord::t_end` (reset by
    /// [`ServingEngine::begin_run`]).
    run_clock: Stopwatch,
    /// Pass counter within the current run.
    next_pass: usize,
    /// Pipeline depth (0 = legacy synchronous stepping).
    pipeline_depth: usize,
    /// Next weight *stage* to consume (pipelined mover protocol: stage
    /// ids run across pass boundaries, stage s sources layer
    /// `s % n_layers`).
    stage_cursor: usize,
    /// The committed speculative next pass, if any.
    prepared: Option<PipelinedStep>,
    /// The long-lived speculative-planner worker (pipelined mode).
    planner: PlannerWorker,
    /// Routing oracle — `Some` iff expert-granular residency is active.
    router: Option<Arc<ExpertRouter>>,
    /// Pipeline commit/replan telemetry.
    stats: PipelineStats,
    /// Online EWMA of observed pass times (measured service model).
    measured_service: bool,
    estimator: ServiceEstimator,
}

impl ServingEngine {
    pub fn load(cfg: EngineConfig) -> Result<ServingEngine> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let pjrt = PjrtEngine::load(&manifest, &cfg.model)?;
        let rc = pjrt.config.clone();

        let cm = manifest.config(&cfg.model)?;
        let weights = Arc::new(WeightFile::load(&cfg.artifacts_dir, &cm.weights)?);
        anyhow::ensure!(
            weights.n_layers() == rc.n_layers,
            "weight file has {} layers, config {}",
            weights.n_layers(),
            rc.n_layers
        );
        let layer_elems = weights.layer_data(0).len();
        let buffer = Arc::new(WeightBuffer::new(layer_elems));
        let link = Arc::new(PcieLink::new(cfg.timing));
        let token_budget = if cfg.token_budget == 0 { 2 * rc.n_tok } else { cfg.token_budget };
        let (mover, router) = if cfg.pinned_experts > 0 {
            let spec = ModelSpec::by_name(&cfg.model)
                .with_context(|| format!("no ModelSpec named '{}'", cfg.model))?;
            let routing = cfg.routing.unwrap_or_else(RoutingSpec::uniform);
            let router = Arc::new(ExpertRouter::new(&spec, routing));
            let residency = Arc::new(ResidencyMap::pin_hottest(
                &router,
                cfg.pinned_experts,
                ResidencyMap::budget_from_bytes(cfg.hbm_bytes, spec.expert_bytes()),
            ));
            let mode = ExpertMode {
                router: Arc::clone(&router),
                residency,
                predict_n: router.predicted_count(token_budget),
            };
            let mover = DataMover::spawn_expert(
                Arc::clone(&weights),
                Arc::clone(&buffer),
                Arc::clone(&link),
                cfg.packet_bytes,
                mode,
            );
            (mover, Some(router))
        } else {
            let mover = DataMover::spawn(
                Arc::clone(&weights),
                Arc::clone(&buffer),
                Arc::clone(&link),
                cfg.packet_bytes,
            );
            (mover, None)
        };

        let shape = AttnShape {
            n_heads: rc.n_heads,
            n_kv_heads: rc.n_kv_heads,
            head_dim: rc.head_dim,
        };
        let cache = PagedKvCache::new(
            KvLayout::new(cfg.block_size, cfg.kv_blocks),
            rc.n_layers,
            shape.kv_dim(),
        );

        let sched = Scheduler::new(
            SchedConfig::new(token_budget, rc.n_tok)
                .atomic()
                .with_admission(cfg.admission)
                .with_victim(cfg.victim)
                .with_service(cfg.service),
        );

        let embedding = Arc::new(weights.tensor_data("embedding")?.to_vec());
        let final_norm = weights.tensor_data("final_norm")?.to_vec();
        let lm_head = weights.tensor_data("lm_head")?.to_vec();

        Ok(ServingEngine {
            pjrt,
            sched,
            cache,
            weights,
            buffer,
            link,
            mover,
            pool: ThreadPool::new(cfg.attn_threads),
            shape,
            embedding,
            final_norm,
            lm_head,
            run_clock: Stopwatch::start(),
            next_pass: 0,
            pipeline_depth: cfg.pipeline_depth,
            stage_cursor: 0,
            prepared: None,
            planner: PlannerWorker::spawn(),
            router,
            stats: PipelineStats::default(),
            measured_service: cfg.measured_service,
            estimator: ServiceEstimator::default(),
        })
    }

    pub fn n_tok(&self) -> usize {
        self.pjrt.config.n_tok
    }

    pub fn link(&self) -> &PcieLink {
        &self.link
    }

    /// Speculation/commit/replan counters (zeros when `pipeline_depth` is
    /// 0 or the admission policy forces replans).
    pub fn pipeline_stats(&self) -> PipelineStats {
        self.stats
    }

    /// The current measured service model, once at least one timed pass
    /// has been observed (`None` before that, or with `measured_service`
    /// off).
    pub fn measured_service_model(&self) -> Option<ServiceModel> {
        self.measured_service.then(|| self.estimator.model()).flatten()
    }

    /// Check a request against the compiled shapes.
    fn validate(&self, r: &Request) -> Result<()> {
        anyhow::ensure!(
            r.prompt.len() + r.max_gen <= self.n_tok(),
            "request {}: prompt({}) + max_gen({}) must fit the compiled \
             bucket ({}) so preemption replay stays atomic",
            r.id,
            r.prompt.len(),
            r.max_gen,
            self.n_tok()
        );
        anyhow::ensure!(
            r.prompt.len() + r.max_gen <= self.pjrt.config.max_ctx,
            "request {} exceeds max_ctx",
            r.id
        );
        // The pipelined path gathers embeddings on the host by direct row
        // index, so out-of-range ids must be rejected up front (the
        // compiled gather silently clamped them, masking bad inputs).
        // Deliberately enforced in *both* modes — accepting garbage ids
        // only at pipeline_depth 0 would make the accepted-input surface
        // depend on a performance flag.
        let vocab = self.pjrt.config.vocab as i32;
        anyhow::ensure!(
            r.prompt.iter().all(|&t| (0..vocab).contains(&t)),
            "request {}: prompt tokens must lie in [0, vocab={vocab})",
            r.id
        );
        Ok(())
    }

    /// Validate and enqueue one request — online admission. The request
    /// joins the Prefill Scheduler's queue and is picked up by the next
    /// [`step`](Self::step). With pipelining on, a request arriving while
    /// a speculative next pass is already committed joins planning one
    /// pass later (see the module docs).
    pub fn submit(&mut self, r: Request) -> Result<()> {
        self.validate(&r)?;
        self.sched.submit(r);
        Ok(())
    }

    /// Start a new run: reset the pass counter and the run-relative clock,
    /// and hand back an empty trace sized to the KV geometry. A committed
    /// speculative pass (pipelined mode) carries over — its plan is
    /// already applied to the scheduler, so discarding it would orphan
    /// reserved KV blocks.
    pub fn begin_run(&mut self) -> Trace {
        self.next_pass = 0;
        self.run_clock = Stopwatch::start();
        Trace::new(self.cache.layout().layout().n_blocks)
    }

    /// Execute exactly one pass: plan → pack → run_pass → complete (or
    /// the pipelined equivalent — see the module docs). Generated tokens
    /// land in the scheduler (`self.sched.finished()` for completed
    /// sequences); the returned [`StepResult`] carries the pass telemetry
    /// and the yielded `(seq, token)` pairs.
    ///
    /// `PassRecord::t_end` and `pass_id` are relative to the last
    /// [`begin_run`](Self::begin_run) — `run`/`run_online` call it for
    /// you; a manual `submit` + `step` loop should call it once up front,
    /// otherwise timestamps count from engine load (or from the previous
    /// run's clock) and pass ids continue the previous run's numbering.
    pub fn step(&mut self) -> Result<StepResult> {
        if self.pipeline_depth == 0 {
            self.step_sync()
        } else {
            self.step_pipelined()
        }
    }

    /// A zero-duration bookkeeping record for a pass whose planning only
    /// shed requests (SLO admission): there is no pass body to execute,
    /// and the record is stamped at the *planning* instant `now` so it
    /// sits between its neighbors and never advances the next pass's
    /// trace timestamps (`Trace::series` stays monotone — the pre-pipeline
    /// code took a second, later clock reading here).
    fn shed_only_record(&mut self, now: f64) -> PassRecord {
        let record = PassRecord {
            pass_id: self.next_pass,
            t_end: now,
            kv_blocks_used: self.cache.layout().used_blocks(),
            active_decode: self.sched.active_decode(),
            ..Default::default()
        };
        self.next_pass += 1;
        record
    }

    /// Feed one completed pass into the measured service model and push
    /// the refreshed estimate into the scheduler (SLO admission and the
    /// weighted victim policy read it; the FIFO/newest defaults ignore
    /// it).
    fn observe_service(&mut self, record: &PassRecord) {
        if !self.measured_service {
            return;
        }
        self.estimator.observe(record.prefill_tokens, record.decode_tokens, record.duration);
        if let Some(model) = self.estimator.model() {
            self.sched.cfg.service = model;
        }
    }

    /// The legacy synchronous step (pipeline_depth = 0): the exact
    /// pre-pipeline pass structure, kept as its own code path so
    /// disabling the pipeline reproduces it byte for byte.
    fn step_sync(&mut self) -> Result<StepResult> {
        let now = self.run_clock.elapsed().as_secs_f64();
        let plan = self.sched.plan_at(self.cache.layout_mut(), now);
        let dropped = plan.dropped.clone();
        if plan.is_empty() {
            let record = self.shed_only_record(now);
            return Ok(StepResult {
                record,
                yielded: Vec::new(),
                finished: Vec::new(),
                dropped,
            });
        }
        let buckets = pack_plan(&plan, &self.sched, self.n_tok());
        let routing = self.router.as_ref().map(|r| plan.routed(r));
        let pass_clock = Stopwatch::start();
        let (tokens, times) = self.run_pass(&buckets, routing.as_ref())?;
        let duration = pass_clock.elapsed().as_secs_f64();
        let generated = tokens.len();
        let finished = self.sched.complete(&tokens, self.cache.layout_mut());

        let record = PassRecord {
            pass_id: self.next_pass,
            t_end: self.run_clock.elapsed().as_secs_f64(),
            duration,
            prefill_tokens: plan.prefill_tokens(),
            decode_tokens: plan.decode_tokens(),
            generated,
            finished: finished.len(),
            preempted: plan.preempted.len(),
            io_time: times.io_wait,
            gpu_time: times.gpu,
            cpu_time: times.cpu,
            overlap_time: times.overlap,
            host_time: 0.0,
            host_overlap_time: 0.0,
            kv_blocks_used: self.cache.layout().used_blocks(),
            active_decode: self.sched.active_decode(),
        };
        self.observe_service(&record);
        self.next_pass += 1;
        Ok(StepResult { record, yielded: tokens, finished, dropped })
    }

    /// One pipelined step — the per-phase state machine:
    ///
    /// 1. **Acquire** this pass: reuse the committed [`PipelinedStep`] or
    ///    replan/pack/embed synchronously (exposed host lane).
    /// 2. **Speculate**: launch the pass-N+1 planner worker — only under
    ///    time-independent planning (FIFO admission; and not weighted
    ///    victims combined with the measured-service EWMA, whose
    ///    per-pass updates would shift the snapshot's victim scores).
    /// 3. **Execute** the layer loop with cross-pass weight prefetch,
    ///    then the LM head (next-pass layer 0 streams under it).
    /// 4. **Complete** on the authoritative scheduler.
    /// 5. **Commit** the speculation if the finished-set prediction held
    ///    (patching placeholder tokens/embeddings), else count a replan.
    /// 6. **Record** the pass with the five-lane decomposition.
    fn step_pipelined(&mut self) -> Result<StepResult> {
        let step_clock = Stopwatch::start();
        let now = self.run_clock.elapsed().as_secs_f64();
        let mut times = PassTimes::default();

        // Phase 1 — acquire.
        let host_clock = Stopwatch::start();
        let (plan, buckets, mut xs, routing) = match self.prepared.take() {
            Some(p) => (p.plan, p.buckets, p.xs, p.routing),
            None => {
                let plan = self.sched.plan_at(self.cache.layout_mut(), now);
                let dropped = plan.dropped.clone();
                if plan.is_empty() {
                    let record = self.shed_only_record(now);
                    return Ok(StepResult {
                        record,
                        yielded: Vec::new(),
                        finished: Vec::new(),
                        dropped,
                    });
                }
                let buckets = pack_plan(&plan, &self.sched, self.n_tok());
                let xs = gather_embeddings(
                    &self.embedding[..],
                    self.pjrt.config.d_model,
                    &buckets,
                );
                let routing = self.router.as_ref().map(|r| plan.routed(r));
                (plan, buckets, xs, routing)
            }
        };
        times.host += host_clock.elapsed().as_secs_f64();
        let dropped = plan.dropped.clone();

        // Phase 2 — speculate. Snapshotting the planner-visible state
        // (scheduler + layout clones) and spawning the worker runs
        // *before* the layer loop starts, so it is exposed host work and
        // books into the host lane like the acquire phase. A pass the
        // generation budget predicts will drain the scheduler skips
        // speculation outright: the snapshot could only produce an empty
        // plan, paying a clone + spawn for a pass that never exists (and
        // inflating the `committed` counter). An EOS can only *add*
        // finishes, so a predicted drain is always a real drain.
        let yields = predicted_yields(&plan);
        let drains = self.sched.queued() == 0
            && yields.iter().all(|&id| {
                self.sched
                    .sequence(id)
                    .is_some_and(|s| s.generated.len() + 1 >= s.req.max_gen)
            });
        // Speculation requires time-*independent* planning, so a committed
        // plan is exactly what a synchronous replan would produce: FIFO
        // admission (SLO shedding depends on the clock), and a service
        // model that cannot change between snapshot and commit — the
        // measured-service EWMA updates every pass, which would shift
        // weighted-victim scores, so that combination always replans.
        // (Newest victim selection ignores the service model entirely.)
        let stable_policies = matches!(self.sched.cfg.admission, AdmissionPolicy::Fifo)
            && (matches!(self.sched.cfg.victim, VictimPolicy::Newest)
                || !self.measured_service);
        let speculate = !drains && stable_policies;
        let spec_pending = if speculate {
            let spec_clock = Stopwatch::start();
            self.stats.speculated += 1;
            let job = SpecJob {
                sched: self.sched.speculate(),
                layout: self.cache.layout().clone(),
                yields,
                now,
                n_tok: self.n_tok(),
                d_model: self.pjrt.config.d_model,
                embedding: Arc::clone(&self.embedding),
                router: self.router.clone(),
            };
            self.planner.submit(job);
            times.host += spec_clock.elapsed().as_secs_f64();
            true
        } else {
            false
        };

        // Phase 3 — execute.
        let tokens =
            self.run_pass_pipelined(&buckets, &mut xs, routing.as_ref(), &mut times)?;
        let generated = tokens.len();

        // Phase 4 — complete (capture KV/decode telemetry before the
        // commit reserves next-pass blocks).
        let finished = self.sched.complete(&tokens, self.cache.layout_mut());
        let kv_blocks_used = self.cache.layout().used_blocks();
        let active_decode = self.sched.active_decode();

        // Phase 5 — commit or replan.
        if spec_pending {
            let join_clock = Stopwatch::start();
            let spec = self.planner.recv();
            // The receive wait is the worker's exposed tail; the rest of
            // its busy time hid under the layer loop.
            let join_wait = join_clock.elapsed().as_secs_f64().min(spec.host_secs);
            times.host += join_wait;
            times.host_overlap += spec.host_secs - join_wait;
            let commit_clock = Stopwatch::start();
            if self.commit_speculation(spec, &tokens, &finished) {
                self.stats.committed += 1;
            } else {
                self.stats.replanned += 1;
            }
            times.host += commit_clock.elapsed().as_secs_f64();
        }

        // Phase 6 — record. The whole step body is the pass duration, so
        // the five exclusive lanes partition it (up to bookkeeping slack).
        let record = PassRecord {
            pass_id: self.next_pass,
            t_end: self.run_clock.elapsed().as_secs_f64(),
            duration: step_clock.elapsed().as_secs_f64(),
            prefill_tokens: plan.prefill_tokens(),
            decode_tokens: plan.decode_tokens(),
            generated,
            finished: finished.len(),
            preempted: plan.preempted.len(),
            io_time: times.io_wait,
            gpu_time: times.gpu,
            cpu_time: times.cpu,
            overlap_time: times.overlap,
            host_time: times.host,
            host_overlap_time: times.host_overlap,
            kv_blocks_used,
            active_decode,
        };
        self.observe_service(&record);
        self.next_pass += 1;
        Ok(StepResult { record, yielded: tokens, finished, dropped })
    }

    /// Validate the speculative prediction against what pass N actually
    /// did; on success patch the placeholder tokens (scheduler state,
    /// bucket rows, embedding rows) and install the successor state.
    /// Returns `false` when the speculation must be discarded (EOS finish
    /// diverged from the budget-only prediction).
    fn commit_speculation(
        &mut self,
        spec: SpecNext,
        tokens: &[(SeqId, i32)],
        finished: &[SeqId],
    ) -> bool {
        let mut actual: Vec<SeqId> = finished.to_vec();
        actual.sort_unstable();
        if actual != spec.predicted_finished {
            return false;
        }
        let SpecNext {
            placeholders,
            plan,
            mut sched,
            layout,
            mut buckets,
            mut xs,
            patches,
            routing,
            ..
        } = spec;
        if plan.is_empty() {
            // FIFO never sheds, so an empty speculative plan means the
            // clone drained — and the prediction matching means the real
            // scheduler just drained identically. Nothing to prepare.
            // Always-on: a desynced clone here would stall the run.
            assert!(self.sched.is_done(), "empty FIFO plan implies drained scheduler");
            return true;
        }
        let token_of: BTreeMap<SeqId, i32> = tokens.iter().copied().collect();
        let d = self.pjrt.config.d_model;
        for &(id, gen_idx, _) in &placeholders {
            let Some(&tok) = token_of.get(&id) else {
                panic!("placeholder sequence {id} did not yield a token")
            };
            sched.patch_generated(id, gen_idx, tok);
        }
        for &(bi, ri) in &patches {
            let id = buckets[bi].rows[ri].seq;
            let Some(&tok) = token_of.get(&id) else {
                panic!("patched row's sequence {id} did not yield a token")
            };
            buckets[bi].rows[ri].token = tok;
            buckets[bi].ids[ri] = tok;
            let row = tok as usize * d;
            xs[bi][ri * d..(ri + 1) * d]
                .copy_from_slice(&self.embedding[row..row + d]);
        }
        self.sched.commit(sched);
        self.cache.replace_layout(layout);
        self.prepared = Some(PipelinedStep { plan, buckets, xs, routing });
        true
    }

    /// Serve a batch of requests to completion. Returns the trace and the
    /// run report; generated tokens live in `self.sched.finished()`.
    ///
    /// This is the closed-batch special case of the incremental engine:
    /// every request is admitted up front, then [`step`](Self::step) loops
    /// until the scheduler drains.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<(Trace, RunReport)> {
        let n_req = requests.len();
        for r in &requests {
            self.validate(r)?;
        }
        self.sched.submit_all(requests);

        let mut trace = self.begin_run();
        while !self.sched.is_done() {
            let step = self.step()?;
            trace.push(step.record);
        }
        let report = RunReport::from_trace(&trace, n_req);
        Ok((trace, report))
    }

    /// Serve a timed arrival stream: `(arrival_secs, request)` pairs on
    /// the run clock (0 = run start). Requests are admitted when their
    /// arrival time passes; when the system drains before the next
    /// arrival, the engine sleeps until it. Returns the trace, the run
    /// report, and per-request latency stats; `slo_e2e` is the end-to-end
    /// deadline goodput is measured against (`f64::INFINITY` for plain
    /// completed-requests-per-second).
    pub fn run_online(
        &mut self,
        mut arrivals: Vec<(f64, Request)>,
        slo_e2e: f64,
    ) -> Result<(Trace, RunReport, LatencyStats)> {
        anyhow::ensure!(
            self.sched.is_done(),
            "run_online requires a drained scheduler: sequences submitted \
             outside the arrival stream would yield tokens the latency \
             tracker has no arrival record for"
        );
        anyhow::ensure!(
            arrivals.iter().all(|(t, _)| t.is_finite()),
            "non-finite arrival timestamp in arrival stream"
        );
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0));
        for (_, r) in &arrivals {
            self.validate(r)?;
        }
        if let Some(dup) = duplicate_id(&arrivals) {
            anyhow::bail!(
                "duplicate request id {dup} in arrival stream — per-request \
                 latency tracking requires unique ids"
            );
        }
        let n_req = arrivals.len();
        let mut pending: VecDeque<(f64, Request)> = arrivals.into();
        let mut tracker = RequestTracker::new();
        let mut trace = self.begin_run();

        loop {
            let now = self.run_clock.elapsed().as_secs_f64();
            while pending.front().is_some_and(|(t, _)| *t <= now) {
                let Some((t, r)) = pending.pop_front() else { break };
                tracker.arrived(r.id, t);
                self.sched.submit_at(r, t);
            }
            if self.sched.is_done() {
                match pending.front() {
                    Some(&(t, _)) => {
                        // Idle: nothing to serve until the next arrival.
                        let wait = t - self.run_clock.elapsed().as_secs_f64();
                        if wait > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(wait));
                        }
                        continue;
                    }
                    None => break,
                }
            }
            let step = self.step()?;
            let t_end = step.record.t_end;
            for &(id, _) in &step.yielded {
                tracker.token(id, t_end);
            }
            for &id in &step.finished {
                tracker.finished(id, t_end);
            }
            for &(id, reason) in &step.dropped {
                tracker.dropped(id, t_end, reason);
            }
            trace.push(step.record);
        }

        let report = RunReport::from_trace(&trace, n_req);
        let stats = tracker.stats(trace.wall_secs(), slo_e2e);
        Ok((trace, report, stats))
    }

    /// One VSLPipe pass over the packed buckets — the synchronous path:
    /// per-pass mover stream (stages ≡ layers), embed via the PJRT
    /// gather, then the shared layer loop and head.
    fn run_pass(
        &mut self,
        buckets: &[Bucket],
        routing: Option<&PassRouting>,
    ) -> Result<(Vec<(SeqId, i32)>, PassTimes)> {
        let n_layers = self.pjrt.config.n_layers;
        let mut times = PassTimes::default();

        // Prologue: prime the double buffer (§6.4 prologue). In expert
        // mode the pass's exact activated sets are posted first, so every
        // stage of a synchronous pass streams exactly the cold experts it
        // activates (stages ≡ layers after the reset).
        self.mover.reset();
        if let Some(r) = routing {
            for (layer, set) in r.per_layer.iter().enumerate() {
                self.mover.post_routing(layer, set);
            }
        }
        self.mover.request(0);
        if n_layers > 1 {
            self.mover.request(1);
        }

        // Embed every bucket (GPU lane).
        let mut clock = Stopwatch::start();
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
        for b in buckets {
            let outs = self
                .pjrt
                .embed
                .run(&[Arg::I32(&b.ids), Arg::F32(&self.embedding[..])])
                .context("embed")?;
            xs.push(to_f32(&outs[0])?);
        }
        times.gpu += clock.lap().as_secs_f64();

        self.exec_layers(buckets, &mut xs, routing, &mut times, 0, false)?;
        let tokens = self.run_head(buckets, &xs, &mut times)?;
        Ok((tokens, times))
    }

    /// The pipelined pass body: embeddings arrive pre-gathered, the mover
    /// stream continues across pass boundaries (priming only the very
    /// first pass), and the always-on `+2` prefetch issued at the last
    /// layers streams the next pass's layer 0/1 under the LM head.
    fn run_pass_pipelined(
        &mut self,
        buckets: &[Bucket],
        xs: &mut [Vec<f32>],
        routing: Option<&PassRouting>,
        times: &mut PassTimes,
    ) -> Result<Vec<(SeqId, i32)>> {
        let base = self.stage_cursor;
        // Expert mode: post the pass's exact activated sets for every
        // stage whose transfer has *not* been requested yet. The first
        // two stages of a non-first pass were prefetched across the pass
        // boundary before this plan existed — those streamed the
        // popularity prediction and get topped up at the stage boundary
        // instead (`wait_layer_routed`).
        if let Some(r) = routing {
            let first_unrequested = if base == 0 { 0 } else { 2 };
            for (layer, set) in r.per_layer.iter().enumerate().skip(first_unrequested) {
                self.mover.post_routing(base + layer, set);
            }
        }
        if base == 0 {
            self.mover.request(0);
            self.mover.request(1);
        }
        self.exec_layers(buckets, xs, routing, times, base, true)?;
        self.stage_cursor = base + self.pjrt.config.n_layers;
        self.run_head(buckets, xs, times)
    }

    /// The per-layer loop shared by both pass flavors. `stage_base` is
    /// the mover stage of layer 0 this pass; with `stream_ahead` the
    /// `+2` prefetch is unconditional (it runs into the next pass),
    /// otherwise it stops at this pass's last layer (legacy protocol).
    fn exec_layers(
        &mut self,
        buckets: &[Bucket],
        xs: &mut [Vec<f32>],
        routing: Option<&PassRouting>,
        times: &mut PassTimes,
        stage_base: usize,
        stream_ahead: bool,
    ) -> Result<()> {
        let rc = &self.pjrt.config;
        let (n_tok, q_dim, kv_dim) = (rc.n_tok, rc.q_dim(), rc.kv_dim());
        let n_layers = rc.n_layers;
        let mut clock = Stopwatch::start();

        for layer in 0..n_layers {
            let stage = stage_base + layer;
            // Stage-boundary sync: weights for this layer must be staged.
            // Expert mode also settles the stage's transfer set here: any
            // activated cold expert the stream missed is charged to the
            // link while the stage blocks (exposed IO, io_wait lane).
            clock.lap();
            match routing.and_then(|r| r.activated(layer)) {
                Some(activated) => {
                    self.mover.wait_layer_routed(stage, activated);
                }
                None => self.mover.wait_layer(stage),
            }
            times.io_wait += clock.lap().as_secs_f64();

            // Stage the layer's weight literals ONCE (not per bucket) and
            // outside the buffer lock — §Perf iteration 6: the big task_b
            // expert tensors dominated H2D staging when copied per bucket.
            let ta = &self.pjrt.task_a;
            let tb = &self.pjrt.task_b;
            let (a_w, b_w) = self.buffer.read(stage, |w| -> Result<_> {
                let t = |name: &str| self.weights.tensor_in_layer(layer, name, w);
                let a_w = [
                    ta.literal(2, &Arg::F32(t("ln1")?))?,
                    ta.literal(3, &Arg::F32(t("wq")?))?,
                    ta.literal(4, &Arg::F32(t("wk")?))?,
                    ta.literal(5, &Arg::F32(t("wv")?))?,
                ];
                let b_w = [
                    tb.literal(2, &Arg::F32(t("wo")?))?,
                    tb.literal(3, &Arg::F32(t("ln2")?))?,
                    tb.literal(4, &Arg::F32(t("router")?))?,
                    tb.literal(5, &Arg::F32(t("w1")?))?,
                    tb.literal(6, &Arg::F32(t("w3")?))?,
                    tb.literal(7, &Arg::F32(t("w2")?))?,
                ];
                Ok((a_w, b_w))
            })?;

            // --- GPU Task A per bucket, then KV-cache stores (CPU task's
            // store half).
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut ks: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut vs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            for (bi, b) in buckets.iter().enumerate() {
                let x_lit = ta.literal(0, &Arg::F32(&xs[bi]))?;
                let pos_lit = ta.literal(1, &Arg::I32(&b.positions))?;
                let args =
                    [&x_lit, &pos_lit, &a_w[0], &a_w[1], &a_w[2], &a_w[3]];
                let outs = ta.run_prepared(&args).context("task_a")?;
                qs.push(to_f32(&outs[0])?);
                ks.push(to_f32(&outs[1])?);
                vs.push(to_f32(&outs[2])?);
            }
            times.gpu += clock.lap().as_secs_f64();

            // Host-side KV stores + decode-query assembly (CPU lane).
            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    self.cache.write(
                        row.seq,
                        layer,
                        row.pos,
                        &ks[bi][ri * kv_dim..(ri + 1) * kv_dim],
                        &vs[bi][ri * kv_dim..(ri + 1) * kv_dim],
                    );
                }
            }
            let mut decode_refs: Vec<(usize, usize)> = Vec::new(); // (bucket, row)
            let mut queries: Vec<DecodeQuery> = Vec::new();
            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    if row.kind == RowKind::Decode {
                        decode_refs.push((bi, ri));
                        queries.push(DecodeQuery {
                            seq: row.seq,
                            q: &qs[bi][ri * q_dim..(ri + 1) * q_dim],
                        });
                    }
                }
            }
            times.cpu += clock.lap().as_secs_f64();

            // --- Phase overlap: CPU decode attention (pool) runs while the
            // GPU computes packed flash attention for the prefill rows.
            // The phase is booked as three exclusive spans so the trace
            // lanes decompose the pass: GPU-only, both-busy (overlap), and
            // the CPU tail the engine spends waiting on the attention
            // thread. (The seed booked the whole phase to the GPU lane,
            // double-counting the CPU lane in the Fig.-13 series.)
            let mut cpu_out = vec![0f32; queries.len() * q_dim];
            let cpu_nanos = AtomicU64::new(0);
            let mut prefill_attn: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut gpu_lane = 0f64;

            let phase_clock = Stopwatch::start();
            std::thread::scope(|s| -> Result<()> {
                let cache = &self.cache;
                let pool = &self.pool;
                let shape = self.shape;
                let cpu_nanos = &cpu_nanos;
                let queries_ref = &queries;
                let cpu_out_ref = &mut cpu_out;
                let handle = s.spawn(move || {
                    let t0 = std::time::Instant::now();
                    pool.decode_attention(cache, layer, shape, queries_ref, cpu_out_ref);
                    // Ordering: the only reader loads after this scoped
                    // thread is joined, which already orders the store.
                    cpu_nanos.store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                });
                // GPU lane: packed flash attention per bucket. Pure-decode
                // buckets skip the kernel outright — every one of their
                // rows takes the CPU lane's result in the merge below, so
                // the packed output would be computed and then fully
                // overwritten (padding rows get zeros; task_b and the head
                // are row-independent, so real rows are unaffected).
                let gpu_clock = Stopwatch::start();
                for (bi, b) in buckets.iter().enumerate() {
                    if b.n_prefill() == 0 {
                        prefill_attn.push(vec![0f32; n_tok * q_dim]);
                        continue;
                    }
                    let outs = self
                        .pjrt
                        .prefill_attn
                        .run(&[
                            Arg::F32(&qs[bi]),
                            Arg::F32(&ks[bi]),
                            Arg::F32(&vs[bi]),
                            Arg::I32(&b.seg_ids),
                        ])
                        .context("prefill_attn")?;
                    prefill_attn.push(to_f32(&outs[0])?);
                }
                gpu_lane = gpu_clock.elapsed().as_secs_f64();
                if handle.join().is_err() {
                    anyhow::bail!("CPU attention thread panicked");
                }
                Ok(())
            })?;
            let phase_wall = phase_clock.elapsed().as_secs_f64();
            clock.lap(); // resync: the phase is accounted below
            // Ordering: the scope above joined the writer thread, which
            // sequences this load after the store.
            let cpu_busy = cpu_nanos.load(Ordering::Relaxed) as f64 / 1e9;
            let both_busy = gpu_lane.min(cpu_busy);
            times.overlap += both_busy;
            times.gpu += gpu_lane - both_busy;
            times.cpu += (phase_wall - gpu_lane).max(0.0);

            // Merge: decode rows take the CPU result.
            for (qi, &(bi, ri)) in decode_refs.iter().enumerate() {
                prefill_attn[bi][ri * q_dim..(ri + 1) * q_dim]
                    .copy_from_slice(&cpu_out[qi * q_dim..(qi + 1) * q_dim]);
            }
            times.cpu += clock.lap().as_secs_f64();

            // --- GPU Task B per bucket (weights pre-staged once above).
            for (bi, _b) in buckets.iter().enumerate() {
                let attn_lit = tb.literal(0, &Arg::F32(&prefill_attn[bi]))?;
                let resid_lit = tb.literal(1, &Arg::F32(&xs[bi]))?;
                let args = [
                    &attn_lit, &resid_lit, &b_w[0], &b_w[1], &b_w[2], &b_w[3],
                    &b_w[4], &b_w[5],
                ];
                let outs = tb.run_prepared(&args).context("task_b")?;
                xs[bi] = to_f32(&outs[0])?;
            }
            times.gpu += clock.lap().as_secs_f64();

            // Stage epilogue: release the slot, prefetch stage + 2 (§6.4).
            // `stream_ahead` keeps prefetching into the next pass — that
            // is what stages next-pass layer 0/1 while the LM head runs.
            self.mover.done_with(stage);
            if stream_ahead {
                self.mover.request(stage + 2);
            } else if layer + 2 < n_layers {
                self.mover.request(layer + 2);
            }
        }
        Ok(())
    }

    /// Head: greedy next-token ids; collect yielding rows. Buckets with
    /// no yielding row (pure partial-prefill buckets) skip the LM-head
    /// execution entirely — their logits would be discarded.
    fn run_head(
        &mut self,
        buckets: &[Bucket],
        xs: &[Vec<f32>],
        times: &mut PassTimes,
    ) -> Result<Vec<(SeqId, i32)>> {
        let rc = &self.pjrt.config;
        // Always-on (once per pass): a mis-sized table misattributes every
        // token the head yields.
        assert_eq!(self.embedding.len(), rc.vocab * rc.d_model);
        let mut tokens: Vec<(SeqId, i32)> = Vec::new();
        let clock = Stopwatch::start();
        for (bi, b) in buckets.iter().enumerate() {
            if !b.rows.iter().any(|r| r.yields) {
                continue;
            }
            let outs = self
                .pjrt
                .head
                .run(&[
                    Arg::F32(&xs[bi]),
                    Arg::F32(&self.final_norm),
                    Arg::F32(&self.lm_head),
                ])
                .context("head")?;
            let ids = to_i32(&outs[0])?;
            // Always-on (once per bucket): short output would pair rows
            // with the wrong sequences below.
            assert_eq!(ids.len(), rc.n_tok);
            for (ri, row) in b.rows.iter().enumerate() {
                if row.yields {
                    tokens.push((row.seq, ids[ri]));
                }
            }
        }
        times.gpu += clock.elapsed().as_secs_f64();
        Ok(tokens)
    }
}
