//! The serving engine: scheduler + VSLPipe pipeline over the PJRT
//! executables, the paged KV cache, the CPU attention pool, and the
//! weight-streaming path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::batch::{pack_plan, Bucket, RowKind};
use crate::cpuattn::{AttnShape, DecodeQuery, ThreadPool};
use crate::kvcache::{KvLayout, PagedKvCache, SeqId};
use crate::metrics::{PassRecord, RunReport, Stopwatch, Trace};
use crate::model::Request;
use crate::runtime::{to_f32, to_i32, Arg, Manifest, PjrtEngine};
use crate::sched::{SchedConfig, Scheduler};
use crate::transfer::{DataMover, LinkTiming, PcieLink, WeightBuffer, WeightFile};

/// Engine deployment configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: String,
    /// Model config name ("tiny" / "small").
    pub model: String,
    /// Paged-KV geometry (CPU-memory budget in blocks).
    pub block_size: usize,
    pub kv_blocks: usize,
    /// Link clocking (unthrottled for correctness runs, throttled for
    /// timing experiments).
    pub timing: LinkTiming,
    /// Data-mover packet size (§6.5; scaled down from 100 MB for the
    /// small artifacts).
    pub packet_bytes: usize,
    /// CPU attention worker threads.
    pub attn_threads: usize,
    /// Scheduler token budget per pass (buckets of `n_tok` are opened as
    /// needed up to this).
    pub token_budget: usize,
}

impl EngineConfig {
    /// Correctness-oriented defaults for a config name.
    pub fn for_model(model: &str) -> Self {
        EngineConfig {
            artifacts_dir: "artifacts".into(),
            model: model.into(),
            block_size: 16,
            kv_blocks: 256,
            timing: LinkTiming::Unthrottled,
            // §Perf iteration 2: 1 MB packets cost ~2x mover bandwidth vs
            // large packets (5.9 vs 11.5 GB/s memcpy roof); 8 MB keeps
            // §6.5's no-head-of-line-blocking property at small-model
            // scale (paper-scale default stays 100 MB).
            packet_bytes: 8 << 20,
            attn_threads: 2,
            token_budget: 0, // 0 => 2 buckets (set at load)
        }
    }
}

/// Per-pass lane timings (wall clock).
#[derive(Debug, Clone, Copy, Default)]
struct PassTimes {
    io_wait: f64,
    gpu: f64,
    cpu_attn: f64,
}

/// The end-to-end serving engine.
pub struct ServingEngine {
    pub pjrt: PjrtEngine,
    pub sched: Scheduler,
    cache: PagedKvCache,
    weights: Arc<WeightFile>,
    buffer: Arc<WeightBuffer>,
    link: Arc<PcieLink>,
    mover: DataMover,
    pool: ThreadPool,
    shape: AttnShape,
    /// Host-resident non-layer weights (embedding table, final norm, LM
    /// head — the paper keeps only layer weights on the streaming path).
    embedding: Vec<f32>,
    final_norm: Vec<f32>,
    lm_head: Vec<f32>,
}

impl ServingEngine {
    pub fn load(cfg: EngineConfig) -> Result<ServingEngine> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let pjrt = PjrtEngine::load(&manifest, &cfg.model)?;
        let rc = pjrt.config.clone();

        let cm = manifest.config(&cfg.model)?;
        let weights = Arc::new(WeightFile::load(&cfg.artifacts_dir, &cm.weights)?);
        anyhow::ensure!(
            weights.n_layers() == rc.n_layers,
            "weight file has {} layers, config {}",
            weights.n_layers(),
            rc.n_layers
        );
        let layer_elems = weights.layer_data(0).len();
        let buffer = Arc::new(WeightBuffer::new(layer_elems));
        let link = Arc::new(PcieLink::new(cfg.timing));
        let mover = DataMover::spawn(
            Arc::clone(&weights),
            Arc::clone(&buffer),
            Arc::clone(&link),
            cfg.packet_bytes,
        );

        let shape = AttnShape {
            n_heads: rc.n_heads,
            n_kv_heads: rc.n_kv_heads,
            head_dim: rc.head_dim,
        };
        let cache = PagedKvCache::new(
            KvLayout::new(cfg.block_size, cfg.kv_blocks),
            rc.n_layers,
            shape.kv_dim(),
        );

        let token_budget = if cfg.token_budget == 0 { 2 * rc.n_tok } else { cfg.token_budget };
        let sched =
            Scheduler::new(SchedConfig::new(token_budget, rc.n_tok).atomic());

        let embedding = weights.tensor_data("embedding")?.to_vec();
        let final_norm = weights.tensor_data("final_norm")?.to_vec();
        let lm_head = weights.tensor_data("lm_head")?.to_vec();

        Ok(ServingEngine {
            pjrt,
            sched,
            cache,
            weights,
            buffer,
            link,
            mover,
            pool: ThreadPool::new(cfg.attn_threads),
            shape,
            embedding,
            final_norm,
            lm_head,
        })
    }

    pub fn n_tok(&self) -> usize {
        self.pjrt.config.n_tok
    }

    pub fn link(&self) -> &PcieLink {
        &self.link
    }

    /// Serve a batch of requests to completion. Returns the trace and the
    /// run report; generated tokens live in `self.sched.finished()`.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<(Trace, RunReport)> {
        let n_req = requests.len();
        for r in &requests {
            anyhow::ensure!(
                r.prompt.len() + r.max_gen <= self.n_tok(),
                "request {}: prompt({}) + max_gen({}) must fit the compiled \
                 bucket ({}) so preemption replay stays atomic",
                r.id,
                r.prompt.len(),
                r.max_gen,
                self.n_tok()
            );
            anyhow::ensure!(
                r.prompt.len() + r.max_gen <= self.pjrt.config.max_ctx,
                "request {} exceeds max_ctx",
                r.id
            );
        }
        self.sched.submit_all(requests);

        let mut trace = Trace::new(self.cache.layout().layout().n_blocks);
        let run_clock = Stopwatch::start();
        let mut pass_id = 0usize;
        while !self.sched.is_done() {
            let plan = self.sched.plan(self.cache.layout_mut());
            let buckets = pack_plan(&plan, &self.sched, self.n_tok());
            let pass_clock = Stopwatch::start();
            let (tokens, times) = self.run_pass(&buckets)?;
            let duration = pass_clock.elapsed().as_secs_f64();
            let generated = tokens.len();
            let finished = self.sched.complete(&tokens, self.cache.layout_mut());

            trace.push(PassRecord {
                pass_id,
                t_end: run_clock.elapsed().as_secs_f64(),
                duration,
                prefill_tokens: plan.prefill_tokens(),
                decode_tokens: plan.decode_tokens(),
                generated,
                finished,
                preempted: plan.preempted.len(),
                io_time: times.io_wait,
                gpu_time: times.gpu,
                cpu_time: times.cpu_attn,
                kv_blocks_used: self.cache.layout().used_blocks(),
                active_decode: self.sched.active_decode(),
            });
            pass_id += 1;
        }
        let report = RunReport::from_trace(&trace, n_req);
        Ok((trace, report))
    }

    /// One VSLPipe pass over the packed buckets.
    fn run_pass(&mut self, buckets: &[Bucket]) -> Result<(Vec<(SeqId, i32)>, PassTimes)> {
        let rc = &self.pjrt.config;
        let (n_tok, q_dim, kv_dim) = (rc.n_tok, rc.q_dim(), rc.kv_dim());
        let n_layers = rc.n_layers;
        let mut times = PassTimes::default();

        // Prologue: prime the double buffer (§6.4 prologue).
        self.mover.reset();
        self.mover.request(0);
        if n_layers > 1 {
            self.mover.request(1);
        }

        // Embed every bucket.
        let mut clock = Stopwatch::start();
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
        for b in buckets {
            let outs = self
                .pjrt
                .embed
                .run(&[Arg::I32(&b.ids), Arg::F32(&self.embedding)])
                .context("embed")?;
            xs.push(to_f32(&outs[0])?);
        }
        times.gpu += clock.lap().as_secs_f64();

        for layer in 0..n_layers {
            // Stage-boundary sync: weights for this layer must be staged.
            clock.lap();
            self.mover.wait_layer(layer);
            times.io_wait += clock.lap().as_secs_f64();

            // Stage the layer's weight literals ONCE (not per bucket) and
            // outside the buffer lock — §Perf iteration 6: the big task_b
            // expert tensors dominated H2D staging when copied per bucket.
            let ta = &self.pjrt.task_a;
            let tb = &self.pjrt.task_b;
            let (a_w, b_w) = self.buffer.read(layer, |w| -> Result<_> {
                let t = |name: &str| self.weights.tensor_in_layer(layer, name, w);
                let a_w = [
                    ta.literal(2, &Arg::F32(t("ln1")?))?,
                    ta.literal(3, &Arg::F32(t("wq")?))?,
                    ta.literal(4, &Arg::F32(t("wk")?))?,
                    ta.literal(5, &Arg::F32(t("wv")?))?,
                ];
                let b_w = [
                    tb.literal(2, &Arg::F32(t("wo")?))?,
                    tb.literal(3, &Arg::F32(t("ln2")?))?,
                    tb.literal(4, &Arg::F32(t("router")?))?,
                    tb.literal(5, &Arg::F32(t("w1")?))?,
                    tb.literal(6, &Arg::F32(t("w3")?))?,
                    tb.literal(7, &Arg::F32(t("w2")?))?,
                ];
                Ok((a_w, b_w))
            })?;

            // --- GPU Task A per bucket, then KV-cache stores (CPU task's
            // store half).
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut ks: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            let mut vs: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());
            for (bi, b) in buckets.iter().enumerate() {
                let x_lit = ta.literal(0, &Arg::F32(&xs[bi]))?;
                let pos_lit = ta.literal(1, &Arg::I32(&b.positions))?;
                let args =
                    [&x_lit, &pos_lit, &a_w[0], &a_w[1], &a_w[2], &a_w[3]];
                let outs = ta.run_prepared(&args).context("task_a")?;
                qs.push(to_f32(&outs[0])?);
                ks.push(to_f32(&outs[1])?);
                vs.push(to_f32(&outs[2])?);
            }
            times.gpu += clock.lap().as_secs_f64();

            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    self.cache.write(
                        row.seq,
                        layer,
                        row.pos,
                        &ks[bi][ri * kv_dim..(ri + 1) * kv_dim],
                        &vs[bi][ri * kv_dim..(ri + 1) * kv_dim],
                    );
                }
            }

            // --- Phase overlap: CPU decode attention (pool) runs while the
            // GPU computes packed flash attention for the prefill rows.
            let mut decode_refs: Vec<(usize, usize)> = Vec::new(); // (bucket, row)
            let mut queries: Vec<DecodeQuery> = Vec::new();
            for (bi, b) in buckets.iter().enumerate() {
                for (ri, row) in b.rows.iter().enumerate() {
                    if row.kind == RowKind::Decode {
                        decode_refs.push((bi, ri));
                        queries.push(DecodeQuery {
                            seq: row.seq,
                            q: &qs[bi][ri * q_dim..(ri + 1) * q_dim],
                        });
                    }
                }
            }
            let mut cpu_out = vec![0f32; queries.len() * q_dim];
            let cpu_nanos = AtomicU64::new(0);
            let mut prefill_attn: Vec<Vec<f32>> = Vec::with_capacity(buckets.len());

            std::thread::scope(|s| -> Result<()> {
                let cache = &self.cache;
                let pool = &self.pool;
                let shape = self.shape;
                let cpu_nanos = &cpu_nanos;
                let queries_ref = &queries;
                let cpu_out_ref = &mut cpu_out;
                let handle = s.spawn(move || {
                    let t0 = std::time::Instant::now();
                    pool.decode_attention(cache, layer, shape, queries_ref, cpu_out_ref);
                    cpu_nanos.store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                });
                // GPU lane: packed flash attention per bucket.
                for (bi, b) in buckets.iter().enumerate() {
                    let outs = self
                        .pjrt
                        .prefill_attn
                        .run(&[
                            Arg::F32(&qs[bi]),
                            Arg::F32(&ks[bi]),
                            Arg::F32(&vs[bi]),
                            Arg::I32(&b.seg_ids),
                        ])
                        .context("prefill_attn")?;
                    prefill_attn.push(to_f32(&outs[0])?);
                }
                handle.join().expect("attention thread");
                Ok(())
            })?;
            times.gpu += clock.lap().as_secs_f64();
            times.cpu_attn += cpu_nanos.load(Ordering::Relaxed) as f64 / 1e9;

            // Merge: decode rows take the CPU result.
            for (qi, &(bi, ri)) in decode_refs.iter().enumerate() {
                prefill_attn[bi][ri * q_dim..(ri + 1) * q_dim]
                    .copy_from_slice(&cpu_out[qi * q_dim..(qi + 1) * q_dim]);
            }

            // --- GPU Task B per bucket (weights pre-staged once above).
            for (bi, _b) in buckets.iter().enumerate() {
                let attn_lit = tb.literal(0, &Arg::F32(&prefill_attn[bi]))?;
                let resid_lit = tb.literal(1, &Arg::F32(&xs[bi]))?;
                let args = [
                    &attn_lit, &resid_lit, &b_w[0], &b_w[1], &b_w[2], &b_w[3],
                    &b_w[4], &b_w[5],
                ];
                let outs = tb.run_prepared(&args).context("task_b")?;
                xs[bi] = to_f32(&outs[0])?;
            }
            times.gpu += clock.lap().as_secs_f64();

            // Stage epilogue: release the slot, prefetch layer + 2 (§6.4).
            self.mover.done_with(layer);
            if layer + 2 < n_layers {
                self.mover.request(layer + 2);
            }
        }

        // Head: greedy next-token ids; collect yielding rows.
        debug_assert_eq!(self.embedding.len(), rc.vocab * rc.d_model);
        let mut tokens: Vec<(SeqId, i32)> = Vec::new();
        for (bi, b) in buckets.iter().enumerate() {
            let outs = self
                .pjrt
                .head
                .run(&[
                    Arg::F32(&xs[bi]),
                    Arg::F32(&self.final_norm),
                    Arg::F32(&self.lm_head),
                ])
                .context("head")?;
            let ids = to_i32(&outs[0])?;
            debug_assert_eq!(ids.len(), n_tok);
            for (ri, row) in b.rows.iter().enumerate() {
                if row.yields {
                    tokens.push((row.seq, ids[ri]));
                }
            }
        }
        times.gpu += clock.lap().as_secs_f64();

        Ok((tokens, times))
    }
}
