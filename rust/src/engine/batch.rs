//! Token-bucket assembly: pack a pass plan's rows into fixed-shape
//! buckets (the compiled `n_tok` PJRT shape), the engine-side realization
//! of VSLPipe's job partitioning (§6.4).
//!
//! Each bucket is one "partition" of the pipeline: prefill chunks stay
//! whole within a bucket (segment attention must not cross buckets),
//! decode rows are singletons and balance the remainder — mirroring the
//! paper's "balancing the number of decode and prefill tokens" rule.

use crate::kvcache::SeqId;
use crate::sched::{PassPlan, Scheduler};

/// Why a row is in the bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowKind {
    Prefill,
    Decode,
}

/// One scheduled token row.
#[derive(Debug, Clone, Copy)]
pub struct Row {
    pub seq: SeqId,
    pub kind: RowKind,
    /// Token id fed at this row.
    pub token: i32,
    /// Logical position (RoPE) == KV position.
    pub pos: usize,
    /// Whether this row's head output becomes a generated token (every
    /// decode row; the last row of a completing prefill chunk).
    pub yields: bool,
}

/// A fixed-shape packed bucket.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub rows: Vec<Row>,
    /// Capacity (compiled n_tok).
    pub n_tok: usize,
    /// Padded model inputs.
    pub ids: Vec<i32>,
    pub positions: Vec<i32>,
    pub seg_ids: Vec<i32>,
}

impl Bucket {
    fn new(n_tok: usize) -> Self {
        Bucket { rows: Vec::new(), n_tok, ids: Vec::new(), positions: Vec::new(), seg_ids: Vec::new() }
    }

    pub fn free(&self) -> usize {
        self.n_tok - self.rows.len()
    }

    pub fn n_prefill(&self) -> usize {
        self.rows.iter().filter(|r| r.kind == RowKind::Prefill).count()
    }

    pub fn n_decode(&self) -> usize {
        self.rows.len() - self.n_prefill()
    }

    /// Finalize padded arrays. Segment ids: one id per (sequence, chunk)
    /// run of prefill rows; decode and padding rows get -1 / -2 so the
    /// prefill flash kernel masks them out (each decode row's real
    /// attention runs on the CPU over the paged cache).
    fn seal(&mut self) {
        let n = self.n_tok;
        self.ids = vec![0; n];
        self.positions = vec![0; n];
        self.seg_ids = vec![-2; n];
        let mut seg = 0i32;
        let mut prev: Option<(SeqId, usize)> = None;
        for (i, r) in self.rows.iter().enumerate() {
            self.ids[i] = r.token;
            self.positions[i] = r.pos as i32;
            match r.kind {
                RowKind::Decode => {
                    self.seg_ids[i] = -1;
                    prev = None;
                }
                RowKind::Prefill => {
                    // contiguous rows of the same sequence share a segment
                    let cont = prev == Some((r.seq, r.pos.wrapping_sub(1)));
                    if !cont {
                        seg += 1;
                    }
                    self.seg_ids[i] = seg;
                    prev = Some((r.seq, r.pos));
                }
            }
        }
    }
}

/// Pack a pass plan into buckets of `n_tok` rows.
///
/// Prefill chunks are placed first-fit (opening buckets as needed);
/// decode rows then fill the least-loaded buckets, balancing lanes.
pub fn pack_plan(plan: &PassPlan, sched: &Scheduler, n_tok: usize) -> Vec<Bucket> {
    let mut buckets: Vec<Bucket> = Vec::new();

    // Prefill chunks, largest first (first-fit decreasing).
    let mut chunks: Vec<_> = plan.prefill.iter().collect();
    chunks.sort_by_key(|c| std::cmp::Reverse(c.len));
    for c in chunks {
        assert!(c.len <= n_tok, "chunk {} exceeds bucket {}", c.len, n_tok);
        let seq = sched
            .sequence(c.id)
            .unwrap_or_else(|| panic!("planned sequence {} not live", c.id));
        let bi = match buckets.iter().position(|b| b.free() >= c.len) {
            Some(bi) => bi,
            None => {
                buckets.push(Bucket::new(n_tok));
                buckets.len() - 1
            }
        };
        for j in 0..c.len {
            let pos = c.start + j;
            buckets[bi].rows.push(Row {
                seq: c.id,
                kind: RowKind::Prefill,
                token: seq.token_at(pos),
                pos,
                yields: c.completes && j + 1 == c.len,
            });
        }
    }

    // Decode rows: pre-open enough buckets for the whole plan so the
    // least-loaded placement actually balances lanes across partitions
    // (the paper's "balancing the number of decode and prefill tokens").
    let total = plan.total_tokens();
    while buckets.len() * n_tok < total {
        buckets.push(Bucket::new(n_tok));
    }
    for &(id, pos) in &plan.decode {
        let seq = sched
            .sequence(id)
            .unwrap_or_else(|| panic!("decoding sequence {id} not live"));
        // The fed token: the most recently generated one (pos>prompt) or
        // the last prompt token (first decode step never happens here —
        // completing prefill chunks yield it — so generated is non-empty).
        let Some(&token) = seq.generated.last() else {
            panic!("decoding sequence {id} has no generated token to feed")
        };
        if buckets.iter().all(|b| b.free() == 0) {
            buckets.push(Bucket::new(n_tok));
        }
        let Some(bi) = buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| b.free() > 0)
            .min_by_key(|(_, b)| b.rows.len())
            .map(|(i, _)| i)
        else {
            panic!("no bucket with a free row after pre-open")
        };
        buckets[bi].rows.push(Row { seq: id, kind: RowKind::Decode, token, pos, yields: true });
    }

    for b in &mut buckets {
        b.seal();
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvLayout, PagedLayout};
    use crate::model::Request;
    use crate::sched::SchedConfig;

    fn mk(budget: usize, chunk: usize) -> (Scheduler, PagedLayout) {
        (
            Scheduler::new(SchedConfig::new(budget, chunk)),
            PagedLayout::new(KvLayout::new(4, 256)),
        )
    }

    #[test]
    fn prefill_chunks_stay_whole_and_segmented() {
        let (mut s, mut kv) = mk(32, 8);
        s.submit(Request::new(0, vec![10, 11, 12], 4));
        s.submit(Request::new(1, vec![20, 21, 22, 23, 24], 4));
        let plan = s.plan(&mut kv);
        let buckets = pack_plan(&plan, &s, 8);
        assert_eq!(buckets.len(), 1);
        let b = &buckets[0];
        assert_eq!(b.rows.len(), 8);
        // FFD: seq 1 (len 5) first, then seq 0 (len 3)
        assert_eq!(b.ids[..5], [20, 21, 22, 23, 24]);
        assert_eq!(b.ids[5..8], [10, 11, 12]);
        assert_eq!(b.positions[..5], [0, 1, 2, 3, 4]);
        // two distinct segments, no -1s
        assert_eq!(b.seg_ids[0], b.seg_ids[4]);
        assert_eq!(b.seg_ids[5], b.seg_ids[7]);
        assert_ne!(b.seg_ids[0], b.seg_ids[5]);
        // both chunks complete -> last row of each yields
        let yields: Vec<_> = b.rows.iter().map(|r| r.yields).collect();
        assert_eq!(yields, [false, false, false, false, true, false, false, true]);
    }

    #[test]
    fn decode_rows_fill_and_balance() {
        let (mut s, mut kv) = mk(64, 16);
        for i in 0..6 {
            s.submit(Request::new(i, vec![1, 2], 4));
        }
        // pass 1: all prefill
        let p1 = s.plan(&mut kv);
        let toks: Vec<_> = p1.prefill.iter().map(|c| (c.id, 7)).collect();
        s.complete(&toks, &mut kv);
        // pass 2: 6 decode rows into buckets of 4
        let p2 = s.plan(&mut kv);
        assert_eq!(p2.decode_tokens(), 6);
        let buckets = pack_plan(&p2, &s, 4);
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0].n_decode() + buckets[1].n_decode(), 6);
        assert!((buckets[0].n_decode() as i64 - buckets[1].n_decode() as i64).abs() <= 1);
        for b in &buckets {
            for (i, r) in b.rows.iter().enumerate() {
                assert_eq!(b.seg_ids[i], -1);
                assert_eq!(r.token, 7, "fed token is the last generated one");
                assert_eq!(r.pos, 2, "decode position continues the prompt");
            }
        }
    }

    #[test]
    fn padding_rows_are_masked() {
        let (mut s, mut kv) = mk(8, 8);
        s.submit(Request::new(0, vec![5; 3], 2));
        let plan = s.plan(&mut kv);
        let buckets = pack_plan(&plan, &s, 8);
        let b = &buckets[0];
        assert_eq!(&b.seg_ids[3..], &[-2, -2, -2, -2, -2]);
        assert_eq!(&b.ids[3..], &[0, 0, 0, 0, 0]);
    }

    #[test]
    fn mixed_pass_keeps_chunks_contiguous() {
        let (mut s, mut kv) = mk(16, 4);
        s.submit(Request::new(0, vec![1; 2], 8));
        let p1 = s.plan(&mut kv);
        s.complete(&[(0, 3)], &mut kv);
        s.submit(Request::new(1, vec![2; 6], 8));
        let p2 = s.plan(&mut kv);
        assert_eq!(p2.decode_tokens(), 1);
        // The head sequence chunks at max_chunk granularity (4 + 2) until
        // its prompt is exhausted — budget permits the whole prompt.
        assert_eq!(p2.prefill_tokens(), 6);
        assert_eq!(p2.prefill.len(), 2);
        let buckets = pack_plan(&p2, &s, 8);
        let b = &buckets[0];
        // Back-to-back chunks of one sequence are position-contiguous, so
        // they share a segment id; the decode row is masked with -1.
        let segs: Vec<_> = b.seg_ids[..7].to_vec();
        assert_eq!(segs[..6], [1, 1, 1, 1, 1, 1]);
        assert_eq!(segs[6], -1);
    }
}
