//! The paper's holistic two-stage performance model (§5).
//!
//! * [`stage1`] — theoretical upper bound from fundamental components:
//!   GEMM arithmetic-to-IO intensity (Eq. 1–2), Parallelism-Memory
//!   Efficiency (Eq. 3), the throughput roofline (Eq. 4), CPU bandwidth /
//!   compute requirements (Eq. 5–6), and the prefill/decode-overlap KV
//!   amplification (Eq. 7).
//! * [`stage2`] — the realistic model: paged KV cache and bounded request
//!   batch (Eq. 8–14), which converges to Stage 1 as K→∞ and b→1 and
//!   predicts end-to-end execution time (94% average accuracy in §8.1).
//! * [`hrm`] — MoE-Lightning's Hierarchical Roofline Model, reimplemented
//!   for the Table-1/§3.1 contrast: it sees only arithmetic intensity and
//!   IO bandwidth, missing CPU memory capacity and workload shape.

pub mod hrm;
pub mod stage1;
pub mod stage2;

pub use stage1::Stage1Model;
pub use stage2::{Stage2Model, Stage2Prediction};
