//! Stage 1: theoretical performance upper bound (§5.1–§5.4).
//!
//! Inputs are fundamental system components only: GPU GEMM throughput,
//! CPU-GPU IO bandwidth, CPU memory capacity for the KV cache, and the
//! workload's (prompt length p, generation length g). This is the model
//! that identifies CPU memory capacity — not IO bandwidth — as the primary
//! limiter (the paper's central modeling insight).

use crate::config::{MachineSpec, ModelSpec};
use crate::util::cast::{u64_f64, usize_f64};
use crate::workload::routing::{rank_activation_probs, zipf_weights};

/// Which resource binds the Stage-1 roofline (Eq. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// CPU memory capacity limits parallel tokens; throughput scales with
    /// KV cache size.
    MemoryCapacity,
    /// The GPU is saturated; more KV capacity gives diminishing returns.
    GpuCompute,
}

/// Stage-1 analytic model over a (machine, model) pair.
#[derive(Debug, Clone)]
pub struct Stage1Model {
    pub machine: MachineSpec,
    pub model: ModelSpec,
}

impl Stage1Model {
    pub fn new(machine: MachineSpec, model: ModelSpec) -> Self {
        Stage1Model { machine, model }
    }

    // -- Eq. 1: GEMM arithmetic-to-IO intensity ---------------------------

    /// Per-token factor of Eq. 1:
    /// `(6 m N_k + 2 + 2/s) / (6 m N_e + 2 + 2/s)`.
    /// Multiplying by the number of parallel tokens `n` gives the GEMM
    /// compute-per-weight-byte intensity `I`.
    pub fn intensity_per_token(&self) -> f64 {
        let m = self.model.m_ratio();
        let s = usize_f64(self.model.gqa_group());
        let nk = usize_f64(self.model.top_k);
        let ne = usize_f64(self.model.n_experts);
        (6.0 * m * nk + 2.0 + 2.0 / s) / (6.0 * m * ne + 2.0 + 2.0 / s)
    }

    /// Eq. 1 evaluated at `n` parallel tokens.
    pub fn intensity(&self, n: usize) -> f64 {
        usize_f64(n) * self.intensity_per_token()
    }

    /// The paper's sparsity approximation of Eq. 1: `I ≈ n N_k / N_e`.
    pub fn intensity_approx(&self, n: usize) -> f64 {
        usize_f64(n) * usize_f64(self.model.top_k) / usize_f64(self.model.n_experts)
    }

    // -- Eq. 2: tokens needed to saturate GPU compute ---------------------

    /// `n >= (C_GPU / B_IO) * N_e / N_k` (Table 2 uses this approximate
    /// form; A40 + B=32 GB/s + Mixtral-8x7B gives ~19.2k tokens).
    pub fn tokens_to_saturate(&self) -> f64 {
        (self.machine.gpu.bf16_flops / self.machine.pcie_bw)
            * usize_f64(self.model.n_experts)
            / usize_f64(self.model.top_k)
    }

    /// Exact form using Eq. 1's full intensity expression. Note the
    /// intensity here is FLOPs per weight *element*; with `weight_bytes`
    /// bytes per element the IO requirement scales accordingly.
    pub fn tokens_to_saturate_exact(&self) -> f64 {
        let per_byte =
            self.intensity_per_token() / usize_f64(self.model.weight_bytes);
        (self.machine.gpu.bf16_flops / self.machine.pcie_bw) / per_byte
    }

    /// KV-cache bytes needed to sustain `tokens_to_saturate()` parallel
    /// sequences of total length `seq_len` (Table 2, right half).
    pub fn kv_bytes_to_saturate(&self, seq_len: usize) -> f64 {
        self.tokens_to_saturate() * usize_f64(seq_len)
            * u64_f64(self.model.kv_bytes_per_token())
    }

    // -- Eq. 3: Parallelism-Memory Efficiency ------------------------------

    /// `PME = 2 (p + g) / ((2 p + g) g)` — parallel tokens contributed per
    /// token-slot of KV capacity, amortized over the sequence's lifetime.
    pub fn pme(&self, p: usize, g: usize) -> f64 {
        assert!(g > 0, "generation length must be positive");
        let (p, g) = (usize_f64(p), usize_f64(g));
        2.0 * (p + g) / ((2.0 * p + g) * g)
    }

    // -- Eq. 4: throughput roofline ----------------------------------------

    /// Model weight transfer time `δ = model_size / B_IO` (seconds).
    pub fn delta(&self) -> f64 {
        self.machine.transfer_secs(self.model.model_bytes())
    }

    /// GPU-bound token processing rate `T_GPU` (tokens/s): GEMM throughput
    /// divided by activated FLOPs per token.
    pub fn t_gpu(&self) -> f64 {
        self.machine.gpu.bf16_flops / self.model.flops_per_token()
    }

    /// KV capacity in token slots for a byte budget.
    pub fn kv_tokens(&self, kv_bytes: u64) -> f64 {
        u64_f64(kv_bytes) / u64_f64(self.model.kv_bytes_per_token())
    }

    /// Eq. 4: `T_max = min(PME * M / δ, T_GPU)` in processed tokens/s
    /// (prefill + decode), with `M` in token slots.
    pub fn t_max(&self, p: usize, g: usize, kv_bytes: u64) -> f64 {
        let io_bound = self.pme(p, g) * self.kv_tokens(kv_bytes) / self.delta();
        io_bound.min(self.t_gpu())
    }

    /// Which side of Eq. 4's `min` binds.
    pub fn bound(&self, p: usize, g: usize, kv_bytes: u64) -> Bound {
        let io_bound = self.pme(p, g) * self.kv_tokens(kv_bytes) / self.delta();
        if io_bound < self.t_gpu() {
            Bound::MemoryCapacity
        } else {
            Bound::GpuCompute
        }
    }

    /// Maximum GPU utilization `T_max / T_GPU` (Fig. 3).
    pub fn max_gpu_utilization(&self, p: usize, g: usize, kv_bytes: u64) -> f64 {
        self.t_max(p, g, kv_bytes) / self.t_gpu()
    }

    /// Generation throughput (tokens/s of *generated* output): the `g /
    /// (p+g)` share of processed tokens.
    pub fn generation_throughput(&self, p: usize, g: usize, kv_bytes: u64) -> f64 {
        self.t_max(p, g, kv_bytes) * usize_f64(g) / usize_f64(p + g)
    }

    // -- Eq. 5–6: CPU-side requirements ------------------------------------

    /// Eq. 5: CPU memory bandwidth needed so KV reads + weight streaming
    /// never stall: `B_mem = (M / M_weight) * B_IO`, with `M` the total
    /// bytes touched per iteration (weights + KV cache).
    pub fn cpu_mem_bw_required(&self, kv_bytes: u64) -> f64 {
        let m_weight = u64_f64(self.model.model_bytes());
        let m_total = m_weight + u64_f64(kv_bytes);
        (m_total / m_weight) * self.machine.pcie_bw
    }

    /// KV-read share of Eq. 5 (`B_KV`).
    pub fn b_kv(&self, kv_bytes: u64) -> f64 {
        self.cpu_mem_bw_required(kv_bytes) - self.machine.pcie_bw
    }

    /// Eq. 6: CPU attention FLOP rate needed to keep pace:
    /// `T_CPU = 2 * s * I_cpu_attn * B_KV`. `I_cpu_attn` is the arithmetic
    /// intensity of flash-decode attention per KV byte: each BF16 element
    /// (2 bytes) takes one multiply-accumulate for the dot product or the
    /// saxpby accumulate, i.e. 2 FLOPs / 2 bytes = 1 FLOP/byte.
    pub fn cpu_flops_required(&self, kv_bytes: u64) -> f64 {
        const I_CPU_ATTN: f64 = 1.0; // FLOP per KV byte
        2.0 * usize_f64(self.model.gqa_group()) * I_CPU_ATTN * self.b_kv(kv_bytes)
    }

    // -- Eq. 7: prefill/decode overlap -------------------------------------

    /// Eq. 7: effective KV capacity under overlapped scheduling:
    /// `C_eff = (p + g) / (p + g/2) * C_KV`.
    pub fn effective_kv(&self, p: usize, g: usize, kv_bytes: u64) -> f64 {
        let (p, g) = (usize_f64(p), usize_f64(g));
        (p + g) / (p + g / 2.0) * u64_f64(kv_bytes)
    }

    // -- Expert-granular residency (expert-aware extension) ----------------

    /// Expected number of experts streamed over the link per layer per
    /// pass when the `pinned` hottest experts stay HBM-resident under
    /// Zipf(`zipf_s`) routing with `n_tokens` parallel tokens: the tail
    /// `Σ_{r ≥ pinned} a_r` of the rank activation probabilities.
    pub fn experts_streamed(&self, zipf_s: f64, pinned: usize, n_tokens: usize) -> f64 {
        let weights = zipf_weights(self.model.n_experts, zipf_s);
        rank_activation_probs(&weights, self.model.top_k, n_tokens)
            .iter()
            .skip(pinned)
            .sum()
    }

    /// Expert-cache hit rate: the share of per-pass expert weight traffic
    /// served from HBM instead of the link. `0` when nothing is pinned;
    /// approaches the pinned experts' activation mass as skew grows.
    pub fn expert_hit_rate(&self, zipf_s: f64, pinned: usize, n_tokens: usize) -> f64 {
        let weights = zipf_weights(self.model.n_experts, zipf_s);
        let probs = rank_activation_probs(&weights, self.model.top_k, n_tokens);
        let total: f64 = probs.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        let resident: f64 = probs.iter().take(pinned).sum();
        resident / total
    }

    /// δ under expert-granular residency: dense layer bytes always
    /// stream, but only the expected cold activated experts cross the
    /// link. `pinned = 0` disables the residency map (the mover streams
    /// whole dense layers) and returns [`delta`](Self::delta) bit-for-bit.
    pub fn delta_routed(&self, zipf_s: f64, pinned: usize, n_tokens: usize) -> f64 {
        if pinned == 0 {
            return self.delta();
        }
        let streamed = self.experts_streamed(zipf_s, pinned, n_tokens);
        let skipped = usize_f64(self.model.n_experts) - streamed;
        let saved = usize_f64(self.model.n_layers)
            * skipped
            * u64_f64(self.model.expert_bytes());
        (u64_f64(self.model.model_bytes()) - saved) / self.machine.pcie_bw
    }

    /// Eq. 4 with the routed δ: the IO-bound arm shrinks by the expert
    /// cache's hit rate while the GPU arm is untouched.
    pub fn t_max_routed(
        &self,
        p: usize,
        g: usize,
        kv_bytes: u64,
        zipf_s: f64,
        pinned: usize,
        n_tokens: usize,
    ) -> f64 {
        let delta = self.delta_routed(zipf_s, pinned, n_tokens);
        let io_bound = self.pme(p, g) * self.kv_tokens(kv_bytes) / delta;
        io_bound.min(self.t_gpu())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    fn m() -> Stage1Model {
        Stage1Model::new(
            MachineSpec::nominal(GpuSpec::a40()),
            ModelSpec::mixtral_8x7b(),
        )
    }

    #[test]
    fn intensity_approx_close_to_exact() {
        let s1 = m();
        let exact = s1.intensity(1000);
        let approx = s1.intensity_approx(1000);
        // Eq. 1: the approximation is within ~5% for Mixtral-8x7B
        assert!((exact - approx).abs() / approx < 0.05, "{exact} vs {approx}");
    }

    #[test]
    fn table2_a40_tokens_to_saturate() {
        // Paper Table 2: ~19.2k tokens for A40 at B = 32 GB/s.
        let n = m().tokens_to_saturate();
        assert!((n - 19_200.0).abs() / 19_200.0 < 0.05, "n = {n}");
    }

    #[test]
    fn table2_a100_tokens_to_saturate() {
        let s1 = Stage1Model::new(
            MachineSpec::nominal(GpuSpec::a100()),
            ModelSpec::mixtral_8x7b(),
        );
        let n = s1.tokens_to_saturate();
        assert!((n - 40_000.0).abs() / 40_000.0 < 0.05, "n = {n}");
    }

    #[test]
    fn table2_kv_sizes() {
        // A40, 512-token sequences: ~1.2 TB of KV cache (paper: 1228 GB).
        let kv = m().kv_bytes_to_saturate(512) / 1e9;
        assert!((kv - 1228.0).abs() / 1228.0 < 0.08, "kv = {kv} GB");
        // and ~half of it for 256-token sequences
        let kv256 = m().kv_bytes_to_saturate(256) / 1e9;
        assert!((kv256 * 2.0 - kv).abs() < 1.0);
    }

    #[test]
    fn pme_formula() {
        let s1 = m();
        // closed form vs the defining sum: (p+g) / sum_{j=0..g-1} (p+j+1)
        // The paper's denominator sums the per-step KV footprint.
        // (Eq. 3 is the continuous approximation of the sum; it deviates
        // for degenerate p,g ~ 1, so only realistic lengths are checked.)
        for &(p, g) in &[(98usize, 32usize), (926, 128), (32, 16), (100, 256)] {
            let sum: f64 = (0..g).map(|j| (p + j + 1) as f64).sum();
            let direct = (p + g) as f64 / sum;
            let closed = s1.pme(p, g);
            // Eq. 3 uses the continuous approximation (2p+g)g/2 for the sum
            assert!(
                (closed - direct).abs() / direct < 0.02,
                "p={p} g={g}: {closed} vs {direct}"
            );
        }
    }

    #[test]
    fn pme_monotonicity() {
        let s1 = m();
        // longer generation -> lower PME (decode tokens are memory-hungry)
        assert!(s1.pme(100, 32) > s1.pme(100, 64));
        assert!(s1.pme(100, 64) > s1.pme(100, 256));
        // higher prompt:generation ratio at fixed total -> higher PME
        assert!(s1.pme(200, 56) > s1.pme(128, 128));
    }

    #[test]
    fn roofline_regimes() {
        let s1 = m();
        // small KV -> memory-capacity bound; huge KV -> GPU bound (Fig. 3b)
        assert_eq!(s1.bound(100, 128, 10 << 30), Bound::MemoryCapacity);
        assert_eq!(s1.bound(100, 128, 4 << 40), Bound::GpuCompute);
        // utilization is monotone in KV bytes and capped at 1
        let u1 = s1.max_gpu_utilization(100, 128, 50 << 30);
        let u2 = s1.max_gpu_utilization(100, 128, 200 << 30);
        assert!(u1 < u2);
        assert!(s1.max_gpu_utilization(100, 128, 4 << 40) <= 1.0 + 1e-9);
    }

    #[test]
    fn delta_is_5s_on_paper_testbed() {
        let s1 = Stage1Model::new(
            MachineSpec::paper_testbed(),
            ModelSpec::mixtral_8x7b(),
        );
        assert!((s1.delta() - 4.8).abs() < 0.5, "delta = {}", s1.delta());
    }

    #[test]
    fn cpu_bw_requirement_example() {
        // §5.3's example: KV twice the model size -> B_mem ≈ 3 * B_IO.
        let s1 = m();
        let kv = 2 * s1.model.model_bytes();
        let bw = s1.cpu_mem_bw_required(kv);
        assert!((bw / s1.machine.pcie_bw - 3.0).abs() < 1e-9);
    }

    #[test]
    fn cpu_flops_requirement_is_hundreds_of_gflops() {
        // §5.3: "the CPU attention computation [must] deliver throughput on
        // the order of hundreds of GFLOPs".
        let s1 = Stage1Model::new(
            MachineSpec::paper_testbed(),
            ModelSpec::mixtral_8x7b(),
        );
        let kv = 2 * s1.model.model_bytes();
        let f = s1.cpu_flops_required(kv);
        assert!(f > 100e9 && f < 1e12, "{f}");
    }

    #[test]
    fn overlap_amplification() {
        let s1 = m();
        // Eq. 7 at p=100, g=128: (228)/(164) ≈ 1.39x
        let eff = s1.effective_kv(100, 128, 100 << 30) / (100u64 << 30) as f64;
        assert!((eff - 228.0 / 164.0).abs() < 1e-9);
        // bounded: 1x (g→0) to 2x (p→0)
        assert!((s1.effective_kv(1000, 1, 1 << 30) / (1u64 << 30) as f64) < 1.01);
        assert!(s1.effective_kv(0, 1000, 1 << 30) / (1u64 << 30) as f64 <= 2.0);
    }

    #[test]
    fn generation_share() {
        let s1 = m();
        let t = s1.t_max(100, 100, 100 << 30);
        assert!((s1.generation_throughput(100, 100, 100 << 30) - t / 2.0).abs() < 1e-9);
    }

    #[test]
    fn routed_delta_disabled_is_bit_identical() {
        // The pinned = 0 gate must reproduce the dense sweep exactly —
        // the analytic twin of the engine/simulator identity contract.
        let s1 = m();
        assert_eq!(s1.delta_routed(1.2, 0, 4096).to_bits(), s1.delta().to_bits());
        assert_eq!(
            s1.t_max_routed(98, 32, 70 << 30, 1.2, 0, 4096).to_bits(),
            s1.t_max(98, 32, 70 << 30).to_bits()
        );
    }

    #[test]
    fn expert_hit_rate_grows_with_skew_and_pinning() {
        let s1 = m();
        // More pinned experts -> higher hit rate; more skew -> higher hit
        // rate at a fixed pinned count (the hot experts carry more mass).
        let h1 = s1.expert_hit_rate(1.2, 1, 4096);
        let h2 = s1.expert_hit_rate(1.2, 2, 4096);
        assert!(h2 > h1 && h1 > 0.0, "h1={h1} h2={h2}");
        assert!(s1.expert_hit_rate(2.0, 1, 64) > s1.expert_hit_rate(0.5, 1, 64));
        // Pinning everything serves all expert traffic from HBM.
        let all = s1.expert_hit_rate(1.2, s1.model.n_experts, 4096);
        assert!((all - 1.0).abs() < 1e-12);
    }

    #[test]
    fn routed_delta_shrinks_with_pinning() {
        let s1 = m();
        let dense = s1.delta();
        let d1 = s1.delta_routed(1.2, 1, 4096);
        let d2 = s1.delta_routed(1.2, 2, 4096);
        assert!(d1 < dense, "{d1} vs dense {dense}");
        assert!(d2 < d1);
        // Routed IO can only help the IO-bound arm of Eq. 4.
        assert!(
            s1.t_max_routed(98, 32, 70 << 30, 1.2, 1, 4096)
                >= s1.t_max(98, 32, 70 << 30)
        );
    }
}
