//! MoE-Lightning's Hierarchical Roofline Model (HRM), reimplemented for
//! the §3.1 contrast and the Table-1 / Fig-11 baselines.
//!
//! HRM models each phase as a two-level roofline: GPU compute vs CPU-GPU
//! IO, and (for CPU-offloaded attention) CPU compute vs CPU memory
//! bandwidth. It sees *arithmetic intensity and bandwidths only* — the
//! two factors MoE-Lens shows are missing are (a) CPU **memory capacity**
//! and (b) the workload's (p, g) shape, so HRM-planned batches stop
//! growing once the IO pipeline is covered and leave CPU memory idle
//! (Table 1: 52% / 56% / 35% utilization).

use super::stage1::Stage1Model;
use crate::config::{MachineSpec, ModelSpec};
use crate::util::cast::{f64_usize, u64_f64, u64_usize, usize_f64, usize_u64};

/// HRM-style roofline over one (machine, model) pair.
#[derive(Debug, Clone)]
pub struct HrmModel {
    pub machine: MachineSpec,
    pub model: ModelSpec,
    /// CPU attention throughput achieved by the baseline's auto-vectorized
    /// kernel, as a fraction of the machine's memory-bandwidth roofline
    /// (Fig. 10 measures ≈1/3.1 at full threads).
    pub cpu_attn_efficiency: f64,
}

/// An HRM-planned execution configuration (the baseline's "policy").
#[derive(Debug, Clone)]
pub struct HrmPlan {
    /// Decode-stage concurrent sequences the plan admits (always ≥ 1; see
    /// [`HrmPlan::fits_in`] for whether the plan is actually runnable).
    pub decode_seqs: usize,
    /// Tokens per prefill micro-batch.
    pub prefill_tokens: usize,
    /// Predicted decode-iteration time (s).
    pub decode_iter_secs: f64,
    /// CPU memory the plan actually commits (weights + peak KV), bytes.
    pub cpu_mem_used: u64,
}

impl HrmPlan {
    /// Whether the plan's committed memory fits a machine. `plan` clamps
    /// the batch to capacity but never below one sequence, so on machines
    /// whose weights alone exceed host memory this reports `false`.
    pub fn fits_in(&self, cpu_mem_bytes: u64) -> bool {
        self.cpu_mem_used <= cpu_mem_bytes
    }
}

impl HrmModel {
    pub fn new(machine: MachineSpec, model: ModelSpec) -> Self {
        HrmModel { machine, model, cpu_attn_efficiency: 1.0 / 3.1 }
    }

    /// Weight-sweep time δ (same as Stage 1; HRM does model this).
    pub fn delta(&self) -> f64 {
        self.machine.transfer_secs(self.model.model_bytes())
    }

    /// Decode-iteration time for `n` concurrent sequences at average
    /// context length `ctx`: max of the three overlapped lanes
    /// (weight IO, GPU GEMM, CPU attention at the baseline's efficiency).
    pub fn decode_iter_secs(&self, n: usize, ctx: usize) -> f64 {
        let io = self.delta();
        let gpu = usize_f64(n) * self.model.flops_per_token() / self.machine.gpu.bf16_flops;
        let kv_bytes =
            usize_f64(n) * usize_f64(ctx) * u64_f64(self.model.kv_bytes_per_token());
        let cpu = kv_bytes / (self.machine.host.mem_bw * self.cpu_attn_efficiency);
        io.max(gpu).max(cpu)
    }

    /// Decode throughput (tokens/s) for `n` sequences at context `ctx`.
    pub fn decode_throughput(&self, n: usize, ctx: usize) -> f64 {
        usize_f64(n) / self.decode_iter_secs(n, ctx)
    }

    /// δ with the expert-aware engine's residency win priced in: only the
    /// expected cold activated experts cross the link (delegates to
    /// [`Stage1Model::delta_routed`]). `pinned = 0` is the dense sweep
    /// bit-for-bit.
    pub fn delta_routed(&self, zipf_s: f64, pinned: usize, n_tokens: usize) -> f64 {
        Stage1Model::new(self.machine.clone(), self.model.clone())
            .delta_routed(zipf_s, pinned, n_tokens)
    }

    /// [`decode_iter_secs`](Self::decode_iter_secs) with the routed δ on
    /// the IO lane — the HRM prediction of the expert-cache win. GPU and
    /// CPU lanes are untouched, so the benefit saturates once weight IO
    /// stops binding the iteration.
    pub fn decode_iter_secs_routed(
        &self,
        n: usize,
        ctx: usize,
        zipf_s: f64,
        pinned: usize,
    ) -> f64 {
        let io = self.delta_routed(zipf_s, pinned, n);
        let gpu = usize_f64(n) * self.model.flops_per_token() / self.machine.gpu.bf16_flops;
        let kv_bytes =
            usize_f64(n) * usize_f64(ctx) * u64_f64(self.model.kv_bytes_per_token());
        let cpu = kv_bytes / (self.machine.host.mem_bw * self.cpu_attn_efficiency);
        io.max(gpu).max(cpu)
    }

    /// Decode-iteration time with host-side planning/packing overhead
    /// composed in — the cost-model mirror of the engine's
    /// double-buffered pass pipeline. A synchronous schedule serializes
    /// the host work with the lanes (`host + max(lanes)`); the pipelined
    /// schedule plans the next iteration under the current one, so the
    /// host lane joins the overlapped max (`max(lanes, host)`). Pipelined
    /// is never slower, and whenever the host cost fits under the
    /// slowest hardware lane the iteration time is exactly the
    /// hardware-limited [`decode_iter_secs`](Self::decode_iter_secs) —
    /// the "shrunken inter-pass gap" of the Fig.-13 traces.
    pub fn decode_iter_secs_with_host(
        &self,
        n: usize,
        ctx: usize,
        host_secs: f64,
        pipelined: bool,
    ) -> f64 {
        let exec = self.decode_iter_secs(n, ctx);
        if pipelined {
            exec.max(host_secs)
        } else {
            exec + host_secs
        }
    }

    /// The HRM *plan*: grow the decode batch until predicted throughput
    /// stops improving (within `plateau_tol`), i.e. until the slowest
    /// overlapped lane is no longer weight IO. This is the §3.1 blind
    /// spot made executable: the objective contains no CPU-memory-capacity
    /// term, so the search halts at the roofline knee regardless of how
    /// much host memory remains.
    ///
    /// `ctx` is the average context length the planner assumes; MoE-
    /// Lightning provisions KV at the *maximum* length `p + g` (no
    /// overlap-driven early release), which `cpu_mem_used` reflects.
    pub fn plan(&self, p: usize, g: usize, cpu_mem_bytes: u64) -> HrmPlan {
        let ctx_avg = p + g / 2;
        let ctx_peak = p + g;
        let plateau_tol = 0.01;

        // Knee of the decode roofline: the largest n where IO still binds,
        // then one growth step past it (the planner's 1%-gain cutoff).
        let mut n = 64usize;
        let mut best = self.decode_throughput(n, ctx_avg);
        loop {
            let next = f64_usize((usize_f64(n) * 1.25).ceil());
            let t = self.decode_throughput(next, ctx_avg);
            if t < best * (1.0 + plateau_tol) {
                break;
            }
            n = next;
            best = t;
        }
        // Capacity clamp — HRM ignores it in the objective, but a plan
        // that literally overflows host memory cannot run at all. Clamp to
        // the largest batch that fits, never below one sequence: a machine
        // whose weights alone exceed `cpu_mem_bytes` still gets a defined
        // 1-sequence plan (so `decode_throughput` stays finite and nonzero
        // downstream), with the infeasibility visible via
        // [`HrmPlan::fits_in`].
        let kv_per_seq = usize_u64(ctx_peak) * self.model.kv_bytes_per_token();
        let weights = self.model.model_bytes();
        if weights + usize_u64(n) * kv_per_seq > cpu_mem_bytes {
            n = u64_usize((cpu_mem_bytes.saturating_sub(weights) / kv_per_seq).max(1));
        }

        // Prefill micro-batch: compute-bound, sized to cover the per-layer
        // weight transfer (HRM's pipelining condition).
        let layer_io = self.machine.transfer_secs(self.model.layer_bytes());
        let flops_per_tok_layer =
            self.model.flops_per_token() / usize_f64(self.model.n_layers);
        let prefill_tokens =
            f64_usize(layer_io * self.machine.gpu.bf16_flops / flops_per_tok_layer);

        HrmPlan {
            decode_seqs: n,
            prefill_tokens,
            decode_iter_secs: self.decode_iter_secs(n, ctx_avg),
            cpu_mem_used: weights + usize_u64(n) * kv_per_seq,
        }
    }

    /// Table 1's metric: fraction of the machine's CPU memory the plan
    /// commits.
    pub fn cpu_mem_utilization(&self, plan: &HrmPlan, cpu_mem_bytes: u64) -> f64 {
        u64_f64(plan.cpu_mem_used) / u64_f64(cpu_mem_bytes)
    }

    /// MoE-Lightning's *published* execution plans for the Table-1
    /// configurations (Mixtral-8x7B on the paper's 265 GB testbed). The
    /// per-row request batch sizes are back-derived from the artifact's
    /// plans via the paper's measured KV-region utilization — the same
    /// plans `baselines::moe_lightning` replays for Fig. 11/12. Returns
    /// `None` for configurations the artifact does not ship a plan for.
    pub fn artifact_plan(&self, p: usize, g: usize) -> Option<HrmPlan> {
        // (p, g) -> gbs: concurrent sequences the artifact plan admits.
        let gbs = match (p, g) {
            (98, 32) => 4840,
            (98, 64) => 4190,
            (926, 128) => 400,
            _ => return None,
        };
        let ctx_peak = usize_u64(p + g);
        Some(HrmPlan {
            decode_seqs: gbs,
            prefill_tokens: self.plan(p, g, u64::MAX).prefill_tokens,
            decode_iter_secs: self.decode_iter_secs(gbs, p + g / 2),
            cpu_mem_used: self.model.model_bytes()
                + usize_u64(gbs) * ctx_peak * self.model.kv_bytes_per_token(),
        })
    }

    /// Table 1's utilization metric over the *KV region*: the paper charges
    /// plans against the memory available for KV (total minus weights minus
    /// the ~30 GB execution overhead its §7 CPU-memory profile reserves).
    ///
    /// Returns `None` when the machine has no KV region at all — capacity
    /// at or below weights + overhead. (The unchecked subtraction used to
    /// panic in debug builds and wrap to a huge u64 in release for such
    /// machines, silently corrupting the Table-1 metric.)
    pub fn kv_region_utilization(&self, plan: &HrmPlan, cpu_mem_bytes: u64) -> Option<f64> {
        let overhead = 30u64 << 30;
        let kv_capacity = cpu_mem_bytes
            .checked_sub(self.model.model_bytes())?
            .checked_sub(overhead)?;
        if kv_capacity == 0 {
            return None;
        }
        let kv_used = plan.cpu_mem_used.saturating_sub(self.model.model_bytes());
        Some(u64_f64(kv_used) / u64_f64(kv_capacity))
    }

    /// End-to-end generation throughput of the *two-phase* (no-overlap)
    /// schedule the baseline runs: prefill the whole admitted batch, then
    /// decode it to completion, repeating until `k` requests finish.
    pub fn two_phase_generation_throughput(&self, p: usize, g: usize, cpu_mem_bytes: u64) -> f64 {
        let plan = self.plan(p, g, cpu_mem_bytes);
        let n = plan.decode_seqs.max(1);
        // Prefill: n·p tokens at the GPU-or-IO-bound rate.
        let gpu_rate = self.machine.gpu.bf16_flops / self.model.flops_per_token();
        let io_rate_tokens = usize_f64(plan.prefill_tokens)
            / self.machine.transfer_secs(self.model.model_bytes());
        let prefill_secs = usize_f64(n) * usize_f64(p) / gpu_rate.min(io_rate_tokens).max(1.0);
        // Decode: g iterations, each a full weight sweep (or worse).
        let mut decode_secs = 0.0;
        for step in 0..g {
            decode_secs += self.decode_iter_secs(n, p + step);
        }
        usize_f64(n) * usize_f64(g) / (prefill_secs + decode_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hrm() -> HrmModel {
        HrmModel::new(MachineSpec::paper_testbed(), ModelSpec::mixtral_8x7b())
    }

    #[test]
    fn table1_artifact_plans_underutilize_cpu_memory() {
        // The §3.1 phenomenon: on Table 1's 265 GB machine the baseline's
        // plans leave ~half of the KV region idle, the long-prompt RAG row
        // being the worst (paper: 52.0% / 56.2% / 35.0%).
        let h = hrm();
        let cap = 265u64 << 30;
        let u32 = h.kv_region_utilization(&h.artifact_plan(98, 32).unwrap(), cap).unwrap();
        let u64_ = h.kv_region_utilization(&h.artifact_plan(98, 64).unwrap(), cap).unwrap();
        let u128 = h.kv_region_utilization(&h.artifact_plan(926, 128).unwrap(), cap).unwrap();
        assert!((u32 - 0.52).abs() < 0.03, "row1: {u32}");
        assert!((u64_ - 0.562).abs() < 0.03, "row2: {u64_}");
        assert!((u128 - 0.35).abs() < 0.03, "row3: {u128}");
        assert!(u128 < u32 && u128 < u64_, "RAG row lowest");
        assert!(h.artifact_plan(1, 1).is_none());
    }

    #[test]
    fn plan_never_overflows_capacity() {
        let h = hrm();
        for &cap_gb in &[128u64, 200, 265, 350, 500] {
            let cap = cap_gb << 30;
            for &(p, g) in &[(98usize, 32usize), (98, 64), (926, 128), (128, 512)] {
                let plan = h.plan(p, g, cap);
                assert!(plan.cpu_mem_used <= cap, "{p}/{g}@{cap_gb}GB");
            }
        }
    }

    #[test]
    fn decode_iter_floors_at_delta() {
        // With few sequences the weight sweep dominates: iteration time is
        // exactly δ (Fig. 1's decode lane).
        let h = hrm();
        assert!((h.decode_iter_secs(8, 130) - h.delta()).abs() < 1e-9);
    }

    #[test]
    fn slow_cpu_attention_caps_the_plan() {
        // A faster CPU-attention kernel moves the roofline knee out, so the
        // plan admits more sequences — the Fig.-10 motivation.
        let mut fast = hrm();
        fast.cpu_attn_efficiency = 1.0;
        let slow = hrm();
        let cap = 1u64 << 40;
        assert!(
            fast.plan(98, 64, cap).decode_seqs >= slow.plan(98, 64, cap).decode_seqs,
            "faster attention must not shrink the plan"
        );
    }

    #[test]
    fn pipelined_host_overhead_hides_under_the_lane_max() {
        let h = hrm();
        let (n, ctx) = (64usize, 130usize);
        let exec = h.decode_iter_secs(n, ctx);
        // A host cost smaller than the slowest lane disappears entirely
        // under pipelining but stretches the synchronous iteration.
        let small = exec * 0.25;
        assert_eq!(h.decode_iter_secs_with_host(n, ctx, small, true), exec);
        assert!((h.decode_iter_secs_with_host(n, ctx, small, false) - (exec + small)).abs() < 1e-12);
        // A dominating host cost binds the pipeline instead.
        let big = exec * 3.0;
        assert_eq!(h.decode_iter_secs_with_host(n, ctx, big, true), big);
        // Pipelined never loses, for any host cost.
        for &hc in &[0.0, small, exec, big] {
            assert!(
                h.decode_iter_secs_with_host(n, ctx, hc, true)
                    <= h.decode_iter_secs_with_host(n, ctx, hc, false)
            );
        }
    }

    #[test]
    fn routed_decode_iter_matches_engine_gate() {
        let h = hrm();
        // pinned = 0 disables residency: bit-identical to the dense lane.
        assert_eq!(
            h.decode_iter_secs_routed(64, 130, 1.2, 0).to_bits(),
            h.decode_iter_secs(64, 130).to_bits()
        );
        // IO-bound regime: pinning hot experts under skew shortens the
        // iteration strictly (δ binds at small n, and the routed sweep is
        // smaller than the dense one).
        let dense = h.decode_iter_secs(64, 130);
        let routed = h.decode_iter_secs_routed(64, 130, 1.2, 2);
        assert!(routed < dense, "routed {routed} vs dense {dense}");
        // The win saturates once compute binds: the routed iteration can
        // never drop below the compute lanes.
        let huge = h.decode_iter_secs_routed(1_000_000, 1030, 1.2, 2);
        assert!(huge >= h.decode_iter_secs(1_000_000, 1030) * 0.5);
        assert!(h.delta_routed(1.2, 2, 64) < h.delta());
    }

    #[test]
    fn prefill_microbatch_magnitude() {
        // Per-layer IO coverage needs hundreds-to-thousands of tokens.
        let h = hrm();
        let plan = h.plan(98, 32, 265 << 30);
        assert!(plan.prefill_tokens > 100 && plan.prefill_tokens < 1_000_000);
    }

    #[test]
    fn kv_region_utilization_is_none_for_machines_without_a_kv_region() {
        // Regression: capacity < weights + 30 GB overhead used to panic in
        // debug builds (u64 underflow) and wrap in release. Mixtral-8x7B
        // weighs ~94 GB, so a 64 GB machine cannot even hold the weights
        // and a 100 GB machine has no room left after the overhead.
        let h = hrm();
        let plan = h.plan(98, 32, 64 << 30);
        assert!(h.kv_region_utilization(&plan, 64 << 30).is_none());
        assert!(h.kv_region_utilization(&plan, 100 << 30).is_none());
        // Exactly weights + overhead: zero-byte KV region, still None.
        let edge = h.model.model_bytes() + (30u64 << 30);
        assert!(h.kv_region_utilization(&plan, edge).is_none());
        // A machine with a real KV region reports a finite ratio.
        let u = h.kv_region_utilization(&plan, 265 << 30).unwrap();
        assert!(u.is_finite() && u >= 0.0);
    }

    #[test]
    fn infeasible_machines_get_a_minimal_but_defined_plan() {
        // Regression: when weights nearly (or fully) exhaust host memory
        // the capacity clamp used to return decode_seqs == 0, which turned
        // downstream `decode_throughput(0, ·)` into 0/NaN rows. The plan
        // must clamp to ≥ 1 and surface infeasibility via `fits_in`.
        let h = hrm();
        for &cap_gb in &[16u64, 64, 80] {
            let cap = cap_gb << 30;
            let plan = h.plan(926, 128, cap);
            assert_eq!(plan.decode_seqs, 1, "{cap_gb} GB");
            assert!(!plan.fits_in(cap), "{cap_gb} GB cannot hold the weights");
            assert!(plan.decode_iter_secs.is_finite() && plan.decode_iter_secs > 0.0);
            let tput = h.decode_throughput(plan.decode_seqs, 926 + 128);
            assert!(tput.is_finite() && tput > 0.0, "throughput {tput}");
            let two_phase = h.two_phase_generation_throughput(926, 128, cap);
            assert!(two_phase.is_finite() && two_phase > 0.0);
        }
        // Feasible machines keep fitting plans.
        let plan = h.plan(98, 32, 265 << 30);
        assert!(plan.fits_in(265 << 30));
        assert!(plan.decode_seqs >= 1);
    }
}
