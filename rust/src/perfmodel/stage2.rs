//! Stage 2: resource- and workload-aware performance model (§5.5).
//!
//! Builds on Stage 1 and prices in the physical execution factors:
//! a *bounded* request batch of `K` sequences, a *paged* KV cache with
//! block size `b` and `N` blocks, and the prefill/decode-overlapped
//! software pipeline (Eq. 8–14). As `K → ∞` and `b → 1` the model
//! converges to the Stage-1 upper bound; against real execution it
//! predicts end-to-end time with ~94% average accuracy (§8.1).

use super::stage1::Stage1Model;
use crate::config::{MachineSpec, ModelSpec};
use crate::util::cast::{u64_f64, usize_f64};

/// Which side of Eq. 14's `min` binds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// `T_1` binds: CPU memory capacity (the paged KV cache) limits the
    /// number of concurrently decoding sequences.
    MemoryCapacity,
    /// `T_2` binds: GPU compute limits how fast new sequences are
    /// prefilled through the overlapped pipeline.
    GpuCompute,
}

/// A full Stage-2 prediction for one workload configuration.
#[derive(Debug, Clone)]
pub struct Stage2Prediction {
    /// Eq. 8: sequences prefilled per iteration at steady state.
    pub q: f64,
    /// Eq. 10: memory-capacity-bound generation throughput (tokens/s).
    pub t1: f64,
    /// Eq. 13: GPU-compute-bound generation throughput (tokens/s).
    pub t2: f64,
    /// Eq. 14: predicted generation throughput (tokens/s).
    pub throughput: f64,
    /// Predicted end-to-end wall-clock for the batch (s): `K g / T`.
    pub wall_secs: f64,
    /// Predicted iteration count of the software pipeline.
    pub iterations: f64,
    /// Predicted GPU utilization: processed tokens/s over `T_GPU`.
    pub gpu_utilization: f64,
    pub regime: Regime,
}

/// Stage-2 analytic model. Wraps Stage 1 and adds the paged-KV and
/// bounded-batch terms.
#[derive(Debug, Clone)]
pub struct Stage2Model {
    pub stage1: Stage1Model,
    /// KV-cache block size `b` in token slots (§5.5; vLLM-style paging).
    pub block_size: usize,
}

impl Stage2Model {
    pub fn new(machine: MachineSpec, model: ModelSpec, block_size: usize) -> Self {
        assert!(block_size >= 1);
        Stage2Model { stage1: Stage1Model::new(machine, model), block_size }
    }

    /// Number of KV-cache blocks `N` for a byte budget.
    pub fn n_blocks(&self, kv_bytes: u64) -> f64 {
        let block_bytes =
            usize_f64(self.block_size) * u64_f64(self.stage1.model.kv_bytes_per_token());
        u64_f64(kv_bytes) / block_bytes
    }

    /// Lifetime block-iterations of one sequence: `Σ_{i=0}^{g} ⌈(p+i)/b⌉`
    /// (the denominator of Eq. 8). Paging rounds every footprint up to a
    /// whole block, which is what shifts Fig. 4's knee right.
    pub fn lifetime_block_cost(&self, p: usize, g: usize) -> f64 {
        let b = usize_f64(self.block_size);
        (0..=g).map(|i| (usize_f64(p + i) / b).ceil()).sum()
    }

    /// Eq. 8: sequences prefilled per iteration, `q = N / Σ ⌈(p+i)/b⌉`.
    pub fn q(&self, p: usize, g: usize, kv_bytes: u64) -> f64 {
        self.n_blocks(kv_bytes) / self.lifetime_block_cost(p, g)
    }

    /// GPU token budget per iteration: tokens the GPU can GEMM in the time
    /// one full weight sweep takes (`δ`). This is Eq. 2's `n` measured on
    /// the iteration clock — what §5.5 calls `T_GPU`.
    pub fn t_gpu_iter(&self) -> f64 {
        self.stage1.t_gpu() * self.stage1.delta()
    }

    /// Eq. 10: `T_1 = K g / ((K/q + g) δ)` — generation throughput when
    /// the paged KV cache limits concurrency.
    pub fn t1(&self, p: usize, g: usize, kv_bytes: u64, k: f64) -> f64 {
        let q = self.q(p, g, kv_bytes);
        let delta = self.stage1.delta();
        k * usize_f64(g) / ((k / q + usize_f64(g)) * delta)
    }

    /// Eq. 11: steady-state prefill token rate per iteration when the GPU
    /// binds, `T_prefill = T_GPU · p / (p + g)`.
    pub fn t_prefill_iter(&self, p: usize, g: usize) -> f64 {
        self.t_gpu_iter() * usize_f64(p) / usize_f64(p + g)
    }

    /// Eq. 12: total pipeline iterations in the GPU-bound regime.
    pub fn iterations_gpu_bound(&self, p: usize, g: usize, k: f64) -> f64 {
        let t_pre = self.t_prefill_iter(p, g);
        let t_gpu = self.t_gpu_iter();
        let g = usize_f64(g);
        let main = (k * usize_f64(p) - (t_pre + t_gpu) / 2.0 * g) / t_pre;
        2.0 * g + main.max(0.0)
    }

    /// Eq. 13: `T_2 = K g / (It · δ)` — generation throughput when GPU
    /// compute binds.
    pub fn t2(&self, p: usize, g: usize, k: f64) -> f64 {
        let it = self.iterations_gpu_bound(p, g, k);
        k * usize_f64(g) / (it * self.stage1.delta())
    }

    /// Eq. 14 and derived quantities.
    pub fn predict(&self, p: usize, g: usize, kv_bytes: u64, k: f64) -> Stage2Prediction {
        assert!(g > 0 && k > 0.0);
        let q = self.q(p, g, kv_bytes);
        let t1 = self.t1(p, g, kv_bytes, k);
        let t2 = self.t2(p, g, k);
        let throughput = t1.min(t2);
        let regime = if t1 <= t2 { Regime::MemoryCapacity } else { Regime::GpuCompute };
        let wall_secs = k * usize_f64(g) / throughput;
        let iterations = wall_secs / self.stage1.delta();
        // Processed (prefill+decode) tokens per second over the GPU rate.
        let processed = throughput * usize_f64(p + g) / usize_f64(g);
        let gpu_utilization = (processed / self.stage1.t_gpu()).min(1.0);
        Stage2Prediction { q, t1, t2, throughput, wall_secs, iterations, gpu_utilization, regime }
    }

    /// The paper's default request-batch sizing for evaluation: `K = 5 g q`
    /// (§7 "the request batch size is set to 5gq").
    pub fn default_batch(&self, p: usize, g: usize, kv_bytes: u64) -> f64 {
        5.0 * usize_f64(g) * self.q(p, g, kv_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    fn m(b: usize) -> Stage2Model {
        Stage2Model::new(MachineSpec::paper_testbed(), ModelSpec::mixtral_8x7b(), b)
    }

    #[test]
    fn q_matches_closed_form_when_unpaged() {
        // b = 1: Σ ⌈(p+i)/1⌉ = (g+1)(p + g/2)
        let s2 = m(1);
        let (p, g) = (100usize, 128usize);
        let n = s2.n_blocks(100 << 30);
        let sum = (g + 1) as f64 * (p as f64 + g as f64 / 2.0);
        assert!((s2.q(p, g, 100 << 30) - n / sum).abs() < 1e-6);
    }

    #[test]
    fn paging_reduces_q() {
        // Rounding footprints up to blocks can only reduce how many
        // sequences fit (Fig. 4's right-shifted knee).
        for &b in &[8usize, 16, 32, 64] {
            let q_paged = m(b).q(100, 128, 100 << 30);
            let q_ideal = m(1).q(100, 128, 100 << 30);
            assert!(q_paged <= q_ideal + 1e-9, "b={b}");
        }
    }

    #[test]
    fn converges_to_stage1_as_k_grows_and_b_shrinks() {
        let s2 = m(1);
        let (p, g, kv) = (100usize, 128usize, 100u64 << 30);
        let pred = s2.predict(p, g, kv, 1e9);
        let s1_gen = s2.stage1.generation_throughput(p, g, kv);
        // §5.5: "the Stage 2 model converges to the Stage 1 theoretical
        // upper bound" — within the (g+1) vs g discretization.
        let rel = (pred.throughput - s1_gen).abs() / s1_gen;
        assert!(rel < 0.02, "stage2={} stage1={} rel={rel}", pred.throughput, s1_gen);
    }

    #[test]
    fn bounded_batch_costs_throughput() {
        let s2 = m(16);
        let (p, g, kv) = (100usize, 128usize, 100u64 << 30);
        let small = s2.predict(p, g, kv, 25_000.0).throughput;
        let large = s2.predict(p, g, kv, 200_000.0).throughput;
        assert!(small < large, "pipeline epilogue should hurt small K");
    }

    #[test]
    fn regime_switches_with_kv_capacity() {
        let s2 = m(16);
        let small_kv = s2.predict(100, 128, 20 << 30, 100_000.0);
        let big_kv = s2.predict(100, 128, 4 << 40, 100_000.0);
        assert_eq!(small_kv.regime, Regime::MemoryCapacity);
        assert_eq!(big_kv.regime, Regime::GpuCompute);
        assert!(big_kv.gpu_utilization > small_kv.gpu_utilization);
    }

    #[test]
    fn utilization_capped_and_monotone_in_kv() {
        let s2 = m(16);
        let mut last = 0.0;
        for kv_gb in [10u64, 50, 100, 200, 400, 1000, 2000] {
            let u = s2.predict(100, 128, kv_gb << 30, 200_000.0).gpu_utilization;
            assert!(u >= last - 1e-9, "monotone: {u} < {last} at {kv_gb} GB");
            assert!(u <= 1.0 + 1e-9);
            last = u;
        }
    }

    #[test]
    fn mtbench_70gb_prediction_magnitude() {
        // Sanity: MTBench-like p=98, g=32, 70 GB KV on the paper testbed
        // should land in the hundreds-of-tokens/s band Fig. 11 reports.
        let s2 = Stage2Model::new(
            MachineSpec::paper_testbed(),
            ModelSpec::mixtral_8x7b(),
            16,
        );
        let pred = s2.predict(98, 32, 70 << 30, 25_000.0);
        assert!(
            pred.throughput > 100.0 && pred.throughput < 3000.0,
            "tput = {}",
            pred.throughput
        );
    }

    #[test]
    fn default_batch_is_5gq() {
        let s2 = m(16);
        let q = s2.q(98, 64, 70 << 30);
        assert!((s2.default_batch(98, 64, 70 << 30) - 5.0 * 64.0 * q).abs() < 1e-9);
    }

    #[test]
    fn gpu_bound_iterations_floor_at_prologue() {
        // Tiny K: the 2g prologue/epilogue dominates (Eq. 12's max(0,..)).
        let s2 = m(16);
        let it = s2.iterations_gpu_bound(100, 128, 1.0);
        assert!((it - 256.0).abs() < 1e-9);
    }
}
