//! Execution telemetry: per-pass breakdowns, throughput summaries, and
//! the Fig.-13-style execution traces.
//!
//! Both clocks feed the same records: the real engine stamps wall-clock
//! durations; the simulator stamps virtual seconds. The benches render
//! these as the paper's throughput / utilization / per-pass IO-GPU-CPU
//! series.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::kvcache::SeqId;
use crate::sched::DropReason;
use crate::util::cast::{f64_usize, usize_f64};
use crate::util::stats::percentile;

/// One inference pass (forward iteration) of the pipeline.
#[derive(Debug, Clone, Default)]
pub struct PassRecord {
    pub pass_id: usize,
    /// Time since run start at pass end (seconds, wall or virtual).
    pub t_end: f64,
    /// Pass duration (seconds).
    pub duration: f64,
    /// Prefill tokens processed this pass.
    pub prefill_tokens: usize,
    /// Decode tokens processed this pass.
    pub decode_tokens: usize,
    /// Tokens *yielded* this pass: decode rows plus completing prefill
    /// chunks (whose last row emits the sequence's first new token).
    pub generated: usize,
    /// Sequences finished this pass.
    pub finished: usize,
    /// Sequences preempted this pass (§6.2 preemption mode).
    pub preempted: usize,
    /// *Exposed* weight-transfer (IO) time within the pass (seconds):
    /// the window the pass spends only waiting on the link. The engine
    /// stamps its stage-boundary waits; the simulator books the
    /// contended sweep minus the compute it overlaps. IO that hides
    /// under compute is *not* in this lane — the four lanes partition
    /// the pass.
    pub io_time: f64,
    /// GPU-exclusive compute time within the pass (seconds): GPU busy
    /// while the CPU attention lane is idle.
    pub gpu_time: f64,
    /// CPU-exclusive time within the pass (seconds): host-side work (KV
    /// stores, merges, attention tail) while the GPU lane is idle.
    pub cpu_time: f64,
    /// Overlapped time within the pass (seconds): GPU flash attention and
    /// CPU decode attention both busy (§6.4's phase overlap). Total GPU
    /// busy is `gpu_time + overlap_time`; likewise for the CPU lane — the
    /// seed booked this window to the GPU lane alone, double-counting the
    /// CPU lane and inflating the Fig.-13 utilization series.
    pub overlap_time: f64,
    /// *Exposed* host plan/pack/embed time within the pass (seconds): the
    /// window spent planning, packing, or gathering embeddings with no
    /// concurrent layer execution to hide under. Zero for the synchronous
    /// engine/simulator (planning happens outside the pass body there,
    /// exactly as before the pipeline landed); the pipelined paths book
    /// replan fallbacks, the exposed tail of an overrunning speculative
    /// plan, and commit/patch bookkeeping here. This is the fifth
    /// exclusive lane: it participates in [`lanes_total`].
    ///
    /// [`lanes_total`]: Self::lanes_total
    pub host_time: f64,
    /// Host plan/pack/embed work *hidden* under this pass's layer
    /// execution (seconds): the speculative next-pass preparation that
    /// ran concurrently on the planner worker. Like the GPU/CPU busy
    /// shadows, this overlaps wall-clock already partitioned by the
    /// io/gpu/cpu/overlap lanes, so it is informational and NOT part of
    /// [`lanes_total`]; total host busy is [`host_busy`].
    ///
    /// [`lanes_total`]: Self::lanes_total
    /// [`host_busy`]: Self::host_busy
    pub host_overlap_time: f64, // pallas-lint: allow(lane-partition) — shadow of partitioned time
    /// KV blocks in use at pass end.
    pub kv_blocks_used: usize,
    /// Active decode sequences at pass end.
    pub active_decode: usize,
}

impl PassRecord {
    /// Sum of the exclusive lane times. For engine-recorded passes this
    /// decomposes `duration` (up to unattributed bookkeeping slack): the
    /// io, gpu, cpu, overlap, and exposed-host lanes partition the pass
    /// wall clock. (`host_overlap_time` is a shadow of already-partitioned
    /// time and is deliberately excluded.)
    pub fn lanes_total(&self) -> f64 {
        self.io_time + self.gpu_time + self.cpu_time + self.overlap_time + self.host_time
    }

    /// Total GPU busy time: the GPU-exclusive lane plus the overlapped
    /// window. The single source of truth for utilization figures.
    pub fn gpu_busy(&self) -> f64 {
        self.gpu_time + self.overlap_time
    }

    /// Total CPU busy time: the CPU-exclusive lane plus the overlapped
    /// window.
    pub fn cpu_busy(&self) -> f64 {
        self.cpu_time + self.overlap_time
    }

    /// Total host planning/packing/embedding busy time: the exposed lane
    /// plus the part hidden under layer execution by the pass pipeline.
    pub fn host_busy(&self) -> f64 {
        self.host_time + self.host_overlap_time
    }
}

/// A whole run's trace + derived summaries.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub passes: Vec<PassRecord>,
    /// Total KV blocks (for utilization ratios).
    pub kv_blocks_total: usize,
}

impl Trace {
    pub fn new(kv_blocks_total: usize) -> Self {
        Trace { passes: Vec::new(), kv_blocks_total }
    }

    pub fn push(&mut self, rec: PassRecord) {
        // Pass end times must never regress: zero-duration bookkeeping
        // passes (SLO shed-only records) stamp the *planning* instant, so
        // they sit between their neighbors and the Fig.-13 series stays
        // monotone. Always-on: once per pass, and a regressed timestamp
        // silently corrupts every downstream time series.
        assert!(
            self.passes.last().is_none_or(|p| rec.t_end >= p.t_end),
            "pass {} t_end {} regresses below previous {}",
            rec.pass_id,
            rec.t_end,
            self.passes.last().map_or(0.0, |p| p.t_end),
        );
        self.passes.push(rec);
    }

    pub fn wall_secs(&self) -> f64 {
        self.passes.last().map_or(0.0, |p| p.t_end)
    }

    pub fn total_decode_tokens(&self) -> usize {
        self.passes.iter().map(|p| p.decode_tokens).sum()
    }

    /// Total generated (yielded) tokens — the numerator of Fig. 11's
    /// generation-throughput metric.
    pub fn total_generated(&self) -> usize {
        self.passes.iter().map(|p| p.generated).sum()
    }

    pub fn total_prefill_tokens(&self) -> usize {
        self.passes.iter().map(|p| p.prefill_tokens).sum()
    }

    pub fn total_preemptions(&self) -> usize {
        self.passes.iter().map(|p| p.preempted).sum()
    }

    /// Generation throughput: generated tokens per second (Fig. 11).
    pub fn generation_throughput(&self) -> f64 {
        let t = self.wall_secs();
        if t > 0.0 {
            usize_f64(self.total_generated()) / t
        } else {
            0.0
        }
    }

    /// Processed-token throughput (prefill + decode).
    pub fn processed_throughput(&self) -> f64 {
        let t = self.wall_secs();
        if t > 0.0 {
            usize_f64(self.total_decode_tokens() + self.total_prefill_tokens()) / t
        } else {
            0.0
        }
    }

    /// Mean GPU busy fraction (Fig. 13 row 3): GPU-exclusive plus
    /// overlapped time over pass duration.
    pub fn mean_gpu_utilization(&self) -> f64 {
        if self.passes.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.passes.iter().map(|p| p.gpu_busy()).sum();
        let total: f64 = self.passes.iter().map(|p| p.duration).sum();
        if total > 0.0 {
            busy / total
        } else {
            0.0
        }
    }

    /// Downsample to at most `n` points for the Fig.-13 time-series
    /// plots. The final pass is always included — the end state (e.g. KV
    /// blocks draining back to 0) is exactly what the plots are read for
    /// — and the output never exceeds `n` points. (The seed's
    /// `step_by(len / n)` stride dropped the last pass unless aligned and
    /// could return up to 2n-1 points.)
    pub fn series<F: Fn(&PassRecord) -> f64>(&self, n: usize, f: F) -> Vec<(f64, f64)> {
        let len = self.passes.len();
        if len == 0 || n == 0 {
            return Vec::new();
        }
        if len <= n {
            return self.passes.iter().map(|p| (p.t_end, f(p))).collect();
        }
        if n == 1 {
            let p = self.passes.last().unwrap();
            return vec![(p.t_end, f(p))];
        }
        // n evenly spaced samples, pinned to the first and last pass.
        // len > n ⇒ the stride ratio exceeds 1, so rounded indices are
        // strictly increasing (no duplicates).
        let ratio = usize_f64(len - 1) / usize_f64(n - 1);
        (0..n)
            .map(|i| {
                let p = &self.passes[f64_usize((usize_f64(i) * ratio).round())];
                (p.t_end, f(p))
            })
            .collect()
    }

    /// Render as CSV (one row per pass) for offline plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "pass,t_end,duration,prefill_tokens,decode_tokens,finished,preempted,\
             io_time,gpu_time,cpu_time,overlap_time,host_time,host_overlap_time,\
             kv_blocks_used,active_decode\n",
        );
        for p in &self.passes {
            s.push_str(&format!(
                "{},{:.6},{:.6},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{},{}\n",
                p.pass_id,
                p.t_end,
                p.duration,
                p.prefill_tokens,
                p.decode_tokens,
                p.finished,
                p.preempted,
                p.io_time,
                p.gpu_time,
                p.cpu_time,
                p.overlap_time,
                p.host_time,
                p.host_overlap_time,
                p.kv_blocks_used,
                p.active_decode,
            ));
        }
        s
    }
}

/// Final report of a serving run (engine or simulator).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub requests: usize,
    pub generated_tokens: usize,
    pub wall_secs: f64,
    pub generation_throughput: f64,
    pub processed_throughput: f64,
    pub mean_gpu_utilization: f64,
    pub preemptions: usize,
    pub passes: usize,
}

impl RunReport {
    pub fn from_trace(trace: &Trace, requests: usize) -> Self {
        RunReport {
            requests,
            generated_tokens: trace.total_generated(),
            wall_secs: trace.wall_secs(),
            generation_throughput: trace.generation_throughput(),
            processed_throughput: trace.processed_throughput(),
            mean_gpu_utilization: trace.mean_gpu_utilization(),
            preemptions: trace.total_preemptions(),
            passes: trace.passes.len(),
        }
    }

    pub fn print(&self, label: &str) {
        println!("== {label} ==");
        println!("  requests          : {}", self.requests);
        println!("  generated tokens  : {}", self.generated_tokens);
        println!("  wall time         : {:.3} s", self.wall_secs);
        println!("  gen throughput    : {:.1} tok/s", self.generation_throughput);
        println!("  total throughput  : {:.1} tok/s", self.processed_throughput);
        println!("  mean GPU util     : {:.1} %", self.mean_gpu_utilization * 100.0);
        println!("  preemptions       : {}", self.preemptions);
        println!("  passes            : {}", self.passes);
    }
}

/// Per-request lifecycle timestamps for online serving. Both clocks feed
/// the same records: the engine stamps wall-clock seconds, the simulator
/// virtual seconds.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    /// When the request entered the system (arrival-process time, so TTFT
    /// includes queueing delay).
    pub arrival: f64,
    /// When its first generated token was produced.
    pub first_token: Option<f64>,
    /// When its last token was produced (request completion).
    pub finish: Option<f64>,
    /// When (and why) the SLO admission policy dropped it, if it was
    /// shed instead of served.
    pub dropped: Option<(f64, DropReason)>,
    /// Tokens generated so far.
    pub generated: usize,
}

/// Tracks per-request latency through an online serving run and derives
/// the TTFT / TPOT / end-to-end / goodput summary.
#[derive(Debug, Clone, Default)]
pub struct RequestTracker {
    timings: BTreeMap<SeqId, RequestTiming>,
}

impl RequestTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a request entering the system at time `t`. Panics on a
    /// duplicate id: a second arrival would silently overwrite the first
    /// one's timings (the seed only `debug_assert`ed, so release-mode
    /// traces with duplicate ids corrupted every latency stat). The
    /// serving loops validate id uniqueness up front and surface a
    /// proper error; this is the last-resort guard.
    pub fn arrived(&mut self, id: SeqId, t: f64) {
        let prev = self.timings.insert(
            id,
            RequestTiming {
                arrival: t,
                first_token: None,
                finish: None,
                dropped: None,
                generated: 0,
            },
        );
        assert!(prev.is_none(), "request {id} arrived twice");
    }

    /// Record one generated token for `id` at time `t` (the first call
    /// stamps TTFT).
    pub fn token(&mut self, id: SeqId, t: f64) {
        let r = self.timings.get_mut(&id).expect("token for untracked request");
        r.generated += 1;
        if r.first_token.is_none() {
            r.first_token = Some(t);
        }
    }

    /// Record request completion at time `t`. A double finish or a drop
    /// of a finished request would corrupt the completion counts feeding
    /// goodput, so these guards stay on in release builds (once per
    /// request lifecycle — cold, like `arrived`).
    pub fn finished(&mut self, id: SeqId, t: f64) {
        let r = self.timings.get_mut(&id).expect("finish for untracked request");
        assert!(r.finish.is_none(), "request {id} finished twice");
        r.finish = Some(t);
    }

    /// Record the request being shed by the SLO admission policy at time
    /// `t` (it will never finish).
    pub fn dropped(&mut self, id: SeqId, t: f64, reason: DropReason) {
        let r = self.timings.get_mut(&id).expect("drop for untracked request");
        assert!(r.finish.is_none(), "request {id} dropped after finishing");
        assert!(r.dropped.is_none(), "request {id} dropped twice");
        r.dropped = Some((t, reason));
    }

    pub fn timing(&self, id: SeqId) -> Option<&RequestTiming> {
        self.timings.get(&id)
    }

    /// Ids that neither finished nor were dropped — the cluster's request
    /// conservation check. After a run every admitted request must be
    /// resolved one way (finished) or the other (rejected / expired /
    /// failed); a non-empty result means the recovery machinery silently
    /// lost work.
    pub fn unresolved(&self) -> Vec<SeqId> {
        self.timings
            .iter()
            .filter(|(_, r)| r.finish.is_none() && r.dropped.is_none())
            .map(|(&id, _)| id)
            .collect()
    }

    /// Merge per-replica trackers into one cluster-level view. Replica
    /// trackers each see a request id at most once (the cluster admits an
    /// id to one replica at a time; a crash re-route lands it on a
    /// *different* replica's tracker), so the roll-up is a per-id fold:
    ///
    /// * `arrival` — the earliest stamp (the original admission; a
    ///   re-routed request keeps its true queueing delay);
    /// * `first_token` / `finish` — earliest stamp anywhere (TTFT is the
    ///   first token the *user* saw, wherever it was produced);
    /// * `generated` — summed: a crash replay preserves already-produced
    ///   tokens in the re-enqueued sequence, so each tracker only counts
    ///   the tokens its replica actually produced and the sum is the
    ///   request's total;
    /// * `dropped` — the latest drop, and cleared entirely if the request
    ///   finished anywhere (a stale drop stamp on a crashed replica must
    ///   not shadow a successful recovery).
    pub fn rollup<'a>(trackers: impl IntoIterator<Item = &'a RequestTracker>) -> RequestTracker {
        let mut merged: BTreeMap<SeqId, RequestTiming> = BTreeMap::new();
        for tr in trackers {
            for (&id, r) in &tr.timings {
                let Some(m) = merged.get_mut(&id) else {
                    merged.insert(id, *r);
                    continue;
                };
                m.arrival = m.arrival.min(r.arrival);
                m.first_token = match (m.first_token, r.first_token) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
                m.finish = match (m.finish, r.finish) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
                m.generated += r.generated;
                m.dropped = match (m.dropped, r.dropped) {
                    (Some(a), Some(b)) => Some(if b.0 > a.0 { b } else { a }),
                    (a, b) => a.or(b),
                };
            }
        }
        for m in merged.values_mut() {
            if m.finish.is_some() {
                m.dropped = None;
            }
        }
        RequestTracker { timings: merged }
    }

    pub fn completed(&self) -> usize {
        self.timings.values().filter(|r| r.finish.is_some()).count()
    }

    /// Summarize the run. `wall_secs` is the run's total span; `slo_e2e`
    /// is the end-to-end deadline goodput counts against (pass
    /// `f64::INFINITY` for plain completed-requests-per-second).
    pub fn stats(&self, wall_secs: f64, slo_e2e: f64) -> LatencyStats {
        let mut ttft = Vec::new();
        let mut tpot = Vec::new();
        let mut e2e = Vec::new();
        let mut within_slo = 0usize;
        let mut rejected = 0usize;
        let mut expired = 0usize;
        for r in self.timings.values() {
            match r.dropped {
                Some((_, DropReason::Rejected)) => rejected += 1,
                Some((_, DropReason::Expired)) => expired += 1,
                None => {}
            }
            let (Some(first), Some(fin)) = (r.first_token, r.finish) else {
                continue;
            };
            ttft.push(first - r.arrival);
            let e = fin - r.arrival;
            e2e.push(e);
            // TPOT is defined over the decode gaps, so it needs >= 2 tokens.
            if r.generated >= 2 {
                tpot.push((fin - first) / usize_f64(r.generated - 1));
            }
            if e <= slo_e2e {
                within_slo += 1;
            }
        }
        LatencyStats {
            requests: self.timings.len(),
            completed: e2e.len(),
            rejected,
            expired,
            rerouted: 0,
            replayed: 0,
            failed: 0,
            ttft_p50: percentile(&ttft, 0.50),
            ttft_p99: percentile(&ttft, 0.99),
            tpot_p50: percentile(&tpot, 0.50),
            tpot_p99: percentile(&tpot, 0.99),
            e2e_p50: percentile(&e2e, 0.50),
            e2e_p99: percentile(&e2e, 0.99),
            goodput_rps: if wall_secs > 0.0 { usize_f64(within_slo) / wall_secs } else { 0.0 },
            slo_e2e,
        }
    }
}

/// Request-level latency summary of an online serving run (the
/// MoE-Lightning-style request-latency comparison, arXiv:2411.11217).
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// Requests that entered the system.
    pub requests: usize,
    /// Requests that finished.
    pub completed: usize,
    /// Requests shed by SLO admission before any work was done.
    pub rejected: usize,
    /// Requests dropped mid-flight (deadline slack ran out).
    pub expired: usize,
    /// Cluster serving only (zero for single-machine runs): queued
    /// requests moved to another replica after a crash or drain, with no
    /// work lost.
    pub rerouted: usize,
    /// Cluster serving only: in-flight crash casualties re-enqueued
    /// elsewhere as preemption-style replays (KV lost, context
    /// re-prefilled).
    pub replayed: usize,
    /// Cluster serving only: requests the recovery machinery gave up on
    /// (retry budget exhausted, or no surviving replica could admit
    /// them). Also stamped expired on the roll-up tracker.
    pub failed: usize,
    /// Time-to-first-token percentiles (seconds).
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// Time-per-output-token percentiles (seconds/token).
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    /// End-to-end latency percentiles (seconds).
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    /// Completed requests per second meeting the end-to-end SLO.
    pub goodput_rps: f64,
    /// The SLO `goodput_rps` was measured against (infinite = none).
    pub slo_e2e: f64,
}

impl LatencyStats {
    pub fn print(&self) {
        println!("  completed         : {}/{}", self.completed, self.requests);
        if self.rejected + self.expired > 0 {
            println!(
                "  shed (SLO)        : {} rejected, {} expired",
                self.rejected, self.expired
            );
        }
        if self.rerouted + self.replayed + self.failed > 0 {
            println!(
                "  fault recovery    : {} rerouted, {} replayed, {} failed",
                self.rerouted, self.replayed, self.failed
            );
        }
        println!(
            "  TTFT p50/p99      : {:.3} s / {:.3} s",
            self.ttft_p50, self.ttft_p99
        );
        println!(
            "  TPOT p50/p99      : {:.1} ms / {:.1} ms",
            self.tpot_p50 * 1e3,
            self.tpot_p99 * 1e3
        );
        println!(
            "  e2e  p50/p99      : {:.3} s / {:.3} s",
            self.e2e_p50, self.e2e_p99
        );
        if self.slo_e2e.is_finite() {
            println!(
                "  goodput (e2e<{:.1}s): {:.2} req/s",
                self.slo_e2e, self.goodput_rps
            );
        } else {
            println!("  goodput           : {:.2} req/s", self.goodput_rps);
        }
    }
}

/// Wall-clock stopwatch for engine instrumentation.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn lap(&mut self) -> Duration {
        let now = std::time::Instant::now();
        let d = now - self.0;
        self.0 = now;
        d
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pass(id: usize, t: f64, pf: usize, dc: usize, gpu: f64, dur: f64) -> PassRecord {
        PassRecord {
            pass_id: id,
            t_end: t,
            duration: dur,
            prefill_tokens: pf,
            decode_tokens: dc,
            generated: dc,
            gpu_time: gpu,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_math() {
        let mut tr = Trace::new(100);
        tr.push(pass(0, 1.0, 100, 10, 0.5, 1.0));
        tr.push(pass(1, 2.0, 50, 20, 1.0, 1.0));
        assert_eq!(tr.total_decode_tokens(), 30);
        assert_eq!(tr.total_prefill_tokens(), 150);
        assert!((tr.generation_throughput() - 15.0).abs() < 1e-9);
        assert!((tr.processed_throughput() - 90.0).abs() < 1e-9);
        assert!((tr.mean_gpu_utilization() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn empty_trace_is_zero() {
        let tr = Trace::new(10);
        assert_eq!(tr.generation_throughput(), 0.0);
        assert_eq!(tr.mean_gpu_utilization(), 0.0);
        assert_eq!(tr.wall_secs(), 0.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut tr = Trace::new(10);
        tr.push(pass(0, 0.5, 1, 2, 0.1, 0.5));
        let csv = tr.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("pass,"));
        assert!(csv.contains("0,0.5"));
    }

    #[test]
    fn series_downsamples() {
        let mut tr = Trace::new(10);
        for i in 0..100 {
            tr.push(pass(i, i as f64, 0, i, 0.0, 1.0));
        }
        let s = tr.series(10, |p| p.decode_tokens as f64);
        assert_eq!(s.len(), 10);
        assert_eq!(s[0], (0.0, 0.0));
    }

    #[test]
    fn series_always_includes_the_final_pass_and_bounds_length() {
        // The seed's step_by stride dropped the final pass on unaligned
        // lengths (19 % 10 != 0) and returned up to 2n-1 points.
        let mut tr = Trace::new(10);
        for i in 0..19 {
            tr.push(pass(i, i as f64, 0, i, 0.0, 1.0));
        }
        for n in 1..=25 {
            let s = tr.series(n, |p| p.decode_tokens as f64);
            assert!(s.len() <= n, "n={n}: {} points", s.len());
            assert_eq!(s.len(), n.min(19));
            assert_eq!(
                *s.last().unwrap(),
                (18.0, 18.0),
                "n={n}: final pass must be included"
            );
            if n >= 2 {
                assert_eq!(s[0], (0.0, 0.0), "n={n}: first pass pinned");
            }
            // Strictly increasing timestamps: no duplicate samples.
            for w in s.windows(2) {
                assert!(w[0].0 < w[1].0, "n={n}");
            }
        }
        assert!(tr.series(0, |p| p.duration).is_empty());
    }

    #[test]
    fn request_tracker_drop_accounting() {
        let mut t = RequestTracker::new();
        t.arrived(0, 0.0);
        t.token(0, 1.0);
        t.finished(0, 1.0);
        t.arrived(1, 0.5);
        t.dropped(1, 2.0, DropReason::Rejected);
        t.arrived(2, 0.7);
        t.dropped(2, 3.0, DropReason::Expired);
        let s = t.stats(10.0, f64::INFINITY);
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.expired, 1);
        assert!((s.goodput_rps - 0.1).abs() < 1e-12);
        assert_eq!(t.timing(1).unwrap().dropped, Some((2.0, DropReason::Rejected)));
        s.print();
    }

    #[test]
    fn rollup_merges_replica_trackers() {
        // Replica A: request 0 arrives at 0, produces 3 tokens (first at
        // 1.0), then the replica crashes — no finish, no drop.
        let mut a = RequestTracker::new();
        a.arrived(0, 0.0);
        a.token(0, 1.0);
        a.token(0, 2.0);
        a.token(0, 3.0);
        // Request 1 lives and dies on A.
        a.arrived(1, 0.5);
        a.dropped(1, 4.0, DropReason::Expired);
        // Replica B: request 0 re-routed (same arrival stamp, replayed),
        // produces its remaining 2 tokens and finishes.
        let mut b = RequestTracker::new();
        b.arrived(0, 0.0);
        b.token(0, 7.0);
        b.token(0, 8.0);
        b.finished(0, 8.0);
        // Request 2 is B-only.
        b.arrived(2, 1.0);
        b.token(2, 2.0);
        b.finished(2, 2.0);

        let r = RequestTracker::rollup([&a, &b]);
        let t0 = r.timing(0).unwrap();
        assert_eq!(t0.arrival, 0.0);
        assert_eq!(t0.first_token, Some(1.0), "TTFT is the pre-crash first token");
        assert_eq!(t0.finish, Some(8.0));
        assert_eq!(t0.generated, 5, "pre- and post-crash tokens sum");
        assert_eq!(t0.dropped, None);
        assert_eq!(r.timing(1).unwrap().dropped, Some((4.0, DropReason::Expired)));
        assert_eq!(r.completed(), 2);
        let s = r.stats(10.0, f64::INFINITY);
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.expired, 1);
        // TPOT for request 0 spans the crash gap: (8-1)/4.
        assert!((s.tpot_p99 - 1.75).abs() < 1e-12);

        // A finish anywhere clears a stale drop stamp from another
        // replica (recovery must not double-count the casualty).
        let mut c = RequestTracker::new();
        c.arrived(1, 0.5);
        c.token(1, 6.0);
        c.finished(1, 6.0);
        let r2 = RequestTracker::rollup([&a, &b, &c]);
        assert_eq!(r2.timing(1).unwrap().dropped, None);
        assert_eq!(r2.timing(1).unwrap().finish, Some(6.0));
        assert_eq!(r2.stats(10.0, f64::INFINITY).expired, 0);
    }

    #[test]
    #[should_panic(expected = "arrived twice")]
    fn duplicate_arrival_panics_in_release_too() {
        let mut t = RequestTracker::new();
        t.arrived(7, 0.0);
        t.arrived(7, 1.0);
    }

    #[test]
    fn shed_only_passes_keep_series_monotone() {
        // Regression (pipeline PR): a zero-duration shed-only pass is
        // stamped at its *planning* instant, between its neighbors; the
        // trace accepts it and every downsampled Fig.-13 series stays
        // time-monotone for all sample counts.
        let mut tr = Trace::new(10);
        tr.push(pass(0, 1.0, 10, 0, 0.5, 1.0));
        let shed_only = PassRecord { pass_id: 1, t_end: 1.25, ..Default::default() };
        assert_eq!(shed_only.duration, 0.0);
        tr.push(shed_only);
        tr.push(pass(2, 2.5, 0, 10, 0.5, 1.0));
        // Equal timestamps are tolerated too (back-to-back shed passes on
        // a coarse clock).
        tr.push(PassRecord { pass_id: 3, t_end: 2.5, ..Default::default() });
        tr.push(pass(4, 3.0, 0, 10, 0.5, 0.5));
        for n in 1..=8 {
            let s = tr.series(n, |p| p.decode_tokens as f64);
            for w in s.windows(2) {
                assert!(w[0].0 <= w[1].0, "n={n}: series regressed: {s:?}");
            }
            assert_eq!(*s.last().unwrap(), (3.0, 10.0), "n={n}: final pass pinned");
        }
        // Throughput denominators ignore the zero-duration records.
        assert!((tr.mean_gpu_utilization() - 1.5 / 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "regresses below previous")]
    fn regressed_pass_timestamps_are_rejected() {
        let mut tr = Trace::new(10);
        tr.push(pass(0, 2.0, 1, 1, 0.1, 1.0));
        tr.push(pass(1, 1.0, 1, 1, 0.1, 1.0));
    }

    #[test]
    fn host_lanes_partition_and_shadow() {
        // host_time is the fifth exclusive lane; host_overlap_time is a
        // shadow (hidden under layer execution) and stays out of the
        // partition, mirroring how gpu_busy() relates to gpu_time.
        let mut p = pass(0, 1.0, 4, 4, 0.3, 1.0);
        p.io_time = 0.2;
        p.cpu_time = 0.1;
        p.overlap_time = 0.25;
        p.host_time = 0.15;
        p.host_overlap_time = 0.6;
        assert!((p.lanes_total() - 1.0).abs() < 1e-12);
        assert!((p.host_busy() - 0.75).abs() < 1e-12);
        let csv = Trace { passes: vec![p], kv_blocks_total: 1 }.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.contains("host_time") && header.contains("host_overlap_time"));
    }

    #[test]
    fn csv_includes_overlap_lane() {
        let mut tr = Trace::new(10);
        let mut p = pass(0, 1.0, 0, 4, 0.2, 1.0);
        p.overlap_time = 0.3;
        p.io_time = 0.4;
        p.cpu_time = 0.1;
        tr.push(p.clone());
        assert!(tr.to_csv().lines().next().unwrap().contains("overlap_time"));
        assert!((p.lanes_total() - 1.0).abs() < 1e-12);
        // GPU busy = exclusive + overlapped.
        assert!((tr.mean_gpu_utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn request_tracker_latency_stats() {
        let mut t = RequestTracker::new();
        // Request 0: arrives at 0, first token at 1, 5 tokens, done at 5.
        t.arrived(0, 0.0);
        for i in 1..=5 {
            t.token(0, i as f64);
        }
        t.finished(0, 5.0);
        // Request 1: arrives at 2, single token at 8 (TTFT 6, no TPOT).
        t.arrived(1, 2.0);
        t.token(1, 8.0);
        t.finished(1, 8.0);
        // Request 2: still in flight — excluded from latency percentiles.
        t.arrived(2, 3.0);
        assert_eq!(t.completed(), 2);
        let s = t.stats(10.0, 7.0);
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 2);
        // TTFTs: [1, 6]; nearest-rank p50 of two samples is the upper one.
        assert_eq!(s.ttft_p50, 6.0);
        assert_eq!(s.ttft_p99, 6.0);
        // TPOT: only request 0 qualifies: (5-1)/4 = 1.
        assert_eq!(s.tpot_p50, 1.0);
        // e2e: [5, 6]; only request 0 (e2e 5) meets the 7s... both do:
        // request 1's e2e is 8-2 = 6 <= 7. Goodput = 2 / 10 s.
        assert_eq!(s.e2e_p99, 6.0);
        assert!((s.goodput_rps - 0.2).abs() < 1e-12);
        // Tight SLO drops request 1.
        let tight = t.stats(10.0, 5.5);
        assert!((tight.goodput_rps - 0.1).abs() < 1e-12);
        // Infinite SLO counts every completion.
        let open = t.stats(10.0, f64::INFINITY);
        assert!((open.goodput_rps - 0.2).abs() < 1e-12);
        open.print();
    }

    #[test]
    fn report_from_trace() {
        let mut tr = Trace::new(10);
        tr.push(pass(0, 2.0, 10, 20, 1.0, 2.0));
        let r = RunReport::from_trace(&tr, 5);
        assert_eq!(r.requests, 5);
        assert_eq!(r.generated_tokens, 20);
        assert_eq!(r.passes, 1);
        assert!((r.generation_throughput - 10.0).abs() < 1e-9);
    }
}
