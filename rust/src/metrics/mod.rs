//! Execution telemetry: per-pass breakdowns, throughput summaries, and
//! the Fig.-13-style execution traces.
//!
//! Both clocks feed the same records: the real engine stamps wall-clock
//! durations; the simulator stamps virtual seconds. The benches render
//! these as the paper's throughput / utilization / per-pass IO-GPU-CPU
//! series.

use std::time::Duration;

/// One inference pass (forward iteration) of the pipeline.
#[derive(Debug, Clone, Default)]
pub struct PassRecord {
    pub pass_id: usize,
    /// Time since run start at pass end (seconds, wall or virtual).
    pub t_end: f64,
    /// Pass duration (seconds).
    pub duration: f64,
    /// Prefill tokens processed this pass.
    pub prefill_tokens: usize,
    /// Decode tokens processed this pass.
    pub decode_tokens: usize,
    /// Tokens *yielded* this pass: decode rows plus completing prefill
    /// chunks (whose last row emits the sequence's first new token).
    pub generated: usize,
    /// Sequences finished this pass.
    pub finished: usize,
    /// Sequences preempted this pass (§6.2 preemption mode).
    pub preempted: usize,
    /// Weight-transfer (IO) time within the pass (seconds).
    pub io_time: f64,
    /// GPU compute time within the pass (seconds).
    pub gpu_time: f64,
    /// CPU attention time within the pass (seconds).
    pub cpu_time: f64,
    /// KV blocks in use at pass end.
    pub kv_blocks_used: usize,
    /// Active decode sequences at pass end.
    pub active_decode: usize,
}

/// A whole run's trace + derived summaries.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub passes: Vec<PassRecord>,
    /// Total KV blocks (for utilization ratios).
    pub kv_blocks_total: usize,
}

impl Trace {
    pub fn new(kv_blocks_total: usize) -> Self {
        Trace { passes: Vec::new(), kv_blocks_total }
    }

    pub fn push(&mut self, rec: PassRecord) {
        self.passes.push(rec);
    }

    pub fn wall_secs(&self) -> f64 {
        self.passes.last().map_or(0.0, |p| p.t_end)
    }

    pub fn total_decode_tokens(&self) -> usize {
        self.passes.iter().map(|p| p.decode_tokens).sum()
    }

    /// Total generated (yielded) tokens — the numerator of Fig. 11's
    /// generation-throughput metric.
    pub fn total_generated(&self) -> usize {
        self.passes.iter().map(|p| p.generated).sum()
    }

    pub fn total_prefill_tokens(&self) -> usize {
        self.passes.iter().map(|p| p.prefill_tokens).sum()
    }

    pub fn total_preemptions(&self) -> usize {
        self.passes.iter().map(|p| p.preempted).sum()
    }

    /// Generation throughput: generated tokens per second (Fig. 11).
    pub fn generation_throughput(&self) -> f64 {
        let t = self.wall_secs();
        if t == 0.0 {
            0.0
        } else {
            self.total_generated() as f64 / t
        }
    }

    /// Processed-token throughput (prefill + decode).
    pub fn processed_throughput(&self) -> f64 {
        let t = self.wall_secs();
        if t == 0.0 {
            0.0
        } else {
            (self.total_decode_tokens() + self.total_prefill_tokens()) as f64 / t
        }
    }

    /// Mean GPU busy fraction (Fig. 13 row 3: gpu_time / pass duration).
    pub fn mean_gpu_utilization(&self) -> f64 {
        if self.passes.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.passes.iter().map(|p| p.gpu_time).sum();
        let total: f64 = self.passes.iter().map(|p| p.duration).sum();
        if total == 0.0 {
            0.0
        } else {
            busy / total
        }
    }

    /// Downsample to `n` points for the Fig.-13 time-series plots.
    pub fn series<F: Fn(&PassRecord) -> f64>(&self, n: usize, f: F) -> Vec<(f64, f64)> {
        if self.passes.is_empty() {
            return Vec::new();
        }
        let stride = (self.passes.len() / n.max(1)).max(1);
        self.passes
            .iter()
            .step_by(stride)
            .map(|p| (p.t_end, f(p)))
            .collect()
    }

    /// Render as CSV (one row per pass) for offline plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "pass,t_end,duration,prefill_tokens,decode_tokens,finished,preempted,\
             io_time,gpu_time,cpu_time,kv_blocks_used,active_decode\n",
        );
        for p in &self.passes {
            s.push_str(&format!(
                "{},{:.6},{:.6},{},{},{},{},{:.6},{:.6},{:.6},{},{}\n",
                p.pass_id,
                p.t_end,
                p.duration,
                p.prefill_tokens,
                p.decode_tokens,
                p.finished,
                p.preempted,
                p.io_time,
                p.gpu_time,
                p.cpu_time,
                p.kv_blocks_used,
                p.active_decode,
            ));
        }
        s
    }
}

/// Final report of a serving run (engine or simulator).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub requests: usize,
    pub generated_tokens: usize,
    pub wall_secs: f64,
    pub generation_throughput: f64,
    pub processed_throughput: f64,
    pub mean_gpu_utilization: f64,
    pub preemptions: usize,
    pub passes: usize,
}

impl RunReport {
    pub fn from_trace(trace: &Trace, requests: usize) -> Self {
        RunReport {
            requests,
            generated_tokens: trace.total_generated(),
            wall_secs: trace.wall_secs(),
            generation_throughput: trace.generation_throughput(),
            processed_throughput: trace.processed_throughput(),
            mean_gpu_utilization: trace.mean_gpu_utilization(),
            preemptions: trace.total_preemptions(),
            passes: trace.passes.len(),
        }
    }

    pub fn print(&self, label: &str) {
        println!("== {label} ==");
        println!("  requests          : {}", self.requests);
        println!("  generated tokens  : {}", self.generated_tokens);
        println!("  wall time         : {:.3} s", self.wall_secs);
        println!("  gen throughput    : {:.1} tok/s", self.generation_throughput);
        println!("  total throughput  : {:.1} tok/s", self.processed_throughput);
        println!("  mean GPU util     : {:.1} %", self.mean_gpu_utilization * 100.0);
        println!("  preemptions       : {}", self.preemptions);
        println!("  passes            : {}", self.passes);
    }
}

/// Wall-clock stopwatch for engine instrumentation.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn lap(&mut self) -> Duration {
        let now = std::time::Instant::now();
        let d = now - self.0;
        self.0 = now;
        d
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pass(id: usize, t: f64, pf: usize, dc: usize, gpu: f64, dur: f64) -> PassRecord {
        PassRecord {
            pass_id: id,
            t_end: t,
            duration: dur,
            prefill_tokens: pf,
            decode_tokens: dc,
            generated: dc,
            gpu_time: gpu,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_math() {
        let mut tr = Trace::new(100);
        tr.push(pass(0, 1.0, 100, 10, 0.5, 1.0));
        tr.push(pass(1, 2.0, 50, 20, 1.0, 1.0));
        assert_eq!(tr.total_decode_tokens(), 30);
        assert_eq!(tr.total_prefill_tokens(), 150);
        assert!((tr.generation_throughput() - 15.0).abs() < 1e-9);
        assert!((tr.processed_throughput() - 90.0).abs() < 1e-9);
        assert!((tr.mean_gpu_utilization() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn empty_trace_is_zero() {
        let tr = Trace::new(10);
        assert_eq!(tr.generation_throughput(), 0.0);
        assert_eq!(tr.mean_gpu_utilization(), 0.0);
        assert_eq!(tr.wall_secs(), 0.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut tr = Trace::new(10);
        tr.push(pass(0, 0.5, 1, 2, 0.1, 0.5));
        let csv = tr.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("pass,"));
        assert!(csv.contains("0,0.5"));
    }

    #[test]
    fn series_downsamples() {
        let mut tr = Trace::new(10);
        for i in 0..100 {
            tr.push(pass(i, i as f64, 0, i, 0.0, 1.0));
        }
        let s = tr.series(10, |p| p.decode_tokens as f64);
        assert!(s.len() >= 10 && s.len() <= 11);
        assert_eq!(s[0], (0.0, 0.0));
    }

    #[test]
    fn report_from_trace() {
        let mut tr = Trace::new(10);
        tr.push(pass(0, 2.0, 10, 20, 1.0, 2.0));
        let r = RunReport::from_trace(&tr, 5);
        assert_eq!(r.requests, 5);
        assert_eq!(r.generated_tokens, 20);
        assert_eq!(r.passes, 1);
        assert!((r.generation_throughput - 10.0).abs() < 1e-9);
    }
}
