//! Workload descriptors matching the paper's Table 3.
//!
//! The real datasets (MTBench, RAG-12000, AIME-2024) are unavailable
//! offline; `workload::generator` draws per-request prompt lengths from a
//! clipped lognormal fitted to each dataset's published (avg, max) and
//! caps generation at the per-dataset maximum — the only properties the
//! paper's evaluation depends on (DESIGN.md §1).

/// A (prompt-length, generation-length) workload family.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: &'static str,
    /// Average prefill (prompt) length, tokens.
    pub avg_prefill: usize,
    /// Maximum prefill length, tokens.
    pub max_prefill: usize,
    /// Maximum generation length(s) evaluated in the paper.
    pub gen_lengths: &'static [usize],
    pub category: &'static str,
}

/// MTBench: multi-turn conversation; avg 98 / max 450 prompt tokens.
pub const MTBENCH: WorkloadSpec = WorkloadSpec {
    name: "mtbench",
    avg_prefill: 98,
    max_prefill: 450,
    gen_lengths: &[32, 64, 128, 256],
    category: "Multi-turn conversation",
};

/// RAG: retrieval-augmented Q&A; prefill-heavy (avg 926 / max 1843).
pub const RAG: WorkloadSpec = WorkloadSpec {
    name: "rag",
    avg_prefill: 926,
    max_prefill: 1843,
    gen_lengths: &[128],
    category: "Retrieval-Augmented Q&A",
};

/// AIME 2024: math problem solving; generation-heavy (512-token budget).
pub const AIME: WorkloadSpec = WorkloadSpec {
    name: "aime",
    avg_prefill: 128,
    max_prefill: 410,
    gen_lengths: &[512],
    category: "Math Problem Solving",
};

impl WorkloadSpec {
    pub fn all() -> [&'static WorkloadSpec; 3] {
        [&MTBENCH, &RAG, &AIME]
    }

    pub fn by_name(name: &str) -> Option<&'static WorkloadSpec> {
        Self::all().into_iter().find(|w| w.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_constants() {
        assert_eq!(MTBENCH.avg_prefill, 98);
        assert_eq!(MTBENCH.max_prefill, 450);
        assert_eq!(MTBENCH.gen_lengths, &[32, 64, 128, 256]);
        assert_eq!(RAG.avg_prefill, 926);
        assert_eq!(AIME.gen_lengths, &[512]);
    }

    #[test]
    fn lookup() {
        assert_eq!(WorkloadSpec::by_name("rag").unwrap().max_prefill, 1843);
        assert!(WorkloadSpec::by_name("x").is_none());
    }
}
