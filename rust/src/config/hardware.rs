//! Hardware profiles for the performance model and simulator.
//!
//! Constants follow the paper's §5/§7: GPU BF16 GEMM throughput, GPU
//! memory capacity, CPU-GPU PCIe bandwidth (the paper *measures* 19.5 GB/s
//! on its PCIe 4.0 testbed; Table 2 uses the nominal 32 GB/s), CPU memory
//! capacity/bandwidth, and CPU vector throughput.

/// GPU profile.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Dense BF16 GEMM throughput, FLOP/s.
    pub bf16_flops: f64,
    /// Device memory, bytes.
    pub mem_bytes: u64,
}

impl GpuSpec {
    pub fn a40() -> GpuSpec {
        GpuSpec { name: "A40", bf16_flops: 150e12, mem_bytes: 48 << 30 }
    }
    pub fn l40() -> GpuSpec {
        GpuSpec { name: "L40", bf16_flops: 181e12, mem_bytes: 48 << 30 }
    }
    pub fn a100() -> GpuSpec {
        GpuSpec { name: "A100", bf16_flops: 312e12, mem_bytes: 80 << 30 }
    }
    pub fn t4() -> GpuSpec {
        // T4 has no BF16; FP16 tensor throughput is the comparable number.
        GpuSpec { name: "T4", bf16_flops: 65e12, mem_bytes: 16 << 30 }
    }
    pub fn l4() -> GpuSpec {
        GpuSpec { name: "L4", bf16_flops: 121e12, mem_bytes: 24 << 30 }
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        ["A40", "L40", "A100", "T4", "L4"]
            .iter()
            .map(|n| match *n {
                "A40" => Self::a40(),
                "L40" => Self::l40(),
                "A100" => Self::a100(),
                "T4" => Self::t4(),
                _ => Self::l4(),
            })
            .find(|g| g.name.eq_ignore_ascii_case(name))
    }
}

/// Host (CPU + memory) profile.
#[derive(Debug, Clone, PartialEq)]
pub struct HostSpec {
    pub name: &'static str,
    /// Total CPU memory, bytes.
    pub mem_bytes: u64,
    /// Aggregate CPU memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Peak per-core vector FP32 throughput, FLOP/s (for the CPU-attention
    /// requirement analysis, §5.3/§6.6).
    pub core_flops: f64,
    pub n_cores: usize,
}

impl HostSpec {
    /// The paper's testbed: one socket of a dual Xeon Platinum 8380
    /// (8x DDR4-3200, ~150 GB/s measured, 40 cores).
    pub fn xeon_8380_socket() -> HostSpec {
        HostSpec {
            name: "Xeon-8380-socket",
            mem_bytes: 750 << 30,
            mem_bw: 150e9,
            // AVX-512: 2 FMA ports * 16 f32 * 2 flops * ~2.0 GHz
            core_flops: 128e9,
            n_cores: 40,
        }
    }

    /// This reproduction box (1 core, 35 GB) — used to scale live
    /// measurements up to paper constants.
    pub fn repro_box() -> HostSpec {
        HostSpec {
            name: "repro-box",
            mem_bytes: 35 << 30,
            mem_bw: 10e9,
            core_flops: 20e9,
            n_cores: 1,
        }
    }
}

/// A complete machine: GPU + host + interconnect.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineSpec {
    pub gpu: GpuSpec,
    pub host: HostSpec,
    /// CPU->GPU transfer bandwidth, bytes/s.
    pub pcie_bw: f64,
    /// GPU memory carved out for serving (the paper constrains A40 to
    /// 16-24 GB to emulate T4/L4-class deployments).
    pub gpu_mem_for_serving: u64,
}

impl MachineSpec {
    /// The paper's evaluation machine: A40 + Xeon 8380 socket, measured
    /// PCIe bandwidth 19.5 GB/s (§8.1).
    pub fn paper_testbed() -> MachineSpec {
        MachineSpec {
            gpu: GpuSpec::a40(),
            host: HostSpec::xeon_8380_socket(),
            pcie_bw: 19.5e9,
            gpu_mem_for_serving: 16 << 30,
        }
    }

    /// Nominal PCIe 4.0 x16 configuration used by Table 2 (B = 32 GB/s).
    pub fn nominal(gpu: GpuSpec) -> MachineSpec {
        MachineSpec {
            gpu,
            host: HostSpec::xeon_8380_socket(),
            pcie_bw: 32e9,
            gpu_mem_for_serving: 16 << 30,
        }
    }

    /// Time to stream `bytes` over PCIe (the paper's delta = size / B_IO).
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.pcie_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_lookup() {
        assert_eq!(GpuSpec::by_name("a100").unwrap().name, "A100");
        assert_eq!(GpuSpec::by_name("A40").unwrap().bf16_flops, 150e12);
        assert!(GpuSpec::by_name("H100").is_none());
    }

    #[test]
    fn paper_testbed_constants() {
        let m = MachineSpec::paper_testbed();
        assert_eq!(m.gpu.name, "A40");
        assert_eq!(m.pcie_bw, 19.5e9);
        assert_eq!(m.host.mem_bw, 150e9);
        assert_eq!(m.host.n_cores, 40);
    }

    #[test]
    fn transfer_time_mixtral_weights() {
        // 94 GB over 19.5 GB/s ~ 4.8 s: the paper's ~5 s per-pass weight IO
        // (§8.2 "approximately 5 seconds").
        let m = MachineSpec::paper_testbed();
        let model = crate::config::ModelSpec::mixtral_8x7b();
        let t = m.transfer_secs(model.model_bytes());
        assert!(t > 4.0 && t < 5.5, "t={t}");
    }
}
