//! Configuration: model architectures, hardware profiles, and workload
//! descriptors. The paper-scale entries (Mixtral-8x7B/8x22B, DBRX; A40/L40/
//! A100/T4/L4; MTBench/RAG/AIME) drive the performance model and the
//! hardware simulator; the executable entries (`tiny`, `small`) mirror
//! `python/compile/config.py` and are cross-checked against the AOT
//! manifest at load time.

mod hardware;
mod model;
mod workload;

pub use hardware::{GpuSpec, HostSpec, MachineSpec};
pub use model::ModelSpec;
pub use workload::{WorkloadSpec, MTBENCH, RAG, AIME};
