//! MoE model architecture specs.
//!
//! `ModelSpec` carries the dimensions the performance model (Eqs. 1–14)
//! and the simulator need. The paper-scale entries use the published
//! architectures; `tiny`/`small` mirror `python/compile/config.py` and are
//! actually executed through PJRT.

/// Architecture of a Mixtral-style MoE transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,      // h
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub n_experts: usize,    // N_e
    pub top_k: usize,        // N_k
    pub d_ff: usize,         // h_i
    /// Bytes per weight element (BF16 for the paper models, F32 for the
    /// executable configs — matching what the AOT path exports).
    pub weight_bytes: usize,
    /// Bytes per KV-cache element (BF16, §5.3).
    pub kv_bytes: usize,
}

impl ModelSpec {
    pub const fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub const fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub const fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// `m` in Eq. 1: expert intermediate expansion factor h_i / h.
    pub fn m_ratio(&self) -> f64 {
        self.d_ff as f64 / self.d_model as f64
    }

    /// Total parameter count (embedding + per-layer attn/router/experts +
    /// final norm + LM head).
    pub fn param_count(&self) -> u64 {
        let h = self.d_model as u64;
        let attn = h * self.q_dim() as u64 * 2 + h * self.kv_dim() as u64 * 2;
        let router = h * self.n_experts as u64;
        let experts = 3 * h * self.d_ff as u64 * self.n_experts as u64;
        let norms = 2 * h;
        let per_layer = attn + router + experts + norms;
        let emb = self.vocab as u64 * h;
        emb * 2 + per_layer * self.n_layers as u64 + h
    }

    /// Model size in bytes at `weight_bytes` precision.
    pub fn model_bytes(&self) -> u64 {
        self.param_count() * self.weight_bytes as u64
    }

    /// Per-layer weight bytes (the data mover's transfer granularity).
    pub fn layer_bytes(&self) -> u64 {
        let h = self.d_model as u64;
        let attn = h * self.q_dim() as u64 * 2 + h * self.kv_dim() as u64 * 2;
        let router = h * self.n_experts as u64;
        let experts = 3 * h * self.d_ff as u64 * self.n_experts as u64;
        (attn + router + experts + 2 * h) * self.weight_bytes as u64
    }

    /// Bytes of one expert's FFN weights (w1 + w3 + w2 slices) — the unit
    /// of expert-granular residency and streaming.
    pub fn expert_bytes(&self) -> u64 {
        3 * self.d_model as u64 * self.d_ff as u64 * self.weight_bytes as u64
    }

    /// Per-layer bytes that are *not* expert FFN weights (attention
    /// projections, norms, router) — always streamed, never pinned.
    pub fn layer_dense_bytes(&self) -> u64 {
        self.layer_bytes() - self.n_experts as u64 * self.expert_bytes()
    }

    /// KV-cache bytes per token (both K and V, all layers).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers as u64 * self.kv_dim() as u64 * self.kv_bytes as u64
    }

    /// FLOPs per token for the *activated* GEMM path (dense per-token work:
    /// QKVO projections + top-k experts; 2 FLOPs per MAC).
    pub fn flops_per_token(&self) -> f64 {
        let h = self.d_model as f64;
        let attn = 2.0 * (h * self.q_dim() as f64 * 2.0 + h * self.kv_dim() as f64 * 2.0);
        let experts = 2.0 * 3.0 * h * self.d_ff as f64 * self.top_k as f64;
        (attn + experts) * self.n_layers as f64
    }

    /// All specs (paper-scale + executable).
    pub fn all() -> Vec<ModelSpec> {
        vec![
            Self::mixtral_8x7b(),
            Self::mixtral_8x22b(),
            Self::dbrx(),
            Self::tiny(),
            Self::small(),
        ]
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        Self::all().into_iter().find(|m| m.name == name)
    }

    /// Mixtral-8x7B: 47B params, 94 GB in BF16.
    pub fn mixtral_8x7b() -> ModelSpec {
        ModelSpec {
            name: "mixtral-8x7b",
            vocab: 32_000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            n_experts: 8,
            top_k: 2,
            d_ff: 14_336,
            weight_bytes: 2,
            kv_bytes: 2,
        }
    }

    /// Mixtral-8x22B: 141B params, 282 GB in BF16.
    pub fn mixtral_8x22b() -> ModelSpec {
        ModelSpec {
            name: "mixtral-8x22b",
            vocab: 32_768,
            d_model: 6144,
            n_layers: 56,
            n_heads: 48,
            n_kv_heads: 8,
            head_dim: 128,
            n_experts: 8,
            top_k: 2,
            d_ff: 16_384,
            weight_bytes: 2,
            kv_bytes: 2,
        }
    }

    /// DBRX: 132B params, 264 GB in BF16 (16 experts, top-4).
    pub fn dbrx() -> ModelSpec {
        ModelSpec {
            name: "dbrx",
            vocab: 100_352,
            d_model: 6144,
            n_layers: 40,
            n_heads: 48,
            n_kv_heads: 8,
            head_dim: 128,
            n_experts: 16,
            top_k: 4,
            d_ff: 10_752,
            weight_bytes: 2,
            kv_bytes: 2,
        }
    }

    /// Executable config mirroring python/compile/config.py TINY.
    pub fn tiny() -> ModelSpec {
        ModelSpec {
            name: "tiny",
            vocab: 512,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            n_experts: 4,
            top_k: 2,
            d_ff: 128,
            weight_bytes: 4,
            kv_bytes: 2,
        }
    }

    /// Executable config mirroring python/compile/config.py SMALL.
    pub fn small() -> ModelSpec {
        ModelSpec {
            name: "small",
            vocab: 2048,
            d_model: 256,
            n_layers: 4,
            n_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            n_experts: 8,
            top_k: 2,
            d_ff: 512,
            weight_bytes: 4,
            kv_bytes: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixtral_8x7b_matches_published_size() {
        let m = ModelSpec::mixtral_8x7b();
        let params = m.param_count() as f64;
        assert!((params / 1e9 - 47.0).abs() < 1.0, "params={params:.3e}");
        let gb = m.model_bytes() as f64 / 1e9;
        assert!((gb - 94.0).abs() < 3.0, "size={gb} GB");
    }

    #[test]
    fn mixtral_8x22b_matches_published_size() {
        let m = ModelSpec::mixtral_8x22b();
        assert!((m.param_count() as f64 / 1e9 - 141.0).abs() < 4.0);
    }

    #[test]
    fn dbrx_matches_published_size() {
        let m = ModelSpec::dbrx();
        assert!((m.param_count() as f64 / 1e9 - 132.0).abs() < 4.0);
    }

    #[test]
    fn kv_bytes_per_token_mixtral() {
        // 2 (K+V) * 32 layers * 8 heads * 128 dim * 2 bytes = 131072 B
        let m = ModelSpec::mixtral_8x7b();
        assert_eq!(m.kv_bytes_per_token(), 131_072);
    }

    #[test]
    fn layer_bytes_sum_close_to_model_bytes() {
        let m = ModelSpec::mixtral_8x7b();
        let layers = m.layer_bytes() * m.n_layers as u64;
        let total = m.model_bytes();
        // embedding + head are the only difference
        let emb = 2 * m.vocab as u64 * m.d_model as u64 * m.weight_bytes as u64;
        assert!(layers <= total);
        assert!(total - layers <= emb + 1_000_000);
    }

    #[test]
    fn expert_and_dense_bytes_partition_the_layer() {
        for m in ModelSpec::all() {
            assert_eq!(
                m.layer_dense_bytes() + m.n_experts as u64 * m.expert_bytes(),
                m.layer_bytes(),
                "{}",
                m.name
            );
            assert!(m.layer_dense_bytes() > 0, "{}", m.name);
        }
        // Mixtral-8x7B expert: 3 * 4096 * 14336 * 2 B ≈ 352 MB.
        let e = ModelSpec::mixtral_8x7b().expert_bytes();
        assert_eq!(e, 352_321_536);
    }

    #[test]
    fn gqa_group_sizes() {
        assert_eq!(ModelSpec::mixtral_8x7b().gqa_group(), 4);
        assert_eq!(ModelSpec::dbrx().gqa_group(), 6);
        assert_eq!(ModelSpec::tiny().gqa_group(), 2);
    }

    #[test]
    fn by_name_roundtrip() {
        for m in ModelSpec::all() {
            assert_eq!(ModelSpec::by_name(m.name).unwrap(), m);
        }
        assert!(ModelSpec::by_name("nope").is_none());
    }

    #[test]
    fn flops_per_token_scale() {
        // Mixtral-8x7B activates ~13B params per token -> ~26 GFLOPs/token
        let m = ModelSpec::mixtral_8x7b();
        let gf = m.flops_per_token() / 1e9;
        assert!(gf > 20.0 && gf < 32.0, "{gf} GFLOPs/token");
    }
}
