//! vLLM-like (CPU-offload) policy: all compute on the GPU, weights and
//! the paged KV cache streamed over PCIe (§7, §8.1).
//!
//! "Since model weights and KV cache exceed GPU memory capacity, vLLM is
//! bottlenecked by the limited CPU–GPU PCIe bandwidth": every decode
//! iteration moves one weight sweep *plus* the active sequences' KV
//! contexts across the link; GPU time is negligible by comparison.

use crate::config::{MachineSpec, ModelSpec};
use crate::metrics::{PassRecord, RunReport, Trace};
use crate::simhw::CostModel;

pub struct VllmSim {
    pub machine: MachineSpec,
    pub model: ModelSpec,
    /// CPU-side KV budget, bytes (same budget as the other systems).
    pub kv_bytes: u64,
}

impl VllmSim {
    pub fn new(model: ModelSpec, kv_gb: u64) -> Self {
        VllmSim { machine: MachineSpec::paper_testbed(), model, kv_bytes: kv_gb << 30 }
    }

    /// Decode batch: bounded by what the *GPU* can hold of paged-in KV
    /// working state plus by the CPU-side budget at peak length.
    fn decode_batch(&self, p: usize, g: usize) -> usize {
        let kv_per_seq = (p + g) as u64 * self.model.kv_bytes_per_token();
        let cpu_cap = (self.kv_bytes / kv_per_seq).max(1) as usize;
        // GPU working set: weight buffer for one layer (double-buffered)
        // leaves the rest for paged-in KV of the running batch; vLLM's
        // CPU-offload swaps per layer, needing the batch's per-layer KV
        // resident.
        let gpu_free = self
            .machine
            .gpu_mem_for_serving
            .saturating_sub(2 * self.model.layer_bytes());
        let per_layer_kv =
            (p + g) as u64 * self.model.kv_bytes_per_token() / self.model.n_layers as u64;
        let gpu_cap = (gpu_free / per_layer_kv.max(1)).max(1) as usize;
        cpu_cap.min(gpu_cap)
    }

    pub fn run_uniform(&self, p: usize, g: usize, k: usize) -> (Trace, RunReport) {
        let costs =
            CostModel { machine: &self.machine, model: &self.model, cpu_attn_eff: 1.0 };
        let batch = self.decode_batch(p, g);
        let mut trace = Trace::new(0);
        let mut now = 0.0;
        let mut pass_id = 0;
        let mut remaining = k;

        while remaining > 0 {
            let b = remaining.min(batch);

            // Prefill: weights stream once per sweep; prompt KV is written
            // back to CPU (adds to link traffic).
            let prefill_tokens = b * p;
            let kv_out = prefill_tokens as u64 * self.model.kv_bytes_per_token();
            let io = costs.delta()
                + kv_out as f64 / self.machine.pcie_bw;
            let gpu = costs.gpu_time(prefill_tokens);
            let dur = io.max(gpu);
            now += dur;
            // Exclusive lanes partitioning `dur`: IO books only the link
            // time exposed past the GPU compute it overlaps.
            trace.push(PassRecord {
                pass_id,
                t_end: now,
                duration: dur,
                prefill_tokens,
                io_time: (io - gpu).max(0.0),
                gpu_time: gpu,
                ..Default::default()
            });
            pass_id += 1;

            // Decode: per iteration, weights + the whole active context
            // page in over PCIe (attention is on the GPU).
            for step in 0..g {
                let ctx = p + step;
                let kv_in = (b * ctx) as u64 * self.model.kv_bytes_per_token();
                let io = costs.delta() + kv_in as f64 / self.machine.pcie_bw;
                let gpu = costs.gpu_time(b);
                let dur = io.max(gpu);
                now += dur;
                trace.push(PassRecord {
                    pass_id,
                    t_end: now,
                    duration: dur,
                    decode_tokens: b,
                    generated: b,
                    finished: if step + 1 == g { b } else { 0 },
                    io_time: (io - gpu).max(0.0),
                    gpu_time: gpu,
                    active_decode: b,
                    ..Default::default()
                });
                pass_id += 1;
            }
            remaining -= b;
        }
        let report = RunReport::from_trace(&trace, k);
        (trace, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::MoeLightningSim;

    #[test]
    fn completes_all_requests() {
        let (trace, r) = VllmSim::new(ModelSpec::mixtral_8x7b(), 70).run_uniform(98, 32, 500);
        assert_eq!(r.requests, 500);
        assert_eq!(r.generated_tokens, 500 * 32);
        // The exclusive-lane contract holds for baseline traces too.
        for p in &trace.passes {
            assert!(
                (p.lanes_total() - p.duration).abs() < 1e-9,
                "pass {}: lanes {} vs duration {}",
                p.pass_id,
                p.lanes_total(),
                p.duration
            );
        }
    }

    #[test]
    fn vllm_is_the_slowest_system() {
        // Fig. 11: vLLM < MoE-Lightning < MoE-Lens everywhere.
        let (_, v) = VllmSim::new(ModelSpec::mixtral_8x7b(), 70).run_uniform(98, 64, 500);
        let (_, l) =
            MoeLightningSim::new(ModelSpec::mixtral_8x7b(), 70).run_uniform(98, 64, 500);
        assert!(
            v.generation_throughput < l.generation_throughput,
            "vllm {} vs lightning {}",
            v.generation_throughput,
            l.generation_throughput
        );
    }

    #[test]
    fn io_dominates_every_decode_pass() {
        // With exclusive lanes, "IO binds" means the pass has exposed IO:
        // the link time sticks out past the GPU compute it overlaps.
        let (trace, _) =
            VllmSim::new(ModelSpec::mixtral_8x7b(), 70).run_uniform(98, 32, 200);
        for p in trace.passes.iter().filter(|p| p.decode_tokens > 0) {
            assert!(p.io_time > 0.0, "pass {}: IO must bind", p.pass_id);
            assert!(
                (p.io_time + p.gpu_time - p.duration).abs() < 1e-9,
                "pass {}: duration is the IO sweep",
                p.pass_id
            );
        }
    }
}
