//! Baseline serving policies (§7): MoE-Lightning-like and vLLM-like,
//! replayed on the same simulated machine and cost model as MoE-Lens.
//!
//! Fig. 11/12 compare *policies* under identical hardware constants
//! (DESIGN.md §1): the baselines' handicaps are structural —
//!
//! * **MoE-Lightning**: HRM-planned batches that underutilize CPU memory
//!   (Table 1), strict prefill/decode phase separation (no overlap, so no
//!   Eq.-7 KV amplification and idle IO during prefill / idle GPU during
//!   decode), and the auto-vectorized CPU attention kernel (Fig. 10's
//!   1/3.1 efficiency).
//! * **vLLM (CPU-offload)**: all compute on the GPU; model weights *and*
//!   the active KV cache stream over PCIe every iteration, so the link
//!   is the only lane that matters.

mod moe_lightning;
mod vllm;

pub use moe_lightning::MoeLightningSim;
pub use vllm::VllmSim;
