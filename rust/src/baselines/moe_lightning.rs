//! MoE-Lightning-like policy: HRM-planned, two-phase (no prefill/decode
//! overlap), CPU attention at auto-vectorized efficiency.

use crate::config::{MachineSpec, ModelSpec};
use crate::metrics::{PassRecord, RunReport, Trace};
use crate::perfmodel::hrm::HrmModel;
use crate::simhw::CostModel;

/// Fig.-10: the auto-vectorized kernel reaches ~1/3.1 of the optimized
/// kernel's full-thread throughput.
pub const AUTOVEC_CPU_ATTN_EFF: f64 = 0.8 / 3.1;

/// The baseline simulator.
pub struct MoeLightningSim {
    pub machine: MachineSpec,
    pub model: ModelSpec,
    /// CPU memory available for weights + KV (its §7 "memory profile").
    pub cpu_mem_bytes: u64,
    pub hrm: HrmModel,
    /// Attention-kernel efficiency of the baseline (default: the
    /// auto-vectorized 1/3.1 of Fig. 10; ablations override it).
    pub cpu_attn_eff: f64,
}

impl MoeLightningSim {
    pub fn new(model: ModelSpec, kv_gb: u64) -> Self {
        let machine = MachineSpec::paper_testbed();
        // §7: profile = model size + KV size + 30 GB overhead.
        let cpu_mem_bytes = model.model_bytes() + (kv_gb << 30) + (30 << 30);
        let mut hrm = HrmModel::new(machine.clone(), model.clone());
        hrm.cpu_attn_efficiency = AUTOVEC_CPU_ATTN_EFF;
        MoeLightningSim { machine, model, cpu_mem_bytes, hrm, cpu_attn_eff: AUTOVEC_CPU_ATTN_EFF }
    }

    /// The plan the baseline runs: the artifact's published plan when one
    /// exists for (p, g), else the HRM roofline plan.
    fn decode_batch(&self, p: usize, g: usize) -> usize {
        let kv_budget = self.cpu_mem_bytes - self.model.model_bytes() - (30 << 30);
        let plan = self
            .hrm
            .artifact_plan(p, g)
            .unwrap_or_else(|| self.hrm.plan(p, g, self.cpu_mem_bytes));
        // Never exceed what the KV region can actually hold at peak.
        let cap = (kv_budget / ((p + g) as u64 * self.model.kv_bytes_per_token()))
            .max(1) as usize;
        plan.decode_seqs.min(cap).max(1)
    }

    /// Run `k` uniform (p, g) requests through the two-phase schedule.
    /// Returns the trace on the virtual clock.
    pub fn run_uniform(&self, p: usize, g: usize, k: usize) -> (Trace, RunReport) {
        let costs = CostModel {
            machine: &self.machine,
            model: &self.model,
            cpu_attn_eff: self.cpu_attn_eff,
        };
        let gbs = self.decode_batch(p, g);
        let mut trace = Trace::new(0);
        let mut now = 0.0;
        let mut pass_id = 0;
        let mut remaining = k;

        while remaining > 0 {
            let batch = remaining.min(gbs);

            // --- Prefill phase: GPU-bound micro-batches; the weight sweep
            // streams once per full-model pass over the batch. IO and GPU
            // are pipelined *within* the phase, but decode is NOT running,
            // so the CPU-attention lane idles (§3.2, Fig. 1).
            let prefill_tokens = batch * p;
            let gpu = costs.gpu_time(prefill_tokens);
            // Every full-model pass needs one δ sweep; a compute-saturated
            // prefill amortizes it entirely, a small batch pays δ.
            let dur = costs.delta().max(gpu);
            now += dur;
            // Exclusive lanes (they partition `dur`): the IO lane books
            // only the sweep time *exposed* past the GPU compute it
            // pipelines with; the CPU-attention lane idles all phase.
            trace.push(PassRecord {
                pass_id,
                t_end: now,
                duration: dur,
                prefill_tokens,
                decode_tokens: 0,
                io_time: (costs.delta() - gpu).max(0.0),
                gpu_time: gpu,
                cpu_time: 0.0,
                active_decode: 0,
                ..Default::default()
            });
            pass_id += 1;

            // --- Decode phase: g iterations; each sweeps the weights while
            // the slow CPU attention scans every sequence's context. No
            // prefill refills the batch as sequences finish (§3.2: GPU
            // utilization collapses to ~16.5%).
            for step in 0..g {
                let ctx = p + step;
                let kv_tokens = (batch * ctx) as u64;
                let lanes = costs.overlapped_iter(batch, kv_tokens);
                // Without VSLPipe's compute-graph regrouping (§6.4), each
                // layer's CPU attention serializes between GPU task A and
                // task B: the attention lane sits ON the critical path
                // rather than overlapping the next partition's GEMMs
                // (Fig. 1's idle gaps).
                let dur = lanes.io_contended.max(lanes.gpu) + lanes.cpu;
                now += dur;
                let finished = if step + 1 == g { batch } else { 0 };
                // Exclusive lanes partitioning `dur`: IO books only the
                // contended sweep exposed past the GPU GEMMs it pipelines
                // with; the serialized CPU attention is its own span (it
                // sits on the critical path, so overlap_time stays 0 —
                // exactly the §6.4 overlap this baseline lacks).
                trace.push(PassRecord {
                    pass_id,
                    t_end: now,
                    duration: dur,
                    prefill_tokens: 0,
                    decode_tokens: batch,
                    generated: batch,
                    finished,
                    io_time: (lanes.io_contended - lanes.gpu).max(0.0),
                    gpu_time: lanes.gpu,
                    cpu_time: lanes.cpu,
                    active_decode: batch,
                    ..Default::default()
                });
                pass_id += 1;
            }
            remaining -= batch;
        }
        let report = RunReport::from_trace(&trace, k);
        (trace, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simhw::{run_uniform as lens_run, SimConfig};

    fn sim(kv_gb: u64) -> MoeLightningSim {
        MoeLightningSim::new(ModelSpec::mixtral_8x7b(), kv_gb)
    }

    #[test]
    fn completes_all_requests() {
        let (trace, report) = sim(70).run_uniform(98, 32, 5000);
        assert_eq!(report.requests, 5000);
        assert_eq!(report.generated_tokens, 5000 * 32);
        // The exclusive-lane contract holds for baseline traces too.
        for p in &trace.passes {
            assert!(
                (p.lanes_total() - p.duration).abs() < 1e-9,
                "pass {}: lanes {} vs duration {}",
                p.pass_id,
                p.lanes_total(),
                p.duration
            );
        }
    }

    #[test]
    fn moe_lens_beats_moe_lightning() {
        // Fig. 11's headline shape: MoE-Lens wins everywhere, and by more
        // at a larger KV cache (paper: 3.2x avg at 70 GB, 6.4x at 210 GB).
        // K must be large enough to leave the pipeline-fill regime.
        let mut speedups = Vec::new();
        for kv_gb in [70u64, 210] {
            let k = 10_000usize;
            let (_, light) = sim(kv_gb).run_uniform(98, 64, k);
            let (_, lens) = lens_run(
                SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), kv_gb),
                98,
                64,
                k,
            );
            let speedup = lens.generation_throughput / light.generation_throughput;
            assert!(
                speedup > 1.1,
                "kv={kv_gb}GB: lens {} vs lightning {} (x{speedup:.2})",
                lens.generation_throughput,
                light.generation_throughput
            );
            speedups.push(speedup);
        }
        assert!(
            speedups[1] > speedups[0],
            "larger KV must widen the gap: {speedups:?}"
        );
    }

    #[test]
    fn decode_phase_gpu_utilization_is_low() {
        // §3.2: "GPU utilization drops to 16.5% during decode".
        let (trace, _) = sim(70).run_uniform(98, 32, 3000);
        let decode_passes: Vec<_> =
            trace.passes.iter().filter(|p| p.decode_tokens > 0).collect();
        let util: f64 = decode_passes.iter().map(|p| p.gpu_time / p.duration).sum::<f64>()
            / decode_passes.len() as f64;
        assert!(util < 0.5, "decode GPU util {util} should be far from 1");
    }

    #[test]
    fn artifact_plans_drive_table1_rows() {
        // With enough CPU memory the artifact plan is used verbatim...
        let s = sim(141); // Table 1's machine: 265 GB total - 94 - 30
        assert_eq!(s.decode_batch(98, 32), 4840);
        // ...a smaller KV region clamps it at peak-length capacity...
        let tight = sim(70);
        let cap = (70u64 << 30) / (130 * ModelSpec::mixtral_8x7b().kv_bytes_per_token());
        assert_eq!(tight.decode_batch(98, 32), cap as usize);
        // ...and unknown configs fall back to the HRM roofline plan.
        assert!(s.decode_batch(64, 48) > 0);
    }
}
