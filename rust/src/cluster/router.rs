//! The request-routing seam: how new arrivals pick a replica.
//!
//! The cluster driver computes a [`ReplicaView`] snapshot of every
//! *admitting* replica at each routing decision and asks a boxed
//! [`Router`] to pick one. Four policies ship: round-robin (the
//! baseline), join-shortest-queue, power-of-two-choices (seeded, so runs
//! are reproducible), and deadline-aware (ranks replicas by predicted
//! start time plus service-model backlog, discounted by the health
//! layer's suspicion score). All are deterministic for a fixed seed.

use crate::model::Request;
use crate::util::Rng;

/// Snapshot of one admitting replica at a routing decision.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// Replica index in the cluster.
    pub index: usize,
    /// The replica's local virtual clock (its last pass boundary).
    pub now: f64,
    /// Requests waiting in its prefill queue.
    pub queued: usize,
    /// Sequences in its decode set.
    pub active_decode: usize,
    /// Predicted seconds of live work (queue + decode set) under the
    /// replica's service model.
    pub backlog_secs: f64,
    /// Health-layer suspicion (≥ 1.0): recent-vs-norm pass duration.
    pub suspicion: f64,
}

impl ReplicaView {
    /// Queue depth in requests — the JSQ / power-of-two ranking key.
    pub fn depth(&self) -> usize {
        self.queued + self.active_decode
    }
}

/// A routing policy. `candidates` holds only admitting replicas and is
/// never empty (the driver handles the no-survivor case before routing);
/// the return value is the chosen candidate's [`ReplicaView::index`].
pub trait Router {
    fn route(&mut self, req: &Request, now: f64, candidates: &[ReplicaView]) -> usize;
}

/// Cycle through the candidates in order, ignoring load.
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin { next: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        RoundRobin::new()
    }
}

impl Router for RoundRobin {
    fn route(&mut self, _req: &Request, _now: f64, candidates: &[ReplicaView]) -> usize {
        let v = &candidates[self.next % candidates.len()];
        self.next = self.next.wrapping_add(1);
        v.index
    }
}

/// Send each request to the replica with the fewest live requests
/// (queue + decode set); ties break to the lowest replica index.
pub struct JoinShortestQueue;

impl Router for JoinShortestQueue {
    fn route(&mut self, _req: &Request, _now: f64, candidates: &[ReplicaView]) -> usize {
        candidates
            .iter()
            .min_by_key(|v| (v.depth(), v.index))
            .expect("route() requires at least one candidate")
            .index
    }
}

/// Power-of-two-choices: sample two candidates uniformly (seeded) and
/// keep the shallower one — most of JSQ's balance at O(1) inspection
/// cost, the classic two-choices result.
pub struct PowerOfTwoChoices {
    rng: Rng,
}

impl PowerOfTwoChoices {
    pub fn new(seed: u64) -> Self {
        PowerOfTwoChoices { rng: Rng::new(seed) }
    }
}

impl Router for PowerOfTwoChoices {
    fn route(&mut self, _req: &Request, _now: f64, candidates: &[ReplicaView]) -> usize {
        let a = self.rng.range(0, candidates.len() - 1);
        let b = self.rng.range(0, candidates.len() - 1);
        let pick = if (candidates[b].depth(), candidates[b].index)
            < (candidates[a].depth(), candidates[a].index)
        {
            b
        } else {
            a
        };
        candidates[pick].index
    }
}

/// Deadline-aware: rank replicas by when they would plausibly *finish*
/// the new request — local clock (a stale clock means the replica is
/// idle and can start immediately) plus its service-model backlog,
/// stretched by the health layer's suspicion so a degraded replica's
/// queue is priced at its observed (not nominal) drain rate. The
/// request's own predicted service time is identical on identical
/// replicas, so it cancels out of the ranking and is omitted.
pub struct DeadlineAware;

impl Router for DeadlineAware {
    fn route(&mut self, _req: &Request, now: f64, candidates: &[ReplicaView]) -> usize {
        let score = |v: &ReplicaView| v.now.max(now) + v.backlog_secs * v.suspicion;
        candidates
            .iter()
            .min_by(|a, b| {
                score(a)
                    .partial_cmp(&score(b))
                    .expect("finite routing scores")
                    .then_with(|| a.index.cmp(&b.index))
            })
            .expect("route() requires at least one candidate")
            .index
    }
}

/// Constructible router policy — the CLI / config surface of the seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    Jsq,
    P2c { seed: u64 },
    Deadline,
}

/// Default seed for `p2c` when the CLI does not provide one.
pub const DEFAULT_P2C_SEED: u64 = 0x2C01;

impl RouterPolicy {
    /// Parse a CLI name (`rr` | `jsq` | `p2c` | `deadline`).
    pub fn parse(s: &str) -> Result<RouterPolicy, String> {
        match s {
            "rr" | "round-robin" => Ok(RouterPolicy::RoundRobin),
            "jsq" => Ok(RouterPolicy::Jsq),
            "p2c" => Ok(RouterPolicy::P2c { seed: DEFAULT_P2C_SEED }),
            "deadline" => Ok(RouterPolicy::Deadline),
            other => Err(format!(
                "unknown router policy '{other}' (expected rr | jsq | p2c | deadline)"
            )),
        }
    }

    pub fn build(self) -> Box<dyn Router> {
        match self {
            RouterPolicy::RoundRobin => Box::new(RoundRobin::new()),
            RouterPolicy::Jsq => Box::new(JoinShortestQueue),
            RouterPolicy::P2c { seed } => Box::new(PowerOfTwoChoices::new(seed)),
            RouterPolicy::Deadline => Box::new(DeadlineAware),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(index: usize, depth: usize, backlog: f64) -> ReplicaView {
        ReplicaView {
            index,
            now: 0.0,
            queued: depth,
            active_decode: 0,
            backlog_secs: backlog,
            suspicion: 1.0,
        }
    }

    fn req() -> Request {
        Request::new(0, vec![1; 8], 4)
    }

    #[test]
    fn round_robin_cycles_over_candidates() {
        let mut r = RoundRobin::new();
        let c = [view(0, 0, 0.0), view(1, 0, 0.0), view(2, 0, 0.0)];
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), 0.0, &c)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_the_shallowest_with_index_ties() {
        let mut r = JoinShortestQueue;
        let c = [view(0, 5, 0.0), view(1, 2, 0.0), view(2, 2, 0.0)];
        assert_eq!(r.route(&req(), 0.0, &c), 1);
    }

    #[test]
    fn p2c_is_seed_deterministic_and_never_picks_the_deeper_of_its_pair() {
        let c = [view(0, 9, 0.0), view(1, 1, 0.0), view(2, 5, 0.0)];
        let picks_a: Vec<usize> =
            (0..32).map(|_| PowerOfTwoChoices::new(7).route(&req(), 0.0, &c)).collect();
        let mut r1 = PowerOfTwoChoices::new(7);
        let mut r2 = PowerOfTwoChoices::new(7);
        for _ in 0..32 {
            assert_eq!(r1.route(&req(), 0.0, &c), r2.route(&req(), 0.0, &c));
        }
        // A fresh router's first pick can never be the strictly deepest
        // replica unless both samples landed on it; over 32 independent
        // first-picks at least one must avoid index 0.
        assert!(picks_a.iter().any(|&p| p != 0));
    }

    #[test]
    fn deadline_prefers_the_earliest_predicted_start() {
        let mut r = DeadlineAware;
        // Replica 0 is idle but buried; replica 1 has a short backlog.
        let c = [view(0, 8, 40.0), view(1, 2, 10.0)];
        assert_eq!(r.route(&req(), 5.0, &c), 1);
    }

    #[test]
    fn deadline_discounts_a_suspicious_replica() {
        let mut r = DeadlineAware;
        let mut slow = view(0, 2, 10.0);
        slow.suspicion = 3.0; // recent passes run 3x its norm
        let healthy = view(1, 2, 20.0);
        // Nominal backlogs favor replica 0 (10 s < 20 s), but suspicion
        // prices its queue at 30 s of observed drain time.
        assert_eq!(r.route(&req(), 0.0, &[slow, healthy]), 1);
    }

    #[test]
    fn policy_parse_round_trips_and_rejects_unknown_names() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(RouterPolicy::parse("jsq").unwrap(), RouterPolicy::Jsq);
        assert_eq!(
            RouterPolicy::parse("p2c").unwrap(),
            RouterPolicy::P2c { seed: DEFAULT_P2C_SEED }
        );
        assert_eq!(RouterPolicy::parse("deadline").unwrap(), RouterPolicy::Deadline);
        assert!(RouterPolicy::parse("random").is_err());
    }
}
