//! Deterministic fault injection for the cluster simulator.
//!
//! A [`FaultPlan`] is a time-sorted list of per-replica events — crash,
//! drain, or transient slowdown — applied at pass boundaries of the
//! target replica's local virtual clock (a fault can't land mid-pass any
//! more than a real signal can interrupt a CUDA graph launch; the
//! simulator's passes are atomic). Plans come from three constructors:
//! explicit events ([`new`](FaultPlan::new)), a CLI spec string
//! ([`parse`](FaultPlan::parse)), or a seeded generator
//! ([`random`](FaultPlan::random)) for randomized-but-reproducible
//! recovery tests. The empty plan ([`none`](FaultPlan::none)) is the
//! default everywhere and leaves the cluster's behavior f64-identical to
//! fault-free serving.

use std::collections::VecDeque;

use crate::util::cast::usize_f64;
use crate::util::Rng;

/// What happens to the target replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The replica dies at the next pass boundary: queued and in-flight
    /// requests are extracted and handed to the recovery machinery
    /// (in-flight sequences lose their KV and replay like preemption
    /// victims); the replica never executes or admits again.
    Crash,
    /// Planned maintenance: stop admitting, finish in-flight work.
    /// Nothing is lost or re-routed.
    Drain,
    /// Transient degradation: passes starting in `[at_secs, until_secs)`
    /// have every execution lane stretched by `factor` (≥ 1), modelling
    /// e.g. a memory-bandwidth or thermal throttle. Overlapping windows
    /// take the worst factor.
    Slow { until_secs: f64, factor: f64 },
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Virtual time the fault takes effect (at the target's next pass
    /// boundary at or after this).
    pub at_secs: f64,
    /// Target replica index.
    pub replica: usize,
    pub kind: FaultKind,
}

/// A validated, time-sorted fault schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: no faults, cluster behavior f64-identical to
    /// fault-free serving.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// Build a plan from explicit events. Panics on non-finite times,
    /// slowdown factors < 1, or inverted slow windows — a malformed plan
    /// is programmer error, not data. Events are stably sorted by
    /// (time, replica) so application order is deterministic.
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        for ev in &events {
            assert!(ev.at_secs.is_finite() && ev.at_secs >= 0.0, "fault time must be finite and non-negative");
            if let FaultKind::Slow { until_secs, factor } = ev.kind {
                assert!(until_secs.is_finite() && until_secs >= ev.at_secs, "slow window must end at or after it starts");
                assert!(factor.is_finite() && factor >= 1.0, "slowdown factor must be >= 1 (a speedup is not a fault)");
            }
        }
        events.sort_by(|a, b| {
            a.at_secs
                .partial_cmp(&b.at_secs)
                .expect("finite fault times")
                .then_with(|| a.replica.cmp(&b.replica))
        });
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Split into per-replica queues (each time-sorted, inheriting the
    /// plan's global order). Panics if an event targets a replica index
    /// outside `0..n`.
    pub fn split(&self, n: usize) -> Vec<VecDeque<FaultEvent>> {
        let mut qs: Vec<VecDeque<FaultEvent>> = (0..n).map(|_| VecDeque::new()).collect();
        for ev in &self.events {
            assert!(
                ev.replica < n,
                "fault event targets replica {} but the cluster has {n} replicas",
                ev.replica
            );
            qs[ev.replica].push_back(*ev);
        }
        qs
    }

    /// Parse a comma-separated CLI spec. Grammar per event:
    ///
    /// * `crash@T:rI`    — crash replica I at time T
    /// * `drain@T:rI`    — drain replica I at time T
    /// * `slow@T+D*F:rI` — slow replica I by factor F for D seconds from T
    ///
    /// e.g. `crash@12.5:r0,slow@5+10*2:r2`. An empty string or `none`
    /// yields the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::none());
        }
        let mut events = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let (head, rep) = part
                .rsplit_once(":r")
                .ok_or_else(|| format!("fault '{part}': expected '<kind>@<time>:r<replica>'"))?;
            let replica: usize = rep
                .parse()
                .map_err(|_| format!("fault '{part}': bad replica index '{rep}'"))?;
            let (kind, time) = head
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}': expected '<kind>@<time>'"))?;
            let num = |s: &str, what: &str| -> Result<f64, String> {
                let v: f64 = s
                    .parse()
                    .map_err(|_| format!("fault '{part}': bad {what} '{s}'"))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("fault '{part}': {what} must be finite and non-negative"));
                }
                Ok(v)
            };
            let ev = match kind {
                "crash" => FaultEvent { at_secs: num(time, "time")?, replica, kind: FaultKind::Crash },
                "drain" => FaultEvent { at_secs: num(time, "time")?, replica, kind: FaultKind::Drain },
                "slow" => {
                    let (t, rest) = time.split_once('+').ok_or_else(|| {
                        format!("fault '{part}': slow wants '<time>+<duration>*<factor>'")
                    })?;
                    let (d, f) = rest.split_once('*').ok_or_else(|| {
                        format!("fault '{part}': slow wants '<time>+<duration>*<factor>'")
                    })?;
                    let at = num(t, "time")?;
                    let dur = num(d, "duration")?;
                    let factor = num(f, "factor")?;
                    if factor < 1.0 {
                        return Err(format!(
                            "fault '{part}': slowdown factor must be >= 1 (a speedup is not a fault)"
                        ));
                    }
                    FaultEvent {
                        at_secs: at,
                        replica,
                        kind: FaultKind::Slow { until_secs: at + dur, factor },
                    }
                }
                other => {
                    return Err(format!(
                        "fault '{part}': unknown kind '{other}' (expected crash | drain | slow)"
                    ))
                }
            };
            events.push(ev);
        }
        Ok(FaultPlan::new(events))
    }

    /// Seeded random plan for randomized-but-reproducible recovery tests:
    /// one event per replica in `1..replicas` (replica 0 is always left
    /// untouched so the cluster keeps a guaranteed survivor), each
    /// landing uniformly in the middle 10–90% of `horizon_secs`.
    pub fn random(replicas: usize, horizon_secs: f64, seed: u64) -> FaultPlan {
        assert!(horizon_secs > 0.0 && horizon_secs.is_finite(), "fault horizon must be positive and finite");
        let mut rng = Rng::new(seed ^ 0xFA17_FA17);
        let mut events = Vec::new();
        for replica in 1..replicas {
            let at_secs = horizon_secs * usize_f64(rng.range(10, 90)) / 100.0;
            let kind = match rng.below(3) {
                0 => FaultKind::Crash,
                1 => FaultKind::Drain,
                _ => FaultKind::Slow {
                    until_secs: at_secs + horizon_secs / 4.0,
                    factor: 1.0 + usize_f64(rng.range(1, 3)) * 0.5,
                },
            };
            events.push(FaultEvent { at_secs, replica, kind });
        }
        FaultPlan::new(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_reads_all_three_kinds_and_sorts_by_time() {
        let plan = FaultPlan::parse("crash@12.5:r0,drain@30:r1,slow@5+10*2:r2").unwrap();
        let evs = plan.events();
        assert_eq!(evs.len(), 3);
        // Sorted by time: slow@5, crash@12.5, drain@30.
        assert_eq!(evs[0].replica, 2);
        assert_eq!(
            evs[0].kind,
            FaultKind::Slow { until_secs: 15.0, factor: 2.0 }
        );
        assert_eq!(evs[1].replica, 0);
        assert_eq!(evs[1].kind, FaultKind::Crash);
        assert!((evs[1].at_secs - 12.5).abs() < 1e-12);
        assert_eq!(evs[2].replica, 1);
        assert_eq!(evs[2].kind, FaultKind::Drain);
    }

    #[test]
    fn parse_accepts_empty_and_none_as_the_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        assert!(FaultPlan::parse("  none  ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("reboot@5:r0").is_err(), "unknown kind");
        assert!(FaultPlan::parse("crash@5").is_err(), "missing replica");
        assert!(FaultPlan::parse("crash@abc:r0").is_err(), "bad time");
        assert!(FaultPlan::parse("crash@inf:r0").is_err(), "non-finite time");
        assert!(FaultPlan::parse("slow@5:r0").is_err(), "slow without window");
        assert!(FaultPlan::parse("slow@5+10*0.5:r0").is_err(), "speedup factor");
        assert!(FaultPlan::parse("crash@5:rx").is_err(), "bad replica index");
    }

    #[test]
    fn split_routes_events_to_their_replica_in_time_order() {
        let plan =
            FaultPlan::parse("drain@30:r1,crash@12.5:r1,slow@5+1*2:r0").unwrap();
        let qs = plan.split(2);
        assert_eq!(qs[0].len(), 1);
        assert_eq!(qs[1].len(), 2);
        assert!(qs[1][0].at_secs < qs[1][1].at_secs, "per-replica queues stay sorted");
    }

    #[test]
    #[should_panic(expected = "targets replica")]
    fn split_rejects_out_of_range_replicas() {
        FaultPlan::parse("crash@5:r3").unwrap().split(2);
    }

    #[test]
    fn random_plans_are_seed_deterministic_and_spare_replica_zero() {
        let a = FaultPlan::random(4, 100.0, 9);
        let b = FaultPlan::random(4, 100.0, 9);
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::random(4, 100.0, 10));
        assert_eq!(a.events().len(), 3);
        for ev in a.events() {
            assert!(ev.replica >= 1, "replica 0 must survive a random plan");
            assert!(ev.at_secs >= 10.0 && ev.at_secs <= 90.0);
        }
    }
}
