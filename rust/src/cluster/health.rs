//! Per-replica health tracking from pass telemetry.
//!
//! The cluster has no out-of-band failure detector: everything it knows
//! about a replica comes from the fault plan's explicit state flips
//! (crash/drain) and from the pass durations the replica itself reports.
//! A dual-rate EWMA over pass duration turns the latter into a
//! *suspicion* score — "how much slower is this replica running right now
//! than its own long-run norm" — which the deadline-aware router uses to
//! discount a degraded replica's capacity before the degradation shows up
//! in its queue depth.

/// Lifecycle state of a replica as the cluster sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Serving and admitting new requests.
    Up,
    /// Finishing in-flight work but admitting nothing new (planned
    /// maintenance). A draining replica loses no requests: it keeps
    /// executing passes until its scheduler drains.
    Draining,
    /// Dead. Its queued and in-flight requests were extracted at the
    /// crash boundary and handed to the recovery machinery; it executes
    /// no further passes and is never routed to again.
    Crashed,
}

/// Smoothing factor of the fast (recent-window) pass-duration EWMA.
pub const FAST_ALPHA: f64 = 0.5;
/// Smoothing factor of the slow (long-run norm) pass-duration EWMA.
pub const SLOW_ALPHA: f64 = 0.05;

/// Health view of one replica: lifecycle state plus the dual-rate pass
/// duration EWMA behind [`suspicion`](Self::suspicion).
#[derive(Debug, Clone)]
pub struct ReplicaHealth {
    pub state: ReplicaState,
    fast: f64,
    slow: f64,
    seen: bool,
}

impl ReplicaHealth {
    pub fn new() -> Self {
        ReplicaHealth { state: ReplicaState::Up, fast: 0.0, slow: 0.0, seen: false }
    }

    /// Whether the router may send new requests here.
    pub fn admitting(&self) -> bool {
        self.state == ReplicaState::Up
    }

    /// Feed one observed pass duration (virtual seconds) into both EWMAs.
    /// The first observation seeds both rails so `suspicion` starts at
    /// exactly 1.0 instead of diverging off a zero denominator.
    pub fn observe_pass(&mut self, dur: f64) {
        if !self.seen {
            self.fast = dur;
            self.slow = dur;
            self.seen = true;
            return;
        }
        self.fast += FAST_ALPHA * (dur - self.fast);
        self.slow += SLOW_ALPHA * (dur - self.slow);
    }

    /// Recent-vs-norm pass duration ratio, clamped to ≥ 1.0: a healthy
    /// replica (or one with no passes yet) scores exactly 1.0, and a
    /// replica whose recent passes run k× its long-run norm scores ~k.
    /// The clamp means a replica is never rewarded for a *fast* recent
    /// window — suspicion only ever discounts capacity.
    pub fn suspicion(&self) -> f64 {
        if !self.seen || self.slow <= 0.0 {
            return 1.0;
        }
        (self.fast / self.slow).max(1.0)
    }
}

impl Default for ReplicaHealth {
    fn default() -> Self {
        ReplicaHealth::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_replica_is_up_and_unsuspicious() {
        let h = ReplicaHealth::new();
        assert!(h.admitting());
        assert!((h.suspicion() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn steady_passes_keep_suspicion_at_one() {
        let mut h = ReplicaHealth::new();
        for _ in 0..50 {
            h.observe_pass(2.0);
        }
        assert!((h.suspicion() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sudden_slowdown_raises_suspicion_quickly() {
        let mut h = ReplicaHealth::new();
        for _ in 0..50 {
            h.observe_pass(2.0);
        }
        // Three slow passes: the fast rail chases 6.0 while the slow rail
        // barely moves, so the ratio approaches the 3x degradation.
        for _ in 0..3 {
            h.observe_pass(6.0);
        }
        let s = h.suspicion();
        assert!(s > 2.0, "suspicion {s} should reflect the 3x slowdown");
        assert!(s < 3.5, "suspicion {s} cannot exceed the degradation by much");
    }

    #[test]
    fn suspicion_never_drops_below_one() {
        let mut h = ReplicaHealth::new();
        for _ in 0..50 {
            h.observe_pass(4.0);
        }
        // A recent *fast* window must not produce suspicion < 1 (that
        // would let the router over-commit a briefly idle replica).
        for _ in 0..5 {
            h.observe_pass(1.0);
        }
        assert!((h.suspicion() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn draining_and_crashed_stop_admission() {
        let mut h = ReplicaHealth::new();
        h.state = ReplicaState::Draining;
        assert!(!h.admitting());
        h.state = ReplicaState::Crashed;
        assert!(!h.admitting());
    }
}
