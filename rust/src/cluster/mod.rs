//! Fault-tolerant multi-replica serving on the virtual clock.
//!
//! N identical [`SimMachine`] replicas run on replica-local virtual
//! clocks under one discrete-event driver. A pluggable [`Router`] seam
//! assigns each arrival to an admitting replica; a deterministic
//! [`FaultPlan`] injects crashes, drains, and transient slowdowns at
//! pass boundaries; and the recovery machinery re-routes a crashed
//! replica's stranded requests to survivors — queued requests move with
//! no work lost, in-flight sequences lose their KV and replay like
//! preemption victims (priced by the §8.2-contended re-prefill cost the
//! weighted victim policy uses). Per-replica [`RequestTracker`]s roll up
//! into one cluster-level latency view with rerouted / replayed / failed
//! counters.
//!
//! Two invariants anchor the design, both asserted in every run:
//!
//! * **Identity** — a 1-replica cluster with the empty fault plan drives
//!   the same stepping primitives as [`SimMachine`]'s own serving loop in
//!   the same order, so its trace is f64-identical to single-machine
//!   serving.
//! * **Conservation** — every admitted request resolves exactly once:
//!   finished, rejected, expired, or failed. Crashes move requests
//!   around; they never silently lose them.

use std::collections::{BTreeMap, VecDeque};

use crate::kvcache::SeqId;
use crate::metrics::{LatencyStats, RequestTracker, RunReport, Trace};
use crate::model::{Request, Sequence};
use crate::sched::DropReason;
use crate::simhw::{PassState, SimConfig, SimMachine};
use crate::util::cast::usize_f64;
use crate::workload::duplicate_id;

pub mod faults;
pub mod health;
pub mod router;

pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use health::{ReplicaHealth, ReplicaState};
pub use router::{ReplicaView, Router, RouterPolicy};

/// Cluster deployment: N identical replicas plus the fault-tolerance
/// knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-replica deployment (replicas are identical machines).
    pub replica: SimConfig,
    pub replicas: usize,
    pub router: RouterPolicy,
    pub faults: FaultPlan,
    /// How many times a crash casualty may be re-enqueued before the
    /// cluster gives up on it (0 = no failover: casualties fail at the
    /// first crash).
    pub max_retries: usize,
    /// Linear re-route backoff: attempt k is re-enqueued k × this many
    /// virtual seconds after the crash boundary.
    pub backoff_secs: f64,
}

impl ClusterConfig {
    pub fn new(replica: SimConfig, replicas: usize) -> Self {
        assert!(replicas >= 1, "a cluster needs at least one replica");
        ClusterConfig {
            replica,
            replicas,
            router: RouterPolicy::RoundRobin,
            faults: FaultPlan::none(),
            max_retries: 2,
            backoff_secs: 0.05,
        }
    }

    pub fn with_router(mut self, router: RouterPolicy) -> Self {
        self.router = router;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }
}

/// Everything a cluster run produces.
pub struct ClusterReport {
    /// Per-replica execution traces (a crashed replica's trace ends at
    /// its crash boundary).
    pub traces: Vec<Trace>,
    /// Per-replica pass-level reports (request counts are submissions to
    /// that replica, so a re-routed request counts on its new host).
    pub reports: Vec<RunReport>,
    /// Cluster-level per-request latency summary, including the
    /// rerouted / replayed / failed recovery counters.
    pub stats: LatencyStats,
    /// The rolled-up tracker behind `stats`.
    pub tracker: RequestTracker,
    /// Final lifecycle state of each replica.
    pub replica_states: Vec<ReplicaState>,
    /// Submissions per replica (arrivals + re-routes).
    pub admitted: Vec<usize>,
}

/// A crash casualty waiting to be re-routed.
struct RetryEntry {
    /// Virtual time the re-route becomes due (crash boundary + backoff).
    due: f64,
    /// Replica it was extracted from — its timings (and, if the cluster
    /// gives up, its terminal drop stamp) live on that tracker.
    from: usize,
    seq: Sequence,
}

/// The multi-replica discrete-event driver.
pub struct Cluster {
    cfg: ClusterConfig,
    machines: Vec<SimMachine>,
    states: Vec<PassState>,
    trackers: Vec<RequestTracker>,
    health: Vec<ReplicaHealth>,
    /// Active transient-slowdown windows per replica: (from, until,
    /// factor).
    slow: Vec<Vec<(f64, f64, f64)>>,
    fault_q: Vec<VecDeque<FaultEvent>>,
    router: Box<dyn Router>,
    pending: VecDeque<(f64, Request)>,
    /// Time-sorted re-route queue (stable order: due, then id).
    retry: Vec<RetryEntry>,
    /// Re-enqueue attempts per casualty id (persists across repeated
    /// crashes of the same request).
    retry_count: BTreeMap<SeqId, usize>,
    admitted: Vec<usize>,
    /// Arrivals that found no admitting replica at all — tracked here so
    /// conservation still covers them.
    unrouted: RequestTracker,
    rerouted: usize,
    replayed: usize,
    failed: usize,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        assert!(cfg.replicas >= 1, "a cluster needs at least one replica");
        assert!(
            cfg.backoff_secs.is_finite() && cfg.backoff_secs >= 0.0,
            "re-route backoff must be finite and non-negative"
        );
        let machines: Vec<SimMachine> =
            (0..cfg.replicas).map(|_| SimMachine::new(cfg.replica.clone())).collect();
        let states: Vec<PassState> = machines.iter().map(SimMachine::begin_run).collect();
        let fault_q = cfg.faults.split(cfg.replicas);
        let router = cfg.router.build();
        let n = cfg.replicas;
        Cluster {
            cfg,
            machines,
            states,
            trackers: (0..n).map(|_| RequestTracker::new()).collect(),
            health: (0..n).map(|_| ReplicaHealth::new()).collect(),
            slow: (0..n).map(|_| Vec::new()).collect(),
            fault_q,
            router,
            pending: VecDeque::new(),
            retry: Vec::new(),
            retry_count: BTreeMap::new(),
            admitted: vec![0; n],
            unrouted: RequestTracker::new(),
            rerouted: 0,
            replayed: 0,
            failed: 0,
        }
    }

    /// Serve a timed arrival stream across the cluster. The driver is a
    /// discrete-event loop: at each step it either injects the next due
    /// arrival / re-route (when its timestamp is at or before the
    /// earliest working replica's clock, or when the whole cluster is
    /// idle), or executes one pass on the replica with the smallest local
    /// clock. With one replica and no faults this reduces exactly to
    /// [`SimMachine`]'s serving loop.
    pub fn run_online(
        mut self,
        mut arrivals: Vec<(f64, Request)>,
        slo_e2e: f64,
    ) -> ClusterReport {
        if let Some(dup) = duplicate_id(&arrivals) {
            panic!(
                "duplicate request id {dup} in arrival stream — per-request \
                 latency tracking requires unique ids"
            );
        }
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("non-NaN arrival times"));
        let n_req = arrivals.len();
        self.pending = arrivals.into();

        loop {
            let exec = self.pick_executor();
            let ta = self.pending.front().map(|&(t, _)| t);
            let td = self.retry.first().map(|e| e.due);
            // Next injectable item; arrivals win timestamp ties (a
            // re-route is conceptually a *re*-submission).
            let inject = match (ta, td) {
                (Some(a), Some(d)) if d < a => Some((d, true)),
                (Some(a), _) => Some((a, false)),
                (None, Some(d)) => Some((d, true)),
                (None, None) => None,
            };
            match (inject, exec) {
                (Some((t, is_retry)), Some(i)) if t <= self.states[i].now => {
                    if is_retry {
                        self.inject_retry();
                    } else {
                        self.inject_arrival();
                    }
                }
                (_, Some(i)) => self.execute(i),
                (Some((_, is_retry)), None) => {
                    // Whole cluster idle: the injection target's clock
                    // jumps to the item's timestamp (the single-machine
                    // idle jump, per replica).
                    if is_retry {
                        self.inject_retry();
                    } else {
                        self.inject_arrival();
                    }
                }
                (None, None) => break,
            }
        }

        // Degraded shutdown: every replica — up, draining, or crashed —
        // must end with a drained scheduler (crash extraction guarantees
        // it for the dead; drains run to completion).
        for (i, m) in self.machines.iter().enumerate() {
            assert!(m.sched.is_done(), "replica {i} ended with an undrained scheduler");
        }
        let tracker =
            RequestTracker::rollup(self.trackers.iter().chain(std::iter::once(&self.unrouted)));
        // Conservation: crashes move requests, they never lose them.
        let lost = tracker.unresolved();
        assert!(
            lost.is_empty(),
            "requests lost by the cluster (neither finished nor dropped): {lost:?}"
        );
        let wall = self.states.iter().map(|st| st.trace.wall_secs()).fold(0.0f64, f64::max);
        let mut stats = tracker.stats(wall, slo_e2e);
        assert_eq!(stats.requests, n_req, "every request must be tracked exactly once");
        assert_eq!(
            stats.completed + stats.rejected + stats.expired,
            n_req,
            "request conservation: finished + rejected + expired must cover the stream"
        );
        stats.rerouted = self.rerouted;
        stats.replayed = self.replayed;
        stats.failed = self.failed;
        let traces: Vec<Trace> = self.states.into_iter().map(|st| st.trace).collect();
        let reports: Vec<RunReport> = traces
            .iter()
            .zip(&self.admitted)
            .map(|(t, &n)| RunReport::from_trace(t, n))
            .collect();
        ClusterReport {
            traces,
            reports,
            stats,
            tracker,
            replica_states: self.health.iter().map(|h| h.state).collect(),
            admitted: self.admitted,
        }
    }

    /// The non-crashed replica with live work and the smallest local
    /// clock (ties break to the lowest index); `None` when the whole
    /// cluster is idle.
    fn pick_executor(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.machines.len() {
            if self.health[i].state == ReplicaState::Crashed {
                continue;
            }
            if !self.machines[i].has_live_work(&self.states[i]) {
                continue;
            }
            if best.is_none_or(|b| self.states[i].now < self.states[b].now) {
                best = Some(i);
            }
        }
        best
    }

    /// Execute one pass on replica `i`, applying its due fault events at
    /// the pass boundary first.
    fn execute(&mut self, i: usize) {
        let boundary = self.states[i].now;
        self.apply_faults(i, boundary);
        if self.health[i].state == ReplicaState::Crashed {
            return;
        }
        if !self.machines[i].has_live_work(&self.states[i]) {
            return;
        }
        let factor = self.slow_factor(i, boundary);
        if let Some(dur) =
            self.machines[i].step_pass(&mut self.states[i], Some(&mut self.trackers[i]), factor)
        {
            self.health[i].observe_pass(dur);
        }
    }

    /// Route the next pending arrival to an admitting replica (or fail it
    /// at the door when none survives).
    fn inject_arrival(&mut self) {
        let (t, r) =
            self.pending.pop_front().expect("inject_arrival requires a pending arrival");
        self.catch_up_idle_faults(t);
        let views = self.views();
        if views.is_empty() {
            self.unrouted.arrived(r.id, t);
            self.unrouted.dropped(r.id, t, DropReason::Expired);
            self.failed += 1;
            return;
        }
        let j = self.router.route(&r, t, &views);
        let was_idle = !self.machines[j].has_live_work(&self.states[j]);
        self.trackers[j].arrived(r.id, t);
        self.admitted[j] += 1;
        self.machines[j].sched.submit_at(r, t);
        if was_idle {
            self.states[j].now = self.states[j].now.max(t);
        }
    }

    /// Re-route the next due crash casualty. SLO-style graceful
    /// degradation: a deadline request is only re-admitted if some
    /// survivor can plausibly finish it (its backlog plus the casualty's
    /// remaining work — a full re-prefill for replays — fits the slack);
    /// otherwise it fails here instead of wasting survivor capacity.
    fn inject_retry(&mut self) {
        let e = self.retry.remove(0);
        self.catch_up_idle_faults(e.due);
        let views = self.views();
        if views.is_empty() {
            self.fail(e.from, e.seq.id(), e.due);
            return;
        }
        if let Some(deadline) = e.seq.req.deadline {
            let feasible = views.iter().any(|v| {
                let service = &self.machines[v.index].sched.cfg.service;
                v.now.max(e.due) + v.backlog_secs + service.predicted_remaining(&e.seq)
                    <= deadline
            });
            if !feasible {
                self.fail(e.from, e.seq.id(), e.due);
                return;
            }
        }
        let j = self.router.route(&e.seq.req, e.due, &views);
        let was_idle = !self.machines[j].has_live_work(&self.states[j]);
        // The new host's tracker records the *original* arrival so
        // end-to-end latency keeps charging the disruption.
        self.trackers[j].arrived(e.seq.id(), e.seq.arrival);
        self.admitted[j] += 1;
        self.machines[j].sched.resubmit(e.seq);
        if was_idle {
            self.states[j].now = self.states[j].now.max(e.due);
        }
    }

    /// Snapshot every admitting replica for a routing decision.
    fn views(&self) -> Vec<ReplicaView> {
        (0..self.machines.len())
            .filter(|&i| self.health[i].admitting())
            .map(|i| ReplicaView {
                index: i,
                now: self.states[i].now,
                queued: self.machines[i].sched.queued(),
                active_decode: self.machines[i].sched.active_decode(),
                backlog_secs: self.machines[i]
                    .sched
                    .live_predicted_secs(&self.machines[i].sched.cfg.service),
                suspicion: self.health[i].suspicion(),
            })
            .collect()
    }

    /// Apply replica `i`'s fault events due at or before `t_ref`.
    fn apply_faults(&mut self, i: usize, t_ref: f64) {
        while let Some(ev) = self.fault_q[i].front().copied() {
            if ev.at_secs > t_ref {
                break;
            }
            self.fault_q[i].pop_front();
            match ev.kind {
                FaultKind::Crash => {
                    self.fault_q[i].clear(); // nothing after death matters
                    self.crash(i);
                    return;
                }
                FaultKind::Drain => {
                    if self.health[i].state == ReplicaState::Up {
                        self.health[i].state = ReplicaState::Draining;
                    }
                }
                FaultKind::Slow { until_secs, factor } => {
                    self.slow[i].push((ev.at_secs, until_secs, factor));
                }
            }
        }
    }

    /// Idle replicas' clocks lag the cluster; before a routing decision
    /// at time `t`, bring their fault state up to date so a replica that
    /// crashed or drained *before* `t` is not offered as a candidate.
    /// Working replicas apply their own faults at execution boundaries.
    fn catch_up_idle_faults(&mut self, t: f64) {
        for i in 0..self.machines.len() {
            if self.health[i].state != ReplicaState::Crashed
                && !self.machines[i].has_live_work(&self.states[i])
            {
                let t_ref = self.states[i].now.max(t);
                self.apply_faults(i, t_ref);
            }
        }
    }

    /// Kill replica `i` at its current pass boundary: extract its queued
    /// and in-flight sequences and hand them to the retry machinery.
    fn crash(&mut self, i: usize) {
        self.health[i].state = ReplicaState::Crashed;
        let boundary = self.states[i].now;
        let m = &mut self.machines[i];
        let live = m.sched.extract_live(&mut m.kv);
        for seq in live {
            // `started()` survives the extraction's preempt (it counts
            // preemptions), so it cleanly separates requests that lose
            // re-prefill work from queued ones that move for free.
            if seq.started() {
                self.replayed += 1;
            } else {
                self.rerouted += 1;
            }
            let id = seq.id();
            let tries = self.retry_count.get(&id).copied().unwrap_or(0) + 1;
            self.retry_count.insert(id, tries);
            if tries > self.cfg.max_retries {
                self.fail(i, id, boundary);
                continue;
            }
            self.retry.push(RetryEntry {
                due: boundary + self.cfg.backoff_secs * usize_f64(tries),
                from: i,
                seq,
            });
        }
        self.retry.sort_by(|a, b| {
            a.due
                .partial_cmp(&b.due)
                .expect("finite retry deadlines")
                .then_with(|| a.seq.id().cmp(&b.seq.id()))
        });
    }

    /// Give up on a casualty: terminal Expired drop on the tracker that
    /// holds its timings, plus the failed counter.
    fn fail(&mut self, from: usize, id: SeqId, t: f64) {
        self.trackers[from].dropped(id, t, DropReason::Expired);
        self.failed += 1;
    }

    /// The worst active slowdown factor for replica `i` at time `now`
    /// (1.0 — bit-identity — when no window is active).
    fn slow_factor(&self, i: usize, now: f64) -> f64 {
        let mut f = 1.0;
        for &(from, until, factor) in &self.slow[i] {
            if from <= now && now < until {
                f = f.max(factor);
            }
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::sched::{AdmissionPolicy, VictimPolicy};
    use crate::util::cast::usize_u64;
    use crate::util::Rng;

    fn small_cfg(kv_gb: u64) -> SimConfig {
        SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), kv_gb)
    }

    fn poisson(rate: f64, k: usize, p: usize, g: usize, seed: u64) -> Vec<(f64, Request)> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        (0..k)
            .map(|i| {
                t += rng.exponential(rate);
                (t, Request::new(usize_u64(i), vec![1; p], g))
            })
            .collect()
    }

    fn assert_traces_f64_identical(a: &Trace, b: &Trace) {
        assert_eq!(a.passes.len(), b.passes.len(), "pass counts differ");
        for (x, y) in a.passes.iter().zip(&b.passes) {
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits(), "pass {}", x.pass_id);
            assert_eq!(x.duration.to_bits(), y.duration.to_bits(), "pass {}", x.pass_id);
            assert_eq!(x.io_time.to_bits(), y.io_time.to_bits(), "pass {}", x.pass_id);
            assert_eq!(x.gpu_time.to_bits(), y.gpu_time.to_bits(), "pass {}", x.pass_id);
            assert_eq!(x.cpu_time.to_bits(), y.cpu_time.to_bits(), "pass {}", x.pass_id);
            assert_eq!(
                x.overlap_time.to_bits(),
                y.overlap_time.to_bits(),
                "pass {}",
                x.pass_id
            );
            assert_eq!(x.host_time.to_bits(), y.host_time.to_bits(), "pass {}", x.pass_id);
            assert_eq!(
                x.host_overlap_time.to_bits(),
                y.host_overlap_time.to_bits(),
                "pass {}",
                x.pass_id
            );
            assert_eq!(x.generated, y.generated, "pass {}", x.pass_id);
            assert_eq!(x.finished, y.finished, "pass {}", x.pass_id);
            assert_eq!(x.preempted, y.preempted, "pass {}", x.pass_id);
        }
    }

    fn assert_lane_partition(trace: &Trace) {
        for p in &trace.passes {
            assert!(
                (p.lanes_total() - p.duration).abs() < 1e-9,
                "pass {}: lanes {} vs duration {}",
                p.pass_id,
                p.lanes_total(),
                p.duration
            );
        }
    }

    #[test]
    fn one_replica_no_faults_is_f64_identical_to_the_single_machine() {
        let arrivals = poisson(2.0, 24, 64, 16, 42);
        let slo = f64::INFINITY;
        let (trace, _, stats, _) =
            SimMachine::new(small_cfg(70)).run_online_tracked(arrivals.clone(), slo);
        let rep =
            Cluster::new(ClusterConfig::new(small_cfg(70), 1)).run_online(arrivals, slo);
        assert_traces_f64_identical(&rep.traces[0], &trace);
        assert_eq!(rep.stats.completed, stats.completed);
        assert_eq!(rep.stats.goodput_rps.to_bits(), stats.goodput_rps.to_bits());
        assert_eq!(rep.replica_states, vec![ReplicaState::Up]);
    }

    #[test]
    fn one_replica_identity_holds_under_slo_shedding_and_preemption() {
        // Tight cache + deadlines: the weighted victim and SLO admission
        // paths (preemptions, rejects, expiries) must also be identical.
        let mut cfg = small_cfg(4);
        cfg.admission = AdmissionPolicy::slo();
        cfg.victim = VictimPolicy::Weighted;
        let slo = 120.0;
        let arrivals: Vec<(f64, Request)> = poisson(3.0, 30, 96, 24, 5)
            .into_iter()
            .map(|(t, r)| (t, r.with_deadline(t + slo)))
            .collect();
        let (trace, _, stats, _) =
            SimMachine::new(cfg.clone()).run_online_tracked(arrivals.clone(), slo);
        let rep = Cluster::new(ClusterConfig::new(cfg, 1)).run_online(arrivals, slo);
        assert_traces_f64_identical(&rep.traces[0], &trace);
        assert_eq!(rep.stats.completed, stats.completed);
        assert_eq!(rep.stats.rejected, stats.rejected);
        assert_eq!(rep.stats.expired, stats.expired);
        assert_eq!(rep.stats.goodput_rps.to_bits(), stats.goodput_rps.to_bits());
    }

    #[test]
    fn crash_reroutes_stranded_work_and_conserves_every_request() {
        let cfg = ClusterConfig::new(small_cfg(70), 2)
            .with_router(RouterPolicy::RoundRobin)
            .with_faults(FaultPlan::parse("crash@20:r1").unwrap());
        let n = 40;
        let rep = Cluster::new(cfg).run_online(poisson(4.0, n, 64, 32, 7), f64::INFINITY);
        assert_eq!(rep.replica_states, vec![ReplicaState::Up, ReplicaState::Crashed]);
        assert!(
            rep.stats.rerouted + rep.stats.replayed > 0,
            "a mid-run crash must strand work"
        );
        assert!(rep.stats.replayed > 0, "in-flight sequences lose KV and replay");
        assert_eq!(rep.stats.failed, 0);
        assert_eq!(
            rep.stats.completed, n,
            "without deadlines every request must recover and finish"
        );
        // Five-lane partition must survive crash/re-route churn on every
        // replica (the crashed one's truncated trace included).
        for trace in &rep.traces {
            assert_lane_partition(trace);
        }
    }

    #[test]
    fn drain_finishes_in_flight_work_without_losing_anything() {
        let cfg = ClusterConfig::new(small_cfg(70), 2)
            .with_router(RouterPolicy::Jsq)
            .with_faults(FaultPlan::parse("drain@10:r0").unwrap());
        let n = 30;
        let rep = Cluster::new(cfg).run_online(poisson(1.0, n, 64, 16, 11), f64::INFINITY);
        assert_eq!(rep.replica_states, vec![ReplicaState::Draining, ReplicaState::Up]);
        assert_eq!(rep.stats.completed, n, "a drain loses nothing");
        assert_eq!(rep.stats.rerouted + rep.stats.replayed + rep.stats.failed, 0);
        assert!(
            rep.traces[0].wall_secs() > 10.0,
            "the draining replica keeps executing its in-flight work"
        );
        assert!(
            rep.admitted[1] > rep.admitted[0],
            "post-drain arrivals must all land on the surviving replica"
        );
    }

    #[test]
    fn recovery_strictly_beats_no_failover_on_completions() {
        let arrivals = poisson(4.0, 40, 64, 32, 7);
        let base = ClusterConfig::new(small_cfg(70), 2)
            .with_router(RouterPolicy::Deadline)
            .with_faults(FaultPlan::parse("crash@20:r1").unwrap());
        let mut nofail = base.clone();
        nofail.max_retries = 0;
        let with = Cluster::new(base).run_online(arrivals.clone(), f64::INFINITY);
        let without = Cluster::new(nofail).run_online(arrivals, f64::INFINITY);
        assert!(without.stats.failed > 0, "no-failover must lose the casualties");
        assert_eq!(with.stats.completed, 40);
        assert!(with.stats.completed > without.stats.completed);
    }

    #[test]
    fn slow_fault_steers_deadline_routing_toward_the_healthy_replica() {
        let cfg = ClusterConfig::new(small_cfg(70), 2)
            .with_router(RouterPolicy::Deadline)
            .with_faults(FaultPlan::parse("slow@0+1000000*3:r1").unwrap());
        let n = 40;
        let rep = Cluster::new(cfg).run_online(poisson(1.0, n, 64, 16, 13), f64::INFINITY);
        assert_eq!(rep.stats.completed, n);
        assert_eq!(rep.stats.failed, 0);
        assert!(!rep.traces[1].passes.is_empty(), "the slowed replica still serves");
        assert!(
            rep.admitted[0] > rep.admitted[1],
            "backlog-aware routing must favor the healthy replica"
        );
        // Scaled lanes must still partition the scaled duration exactly.
        for trace in &rep.traces {
            assert_lane_partition(trace);
        }
    }

    #[test]
    fn losing_every_replica_fails_requests_instead_of_losing_them() {
        let cfg = ClusterConfig::new(small_cfg(70), 1)
            .with_faults(FaultPlan::parse("crash@5:r0").unwrap());
        let n = 20;
        let arrivals = poisson(1.0, n, 64, 16, 17);
        let rep = Cluster::new(cfg.clone()).run_online(arrivals.clone(), f64::INFINITY);
        assert_eq!(rep.replica_states, vec![ReplicaState::Crashed]);
        assert!(rep.stats.failed > 0);
        assert_eq!(
            rep.stats.completed + rep.stats.expired,
            n,
            "every request either finished before the crash or failed"
        );
        assert_eq!(
            rep.stats.failed, rep.stats.expired,
            "with no deadlines, the only expiries are recovery failures"
        );
        // Determinism: an identical run resolves identically.
        let again = Cluster::new(cfg).run_online(arrivals, f64::INFINITY);
        assert_eq!(again.stats.completed, rep.stats.completed);
        assert_eq!(again.stats.failed, rep.stats.failed);
        assert_eq!(again.stats.goodput_rps.to_bits(), rep.stats.goodput_rps.to_bits());
    }

    #[test]
    fn routing_is_reproducible_and_round_robin_splits_exactly() {
        let arrivals = poisson(2.0, 10, 64, 8, 23);
        let rr = ClusterConfig::new(small_cfg(70), 2);
        let rep = Cluster::new(rr).run_online(arrivals.clone(), f64::INFINITY);
        assert_eq!(rep.admitted, vec![5, 5], "round-robin alternates exactly");

        let p2c = ClusterConfig::new(small_cfg(70), 3)
            .with_router(RouterPolicy::P2c { seed: 99 });
        let a = Cluster::new(p2c.clone()).run_online(arrivals.clone(), f64::INFINITY);
        let b = Cluster::new(p2c).run_online(arrivals, f64::INFINITY);
        assert_eq!(a.admitted, b.admitted, "p2c is seed-deterministic");
        assert_eq!(a.stats.goodput_rps.to_bits(), b.stats.goodput_rps.to_bits());
    }
}
