//! The eleven repo-specific lint rules and their detection logic.
//!
//! Each rule encodes an invariant the ROADMAP's engine/simulator/cost-model
//! agreement rests on; see the README's "Static analysis & invariants"
//! section for the rationale and the per-rule scopes. The first seven
//! rules are line-lexical (they match scrubbed line text); the v2 rules
//! (atomic-ordering, nondeterministic-order, precision-laundering,
//! thread-spawn-policy) run on the token stream from [`super::tokens`]
//! because they need adjacency, call-argument spans, or `fn`/`impl`
//! membership.

use super::lexer::{ident_occurrences, is_ident_char, Line};
use super::tokens::{fn_spans, impl_spans, matching_paren, TokKind, Token};

/// A lint rule. Names are the stable identifiers used in allow
/// directives and the ratchet baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `Instant::now` / `SystemTime::now` in virtual-clock modules.
    WallClockInSim,
    /// `HashMap` / `HashSet` in deterministic modules or tests.
    UnorderedIteration,
    /// A `pub *_time: f64` lane on `PassRecord` missing from
    /// `lanes_total()`, from `to_csv()`, or from the CSV header string
    /// (a lane summed into the row but unnamed in the header drifts
    /// silently in offline plots).
    LanePartition,
    /// `as u64` / `as usize` / `as f64` in accounting modules.
    UncheckedCast,
    /// `.unwrap()` / `.expect(` in library hot paths outside tests.
    PanicPolicy,
    /// Direct `==` / `!=` against a float literal.
    FloatEq,
    /// An `unsafe` block or `unsafe impl` in `src/` without a
    /// `// Safety:` comment on it or on the comment block directly above.
    UndocumentedUnsafe,
    /// A relaxed-family atomic ordering (`Ordering::Relaxed` / `Acquire`
    /// / `Release` / `AcqRel`) in concurrency modules without an
    /// `// Ordering:` justification comment — the same discipline
    /// `// Safety:` enforces for unsafe blocks. `SeqCst` is exempt: it
    /// is the conservative default and needs no argument.
    AtomicOrdering,
    /// Iteration-order hazards in deterministic modules:
    /// `Vec::swap_remove` (reorders the tail), float-keyed
    /// `sort_unstable_by`/`_key` (unstable among ties), and `retain`
    /// closures with side effects (visit order becomes observable).
    NondeterministicOrder,
    /// f32 precision laundered into f64 in accounting modules: an f32
    /// value (parameter, `let` binding, or direct `as f32` result)
    /// widened to f64 reads as full precision downstream but carries
    /// only 24 bits; float literals truncated via `as f32` likewise.
    PrecisionLaundering,
    /// `std::thread::spawn` outside the blessed seams (`PlannerWorker`,
    /// `ThreadPool`) — ad-hoc threads bypass the join/panic-propagation
    /// discipline those impls provide.
    ThreadSpawnPolicy,
}

impl Rule {
    pub const ALL: [Rule; 11] = [
        Rule::WallClockInSim,
        Rule::UnorderedIteration,
        Rule::LanePartition,
        Rule::UncheckedCast,
        Rule::PanicPolicy,
        Rule::FloatEq,
        Rule::UndocumentedUnsafe,
        Rule::AtomicOrdering,
        Rule::NondeterministicOrder,
        Rule::PrecisionLaundering,
        Rule::ThreadSpawnPolicy,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::WallClockInSim => "wall-clock-in-sim",
            Rule::UnorderedIteration => "unordered-iteration",
            Rule::LanePartition => "lane-partition",
            Rule::UncheckedCast => "unchecked-cast",
            Rule::PanicPolicy => "panic-policy",
            Rule::FloatEq => "float-eq",
            Rule::UndocumentedUnsafe => "undocumented-unsafe",
            Rule::AtomicOrdering => "atomic-ordering",
            Rule::NondeterministicOrder => "nondeterministic-order",
            Rule::PrecisionLaundering => "precision-laundering",
            Rule::ThreadSpawnPolicy => "thread-spawn-policy",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.name() == name)
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the crate root, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// What matched (pattern or field name).
    pub detail: String,
}

/// Modules whose time must be virtual/replayable (wall-clock and
/// unordered-iteration scope). `cluster` interleaves N replica-local
/// virtual clocks, so a wall-clock read or an unordered container there
/// breaks multi-replica replay just as badly as in `simhw`.
pub const DET_MODULES: &[&str] =
    &["simhw", "perfmodel", "baselines", "sched", "kvcache", "workload", "cluster"];
/// Accounting / cost-model modules (unchecked-cast scope).
pub const CAST_MODULES: &[&str] = &["metrics", "perfmodel", "simhw", "sched", "kvcache"];
/// Library hot paths (panic-policy scope).
pub const PANIC_MODULES: &[&str] = &["engine", "sched", "kvcache", "transfer"];
/// Concurrency modules (atomic-ordering scope): every relaxed-family
/// ordering here must argue why it is sound.
pub const ATOMIC_MODULES: &[&str] = &["cpuattn", "engine", "transfer"];
/// Deterministic-order modules (nondeterministic-order scope): replay
/// and golden traces depend on container visit order here.
pub const NONDET_MODULES: &[&str] = &["sched", "simhw", "kvcache", "workload", "cluster"];
/// Accounting modules where f32→f64 laundering corrupts cost arithmetic
/// (precision-laundering scope).
pub const PRECISION_MODULES: &[&str] = &["perfmodel", "metrics"];
/// Impl blocks allowed to call `std::thread::spawn`
/// (thread-spawn-policy): the planner worker and the CPU-attention
/// thread pool own thread lifetimes and panic propagation.
pub const BLESSED_SPAWN_IMPLS: &[&str] = &["PlannerWorker", "ThreadPool"];

/// Does `rel` (crate-relative path) live in one of `modules` under src/?
pub fn in_modules(rel: &str, modules: &[&str]) -> bool {
    modules.iter().any(|m| {
        let file = format!("src/{m}.rs");
        let dir = format!("src/{m}/");
        rel == file || rel.starts_with(&dir)
    })
}

// ---------------------------------------------------------------------------
// float-eq
// ---------------------------------------------------------------------------

/// The (possibly dotted) token ending just left of char position `pos`.
fn token_left(chars: &[char], pos: usize) -> String {
    let mut j = pos;
    while j > 0 && chars[j - 1] == ' ' {
        j -= 1;
    }
    let mut k = j;
    while k > 0 && (is_ident_char(chars[k - 1]) || chars[k - 1] == '.') {
        k -= 1;
    }
    chars[k..j].iter().collect()
}

/// The (possibly signed, dotted) token starting just right of `pos`.
fn token_right(chars: &[char], pos: usize) -> String {
    let mut j = pos;
    while j < chars.len() && chars[j] == ' ' {
        j += 1;
    }
    let mut k = j;
    if k < chars.len() && (chars[k] == '+' || chars[k] == '-') {
        k += 1;
    }
    while k < chars.len() && (is_ident_char(chars[k]) || chars[k] == '.') {
        k += 1;
    }
    chars[j..k].iter().collect()
}

/// Is `tok` a float literal (`0.0`, `1e-9`, `2.5f64`, `-1.0`, `9e15`)?
fn is_float_lit(tok: &str) -> bool {
    let mut t = tok;
    if let Some(s) = t.strip_prefix('+').or_else(|| t.strip_prefix('-')) {
        t = s;
    }
    let no_sep = t.replace('_', "");
    let mut t = no_sep.as_str();
    for suf in ["f64", "f32"] {
        if let Some(s) = t.strip_suffix(suf) {
            t = s;
            break;
        }
    }
    let Some(first) = t.chars().next() else {
        return false;
    };
    if !first.is_ascii_digit() {
        return false;
    }
    if !t.contains('.') && !t.contains('e') && !t.contains('E') {
        return false;
    }
    t.parse::<f64>().is_ok()
}

/// Char positions of `==` / `!=` operators whose left or right operand is
/// a float literal. `<=`, `>=`, and pattern `=>`s never match; `==` runs
/// (`===`) are skipped defensively.
pub fn float_eq_positions(code: &str) -> Vec<usize> {
    let chars: Vec<char> = code.chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < n {
        let (a, b) = (chars[i], chars[i + 1]);
        if a == '=' && b == '=' {
            if i > 0 && matches!(chars[i - 1], '<' | '>' | '!' | '=') {
                i += 1;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '=' {
                i += 3;
                continue;
            }
        } else if a == '!' && b == '=' {
            if i + 2 < n && chars[i + 2] == '=' {
                i += 3;
                continue;
            }
        } else {
            i += 1;
            continue;
        }
        let lt = token_left(&chars, i);
        let rt = token_right(&chars, i + 2);
        if is_float_lit(&lt) || is_float_lit(&rt) {
            out.push(i);
        }
        i += 2;
    }
    out
}

// ---------------------------------------------------------------------------
// unchecked-cast
// ---------------------------------------------------------------------------

/// Count `as u64` / `as usize` / `as f64` cast sites on a scrubbed line.
pub fn cast_sites(code: &str) -> usize {
    let chars: Vec<char> = code.chars().collect();
    ident_occurrences(code, "as")
        .into_iter()
        .filter(|&k| {
            let ty = token_right(&chars, k + 2);
            matches!(ty.as_str(), "u64" | "usize" | "f64")
        })
        .count()
}

// ---------------------------------------------------------------------------
// undocumented-unsafe
// ---------------------------------------------------------------------------

/// Char positions of `unsafe` keywords that open a block or an `unsafe
/// impl` on a scrubbed line. Declarations (`unsafe fn` / `unsafe trait` /
/// `unsafe extern`) are the *callee* side of the contract — their `#
/// Safety` doc section is rustdoc's (and clippy's) concern — so they are
/// exempt; every *use* site must carry a `// Safety:` comment.
pub fn unsafe_sites(code: &str) -> Vec<usize> {
    let chars: Vec<char> = code.chars().collect();
    ident_occurrences(code, "unsafe")
        .into_iter()
        .filter(|&k| {
            let next = token_right(&chars, k + 6);
            !matches!(next.as_str(), "fn" | "trait" | "extern")
        })
        .collect()
}

// ---------------------------------------------------------------------------
// lane-partition
// ---------------------------------------------------------------------------

/// Inclusive line span of `fn name`, signature line through the
/// brace-matched closing line, or None if the file does not define it.
fn find_fn_span(lines: &[Line], name: &str) -> Option<(usize, usize)> {
    let mut sig = None;
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if ident_occurrences(code, "fn").is_empty() || ident_occurrences(code, name).is_empty() {
            continue;
        }
        if let Some(kfn) = code.find("fn ") {
            if code[kfn..].find(name).is_some_and(|off| off > 0) {
                sig = Some(idx);
                break;
            }
        }
    }
    let sig = sig?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (off, line) in lines[sig..].iter().enumerate() {
        for ch in line.code.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            return Some((sig, sig + off));
        }
    }
    Some((sig, lines.len().saturating_sub(1)))
}

/// Code text of `fn name`'s brace-matched body (signature line included),
/// or None if the file does not define it.
fn find_fn_body(lines: &[Line], name: &str) -> Option<String> {
    let (lo, hi) = find_fn_span(lines, name)?;
    let mut body = String::new();
    for line in &lines[lo..=hi] {
        body.push_str(&line.code);
        body.push(' ');
    }
    Some(body)
}

/// Lane-partition violations: every `pub *_time: f64` field declared on a
/// `PassRecord` struct in this file must appear in `lanes_total()`, in
/// `to_csv()`, *and* — by name — in the CSV header string inside
/// `to_csv()`. Header text lives in a string literal, which the scrubber
/// blanks out of the code channel, so the header check reads `src` (the
/// raw source the `lines` were scrubbed from): an ident-boundary
/// occurrence in the raw `to_csv` body that is in neither the code nor
/// the comment channel can only sit inside a string literal.
/// Returns (0-based field line, field name, missing-from).
pub fn lane_partition(lines: &[Line], src: &str) -> Vec<(usize, String, &'static str)> {
    let mut start = None;
    for (idx, line) in lines.iter().enumerate() {
        let t = line.code.trim();
        let tail = if let Some(r) = t.strip_prefix("pub struct PassRecord") {
            r
        } else if let Some(r) = t.strip_prefix("struct PassRecord") {
            r
        } else {
            continue;
        };
        // Reject PassRecordFoo etc.
        if tail.chars().next().is_none_or(|c| !is_ident_char(c)) {
            start = Some(idx);
            break;
        }
    }
    let Some(start) = start else {
        return Vec::new();
    };
    let mut depth: i64 = 0;
    let mut opened = false;
    let mut fields: Vec<(usize, String)> = Vec::new();
    for (off, line) in lines[start..].iter().enumerate() {
        let t = line.code.trim();
        if opened && depth == 1 && t.starts_with("pub ") {
            if let Some(colon) = t.find(':') {
                let name = t[4..colon].trim().to_string();
                let ty = &t[colon + 1..];
                if name.ends_with("_time") && !ident_occurrences(ty, "f64").is_empty() {
                    fields.push((start + off, name));
                }
            }
        }
        for ch in line.code.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    let lanes = find_fn_body(lines, "lanes_total");
    let csv = find_fn_body(lines, "to_csv");
    let csv_span = find_fn_span(lines, "to_csv");
    let raw: Vec<&str> = src.split('\n').collect();
    // True iff `name` occurs inside a string literal somewhere in the
    // `to_csv` body: raw occurrences on a line beyond what the code and
    // comment channels account for must be literal text.
    let in_csv_header = |name: &str| -> bool {
        let Some((lo, hi)) = csv_span else {
            return false;
        };
        lines[lo..=hi].iter().enumerate().any(|(off, line)| {
            let rawl = raw.get(lo + off).copied().unwrap_or("");
            ident_occurrences(rawl, name).len()
                > ident_occurrences(&line.code, name).len()
                    + ident_occurrences(&line.comment, name).len()
        })
    };
    let mut out = Vec::new();
    for (idx, name) in fields {
        let in_lanes = lanes
            .as_deref()
            .is_some_and(|b| !ident_occurrences(b, &name).is_empty());
        if !in_lanes {
            out.push((idx, name.clone(), "lanes_total"));
        }
        let in_csv = csv
            .as_deref()
            .is_some_and(|b| !ident_occurrences(b, &name).is_empty());
        if !in_csv {
            out.push((idx, name, "to_csv"));
        } else if !in_csv_header(&name) {
            out.push((idx, name, "to_csv header"));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// atomic-ordering (token stream)
// ---------------------------------------------------------------------------

/// (0-based line, variant name) of every relaxed-family atomic ordering
/// use: the token triple `Ordering` `::` `<variant>`. `SeqCst` is exempt.
pub fn atomic_ordering_sites(tokens: &[Token]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if t.ident("Ordering")
            && tokens.get(i + 1).is_some_and(|t| t.punct("::"))
            && tokens.get(i + 2).is_some_and(|t| {
                t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "Relaxed" | "Acquire" | "Release" | "AcqRel")
            })
        {
            out.push((t.line, tokens[i + 2].text.clone()));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// nondeterministic-order (token stream)
// ---------------------------------------------------------------------------

/// The argument token range of a call whose `(` is expected at
/// `open_idx`, exclusive of both parens. `None` if the next token is not
/// an open paren (e.g. the method name is a path, not a call).
fn call_args(tokens: &[Token], open_idx: usize) -> Option<std::ops::Range<usize>> {
    if !tokens.get(open_idx)?.punct("(") {
        return None;
    }
    let close = matching_paren(tokens, open_idx)?;
    Some(open_idx + 1..close)
}

/// Idents in a sort comparator that betray a float key.
fn float_keyed(tokens: &[Token], args: std::ops::Range<usize>) -> bool {
    tokens[args].iter().any(|t| {
        t.kind == TokKind::Float
            || (t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "partial_cmp" | "total_cmp" | "f32" | "f64"))
    })
}

/// Assignment operators or mutating calls inside a `retain` closure —
/// side effects make the (unspecified) visit order observable.
fn retain_side_effects(tokens: &[Token], args: std::ops::Range<usize>) -> bool {
    tokens[args].iter().any(|t| match t.kind {
        TokKind::Punct => matches!(t.text.as_str(), "=" | "+=" | "-=" | "*=" | "/=" | "%="),
        TokKind::Ident => matches!(
            t.text.as_str(),
            "push" | "insert" | "remove" | "swap_remove" | "pop" | "send" | "extend"
        ),
        _ => false,
    })
}

/// (0-based line, detail) of iteration-order hazards: `swap_remove`
/// anywhere, float-keyed `sort_unstable_by`/`_key`, and `retain`
/// closures with side effects. Int-keyed unstable sorts and pure
/// `retain` predicates are fine (equal keys are interchangeable; visit
/// order is unobservable).
pub fn nondet_order_sites(tokens: &[Token]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if !t.punct(".") {
            continue;
        }
        let Some(m) = tokens.get(i + 1) else { continue };
        if m.kind != TokKind::Ident {
            continue;
        }
        match m.text.as_str() {
            "swap_remove" => out.push((m.line, "swap_remove reorders the tail".to_string())),
            "sort_unstable_by" | "sort_unstable_by_key" => {
                if let Some(args) = call_args(tokens, i + 2) {
                    if float_keyed(tokens, args) {
                        out.push((m.line, format!("float-keyed {} is unstable among ties", m.text)));
                    }
                }
            }
            "retain" => {
                if let Some(args) = call_args(tokens, i + 2) {
                    if retain_side_effects(tokens, args) {
                        out.push((m.line, "retain closure with side effects".to_string()));
                    }
                }
            }
            _ => {}
        }
    }
    out
}

// ---------------------------------------------------------------------------
// precision-laundering (token stream)
// ---------------------------------------------------------------------------

/// (0-based line, detail) of f32 precision laundered into f64, tracked
/// across `let` bindings within each `fn` span:
/// - an f32-typed parameter or `let` binding later cast `as f64`;
/// - a direct `as f32 as f64` double cast;
/// - a float literal truncated via `as f32`.
pub fn precision_sites(tokens: &[Token]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for span in fn_spans(tokens) {
        if span.open_tok.is_none() {
            continue;
        }
        // Taint set: (name, token index after which uses count).
        let mut tainted: Vec<(String, usize)> = Vec::new();
        // f32 parameters: `name : [&|mut]* f32` in the signature.
        for j in span.signature() {
            if !tokens[j].ident("f32") {
                continue;
            }
            let mut k = j;
            while k > span.fn_tok && (tokens[k - 1].punct("&") || tokens[k - 1].ident("mut")) {
                k -= 1;
            }
            if k >= span.fn_tok + 2
                && tokens[k - 1].punct(":")
                && tokens[k - 2].kind == TokKind::Ident
            {
                tainted.push((tokens[k - 2].text.clone(), j));
            }
        }
        let body = span.body();
        // f32 `let` bindings: any `f32` mention in the statement (type
        // annotation or `as f32` in the initializer) taints the name.
        for j in body.clone() {
            if !tokens[j].ident("let") {
                continue;
            }
            let mut k = j + 1;
            if tokens.get(k).is_some_and(|t| t.ident("mut")) {
                k += 1;
            }
            let Some(nm) = tokens.get(k).filter(|t| t.kind == TokKind::Ident) else {
                continue;
            };
            let mut e = k;
            while e < body.end && !tokens[e].punct(";") {
                e += 1;
            }
            if (k + 1..e).any(|x| tokens[x].ident("f32")) {
                tainted.push((nm.text.clone(), e));
            }
        }
        for j in body.clone() {
            if tokens[j].ident("as") && tokens.get(j + 1).is_some_and(|t| t.ident("f64")) {
                let p = &tokens[j - 1];
                if p.ident("f32") {
                    out.push((tokens[j].line, "f32 value widened straight to f64".to_string()));
                } else if p.kind == TokKind::Ident
                    && tainted.iter().any(|(n, bind)| *n == p.text && *bind < j)
                {
                    out.push((tokens[j].line, format!("f32 `{}` widened to f64", p.text)));
                }
            }
            if tokens[j].kind == TokKind::Float
                && tokens.get(j + 1).is_some_and(|t| t.ident("as"))
                && tokens.get(j + 2).is_some_and(|t| t.ident("f32"))
            {
                out.push((
                    tokens[j].line,
                    format!("float literal `{}` truncated to f32", tokens[j].text),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// thread-spawn-policy (token stream)
// ---------------------------------------------------------------------------

/// 0-based lines of `thread` `::` `spawn` call sites that are not inside
/// an `impl` block mentioning one of [`BLESSED_SPAWN_IMPLS`]. Scoped
/// `s.spawn(...)` (`std::thread::scope`) is deliberately not matched:
/// scope guarantees the join.
pub fn unblessed_spawn_sites(tokens: &[Token]) -> Vec<usize> {
    let impls = impl_spans(tokens);
    let mut out = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if t.ident("thread")
            && tokens.get(i + 1).is_some_and(|t| t.punct("::"))
            && tokens.get(i + 2).is_some_and(|t| t.ident("spawn"))
        {
            let blessed = impls.iter().any(|s| {
                s.tok_range.contains(&i)
                    && BLESSED_SPAWN_IMPLS.iter().any(|b| s.mentions(b))
            });
            if !blessed {
                out.push(t.line);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::scrub;
    use crate::analysis::tokens::tokenize;

    fn toks(src: &str) -> Vec<Token> {
        tokenize(&scrub(src))
    }

    #[test]
    fn rule_names_round_trip() {
        for r in Rule::ALL {
            assert_eq!(Rule::from_name(r.name()), Some(r));
        }
        assert_eq!(Rule::from_name("nope"), None);
    }

    #[test]
    fn module_scoping() {
        assert!(in_modules("src/sched/policy.rs", DET_MODULES));
        assert!(in_modules("src/simhw.rs", DET_MODULES));
        assert!(in_modules("src/cluster/router.rs", DET_MODULES));
        assert!(in_modules("src/cluster/mod.rs", NONDET_MODULES));
        assert!(!in_modules("src/schedx/policy.rs", DET_MODULES));
        assert!(!in_modules("src/engine/batch.rs", DET_MODULES));
        assert!(!in_modules("benches/sched/x.rs", DET_MODULES));
        assert!(!in_modules("src/clusterx/mod.rs", DET_MODULES));
    }

    #[test]
    fn float_eq_detection() {
        assert_eq!(float_eq_positions("if t == 0.0 {").len(), 1);
        assert_eq!(float_eq_positions("x != 0.5").len(), 1);
        assert_eq!(float_eq_positions("x == 9e15").len(), 1);
        assert_eq!(float_eq_positions("x == 2.5f64").len(), 1);
        // Known hole: a negative exponent stops the token scan ("1e" is
        // not a float literal), so `!= 1e-9` slips through.
        assert_eq!(float_eq_positions("x != 1e-9").len(), 0);
        assert_eq!(float_eq_positions("n == 0").len(), 0, "integer compare");
        assert_eq!(float_eq_positions("t <= 0.0 || t >= 1.0").len(), 0);
        assert_eq!(float_eq_positions("(a - b).abs() < 1e-9").len(), 0);
        assert_eq!(float_eq_positions("match x { 0.5 => 1, _ => 0 }").len(), 0);
        assert_eq!(float_eq_positions("0.0 == x").len(), 1, "literal on the left");
    }

    #[test]
    fn cast_detection() {
        assert_eq!(cast_sites("let x = n as f64 / m as f64;"), 2);
        assert_eq!(cast_sites("let x = n as u32;"), 0, "widening to u32 not flagged");
        assert_eq!(cast_sites("let y = b as usize + 1;"), 1);
        assert_eq!(cast_sites("alias u64"), 0, "ident boundary");
    }

    #[test]
    fn unsafe_site_detection() {
        assert_eq!(unsafe_sites("let x = unsafe { *p };").len(), 1);
        assert_eq!(unsafe_sites("unsafe impl Send for Batch {}").len(), 1);
        assert_eq!(unsafe_sites("unsafe").len(), 1, "block opening on next line");
        assert_eq!(unsafe_sites("pub unsafe fn dot(q: &[f32]) -> f32 {").len(), 0);
        assert_eq!(unsafe_sites("unsafe trait Zeroable {}").len(), 0);
        assert_eq!(unsafe_sites("unsafe extern \"C\" {}").len(), 0);
        assert_eq!(unsafe_sites("let unsafer = 1;").len(), 0, "ident boundary");
    }

    fn lanes(src: &str) -> Vec<(usize, String, &'static str)> {
        lane_partition(&scrub(src), src)
    }

    #[test]
    fn lane_partition_flags_drift() {
        let src = "\
pub struct PassRecord {
    pub io_time: f64,
    pub gpu_time: f64,
    pub count: usize,
}
impl PassRecord {
    pub fn lanes_total(&self) -> f64 { self.io_time }
    pub fn to_csv(&self) -> String { format!(\"io_time={}\", self.io_time) }
}
";
        let v = lanes(src);
        // gpu_time missing from both; io_time fine; count not a lane.
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|(_, name, _)| name == "gpu_time"));
        let missing: Vec<&str> = v.iter().map(|(_, _, m)| *m).collect();
        assert!(missing.contains(&"lanes_total") && missing.contains(&"to_csv"));
    }

    #[test]
    fn lane_partition_ident_boundary() {
        // A shadow lane whose name embeds a real lane's name must not
        // borrow that lane's membership — in code or in the header.
        let src = "\
pub struct PassRecord {
    pub overlap_time: f64,
    pub host_overlap_time: f64,
}
impl PassRecord {
    pub fn lanes_total(&self) -> f64 { self.overlap_time + self.host_overlap_time }
    pub fn to_csv(&self) -> String { format!(\"host_overlap_time={}\", self.host_overlap_time) }
}
";
        let v = lanes(src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].1, "overlap_time");
        assert_eq!(v[0].2, "to_csv");
    }

    #[test]
    fn lane_partition_requires_csv_header_naming() {
        // A lane summed into the CSV row but unnamed in the header string
        // drifts silently in offline plots. The header lives in a string
        // literal — invisible to the scrubbed code channel — so the check
        // reads the raw to_csv body. A comment naming the lane must NOT
        // satisfy it.
        let src = "\
pub struct PassRecord {
    pub io_time: f64,
    pub gpu_time: f64,
}
impl PassRecord {
    pub fn lanes_total(&self) -> f64 { self.io_time + self.gpu_time }
    pub fn to_csv(&self) -> String {
        // gpu_time is appended to the row below
        format!(\"io_time,{},{}\", self.io_time, self.gpu_time)
    }
}
";
        let v = lanes(src);
        assert_eq!(v.len(), 1, "findings: {v:?}");
        assert_eq!(v[0].1, "gpu_time");
        assert_eq!(v[0].2, "to_csv header");
    }

    #[test]
    fn no_passrecord_no_findings() {
        assert!(lanes("pub struct Other { pub t_time: f64 }").is_empty());
        assert!(lanes("pub struct PassRecordX { pub a_time: f64 }").is_empty());
    }

    #[test]
    fn atomic_ordering_detection() {
        let v = atomic_ordering_sites(&toks(
            "x.store(1, Ordering::Relaxed);\n\
             x.load(Ordering::Acquire);\n\
             x.store(2, Ordering::Release);\n\
             x.fetch_sub(1, Ordering::AcqRel);\n\
             x.load(Ordering::SeqCst);\n\
             use std::sync::atomic::Ordering;",
        ));
        let variants: Vec<&str> = v.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(variants, vec!["Relaxed", "Acquire", "Release", "AcqRel"]);
        assert_eq!(v[0].0, 0);
        assert_eq!(v[3].0, 3);
    }

    #[test]
    fn nondet_swap_remove() {
        assert_eq!(nondet_order_sites(&toks("live.swap_remove(i);")).len(), 1);
        assert!(nondet_order_sites(&toks("live.remove(i);")).is_empty());
        // Path form (`Vec::swap_remove(&mut v, i)`) has no leading dot —
        // out of pattern, and the repo never writes it.
        assert!(nondet_order_sites(&toks("let f = Vec::swap_remove;")).is_empty());
    }

    #[test]
    fn nondet_float_sorts() {
        assert_eq!(
            nondet_order_sites(&toks("xs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());"))
                .len(),
            1
        );
        assert_eq!(nondet_order_sites(&toks("xs.sort_unstable_by(f64::total_cmp);")).len(), 1);
        assert_eq!(nondet_order_sites(&toks("xs.sort_unstable_by_key(|x| x.cost as f64);")).len(), 1);
        assert!(
            nondet_order_sites(&toks("xs.sort_unstable_by_key(|x| x.id);")).is_empty(),
            "int keys: equal keys are interchangeable"
        );
        assert!(nondet_order_sites(&toks("xs.sort_unstable();")).is_empty());
    }

    #[test]
    fn nondet_retain_side_effects() {
        assert_eq!(
            nondet_order_sites(&toks("xs.retain(|x| { dropped += 1; x.live })")).len(),
            1
        );
        assert_eq!(
            nondet_order_sites(&toks("xs.retain(|x| { log.push(x.id); x.live })")).len(),
            1
        );
        assert!(
            nondet_order_sites(&toks("xs.retain(|x| x.live && x.len > 0);")).is_empty(),
            "pure predicate: visit order unobservable"
        );
        assert!(
            nondet_order_sites(&toks("xs.retain(|x| x.id == target);")).is_empty(),
            "glued == is not an assignment"
        );
    }

    #[test]
    fn precision_tainted_let_binding() {
        let v = precision_sites(&toks(
            "fn f(y: f64) -> f64 {\n\
             let x = y as f32;\n\
             let clean = y * 2.0;\n\
             (x as f64) + clean\n\
             }",
        ));
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].0, 3);
        assert!(v[0].1.contains("`x`"));
    }

    #[test]
    fn precision_tainted_param_and_double_cast() {
        let v = precision_sites(&toks(
            "fn g(w: f32, n: usize) -> f64 {\n\
             w as f64 * n as f64\n\
             }",
        ));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].1.contains("`w`"));
        let v = precision_sites(&toks("fn h(y: f64) -> f64 { y as f32 as f64 }"));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].1.contains("straight"));
    }

    #[test]
    fn precision_literal_truncation() {
        let v = precision_sites(&toks("fn k() -> f32 { 0.1 as f32 }"));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].1.contains("0.1"));
        assert!(
            precision_sites(&toks("fn k() -> f32 { 0.5f32 }")).is_empty(),
            "a typed literal is not a cast"
        );
    }

    #[test]
    fn precision_taint_is_per_fn_and_ordered() {
        // The taint does not leak across fn spans, and a use *before*
        // the binding (shadowing in a later statement) does not fire.
        let v = precision_sites(&toks(
            "fn a(y: f64) { let x = y as f32; }\n\
             fn b(x: f64) -> f64 { x as f64 }",
        ));
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn spawn_blessing() {
        let bad = unblessed_spawn_sites(&toks(
            "fn run() { std::thread::spawn(move || work()); }",
        ));
        assert_eq!(bad, vec![0]);
        let ok = unblessed_spawn_sites(&toks(
            "impl PlannerWorker {\n\
             fn spawn(self) { std::thread::spawn(move || self.run()); }\n\
             }\n\
             impl ThreadPool {\n\
             pub fn new(n: usize) { std::thread::spawn(move || loop {}); }\n\
             }",
        ));
        assert!(ok.is_empty(), "{ok:?}");
        // An unrelated impl does not bless.
        let bad = unblessed_spawn_sites(&toks(
            "impl DataMover {\n\
             fn start(&self) { std::thread::spawn(move || pump()); }\n\
             }",
        ));
        assert_eq!(bad, vec![1]);
        // Scoped spawns are out of pattern by design.
        let ok = unblessed_spawn_sites(&toks(
            "fn run() { std::thread::scope(|s| { s.spawn(|| work()); }); }",
        ));
        assert!(ok.is_empty(), "{ok:?}");
    }
}
