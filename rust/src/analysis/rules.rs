//! The seven repo-specific lint rules and their detection logic.
//!
//! Each rule encodes an invariant the ROADMAP's engine/simulator/cost-model
//! agreement rests on; see the README's "Static analysis & invariants"
//! section for the rationale and the per-rule scopes.

use super::lexer::{ident_occurrences, is_ident_char, Line};

/// A lint rule. Names are the stable identifiers used in allow
/// directives and the ratchet baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `Instant::now` / `SystemTime::now` in virtual-clock modules.
    WallClockInSim,
    /// `HashMap` / `HashSet` in deterministic modules or tests.
    UnorderedIteration,
    /// A `pub *_time: f64` lane on `PassRecord` missing from
    /// `lanes_total()`, from `to_csv()`, or from the CSV header string
    /// (a lane summed into the row but unnamed in the header drifts
    /// silently in offline plots).
    LanePartition,
    /// `as u64` / `as usize` / `as f64` in accounting modules.
    UncheckedCast,
    /// `.unwrap()` / `.expect(` in library hot paths outside tests.
    PanicPolicy,
    /// Direct `==` / `!=` against a float literal.
    FloatEq,
    /// An `unsafe` block or `unsafe impl` in `src/` without a
    /// `// Safety:` comment on it or on the comment block directly above.
    UndocumentedUnsafe,
}

impl Rule {
    pub const ALL: [Rule; 7] = [
        Rule::WallClockInSim,
        Rule::UnorderedIteration,
        Rule::LanePartition,
        Rule::UncheckedCast,
        Rule::PanicPolicy,
        Rule::FloatEq,
        Rule::UndocumentedUnsafe,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::WallClockInSim => "wall-clock-in-sim",
            Rule::UnorderedIteration => "unordered-iteration",
            Rule::LanePartition => "lane-partition",
            Rule::UncheckedCast => "unchecked-cast",
            Rule::PanicPolicy => "panic-policy",
            Rule::FloatEq => "float-eq",
            Rule::UndocumentedUnsafe => "undocumented-unsafe",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.name() == name)
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the crate root, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// What matched (pattern or field name).
    pub detail: String,
}

/// Modules whose time must be virtual/replayable (wall-clock and
/// unordered-iteration scope).
pub const DET_MODULES: &[&str] =
    &["simhw", "perfmodel", "baselines", "sched", "kvcache", "workload"];
/// Accounting / cost-model modules (unchecked-cast scope).
pub const CAST_MODULES: &[&str] = &["metrics", "perfmodel", "simhw", "sched", "kvcache"];
/// Library hot paths (panic-policy scope).
pub const PANIC_MODULES: &[&str] = &["engine", "sched", "kvcache", "transfer"];

/// Does `rel` (crate-relative path) live in one of `modules` under src/?
pub fn in_modules(rel: &str, modules: &[&str]) -> bool {
    modules.iter().any(|m| {
        let file = format!("src/{m}.rs");
        let dir = format!("src/{m}/");
        rel == file || rel.starts_with(&dir)
    })
}

// ---------------------------------------------------------------------------
// float-eq
// ---------------------------------------------------------------------------

/// The (possibly dotted) token ending just left of char position `pos`.
fn token_left(chars: &[char], pos: usize) -> String {
    let mut j = pos;
    while j > 0 && chars[j - 1] == ' ' {
        j -= 1;
    }
    let mut k = j;
    while k > 0 && (is_ident_char(chars[k - 1]) || chars[k - 1] == '.') {
        k -= 1;
    }
    chars[k..j].iter().collect()
}

/// The (possibly signed, dotted) token starting just right of `pos`.
fn token_right(chars: &[char], pos: usize) -> String {
    let mut j = pos;
    while j < chars.len() && chars[j] == ' ' {
        j += 1;
    }
    let mut k = j;
    if k < chars.len() && (chars[k] == '+' || chars[k] == '-') {
        k += 1;
    }
    while k < chars.len() && (is_ident_char(chars[k]) || chars[k] == '.') {
        k += 1;
    }
    chars[j..k].iter().collect()
}

/// Is `tok` a float literal (`0.0`, `1e-9`, `2.5f64`, `-1.0`, `9e15`)?
fn is_float_lit(tok: &str) -> bool {
    let mut t = tok;
    if let Some(s) = t.strip_prefix('+').or_else(|| t.strip_prefix('-')) {
        t = s;
    }
    let no_sep = t.replace('_', "");
    let mut t = no_sep.as_str();
    for suf in ["f64", "f32"] {
        if let Some(s) = t.strip_suffix(suf) {
            t = s;
            break;
        }
    }
    let Some(first) = t.chars().next() else {
        return false;
    };
    if !first.is_ascii_digit() {
        return false;
    }
    if !t.contains('.') && !t.contains('e') && !t.contains('E') {
        return false;
    }
    t.parse::<f64>().is_ok()
}

/// Char positions of `==` / `!=` operators whose left or right operand is
/// a float literal. `<=`, `>=`, and pattern `=>`s never match; `==` runs
/// (`===`) are skipped defensively.
pub fn float_eq_positions(code: &str) -> Vec<usize> {
    let chars: Vec<char> = code.chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < n {
        let (a, b) = (chars[i], chars[i + 1]);
        if a == '=' && b == '=' {
            if i > 0 && matches!(chars[i - 1], '<' | '>' | '!' | '=') {
                i += 1;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '=' {
                i += 3;
                continue;
            }
        } else if a == '!' && b == '=' {
            if i + 2 < n && chars[i + 2] == '=' {
                i += 3;
                continue;
            }
        } else {
            i += 1;
            continue;
        }
        let lt = token_left(&chars, i);
        let rt = token_right(&chars, i + 2);
        if is_float_lit(&lt) || is_float_lit(&rt) {
            out.push(i);
        }
        i += 2;
    }
    out
}

// ---------------------------------------------------------------------------
// unchecked-cast
// ---------------------------------------------------------------------------

/// Count `as u64` / `as usize` / `as f64` cast sites on a scrubbed line.
pub fn cast_sites(code: &str) -> usize {
    let chars: Vec<char> = code.chars().collect();
    ident_occurrences(code, "as")
        .into_iter()
        .filter(|&k| {
            let ty = token_right(&chars, k + 2);
            matches!(ty.as_str(), "u64" | "usize" | "f64")
        })
        .count()
}

// ---------------------------------------------------------------------------
// undocumented-unsafe
// ---------------------------------------------------------------------------

/// Char positions of `unsafe` keywords that open a block or an `unsafe
/// impl` on a scrubbed line. Declarations (`unsafe fn` / `unsafe trait` /
/// `unsafe extern`) are the *callee* side of the contract — their `#
/// Safety` doc section is rustdoc's (and clippy's) concern — so they are
/// exempt; every *use* site must carry a `// Safety:` comment.
pub fn unsafe_sites(code: &str) -> Vec<usize> {
    let chars: Vec<char> = code.chars().collect();
    ident_occurrences(code, "unsafe")
        .into_iter()
        .filter(|&k| {
            let next = token_right(&chars, k + 6);
            !matches!(next.as_str(), "fn" | "trait" | "extern")
        })
        .collect()
}

// ---------------------------------------------------------------------------
// lane-partition
// ---------------------------------------------------------------------------

/// Inclusive line span of `fn name`, signature line through the
/// brace-matched closing line, or None if the file does not define it.
fn find_fn_span(lines: &[Line], name: &str) -> Option<(usize, usize)> {
    let mut sig = None;
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if ident_occurrences(code, "fn").is_empty() || ident_occurrences(code, name).is_empty() {
            continue;
        }
        if let Some(kfn) = code.find("fn ") {
            if code[kfn..].find(name).is_some_and(|off| off > 0) {
                sig = Some(idx);
                break;
            }
        }
    }
    let sig = sig?;
    let mut depth: i64 = 0;
    let mut opened = false;
    for (off, line) in lines[sig..].iter().enumerate() {
        for ch in line.code.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            return Some((sig, sig + off));
        }
    }
    Some((sig, lines.len().saturating_sub(1)))
}

/// Code text of `fn name`'s brace-matched body (signature line included),
/// or None if the file does not define it.
fn find_fn_body(lines: &[Line], name: &str) -> Option<String> {
    let (lo, hi) = find_fn_span(lines, name)?;
    let mut body = String::new();
    for line in &lines[lo..=hi] {
        body.push_str(&line.code);
        body.push(' ');
    }
    Some(body)
}

/// Lane-partition violations: every `pub *_time: f64` field declared on a
/// `PassRecord` struct in this file must appear in `lanes_total()`, in
/// `to_csv()`, *and* — by name — in the CSV header string inside
/// `to_csv()`. Header text lives in a string literal, which the scrubber
/// blanks out of the code channel, so the header check reads `src` (the
/// raw source the `lines` were scrubbed from): an ident-boundary
/// occurrence in the raw `to_csv` body that is in neither the code nor
/// the comment channel can only sit inside a string literal.
/// Returns (0-based field line, field name, missing-from).
pub fn lane_partition(lines: &[Line], src: &str) -> Vec<(usize, String, &'static str)> {
    let mut start = None;
    for (idx, line) in lines.iter().enumerate() {
        let t = line.code.trim();
        let tail = if let Some(r) = t.strip_prefix("pub struct PassRecord") {
            r
        } else if let Some(r) = t.strip_prefix("struct PassRecord") {
            r
        } else {
            continue;
        };
        // Reject PassRecordFoo etc.
        if tail.chars().next().is_none_or(|c| !is_ident_char(c)) {
            start = Some(idx);
            break;
        }
    }
    let Some(start) = start else {
        return Vec::new();
    };
    let mut depth: i64 = 0;
    let mut opened = false;
    let mut fields: Vec<(usize, String)> = Vec::new();
    for (off, line) in lines[start..].iter().enumerate() {
        let t = line.code.trim();
        if opened && depth == 1 && t.starts_with("pub ") {
            if let Some(colon) = t.find(':') {
                let name = t[4..colon].trim().to_string();
                let ty = &t[colon + 1..];
                if name.ends_with("_time") && !ident_occurrences(ty, "f64").is_empty() {
                    fields.push((start + off, name));
                }
            }
        }
        for ch in line.code.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    let lanes = find_fn_body(lines, "lanes_total");
    let csv = find_fn_body(lines, "to_csv");
    let csv_span = find_fn_span(lines, "to_csv");
    let raw: Vec<&str> = src.split('\n').collect();
    // True iff `name` occurs inside a string literal somewhere in the
    // `to_csv` body: raw occurrences on a line beyond what the code and
    // comment channels account for must be literal text.
    let in_csv_header = |name: &str| -> bool {
        let Some((lo, hi)) = csv_span else {
            return false;
        };
        lines[lo..=hi].iter().enumerate().any(|(off, line)| {
            let rawl = raw.get(lo + off).copied().unwrap_or("");
            ident_occurrences(rawl, name).len()
                > ident_occurrences(&line.code, name).len()
                    + ident_occurrences(&line.comment, name).len()
        })
    };
    let mut out = Vec::new();
    for (idx, name) in fields {
        let in_lanes = lanes
            .as_deref()
            .is_some_and(|b| !ident_occurrences(b, &name).is_empty());
        if !in_lanes {
            out.push((idx, name.clone(), "lanes_total"));
        }
        let in_csv = csv
            .as_deref()
            .is_some_and(|b| !ident_occurrences(b, &name).is_empty());
        if !in_csv {
            out.push((idx, name, "to_csv"));
        } else if !in_csv_header(&name) {
            out.push((idx, name, "to_csv header"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::scrub;

    #[test]
    fn rule_names_round_trip() {
        for r in Rule::ALL {
            assert_eq!(Rule::from_name(r.name()), Some(r));
        }
        assert_eq!(Rule::from_name("nope"), None);
    }

    #[test]
    fn module_scoping() {
        assert!(in_modules("src/sched/policy.rs", DET_MODULES));
        assert!(in_modules("src/simhw.rs", DET_MODULES));
        assert!(!in_modules("src/schedx/policy.rs", DET_MODULES));
        assert!(!in_modules("src/engine/batch.rs", DET_MODULES));
        assert!(!in_modules("benches/sched/x.rs", DET_MODULES));
    }

    #[test]
    fn float_eq_detection() {
        assert_eq!(float_eq_positions("if t == 0.0 {").len(), 1);
        assert_eq!(float_eq_positions("x != 0.5").len(), 1);
        assert_eq!(float_eq_positions("x == 9e15").len(), 1);
        assert_eq!(float_eq_positions("x == 2.5f64").len(), 1);
        // Known hole: a negative exponent stops the token scan ("1e" is
        // not a float literal), so `!= 1e-9` slips through.
        assert_eq!(float_eq_positions("x != 1e-9").len(), 0);
        assert_eq!(float_eq_positions("n == 0").len(), 0, "integer compare");
        assert_eq!(float_eq_positions("t <= 0.0 || t >= 1.0").len(), 0);
        assert_eq!(float_eq_positions("(a - b).abs() < 1e-9").len(), 0);
        assert_eq!(float_eq_positions("match x { 0.5 => 1, _ => 0 }").len(), 0);
        assert_eq!(float_eq_positions("0.0 == x").len(), 1, "literal on the left");
    }

    #[test]
    fn cast_detection() {
        assert_eq!(cast_sites("let x = n as f64 / m as f64;"), 2);
        assert_eq!(cast_sites("let x = n as u32;"), 0, "widening to u32 not flagged");
        assert_eq!(cast_sites("let y = b as usize + 1;"), 1);
        assert_eq!(cast_sites("alias u64"), 0, "ident boundary");
    }

    #[test]
    fn unsafe_site_detection() {
        assert_eq!(unsafe_sites("let x = unsafe { *p };").len(), 1);
        assert_eq!(unsafe_sites("unsafe impl Send for Batch {}").len(), 1);
        assert_eq!(unsafe_sites("unsafe").len(), 1, "block opening on next line");
        assert_eq!(unsafe_sites("pub unsafe fn dot(q: &[f32]) -> f32 {").len(), 0);
        assert_eq!(unsafe_sites("unsafe trait Zeroable {}").len(), 0);
        assert_eq!(unsafe_sites("unsafe extern \"C\" {}").len(), 0);
        assert_eq!(unsafe_sites("let unsafer = 1;").len(), 0, "ident boundary");
    }

    fn lanes(src: &str) -> Vec<(usize, String, &'static str)> {
        lane_partition(&scrub(src), src)
    }

    #[test]
    fn lane_partition_flags_drift() {
        let src = "\
pub struct PassRecord {
    pub io_time: f64,
    pub gpu_time: f64,
    pub count: usize,
}
impl PassRecord {
    pub fn lanes_total(&self) -> f64 { self.io_time }
    pub fn to_csv(&self) -> String { format!(\"io_time={}\", self.io_time) }
}
";
        let v = lanes(src);
        // gpu_time missing from both; io_time fine; count not a lane.
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|(_, name, _)| name == "gpu_time"));
        let missing: Vec<&str> = v.iter().map(|(_, _, m)| *m).collect();
        assert!(missing.contains(&"lanes_total") && missing.contains(&"to_csv"));
    }

    #[test]
    fn lane_partition_ident_boundary() {
        // A shadow lane whose name embeds a real lane's name must not
        // borrow that lane's membership — in code or in the header.
        let src = "\
pub struct PassRecord {
    pub overlap_time: f64,
    pub host_overlap_time: f64,
}
impl PassRecord {
    pub fn lanes_total(&self) -> f64 { self.overlap_time + self.host_overlap_time }
    pub fn to_csv(&self) -> String { format!(\"host_overlap_time={}\", self.host_overlap_time) }
}
";
        let v = lanes(src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].1, "overlap_time");
        assert_eq!(v[0].2, "to_csv");
    }

    #[test]
    fn lane_partition_requires_csv_header_naming() {
        // A lane summed into the CSV row but unnamed in the header string
        // drifts silently in offline plots. The header lives in a string
        // literal — invisible to the scrubbed code channel — so the check
        // reads the raw to_csv body. A comment naming the lane must NOT
        // satisfy it.
        let src = "\
pub struct PassRecord {
    pub io_time: f64,
    pub gpu_time: f64,
}
impl PassRecord {
    pub fn lanes_total(&self) -> f64 { self.io_time + self.gpu_time }
    pub fn to_csv(&self) -> String {
        // gpu_time is appended to the row below
        format!(\"io_time,{},{}\", self.io_time, self.gpu_time)
    }
}
";
        let v = lanes(src);
        assert_eq!(v.len(), 1, "findings: {v:?}");
        assert_eq!(v[0].1, "gpu_time");
        assert_eq!(v[0].2, "to_csv header");
    }

    #[test]
    fn no_passrecord_no_findings() {
        assert!(lanes("pub struct Other { pub t_time: f64 }").is_empty());
        assert!(lanes("pub struct PassRecordX { pub a_time: f64 }").is_empty());
    }
}
