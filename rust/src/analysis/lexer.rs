//! Line-level lexical scrubber for `pallas-lint`.
//!
//! The linter matches patterns against *code only*: string literals, char
//! literals, and comments are blanked to spaces first, so `"HashMap"` in a
//! doc comment or an error message never fires a rule. The scrubber is a
//! small state machine over the raw source — it understands `//` and
//! nested `/* */` comments, plain/byte/raw strings (`"…"`, `b"…"`,
//! `r#"…"#`, `br#"…"#`), char and byte-char literals, escapes (including
//! string line-continuations, which must still break lines so line
//! numbers stay exact), and the char-literal-vs-lifetime ambiguity of
//! `'`.
//!
//! Comment *text* is kept separately per line because suppression
//! directives live in comments: e.g. `// pallas-lint: allow(float-eq)`
//! on the violating line or the line directly above it. Directive names
//! are validated against the rule set at scan time, so this example must
//! name a real rule.

/// One source line after scrubbing.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// The line with non-code characters blanked to spaces.
    pub code: String,
    /// The comment text of the line (for allow-directive parsing).
    pub comment: String,
}

/// Rust identifier-continuation characters (the repo is ASCII-only).
pub fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

#[derive(PartialEq, Clone, Copy)]
enum State {
    Normal,
    LineComment,
    Block,
    Str,
    RawStr,
}

/// Scrub `src` into per-line (code, comment) pairs. The output always has
/// one trailing entry for the (possibly empty) final line, matching
/// `src.split('\n')` line numbering.
pub fn scrub(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines: Vec<Line> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut depth = 0usize; // block-comment nesting
    let mut hashes = 0usize; // raw-string terminator hashes
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if state == State::LineComment {
                state = State::Normal;
            }
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match state {
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::Block => {
                if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                    if depth == 0 {
                        state = State::Normal;
                    }
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < n {
                    // Escape: swallow the next char too — unless it is a
                    // newline (string line-continuation), which must still
                    // break the line so line numbers stay exact.
                    code.push(' ');
                    i += 1;
                    if chars[i] != '\n' {
                        code.push(' ');
                        i += 1;
                    }
                } else if c == '"' {
                    code.push(' ');
                    state = State::Normal;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr => {
                let closes = c == '"'
                    && i + hashes < n
                    && chars[i + 1..i + 1 + hashes].iter().all(|&h| h == '#');
                if closes {
                    for _ in 0..=hashes {
                        code.push(' ');
                    }
                    i += 1 + hashes;
                    state = State::Normal;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::Normal => {
                let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
                let prev_ident = i > 0 && is_ident_char(chars[i - 1]);
                if c == '/' && nxt == '/' {
                    state = State::LineComment;
                    code.push_str("  ");
                    i += 2;
                } else if c == '/' && nxt == '*' {
                    state = State::Block;
                    depth = 1;
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push(' ');
                    i += 1;
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    if nxt == '\\' {
                        // '\X…': the closing quote is the first ' at
                        // index >= i+3 (covers '\'', '\\', '\u{…}').
                        let mut j = i + 3;
                        while j < n && chars[j] != '\'' {
                            j += 1;
                        }
                        for _ in i..(j + 1) {
                            code.push(' ');
                        }
                        i = j + 1;
                    } else if nxt != '\0' && nxt != '\'' && i + 2 < n && chars[i + 2] == '\'' {
                        // Plain 'X' char literal.
                        code.push_str("   ");
                        i += 3;
                    } else {
                        // Lifetime quote ('a in types/bounds).
                        code.push(' ');
                        i += 1;
                    }
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // Raw / byte string prefixes: r"…", r#"…"#, b"…",
                    // br#"…"#, and byte-char b'x'.
                    let isb = c == 'b';
                    let mut j = i + 1;
                    if isb && nxt == 'r' {
                        j = i + 2;
                    }
                    let mut consumed = false;
                    if !isb || nxt == 'r' {
                        let mut h = 0usize;
                        while j + h < n && chars[j + h] == '#' {
                            h += 1;
                        }
                        if j + h < n && chars[j + h] == '"' {
                            for _ in i..(j + h + 1) {
                                code.push(' ');
                            }
                            i = j + h + 1;
                            state = State::RawStr;
                            hashes = h;
                            consumed = true;
                        }
                    }
                    if !consumed {
                        if isb && nxt == '"' {
                            code.push_str("  ");
                            i += 2;
                            state = State::Str;
                        } else if isb && nxt == '\'' {
                            // b'X': blank the b; the quote is handled next
                            // round as a char literal.
                            code.push(' ');
                            i += 1;
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    lines.push(Line { code, comment });
    lines
}

/// Per-line flag: does the line start inside (or armed for) a
/// `#[cfg(test)]` item? Armed means the attribute was seen and the next
/// `{` opens the exempted region; a `;` before any `{` disarms (e.g.
/// `#[cfg(test)] use …;`).
pub fn test_regions(lines: &[Line]) -> Vec<bool> {
    let mut out = Vec::with_capacity(lines.len());
    let mut depth: i64 = 0;
    let mut stack: Vec<i64> = Vec::new();
    let mut armed = false;
    for line in lines {
        out.push(!stack.is_empty() || armed);
        if line.code.contains("#[cfg(test)]") {
            armed = true;
        }
        for ch in line.code.chars() {
            if ch == '{' {
                if armed {
                    stack.push(depth);
                    armed = false;
                }
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
                if stack.last() == Some(&depth) {
                    stack.pop();
                }
            } else if ch == ';' && armed {
                armed = false;
            }
        }
    }
    out
}

/// Is a violation of `rule` on 0-based line `lineno` suppressed by a
/// `pallas-lint: allow` directive on that line or the line above?
pub fn allows(lines: &[Line], lineno: usize, rule: &str) -> bool {
    let lo = lineno.saturating_sub(1);
    for line in &lines[lo..=lineno.min(lines.len() - 1)] {
        let comment = &line.comment;
        let Some(k) = comment.find("pallas-lint: allow(") else {
            continue;
        };
        let rest = &comment[k + "pallas-lint: allow(".len()..];
        let Some(close) = rest.find(')') else {
            continue;
        };
        if rest[..close].split(',').any(|s| s.trim() == rule) {
            return true;
        }
    }
    false
}

/// Does `comment` contain `marker` *as a justification marker* — i.e. not
/// immediately followed by another `:`? The guard matters for markers
/// ending in a colon: a comment that merely *mentions*
/// `Ordering::Relaxed` contains the substring `Ordering:` but is path
/// syntax, not a justification.
fn comment_has_marker(comment: &str, marker: &str) -> bool {
    let mut start = 0usize;
    while let Some(k) = comment[start..].find(marker) {
        let end = start + k + marker.len();
        if !comment[end..].starts_with(':') {
            return true;
        }
        start = end;
    }
    false
}

/// Does 0-based line `idx` carry a `marker` justification comment — on
/// the line itself, or on the contiguous comment block ending directly
/// above it? A code line directly above counts only via its trailing
/// comment; a blank line breaks the block (the justification must
/// visibly attach to the site it covers). Used with `"Safety:"` for
/// unsafe blocks and `"Ordering:"` for atomic memory orderings.
pub fn has_marker_doc(lines: &[Line], idx: usize, marker: &str) -> bool {
    if comment_has_marker(&lines[idx].comment, marker) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if comment_has_marker(&l.comment, marker) {
            return true;
        }
        if !l.code.trim().is_empty() {
            return false; // code line: its trailing comment was just checked
        }
        if l.comment.is_empty() {
            return false; // blank line breaks the comment block
        }
    }
    false
}

/// `has_marker_doc` specialized to the `// Safety:` discipline.
pub fn has_safety_doc(lines: &[Line], idx: usize) -> bool {
    has_marker_doc(lines, idx, "Safety:")
}

/// Positions (char indices) where `pat` occurs in `line` with identifier
/// boundaries on both sides — so `overlap_time` does not match inside
/// `host_overlap_time`.
pub fn ident_occurrences(line: &str, pat: &str) -> Vec<usize> {
    let chars: Vec<char> = line.chars().collect();
    let p: Vec<char> = pat.chars().collect();
    let mut out = Vec::new();
    if p.is_empty() || chars.len() < p.len() {
        return out;
    }
    let mut k = 0usize;
    while k + p.len() <= chars.len() {
        if chars[k..k + p.len()] == p[..] {
            let lb = k == 0 || !is_ident_char(chars[k - 1]);
            let rb = k + p.len() == chars.len() || !is_ident_char(chars[k + p.len()]);
            if lb && rb {
                out.push(k);
            }
        }
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        scrub(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let c = codes("let x = \"HashMap\"; // HashMap here\nuse foo;");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains("let x ="));
        assert_eq!(c[1], "use foo;");
        let l = scrub("x(); // pallas-lint: allow(float-eq)");
        assert!(l[0].comment.contains("pallas-lint: allow(float-eq)"));
    }

    #[test]
    fn nested_block_comments() {
        // Comment chars are captured as comment text, not kept in code
        // (a separating space remains, so tokens never concatenate).
        let c = codes("a /* x /* y */ z */ b");
        assert_eq!(c[0], "a    b");
        assert!(!c[0].contains('x') && !c[0].contains('z'));
    }

    #[test]
    fn string_line_continuation_keeps_line_count() {
        let src = "let s = \"a\\\n   b\";\nnext();";
        let c = codes(src);
        assert_eq!(c.len(), 3, "continuation must still break lines");
        assert_eq!(c[2], "next();");
    }

    #[test]
    fn raw_strings_and_byte_strings() {
        let c = codes("let r = r#\"as u64 == 0.0\"#; let b = b\"x\"; let br = br##\"y\"##;");
        assert!(!c[0].contains("u64") && !c[0].contains("0.0"));
        assert!(c[0].contains("let r =") && c[0].contains("let br ="));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let c = codes("fn f<'a>(x: &'a str) -> char { if y == '\"' { 'z' } else { '\\n' } }");
        assert!(c[0].contains("-> char") && c[0].contains("if y =="));
        assert!(!c[0].contains('z'));
        // The quote inside the char literal must not open a string.
        assert!(c[0].contains("else"));
    }

    #[test]
    fn cfg_test_regions() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}";
        let lines = scrub(src);
        let t = test_regions(&lines);
        assert_eq!(t, vec![false, false, true, true, true, false]);
    }

    #[test]
    fn cfg_test_on_use_item_disarms() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn a() {}";
        let t = test_regions(&scrub(src));
        assert!(!t[2], "a `;` before `{{` must disarm");
    }

    #[test]
    fn allow_parses_multiple_rules_and_previous_line() {
        let lines = scrub("// pallas-lint: allow(float-eq, unchecked-cast)\nx == 0.0;\ny;");
        assert!(allows(&lines, 1, "float-eq"));
        assert!(allows(&lines, 1, "unchecked-cast"));
        assert!(!allows(&lines, 1, "panic-policy"));
        assert!(!allows(&lines, 2, "float-eq"), "allow reaches one line only");
    }

    #[test]
    fn safety_doc_attachment() {
        let lines = scrub(
            "unsafe { a() } // Safety: same line\n\
             // Safety: line above\n\
             unsafe { b() }\n\
             // Safety: a multi-line justification that\n\
             // spills onto a second comment line.\n\
             unsafe { c() }\n\
             // Safety: detached by a blank line\n\
             \n\
             unsafe { d() }\n\
             let x = 1; // Safety: trailing on the code line above\n\
             unsafe { e() }\n\
             unsafe { f() }",
        );
        assert!(has_safety_doc(&lines, 0), "same line");
        assert!(has_safety_doc(&lines, 2), "line directly above");
        assert!(has_safety_doc(&lines, 5), "comment block ending above");
        assert!(!has_safety_doc(&lines, 8), "blank line breaks the block");
        assert!(has_safety_doc(&lines, 10), "trailing comment on code line above");
        assert!(!has_safety_doc(&lines, 11), "undocumented");
    }

    #[test]
    fn marker_doc_rejects_path_syntax() {
        // A comment *mentioning* Ordering::Relaxed contains "Ordering:"
        // as a substring but is path syntax, not a justification.
        let lines = scrub(
            "// uses Ordering::Relaxed here\n\
             x.store(1, Ordering::Relaxed);\n\
             // Ordering: counter, no other memory depends on it\n\
             y.store(1, Ordering::Relaxed);\n\
             z.store(1, Ordering::Relaxed); // Ordering: same-line form\n\
             // mentions Ordering::Relaxed but then — Ordering: justified\n\
             w.store(1, Ordering::Relaxed);",
        );
        assert!(!has_marker_doc(&lines, 1, "Ordering:"), "path mention is not a doc");
        assert!(has_marker_doc(&lines, 3, "Ordering:"), "line above");
        assert!(has_marker_doc(&lines, 4, "Ordering:"), "same line");
        assert!(has_marker_doc(&lines, 6, "Ordering:"), "marker after a path mention");
    }

    #[test]
    fn ident_boundaries() {
        assert_eq!(ident_occurrences("host_overlap_time + x", "overlap_time").len(), 0);
        assert_eq!(ident_occurrences("overlap_time + overlap_time", "overlap_time").len(), 2);
        assert_eq!(ident_occurrences("y as u64", "as").len(), 1);
        assert_eq!(ident_occurrences("alias u64", "as").len(), 0);
    }
}
