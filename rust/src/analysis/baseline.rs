//! The ratchet baseline: committed per-file-per-rule violation counts.
//!
//! `lint-baseline.json` absorbs pre-existing debt so `--check` fails only
//! on *increases* (new violations) or *staleness* (counts above actual —
//! debt was paid down but the file not refreshed, which would let new
//! violations hide in the slack). `--update-baseline` rewrites the file
//! from the actual counts but refuses to raise any entry: the ratchet
//! only turns one way.
//!
//! Counts are per file and rule, not per line, so unrelated edits that
//! shift line numbers never churn the baseline.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::util::json::Json;

use super::Counts;

/// Baseline file name, resolved against the crate root.
pub const BASELINE_FILE: &str = "lint-baseline.json";

/// One count mismatch between the baseline and the actual scan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Regression {
    pub file: String,
    pub rule: String,
    pub baseline: usize,
    pub actual: usize,
}

/// Outcome of checking actual counts against the baseline.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    /// Entries where actual > baseline: new violations.
    pub regressions: Vec<Regression>,
    /// Entries where baseline > actual: stale debt records.
    pub stale: Vec<Regression>,
}

impl CheckReport {
    pub fn is_clean(&self) -> bool {
        self.regressions.is_empty() && self.stale.is_empty()
    }
}

/// The committed ratchet state.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    pub files: Counts,
}

impl Baseline {
    /// Build from scan counts, dropping empty entries.
    pub fn from_counts(counts: &Counts) -> Self {
        let mut files = Counts::new();
        for (file, rules) in counts {
            let kept: BTreeMap<String, usize> =
                rules.iter().filter(|&(_, &n)| n > 0).map(|(r, &n)| (r.clone(), n)).collect();
            if !kept.is_empty() {
                files.insert(file.clone(), kept);
            }
        }
        Baseline { files }
    }

    pub fn total(&self) -> usize {
        self.files.values().flat_map(|m| m.values()).sum()
    }

    /// Parse the baseline JSON (as written by [`to_pretty_json`]).
    ///
    /// [`to_pretty_json`]: Self::to_pretty_json
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let files_j = j.get("files").ok_or_else(|| "baseline missing 'files' key".to_string())?;
        let obj = files_j.as_obj().ok_or_else(|| "'files' must be an object".to_string())?;
        let mut files = Counts::new();
        for (file, rules_j) in obj {
            let rules_obj = rules_j
                .as_obj()
                .ok_or_else(|| format!("baseline entry for '{file}' must be an object"))?;
            let mut m = BTreeMap::new();
            for (rule, n) in rules_obj {
                let count = n
                    .as_usize()
                    .ok_or_else(|| format!("count for '{file}'/'{rule}' must be a number"))?;
                m.insert(rule.clone(), count);
            }
            files.insert(file.clone(), m);
        }
        Ok(Baseline { files })
    }

    /// Load from `path`. A missing file is an error — run
    /// `--update-baseline` to create it.
    pub fn load(path: &Path) -> Result<Baseline, String> {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Serialize in the stable committed format (sorted keys, 2-space
    /// indent, trailing newline).
    pub fn to_pretty_json(&self) -> String {
        let mut s = String::from("{\n  \"version\": 1,\n  \"files\": {");
        if self.files.is_empty() {
            s.push_str("}\n}\n");
            return s;
        }
        s.push('\n');
        let nf = self.files.len();
        for (fi, (file, rules)) in self.files.iter().enumerate() {
            s.push_str(&format!("    {}: {{\n", Json::Str(file.clone())));
            let nr = rules.len();
            for (ri, (rule, n)) in rules.iter().enumerate() {
                let comma = if ri + 1 < nr { "," } else { "" };
                s.push_str(&format!("      {}: {n}{comma}\n", Json::Str(rule.clone())));
            }
            s.push_str(if fi + 1 < nf { "    },\n" } else { "    }\n" });
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Compare actual scan counts against this baseline.
    pub fn check(&self, actual: &Counts) -> CheckReport {
        let mut report = CheckReport::default();
        let mut keys: Vec<(&String, &String)> = Vec::new();
        for (file, rules) in &self.files {
            for rule in rules.keys() {
                keys.push((file, rule));
            }
        }
        for (file, rules) in actual {
            for rule in rules.keys() {
                if !self.files.get(file).is_some_and(|m| m.contains_key(rule)) {
                    keys.push((file, rule));
                }
            }
        }
        keys.sort();
        keys.dedup();
        for (file, rule) in keys {
            let base = self.files.get(file).and_then(|m| m.get(rule)).copied().unwrap_or(0);
            let act = actual.get(file).and_then(|m| m.get(rule)).copied().unwrap_or(0);
            let entry = Regression {
                file: file.clone(),
                rule: rule.clone(),
                baseline: base,
                actual: act,
            };
            if act > base {
                report.regressions.push(entry);
            } else if base > act {
                report.stale.push(entry);
            }
        }
        report
    }

    /// A refreshed baseline from `actual`, refusing to raise any count
    /// (the ratchet only burns down). On refusal, returns the offending
    /// entries.
    pub fn updated(&self, actual: &Counts) -> Result<Baseline, Vec<Regression>> {
        let report = self.check(actual);
        if report.regressions.is_empty() {
            Ok(Baseline::from_counts(actual))
        } else {
            Err(report.regressions)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(entries: &[(&str, &str, usize)]) -> Counts {
        let mut c = Counts::new();
        for &(f, r, n) in entries {
            c.entry(f.to_string()).or_default().insert(r.to_string(), n);
        }
        c
    }

    #[test]
    fn json_round_trip() {
        let b = Baseline::from_counts(&counts(&[
            ("src/a.rs", "panic-policy", 2),
            ("src/a.rs", "unchecked-cast", 5),
            ("src/b.rs", "float-eq", 1),
        ]));
        let text = b.to_pretty_json();
        assert_eq!(Baseline::parse(&text).unwrap(), b);
        assert!(text.ends_with("}\n"));
        assert!(text.contains("\"version\": 1"));
        // Stable output: serializing twice is byte-identical.
        assert_eq!(text, Baseline::parse(&text).unwrap().to_pretty_json());
    }

    #[test]
    fn empty_baseline_round_trips() {
        let b = Baseline::default();
        assert_eq!(Baseline::parse(&b.to_pretty_json()).unwrap(), b);
        assert_eq!(b.total(), 0);
    }

    #[test]
    fn zero_counts_are_dropped() {
        let b = Baseline::from_counts(&counts(&[("src/a.rs", "float-eq", 0)]));
        assert!(b.files.is_empty());
    }

    #[test]
    fn check_flags_regressions_and_staleness() {
        let base = Baseline::from_counts(&counts(&[("src/a.rs", "panic-policy", 2)]));
        // Equal: clean.
        assert!(base.check(&counts(&[("src/a.rs", "panic-policy", 2)])).is_clean());
        // Increase: regression.
        let r = base.check(&counts(&[("src/a.rs", "panic-policy", 3)]));
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].baseline, 2);
        assert_eq!(r.regressions[0].actual, 3);
        assert!(r.stale.is_empty());
        // Decrease: stale (baseline must be refreshed).
        let s = base.check(&counts(&[("src/a.rs", "panic-policy", 1)]));
        assert!(s.regressions.is_empty());
        assert_eq!(s.stale.len(), 1);
        // New file with violations: regression from an implicit 0.
        let n = base.check(&counts(&[
            ("src/a.rs", "panic-policy", 2),
            ("src/new.rs", "float-eq", 1),
        ]));
        assert_eq!(n.regressions.len(), 1);
        assert_eq!(n.regressions[0].file, "src/new.rs");
        // File fixed entirely: stale entry from an implicit 0.
        let gone = base.check(&Counts::new());
        assert_eq!(gone.stale.len(), 1);
        assert_eq!(gone.stale[0].actual, 0);
    }

    #[test]
    fn update_permits_decreases_and_refuses_increases() {
        let base = Baseline::from_counts(&counts(&[("src/a.rs", "panic-policy", 2)]));
        let down = base.updated(&counts(&[("src/a.rs", "panic-policy", 1)])).unwrap();
        assert_eq!(down.files["src/a.rs"]["panic-policy"], 1);
        let err = base.updated(&counts(&[("src/a.rs", "panic-policy", 4)])).unwrap_err();
        assert_eq!(err.len(), 1);
        assert_eq!(err[0].actual, 4);
    }

    #[test]
    fn parse_rejects_malformed_baselines() {
        assert!(Baseline::parse("{}").is_err(), "missing files key");
        assert!(Baseline::parse("{\"files\": 3}").is_err());
        assert!(Baseline::parse("{\"files\": {\"a.rs\": 1}}").is_err());
        assert!(Baseline::parse("{\"files\": {\"a.rs\": {\"r\": \"x\"}}}").is_err());
        assert!(Baseline::parse("not json").is_err());
    }
}
