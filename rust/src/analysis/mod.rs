//! `pallas-lint`: repo-native static analysis.
//!
//! A zero-dependency linter enforcing eleven invariants that clippy
//! cannot express (see `rules`). Seven are line-lexical: wall-clock
//! leakage into virtual-clock code, unordered iteration, `PassRecord`
//! lane-partition drift, unchecked numeric casts in accounting paths,
//! panic policy in library hot paths, float equality, and undocumented
//! `unsafe` use sites. Four run on a token stream (see `tokens`):
//! undocumented relaxed atomic orderings, iteration-order hazards,
//! f32→f64 precision laundering, and `thread::spawn` outside the
//! blessed seams. Pre-existing violations live in a committed
//! per-file-per-rule ratchet baseline (`lint-baseline.json`, see
//! `baseline`): `pallas-lint --check` fails only when a count increases
//! (or the baseline goes stale), so new code is held to the standard
//! immediately while old debt burns down monotonically. The baseline is
//! empty as of the v2 burn-down; `--check --deny-baseline` keeps it that
//! way.
//!
//! An allow directive naming an unknown rule is a hard error, not a
//! silent no-op — a typo'd `pallas-lint: allow` directive would
//! otherwise un-suppress nothing today and shadow a real rule tomorrow.
//!
//! Run it from the crate root:
//!
//! ```text
//! cargo run --release --bin pallas-lint -- --check
//! cargo run --release --bin pallas-lint -- --list
//! cargo run --release --bin pallas-lint -- --update-baseline
//! ```

pub mod baseline;
pub mod lexer;
pub mod rules;
pub mod tokens;

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use baseline::{Baseline, CheckReport, Regression, BASELINE_FILE};
pub use rules::{Rule, Violation};

/// Crate subdirectories the linter scans.
pub const SCAN_DIRS: &[&str] = &["src", "benches", "tests", "examples"];

/// Directory name holding deliberate-violation fixtures, excluded from
/// the default scan.
pub const FIXTURE_DIR: &str = "lint_fixtures";

/// Per-file, per-rule violation counts (the ratchet currency).
pub type Counts = BTreeMap<String, BTreeMap<String, usize>>;

/// All `.rs` files under `root`'s scan dirs, sorted, fixtures excluded.
pub fn collect_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for sub in SCAN_DIRS {
        let base = root.join(sub);
        if base.is_dir() {
            walk(&base, &mut out)?;
        }
    }
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == FIXTURE_DIR) {
                continue;
            }
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `file` relative to `root`, with forward slashes (rule scopes match on
/// these paths).
pub fn rel_path(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    rel.to_string_lossy().replace('\\', "/")
}

/// Reject allow directives naming unknown rules: a typo would otherwise
/// suppress nothing silently and shadow a future rule of that name.
fn validate_allows(rel: &str, lines: &[lexer::Line]) -> io::Result<()> {
    const PREFIX: &str = "pallas-lint: allow(";
    for (idx, line) in lines.iter().enumerate() {
        let comment = &line.comment;
        let mut start = 0usize;
        while let Some(k) = comment[start..].find(PREFIX) {
            let names_start = start + k + PREFIX.len();
            let rest = &comment[names_start..];
            let Some(close) = rest.find(')') else {
                break;
            };
            for name in rest[..close].split(',') {
                let name = name.trim();
                if Rule::from_name(name).is_none() {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "{rel}:{}: unknown rule '{name}' in allow directive",
                            idx + 1
                        ),
                    ));
                }
            }
            start = names_start + close;
        }
    }
    Ok(())
}

/// Scan one file's source text, applying every rule in its scope and
/// filtering out violations suppressed by allow directives. Errors if an
/// allow directive names an unknown rule.
pub fn scan_source(rel: &str, src: &str) -> io::Result<Vec<Violation>> {
    let lines = lexer::scrub(src);
    validate_allows(rel, &lines)?;
    let in_test = lexer::test_regions(&lines);
    let mut raw: Vec<(usize, Rule, String)> = Vec::new();

    let det = rules::in_modules(rel, rules::DET_MODULES);
    let cast = rules::in_modules(rel, rules::CAST_MODULES);
    let panic_scope = rules::in_modules(rel, rules::PANIC_MODULES);
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if det {
            for pat in ["Instant::now", "SystemTime::now"] {
                for _ in lexer::ident_occurrences(code, pat) {
                    raw.push((idx, Rule::WallClockInSim, pat.to_string()));
                }
            }
        }
        if det || rel.starts_with("tests/") {
            for pat in ["HashMap", "HashSet"] {
                for _ in lexer::ident_occurrences(code, pat) {
                    raw.push((idx, Rule::UnorderedIteration, pat.to_string()));
                }
            }
        }
        if cast && !in_test[idx] {
            for _ in 0..rules::cast_sites(code) {
                raw.push((idx, Rule::UncheckedCast, "as".to_string()));
            }
        }
        if panic_scope && !in_test[idx] {
            for pat in [".unwrap()", ".expect("] {
                let mut start = 0usize;
                while let Some(k) = code[start..].find(pat) {
                    raw.push((idx, Rule::PanicPolicy, pat.to_string()));
                    start += k + 1;
                }
            }
        }
        if rel.starts_with("src/") && !in_test[idx] {
            for _ in rules::float_eq_positions(code) {
                raw.push((idx, Rule::FloatEq, "==/!= on float".to_string()));
            }
        }
        // Unlike the rules above, this one also applies inside #[cfg(test)]
        // regions: an unsound unsafe block corrupts test verdicts too.
        if rel.starts_with("src/") {
            let sites = rules::unsafe_sites(code);
            if !sites.is_empty() && !lexer::has_safety_doc(&lines, idx) {
                for _ in sites {
                    raw.push((
                        idx,
                        Rule::UndocumentedUnsafe,
                        "unsafe without // Safety:".to_string(),
                    ));
                }
            }
        }
    }
    for (idx, name, missing) in rules::lane_partition(&lines, src) {
        raw.push((idx, Rule::LanePartition, format!("{name} missing from {missing}")));
    }

    // Token-stream rules (v2). All four exempt #[cfg(test)] regions:
    // tests replay recorded traces single-threaded, so ordering, visit
    // order, and precision there cannot corrupt a shipped artifact.
    let atomic = rules::in_modules(rel, rules::ATOMIC_MODULES);
    let nondet = rules::in_modules(rel, rules::NONDET_MODULES);
    let precision = rules::in_modules(rel, rules::PRECISION_MODULES);
    let spawn_scope = rel.starts_with("src/");
    if atomic || nondet || precision || spawn_scope {
        let toks = tokens::tokenize(&lines);
        if atomic {
            for (idx, variant) in rules::atomic_ordering_sites(&toks) {
                if !in_test[idx] && !lexer::has_marker_doc(&lines, idx, "Ordering:") {
                    raw.push((
                        idx,
                        Rule::AtomicOrdering,
                        format!("Ordering::{variant} without // Ordering:"),
                    ));
                }
            }
        }
        if nondet {
            for (idx, detail) in rules::nondet_order_sites(&toks) {
                if !in_test[idx] {
                    raw.push((idx, Rule::NondeterministicOrder, detail));
                }
            }
        }
        if precision {
            for (idx, detail) in rules::precision_sites(&toks) {
                if !in_test[idx] {
                    raw.push((idx, Rule::PrecisionLaundering, detail));
                }
            }
        }
        if spawn_scope {
            for idx in rules::unblessed_spawn_sites(&toks) {
                if !in_test[idx] {
                    raw.push((
                        idx,
                        Rule::ThreadSpawnPolicy,
                        "thread::spawn outside PlannerWorker/ThreadPool".to_string(),
                    ));
                }
            }
        }
    }

    raw.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    Ok(raw
        .into_iter()
        .filter(|&(idx, rule, _)| !lexer::allows(&lines, idx, rule.name()))
        .map(|(idx, rule, detail)| Violation {
            file: rel.to_string(),
            line: idx + 1,
            rule,
            detail,
        })
        .collect())
}

/// Scan the whole crate tree under `root`.
pub fn scan_root(root: &Path) -> io::Result<Vec<Violation>> {
    let mut all = Vec::new();
    for file in collect_files(root)? {
        let src = fs::read_to_string(&file)?;
        let rel = rel_path(root, &file);
        all.extend(scan_source(&rel, &src)?);
    }
    Ok(all)
}

/// Canonicalize a lint root so baseline keys agree between invocations
/// from different working directories (and across `..`-laden paths).
pub fn canonical_root(root: &Path) -> io::Result<PathBuf> {
    fs::canonicalize(root)
}

/// Aggregate violations into the per-file-per-rule ratchet counts.
pub fn counts(violations: &[Violation]) -> Counts {
    let mut out = Counts::new();
    for v in violations {
        *out.entry(v.file.clone())
            .or_default()
            .entry(v.rule.name().to_string())
            .or_insert(0) += 1;
    }
    out
}
