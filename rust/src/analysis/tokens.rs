//! Token-stream layer over the scrubbed source (`pallas-lint` v2).
//!
//! The line-lexical rules of v1 match substrings; the concurrency and
//! dataflow rules added in v2 (atomic-ordering, nondeterministic-order,
//! precision-laundering, thread-spawn-policy) need *structure*: which
//! tokens are adjacent, how deep in braces a site sits, which `fn` or
//! `impl` body it belongs to. This module tokenizes the already-scrubbed
//! code channel (strings, chars, and comments are spaces by the time we
//! run, so every token here is real code) into idents, integer/float
//! literals, and punctuation, each stamped with its source line and brace
//! depth, plus brace-matched `fn`/`impl` span extraction on top.
//!
//! Deliberate simplifications, safe because the scrubber runs first and
//! the rules only pattern-match short token windows:
//! - lifetimes surface as plain idents (the scrubber blanks the `'`);
//! - raw identifiers (`r#type`) are normalized to the bare name;
//! - shift operators are left as single `<` / `>` tokens so nested
//!   generics (`Vec<Vec<u8>>`) never glue into a phantom `>>`.

use super::lexer::{is_ident_char, Line};

/// Token classes the rules match on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`let`, `as`, `fn` are idents here).
    Ident,
    /// Integer literal, including hex/octal/binary and suffixed forms.
    Int,
    /// Float literal (`0.5`, `1e-9`, `2.5f64`, `7f32`).
    Float,
    /// Punctuation; common two/three-char operators arrive glued
    /// (`::`, `->`, `=>`, `==`, `!=`, `<=`, `>=`, `&&`, `||`, `+=`,
    /// `-=`, `*=`, `/=`, `%=`, `..`, `..=`).
    Punct,
}

/// One token of scrubbed code.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    /// 0-based source line the token starts on.
    pub line: usize,
    /// Brace depth: a `{` and its matching `}` carry the depth *outside*
    /// their block; tokens between them sit one deeper.
    pub depth: i64,
}

impl Token {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }

    pub fn ident(&self, text: &str) -> bool {
        self.is(TokKind::Ident, text)
    }

    pub fn punct(&self, text: &str) -> bool {
        self.is(TokKind::Punct, text)
    }
}

/// Multi-char operators glued into one `Punct` token, longest first so
/// `..=` wins over `..` and `..` over `.`.
const GLUED: &[&str] = &[
    "..=", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=", "*=", "/=", "%=",
    "..",
];

/// Tokenize scrubbed lines into a flat stream with line numbers and
/// brace depths.
pub fn tokenize(lines: &[Line]) -> Vec<Token> {
    let mut out: Vec<Token> = Vec::new();
    let mut depth: i64 = 0;
    for (lineno, line) in lines.iter().enumerate() {
        let chars: Vec<char> = line.code.chars().collect();
        let n = chars.len();
        let mut k = 0usize;
        while k < n {
            let c = chars[k];
            if c == ' ' || c == '\t' {
                k += 1;
                continue;
            }
            if c.is_ascii_alphabetic() || c == '_' {
                let start = k;
                while k < n && is_ident_char(chars[k]) {
                    k += 1;
                }
                let mut text: String = chars[start..k].iter().collect();
                // Raw identifier: `r#name` — normalize to the bare name.
                if text == "r" && k + 1 < n && chars[k] == '#' && is_ident_char(chars[k + 1]) {
                    k += 1; // '#'
                    let rs = k;
                    while k < n && is_ident_char(chars[k]) {
                        k += 1;
                    }
                    text = chars[rs..k].iter().collect();
                }
                out.push(Token { kind: TokKind::Ident, text, line: lineno, depth });
                continue;
            }
            if c.is_ascii_digit() {
                let start = k;
                let hex = c == '0'
                    && k + 1 < n
                    && matches!(chars[k + 1], 'x' | 'X' | 'b' | 'o');
                let mut has_dot = false;
                let mut has_exp = false;
                k += 1;
                while k < n {
                    let ch = chars[k];
                    if ch.is_ascii_alphanumeric() || ch == '_' {
                        if !hex && matches!(ch, 'e' | 'E') {
                            // An exponent only if something numeric follows;
                            // `123usize` must stay an integer.
                            let nx = if k + 1 < n { chars[k + 1] } else { '\0' };
                            if nx.is_ascii_digit() || nx == '+' || nx == '-' {
                                has_exp = true;
                            }
                        }
                        k += 1;
                    } else if ch == '.'
                        && !hex
                        && !has_dot
                        && !has_exp
                        && k + 1 < n
                        && chars[k + 1].is_ascii_digit()
                    {
                        // Decimal point — but `0..n` and `7.max(0)` stop here.
                        has_dot = true;
                        k += 1;
                    } else if matches!(ch, '+' | '-')
                        && has_exp
                        && matches!(chars[k - 1], 'e' | 'E')
                    {
                        k += 1;
                    } else {
                        break;
                    }
                }
                let text: String = chars[start..k].iter().collect();
                let float = !hex
                    && (has_dot || has_exp || text.ends_with("f32") || text.ends_with("f64"));
                let kind = if float { TokKind::Float } else { TokKind::Int };
                out.push(Token { kind, text, line: lineno, depth });
                continue;
            }
            // Punctuation: glued operators first.
            let mut glued = None;
            for op in GLUED {
                let oc: Vec<char> = op.chars().collect();
                if k + oc.len() <= n && chars[k..k + oc.len()] == oc[..] {
                    glued = Some(*op);
                    break;
                }
            }
            if let Some(op) = glued {
                out.push(Token {
                    kind: TokKind::Punct,
                    text: op.to_string(),
                    line: lineno,
                    depth,
                });
                k += op.len();
                continue;
            }
            let d = match c {
                '{' => {
                    let d = depth;
                    depth += 1;
                    d
                }
                '}' => {
                    depth -= 1;
                    depth
                }
                _ => depth,
            };
            out.push(Token { kind: TokKind::Punct, text: c.to_string(), line: lineno, depth: d });
            k += 1;
        }
    }
    out
}

/// A brace-matched `fn` span in the token stream.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Token index of the `fn` keyword.
    pub fn_tok: usize,
    /// Token index of the body-opening `{`, or `None` for a bodyless
    /// declaration (trait method signature).
    pub open_tok: Option<usize>,
    /// Token index just *past* the span: matching `}` + 1, or past the
    /// terminating `;` for declarations.
    pub end_tok: usize,
    pub start_line: usize,
    pub end_line: usize,
}

impl FnSpan {
    /// Body token range (open brace exclusive, close brace exclusive),
    /// empty for declarations.
    pub fn body(&self) -> std::ops::Range<usize> {
        match self.open_tok {
            Some(o) => o + 1..self.end_tok.saturating_sub(1),
            None => 0..0,
        }
    }

    /// Signature token range: `fn` keyword through the token before the
    /// body brace (or the terminating `;`).
    pub fn signature(&self) -> std::ops::Range<usize> {
        self.fn_tok..self.open_tok.unwrap_or(self.end_tok)
    }
}

/// A brace-matched `impl` span.
#[derive(Debug, Clone)]
pub struct ImplSpan {
    /// Every ident in the impl header (`impl<T> Foo for Bar<T>` →
    /// `[T, Foo, Bar, T]`) — enough to ask "is this an impl of X".
    pub header_idents: Vec<String>,
    /// Token range covered by the impl, header included, close brace
    /// included.
    pub tok_range: std::ops::Range<usize>,
    pub start_line: usize,
    pub end_line: usize,
}

impl ImplSpan {
    pub fn mentions(&self, name: &str) -> bool {
        self.header_idents.iter().any(|h| h == name)
    }
}

/// Index of the `}` matching the `{` at `open` (tokens carry their
/// depth, so the match is the next `}` at the same depth).
fn matching_brace(tokens: &[Token], open: usize) -> Option<usize> {
    let d = tokens[open].depth;
    tokens[open + 1..]
        .iter()
        .position(|t| t.punct("}") && t.depth == d)
        .map(|off| open + 1 + off)
}

/// Index of the `)` matching the `(` at `open`.
pub fn matching_paren(tokens: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (off, t) in tokens[open..].iter().enumerate() {
        if t.punct("(") {
            depth += 1;
        } else if t.punct(")") {
            depth -= 1;
            if depth == 0 {
                return Some(open + off);
            }
        }
    }
    None
}

/// All named `fn` items (free functions, methods, trait declarations).
/// `fn` *types* (`fn(usize) -> f64`) have no name ident and are skipped.
pub fn fn_spans(tokens: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if !t.ident("fn") {
            continue;
        }
        let Some(name_tok) = tokens.get(i + 1) else {
            continue;
        };
        if name_tok.kind != TokKind::Ident {
            continue;
        }
        // First `{` opens the body; a `;` at the fn's own depth first
        // means a bodyless declaration. Braces nested in const-generic
        // defaults are rare enough to ignore (the scrubbed repo has none).
        let mut open = None;
        let mut end = None;
        for (off, tk) in tokens[i + 1..].iter().enumerate() {
            let j = i + 1 + off;
            if tk.punct("{") {
                open = Some(j);
                break;
            }
            if tk.punct(";") && tk.depth == t.depth {
                end = Some(j + 1);
                break;
            }
        }
        let (open_tok, end_tok) = match open {
            Some(o) => match matching_brace(tokens, o) {
                Some(c) => (Some(o), c + 1),
                None => (Some(o), tokens.len()),
            },
            None => match end {
                Some(e) => (None, e),
                None => continue,
            },
        };
        out.push(FnSpan {
            name: name_tok.text.clone(),
            fn_tok: i,
            open_tok,
            end_tok,
            start_line: t.line,
            end_line: tokens
                .get(end_tok.saturating_sub(1))
                .map(|tk| tk.line)
                .unwrap_or(t.line),
        });
    }
    out
}

/// All `impl` blocks with their header idents.
pub fn impl_spans(tokens: &[Token]) -> Vec<ImplSpan> {
    let mut out = Vec::new();
    for (i, t) in tokens.iter().enumerate() {
        if !t.ident("impl") {
            continue;
        }
        let mut open = None;
        let mut header_idents = Vec::new();
        for (off, tk) in tokens[i + 1..].iter().enumerate() {
            let j = i + 1 + off;
            if tk.punct("{") {
                open = Some(j);
                break;
            }
            if tk.kind == TokKind::Ident {
                header_idents.push(tk.text.clone());
            }
        }
        let Some(o) = open else { continue };
        let close = matching_brace(tokens, o).unwrap_or(tokens.len().saturating_sub(1));
        out.push(ImplSpan {
            header_idents,
            tok_range: i..close + 1,
            start_line: t.line,
            end_line: tokens[close].line,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::scrub;

    fn toks(src: &str) -> Vec<Token> {
        tokenize(&scrub(src))
    }

    fn texts(src: &str) -> Vec<String> {
        toks(src).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_numbers_and_puncts() {
        let t = toks("let x = n_real + 2;");
        let kinds: Vec<TokKind> = t.iter().map(|t| t.kind).collect();
        assert_eq!(
            kinds,
            vec![
                TokKind::Ident,
                TokKind::Ident,
                TokKind::Punct,
                TokKind::Ident,
                TokKind::Punct,
                TokKind::Int,
                TokKind::Punct
            ]
        );
        assert_eq!(t[1].text, "x");
        assert_eq!(t[5].text, "2");
    }

    #[test]
    fn float_vs_int_classification() {
        for (src, kind) in [
            ("0.5", TokKind::Float),
            ("1e-9", TokKind::Float),
            ("1E+4", TokKind::Float),
            ("2.5f64", TokKind::Float),
            ("7f32", TokKind::Float),
            ("1_000.0", TokKind::Float),
            ("42", TokKind::Int),
            ("123usize", TokKind::Int),
            ("1_000u64", TokKind::Int),
            ("0xFE", TokKind::Int),
            ("0b1010", TokKind::Int),
        ] {
            let t = toks(src);
            assert_eq!(t.len(), 1, "{src}: {t:?}");
            assert_eq!(t[0].kind, kind, "{src}");
            assert_eq!(t[0].text, src);
        }
    }

    #[test]
    fn ranges_and_method_calls_on_ints_split() {
        assert_eq!(texts("0..n"), vec!["0", "..", "n"]);
        assert_eq!(texts("0..=7"), vec!["0", "..=", "7"]);
        assert_eq!(texts("7.max(0)"), vec!["7", ".", "max", "(", "0", ")"]);
        // Tuple field access: the field index is its own int token.
        assert_eq!(texts("a.0.total_cmp"), vec!["a", ".", "0", ".", "total_cmp"]);
    }

    #[test]
    fn glued_operators() {
        assert_eq!(texts("a == b != c <= d"), vec!["a", "==", "b", "!=", "c", "<=", "d"]);
        assert_eq!(texts("x += 1; y -> z => w"), vec!["x", "+=", "1", ";", "y", "->", "z", "=>", "w"]);
        assert_eq!(texts("Ordering::Relaxed"), vec!["Ordering", "::", "Relaxed"]);
    }

    #[test]
    fn nested_generics_stay_single_angles() {
        // `Vec<Vec<u8>>` must not glue the closing angles into a shift.
        assert_eq!(
            texts("let v: Vec<Vec<u8>> = Vec::new();"),
            vec!["let", "v", ":", "Vec", "<", "Vec", "<", "u8", ">", ">", "=", "Vec", "::", "new", "(", ")", ";"]
        );
    }

    #[test]
    fn turbofish() {
        assert_eq!(
            texts("x.collect::<Vec<f64>>()"),
            vec!["x", ".", "collect", "::", "<", "Vec", "<", "f64", ">", ">", "(", ")"]
        );
    }

    #[test]
    fn raw_idents_normalize() {
        assert_eq!(texts("let r#type = r#fn + 1;"), vec!["let", "type", "=", "fn", "+", "1", ";"]);
        // ...while a plain `r` ident survives (no `#` after it).
        assert_eq!(texts("let r = 1;"), vec!["let", "r", "=", "1", ";"]);
    }

    #[test]
    fn lifetimes_surface_as_idents() {
        // The scrubber blanks the tick; the tokenizer sees a bare ident.
        assert_eq!(texts("fn f<'a>(x: &'a str) {}"),
            vec!["fn", "f", "<", "a", ">", "(", "x", ":", "&", "a", "str", ")", "{", "}"]);
    }

    #[test]
    fn brace_depth_across_match_arms() {
        let src = "\
fn f(x: u32) -> u32 {
    match x {
        0 => { 1 }
        _ => {
            let y = { 2 };
            y
        }
    }
}";
        let t = toks(src);
        let depth_of = |text: &str| -> Vec<i64> {
            t.iter().filter(|tk| tk.text == text).map(|tk| tk.depth).collect()
        };
        // fn body brace at 0, match at 1, arm braces at 2, inner block 3.
        assert_eq!(depth_of("match"), vec![1]);
        assert_eq!(depth_of("1"), vec![3]);
        assert_eq!(depth_of("2"), vec![4]);
        assert_eq!(depth_of("y"), vec![3, 3]);
        // Every open has its close: final depth returns to 0.
        let opens = t.iter().filter(|tk| tk.punct("{")).count();
        let closes = t.iter().filter(|tk| tk.punct("}")).count();
        assert_eq!(opens, closes);
        // Matching braces carry equal depth.
        let open_depths: Vec<i64> =
            t.iter().filter(|tk| tk.punct("{")).map(|tk| tk.depth).collect();
        let mut close_depths: Vec<i64> =
            t.iter().filter(|tk| tk.punct("}")).map(|tk| tk.depth).collect();
        close_depths.reverse();
        let mut sorted_open = open_depths.clone();
        sorted_open.sort_unstable();
        let mut sorted_close = close_depths;
        sorted_close.sort_unstable();
        assert_eq!(sorted_open, sorted_close);
    }

    #[test]
    fn fn_spans_brace_matched() {
        let src = "\
impl Foo {
    pub fn a(&self) -> usize {
        if true { 1 } else { 2 }
    }
    fn b();
}
fn free() {}";
        let lines = scrub(src);
        let t = tokenize(&lines);
        let spans = fn_spans(&t);
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "free"]);
        assert_eq!((spans[0].start_line, spans[0].end_line), (1, 3));
        assert!(spans[1].open_tok.is_none(), "declaration has no body");
        assert_eq!((spans[2].start_line, spans[2].end_line), (6, 6));
        // Body range excludes the braces themselves.
        let body: Vec<&str> =
            t[spans[2].body()].iter().map(|tk| tk.text.as_str()).collect();
        assert!(body.is_empty(), "empty body: {body:?}");
    }

    #[test]
    fn fn_pointer_types_are_not_spans() {
        let spans = fn_spans(&toks("let f: fn(usize) -> f64 = g;"));
        assert!(spans.is_empty(), "{spans:?}");
    }

    #[test]
    fn impl_spans_capture_header_idents() {
        let src = "\
impl<T: Clone> Planner for Pool<T> {
    fn go(&self) { spawn(); }
}
impl Other {
    fn x() {}
}";
        let t = toks(src);
        let spans = impl_spans(&t);
        assert_eq!(spans.len(), 2);
        assert!(spans[0].mentions("Planner") && spans[0].mentions("Pool"));
        assert!(!spans[0].mentions("Other"));
        assert_eq!((spans[0].start_line, spans[0].end_line), (0, 2));
        assert!(spans[1].mentions("Other"));
        // The spawn token is covered by span 0, not span 1.
        let spawn_idx = t.iter().position(|tk| tk.ident("spawn")).unwrap();
        assert!(spans[0].tok_range.contains(&spawn_idx));
        assert!(!spans[1].tok_range.contains(&spawn_idx));
    }

    #[test]
    fn matching_paren_nests() {
        let t = toks("f(a, g(b, c), d)");
        let open = t.iter().position(|tk| tk.punct("(")).unwrap();
        let close = matching_paren(&t, open).unwrap();
        assert_eq!(t[close..].len(), 1, "outermost close is the last token");
    }
}
