//! Explicit AVX2(+FMA) kernel bodies operating on BF16 rows directly.
//!
//! The portable unrolled kernel (`kernel.rs`) stages every K/V row through
//! f32 tiles; this tier instead widens 8 BF16 lanes at a time inside the
//! FMA chain (zero-extend `u16` → `u32`, shift left 16, reinterpret as
//! f32 — BF16 *is* the top half of f32) so the dot and the flash update
//! read the cache bits with no staging pass. Dispatch is at runtime:
//! [`simd_available`] checks `is_x86_feature_detected!("avx2")` + `fma`
//! once per call site, and `kernel::attend_one` silently falls back to
//! the unrolled tier on non-x86 builds or pre-AVX2 hosts, so numerics
//! stay within the shared 1e-4 parity tolerance everywhere (see
//! `tests/cpuattn_parity.rs`).

use super::{AttnShape, AttnTuning};
use crate::kvcache::{PagedKvCache, SeqId};

/// Can [`Tier::Simd`](super::Tier::Simd) run its intrinsics bodies on
/// this host? Always `false` off x86_64.
#[inline]
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        x86::available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Hint the head of `row` (up to 4 cache lines) into L1 ahead of use —
/// `_mm_prefetch` on x86_64 (SSE is baseline there, no detection needed),
/// a no-op elsewhere. Prefetch is advisory: wrong or late hints cost
/// nothing but the slot.
#[inline(always)]
pub fn prefetch_row(row: &[u16]) {
    #[cfg(target_arch = "x86_64")]
    {
        let p = row.as_ptr().cast::<i8>();
        let bytes = row.len().saturating_mul(2).min(256);
        let mut off = 0usize;
        while off < bytes {
            // Safety: `off < bytes <= row.len() * 2` keeps the pointer in
            // bounds of the slice allocation; prefetch reads nothing
            // architecturally (it cannot fault) and SSE is part of the
            // x86_64 baseline.
            unsafe {
                std::arch::x86_64::_mm_prefetch::<{ std::arch::x86_64::_MM_HINT_T0 }>(p.add(off))
            };
            off += 64;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = row;
}

/// Widen a BF16 row into f32, dispatching to the AVX2 body when the host
/// supports it and to the portable shift loop otherwise. The two paths
/// are bit-identical (both are the same 16-bit left shift), so callers
/// may mix them freely.
pub fn upconvert_bf16(dst: &mut [f32], src: &[u16]) {
    assert!(dst.len() >= src.len());
    #[cfg(target_arch = "x86_64")]
    if x86::available() {
        // Safety: `available()` verified AVX2 just above; lengths are
        // checked by the assert.
        unsafe { x86::upconvert(dst, src) };
        return;
    }
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f32::from_bits((s as u32) << 16);
    }
}

/// The AVX2+FMA bodies. Compiled only on x86_64; every entry point is an
/// `unsafe fn` gated on [`available`] — the caller promises the CPU
/// features, the bodies promise the slice bounds they document.
#[cfg(target_arch = "x86_64")]
pub mod x86 {
    use std::arch::x86_64::*;

    use crate::util::bf16::bf16_to_f32;

    /// Does this host have the AVX2 + FMA these bodies require?
    #[inline]
    pub fn available() -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    /// Widen 8 BF16 values at `p` into 8 f32 lanes: zero-extend
    /// `u16 → u32`, shift left 16 (BF16 bits are the high half of f32),
    /// reinterpret as floats. This is the upconvert building block every
    /// body below fuses into its load.
    ///
    /// # Safety
    /// `p` must point at 8 readable `u16`s and the caller must have
    /// verified AVX2 support via [`available`].
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen8(p: *const u16) -> __m256 {
        let halves = _mm_loadu_si128(p.cast::<__m128i>());
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(halves)))
    }

    /// Horizontal sum of 8 f32 lanes.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support via [`available`].
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    /// Dot product of an f32 query row against a BF16 K row: two
    /// independent 8-lane FMA chains (16 elements per step), an 8-wide
    /// step, then a scalar tail for odd head_dims.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support via [`available`] and
    /// pass equal-length slices.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_bf16(q: &[f32], k: &[u16]) -> f32 {
        debug_assert_eq!(q.len(), k.len());
        let n = q.len();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(q.as_ptr().add(i)),
                widen8(k.as_ptr().add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(q.as_ptr().add(i + 8)),
                widen8(k.as_ptr().add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(q.as_ptr().add(i)),
                widen8(k.as_ptr().add(i)),
                acc0,
            );
            i += 8;
        }
        let mut dot = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            dot += q[i] * bf16_to_f32(k[i]);
            i += 1;
        }
        dot
    }

    /// Fused flash update `acc = a*acc + b*widen(v)` over a BF16 V row —
    /// the rescale-on-new-max and the weighted accumulate in one pass
    /// (`a` is 1.0 on the common no-new-max step, so the fold is exact
    /// there).
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA support via [`available`] and
    /// pass equal-length slices.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn saxpby_bf16(acc: &mut [f32], v: &[u16], a: f32, b: f32) {
        debug_assert_eq!(acc.len(), v.len());
        let n = acc.len();
        let av = _mm256_set1_ps(a);
        let bv = _mm256_set1_ps(b);
        let mut i = 0usize;
        while i + 8 <= n {
            let cur = _mm256_mul_ps(av, _mm256_loadu_ps(acc.as_ptr().add(i)));
            let upd = _mm256_fmadd_ps(bv, widen8(v.as_ptr().add(i)), cur);
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), upd);
            i += 8;
        }
        while i < n {
            acc[i] = a * acc[i] + b * bf16_to_f32(v[i]);
            i += 1;
        }
    }

    /// Slice-level upconvert: widen `src` BF16 into `dst` f32, 8 lanes at
    /// a time. Bit-identical to the portable shift loop.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support via [`available`] and pass
    /// `dst.len() >= src.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn upconvert(dst: &mut [f32], src: &[u16]) {
        debug_assert!(dst.len() >= src.len());
        let n = src.len();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), widen8(src.as_ptr().add(i)));
            i += 8;
        }
        while i < n {
            dst[i] = bf16_to_f32(src[i]);
            i += 1;
        }
    }
}

/// The SIMD-tier flash-decode body: same partitioned, KV-head-major walk
/// as the unrolled kernel (so the tiers differ only in the vector
/// bodies), with the next row of the current head strip prefetched one
/// token ahead. Only reachable through `kernel::attend_one` after a
/// [`simd_available`] check.
#[cfg(target_arch = "x86_64")]
pub(super) fn attend_simd(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    seq: SeqId,
    q: &[f32],
    out: &mut [f32],
    tuning: AttnTuning,
) {
    debug_assert!(x86::available());
    let hd = shape.head_dim;
    assert!(hd <= super::kernel::MAX_HD, "head_dim {hd} exceeds kernel tile size");
    let kv_dim = shape.kv_dim();
    let group = shape.gqa_group();
    let scale = 1.0 / (hd as f32).sqrt();
    let nh = shape.n_heads;
    let part = tuning.partition.max(1);

    let mut m = vec![f32::NEG_INFINITY; nh];
    let mut denom = vec![0f32; nh];
    let mut acc = vec![0f32; nh * hd];

    cache.walk_context(seq, layer, |k_run, v_run, n| {
        let mut t0 = 0usize;
        while t0 < n {
            let t1 = (t0 + part).min(n);
            for kvh in 0..shape.n_kv_heads {
                for t in t0..t1 {
                    let off = t * kv_dim + kvh * hd;
                    if t + 1 < t1 {
                        prefetch_row(&k_run[off + kv_dim..off + kv_dim + hd]);
                        prefetch_row(&v_run[off + kv_dim..off + kv_dim + hd]);
                    }
                    let k_row = &k_run[off..off + hd];
                    let v_row = &v_run[off..off + hd];
                    for gi in 0..group {
                        let h = kvh * group + gi;
                        let qh = &q[h * hd..(h + 1) * hd];
                        // Safety: `attend_one` dispatches here only after
                        // `simd_available()` confirmed AVX2+FMA; rows and
                        // `qh` are all `hd` long.
                        let s = unsafe { x86::dot_bf16(qh, k_row) } * scale;
                        let mut corr = 1.0f32;
                        if s > m[h] {
                            corr = (m[h] - s).exp();
                            denom[h] *= corr;
                            m[h] = s;
                        }
                        let w = (s - m[h]).exp();
                        denom[h] += w;
                        // Safety: same dispatch guarantee as the dot; the
                        // accumulator window and `v_row` are `hd` long.
                        unsafe {
                            x86::saxpby_bf16(&mut acc[h * hd..(h + 1) * hd], v_row, corr, w)
                        };
                    }
                }
            }
            t0 = t1;
        }
    });

    for h in 0..nh {
        let inv = 1.0 / denom[h];
        let src = &acc[h * hd..(h + 1) * hd];
        let dst = &mut out[h * hd..(h + 1) * hd];
        for d in 0..hd {
            dst[d] = src[d] * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bf16::{bf16_to_f32, f32_to_bf16};
    use crate::util::rng::Rng;

    #[test]
    fn upconvert_dispatch_is_exact() {
        let mut rng = Rng::new(5);
        let src: Vec<u16> =
            (0..37).map(|_| f32_to_bf16(rng.f32() * 8.0 - 4.0)).collect();
        let mut dst = vec![0f32; 37];
        upconvert_bf16(&mut dst, &src);
        for (d, &s) in dst.iter().zip(&src) {
            assert_eq!(d.to_bits(), bf16_to_f32(s).to_bits());
        }
    }

    #[test]
    fn prefetch_row_is_safe_on_any_slice() {
        prefetch_row(&[]);
        prefetch_row(&[1u16]);
        prefetch_row(&[0u16; 4096]);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_trio_matches_scalar_reference() {
        if !x86::available() {
            return; // pre-AVX2 host: the dispatch tests still cover fallback
        }
        let mut rng = Rng::new(77);
        // Odd lengths exercise the 16-wide, 8-wide, and scalar tails.
        for n in [1usize, 7, 8, 9, 16, 23, 64, 127, 128, 160] {
            let q: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let k: Vec<u16> =
                (0..n).map(|_| f32_to_bf16(rng.f32() * 2.0 - 1.0)).collect();
            let naive: f32 =
                q.iter().zip(&k).map(|(x, &y)| x * bf16_to_f32(y)).sum();
            // Safety: `available()` checked at the top of the test.
            let fast = unsafe { x86::dot_bf16(&q, &k) };
            assert!(
                (naive - fast).abs() <= 1e-4 * naive.abs().max(1.0),
                "n={n}: {naive} vs {fast}"
            );

            let mut acc: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut want = acc.clone();
            let (a, b) = (0.25f32, 1.75f32);
            for (w, &v) in want.iter_mut().zip(&k) {
                *w = a * *w + b * bf16_to_f32(v);
            }
            // Safety: `available()` checked at the top of the test.
            unsafe { x86::saxpby_bf16(&mut acc, &k, a, b) };
            for (x, y) in acc.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-5 * y.abs().max(1.0), "n={n}: {x} vs {y}");
            }

            let mut up = vec![0f32; n];
            // Safety: `available()` checked at the top of the test.
            unsafe { x86::upconvert(&mut up, &k) };
            for (x, &y) in up.iter().zip(&k) {
                assert_eq!(x.to_bits(), bf16_to_f32(y).to_bits(), "n={n}");
            }
        }
    }
}
