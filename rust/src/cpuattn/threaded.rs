//! Work-stealing thread-sharded decode attention (§6.6's full-thread
//! rung).
//!
//! The previous pool funneled every job through one contended
//! `Mutex<Receiver>`; this one gives each long-lived worker (std threads
//! + channels; the offline crate set has no rayon or crossbeam-deque) a
//! private injector channel. A batch is *announced* to every worker once
//! (`Arc<Batch>`), and the actual work — query indices — is claimed in
//! chunks straight off a shared atomic cursor. Stealing is implicit:
//! whichever worker drains its chunk first claims the next from the same
//! cursor, so skewed context lengths balance without any queue traffic
//! or locks on the hot path. The announcing call blocks on a completion
//! latch before returning, upholding the borrows behind the batch's raw
//! pointers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::kernel::attend_one;
use super::{AttnShape, AttnTuning, DecodeQuery, Tier};
use crate::kvcache::PagedKvCache;

/// One announced batch: the shared claim cursor plus raw views of the
/// caller's borrows. Heap-allocated behind `Arc` so a worker that
/// dequeues the announcement *after* the batch completed still reads a
/// live cursor — it observes `next >= n` and never touches the raw
/// pointers.
struct Batch {
    cache: *const PagedKvCache,
    shape: AttnShape,
    layer: usize,
    tuning: AttnTuning,
    queries: *const DecodeQueryRaw,
    n: usize,
    out: *mut f32,
    q_dim: usize,
    /// Next unclaimed query index — the work-stealing cursor.
    next: AtomicUsize,
    /// Queries claimed per `fetch_add` (~ n / (threads * 4)).
    chunk: usize,
    /// Queries not yet completed; reaching zero trips the latch.
    remaining: AtomicUsize,
    done: (Mutex<bool>, Condvar),
}

// Safety: the raw pointers are dereferenced only under a claimed index
// `< n`, which (see `run_batch`) can only happen while the announcing
// call still blocks on the latch — so the borrows behind them are live —
// and disjoint claimed ranges write disjoint `out` regions.
unsafe impl Send for Batch {}
// Safety: cross-thread shared state is the atomics and the latch, which
// synchronize themselves; the raw pointers are covered by the `Send`
// reasoning above.
unsafe impl Sync for Batch {}

struct DecodeQueryRaw {
    seq: crate::kvcache::SeqId,
    q_ptr: *const f32,
    q_len: usize,
}

/// Long-lived work-stealing worker pool for the threaded attention rung.
pub struct ThreadPool {
    injectors: Vec<Sender<Arc<Batch>>>,
    workers: Vec<JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Spawn `n_threads` workers; `0` sizes the pool from
    /// `std::thread::available_parallelism` (the `serve --attn-threads 0`
    /// default).
    pub fn new(n_threads: usize) -> Self {
        let n_threads = if n_threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            n_threads
        };
        let mut injectors = Vec::with_capacity(n_threads);
        let mut workers = Vec::with_capacity(n_threads);
        for _ in 0..n_threads {
            let (tx, rx) = channel::<Arc<Batch>>();
            injectors.push(tx);
            workers.push(std::thread::spawn(move || worker_loop(rx)));
        }
        ThreadPool { injectors, workers, n_threads }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Threaded decode attention over the batch at default tuning: shards
    /// sequences across the pool and blocks until every result is written
    /// to `out`. Output is bit-identical to the single-thread
    /// [`Tier::Optimized`] kernel — per-sequence work is deterministic
    /// and claimed ranges are disjoint.
    pub fn decode_attention(
        &self,
        cache: &PagedKvCache,
        layer: usize,
        shape: AttnShape,
        queries: &[DecodeQuery],
        out: &mut [f32],
    ) {
        self.decode_attention_tuned(cache, layer, shape, queries, out, AttnTuning::default());
    }

    /// [`ThreadPool::decode_attention`] with explicit kernel tuning.
    pub fn decode_attention_tuned(
        &self,
        cache: &PagedKvCache,
        layer: usize,
        shape: AttnShape,
        queries: &[DecodeQuery],
        out: &mut [f32],
        tuning: AttnTuning,
    ) {
        let q_dim = shape.q_dim();
        assert_eq!(out.len(), queries.len() * q_dim);
        if queries.is_empty() {
            return;
        }
        let raw: Vec<DecodeQueryRaw> = queries
            .iter()
            .map(|q| DecodeQueryRaw { seq: q.seq, q_ptr: q.q.as_ptr(), q_len: q.q.len() })
            .collect();

        let n = queries.len();
        // ~4 claims per worker: coarse enough to keep cursor traffic
        // negligible, fine enough to steal around skewed context lengths.
        let chunk = n.div_ceil(self.n_threads * 4).max(1);
        let batch = Arc::new(Batch {
            cache,
            shape,
            layer,
            tuning,
            queries: raw.as_ptr(),
            n,
            out: out.as_mut_ptr(),
            q_dim,
            next: AtomicUsize::new(0),
            chunk,
            remaining: AtomicUsize::new(n),
            done: (Mutex::new(false), Condvar::new()),
        });

        for tx in &self.injectors {
            tx.send(Arc::clone(&batch)).expect("worker alive");
        }

        // Completion latch: every query of *this* batch is written (and
        // every claimed chunk retired) before the borrows behind the raw
        // pointers end.
        let (lock, cvar) = &batch.done;
        let mut finished = lock.lock().unwrap();
        while !*finished {
            finished = cvar.wait(finished).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing every injector channel ends the worker loops.
        self.injectors.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Receiver<Arc<Batch>>) {
    while let Ok(batch) = rx.recv() {
        run_batch(&batch);
    }
}

/// Claim and run chunks of `b` until the cursor is drained. The safety
/// invariant throughout: a claim with `start < n` means at least `end -
/// start` completions are still outstanding (`remaining` cannot reach
/// zero until *this* worker's `fetch_sub` below), so the announcing call
/// is parked on the latch and the borrows behind the raw pointers are
/// live for the whole chunk.
fn run_batch(b: &Batch) {
    loop {
        // Ordering: the cursor only partitions indices — each RMW is
        // atomic, and no worker reads memory published by another's
        // claim, so no acquire/release pairing is needed here.
        let start = b.next.fetch_add(b.chunk, Ordering::Relaxed);
        if start >= b.n {
            return;
        }
        let end = (start + b.chunk).min(b.n);
        // Safety: claim invariant above — the caller's `&PagedKvCache`
        // borrow is live while we hold an unretired claim.
        let cache = unsafe { &*b.cache };
        // Safety: claim invariant; `queries` points at the caller's Vec
        // of `n` contiguous raw query records.
        let queries = unsafe { std::slice::from_raw_parts(b.queries, b.n) };
        for i in start..end {
            let q = &queries[i];
            // Safety: claim invariant; `q_ptr`/`q_len` view the caller's
            // i-th query slice.
            let qs = unsafe { std::slice::from_raw_parts(q.q_ptr, q.q_len) };
            // Safety: claim invariant, plus exclusivity — the cursor
            // hands index `i` to exactly one worker, so this `q_dim`
            // window of `out` is written by us alone.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(b.out.add(i * b.q_dim), b.q_dim)
            };
            attend_one(cache, b.layer, b.shape, q.seq, qs, dst, Tier::Optimized, b.tuning);
        }
        let claimed = end - start;
        // Ordering: AcqRel makes every worker's `out` writes visible to
        // whichever worker observes zero remaining (release on each
        // retire, acquire on the read) before it trips the latch the
        // caller is parked on.
        if b.remaining.fetch_sub(claimed, Ordering::AcqRel) == claimed {
            let (lock, cvar) = &b.done;
            // Notify while *holding* the lock: the waiter cannot observe
            // `true` and drop its `Arc` until we release the guard, and
            // our own `Arc` keeps the latch storage alive regardless.
            let mut finished = lock.lock().unwrap();
            *finished = true;
            cvar.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpuattn::tests::{build_cache, oracle};
    use crate::cpuattn::{decode_attention, Tier};
    use crate::kvcache::SeqId;
    use crate::util::rng::Rng;

    #[test]
    fn threaded_matches_single_thread() {
        let shape = AttnShape { n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let mut rng = Rng::new(9);
        let lens: Vec<usize> = (0..17).map(|_| rng.range(1, 50)).collect();
        let (cache, dense) = build_cache(shape, &lens, 8, &mut rng);
        let qs: Vec<Vec<f32>> = lens
            .iter()
            .map(|_| (0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();

        let mut single = vec![0f32; queries.len() * shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut single, Tier::Optimized);

        for n_threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(n_threads);
            let mut out = vec![0f32; queries.len() * shape.q_dim()];
            pool.decode_attention(&cache, 0, shape, &queries, &mut out);
            assert_eq!(out, single, "n_threads={n_threads}");
        }

        // and against the oracle for good measure
        for (i, &len) in lens.iter().enumerate() {
            let (kd, vd) = &dense[i];
            let want = oracle(shape, &qs[i], kd, vd, len);
            let got = &single[i * shape.q_dim()..(i + 1) * shape.q_dim()];
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0));
            }
        }
    }

    #[test]
    fn zero_thread_count_sizes_from_available_parallelism() {
        let pool = ThreadPool::new(0);
        assert!(pool.n_threads() >= 1);
    }

    #[test]
    fn empty_batch_is_noop() {
        let pool = ThreadPool::new(2);
        let shape = AttnShape { n_heads: 2, n_kv_heads: 1, head_dim: 8 };
        let cache = crate::kvcache::PagedKvCache::new(
            crate::kvcache::KvLayout::new(4, 2),
            1,
            shape.kv_dim(),
        );
        let mut out: [f32; 0] = [];
        pool.decode_attention(&cache, 0, shape, &[], &mut out);
    }

    #[test]
    fn pool_survives_many_batches() {
        let pool = ThreadPool::new(3);
        let shape = AttnShape { n_heads: 2, n_kv_heads: 1, head_dim: 8 };
        let mut rng = Rng::new(1);
        let (cache, _) = build_cache(shape, &[5, 5, 5], 4, &mut rng);
        let q: Vec<f32> = (0..shape.q_dim()).map(|_| rng.f32()).collect();
        for _ in 0..50 {
            let queries: Vec<DecodeQuery> =
                (0..3).map(|i| DecodeQuery { seq: i as SeqId, q: &q }).collect();
            let mut out = vec![0f32; 3 * shape.q_dim()];
            pool.decode_attention(&cache, 0, shape, &queries, &mut out);
            assert!(out.iter().all(|x| x.is_finite()));
        }
    }
}
