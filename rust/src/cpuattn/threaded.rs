//! Thread-sharded decode attention (§6.6's full-thread tier).
//!
//! A long-lived worker pool (std threads + channels; the offline crate set
//! has no rayon) shards decode queries by sequence. Work items carry raw
//! pointers bounded by the call's scope — the pool joins a completion
//! latch before `decode_attention` returns, upholding the borrow.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::kernel::{attend_one, Tier};
use super::{AttnShape, DecodeQuery};
use crate::kvcache::PagedKvCache;

/// A batch-scoped work item: attend queries `lo..hi` of the batch.
struct Job {
    ctx: *const BatchCtx,
    lo: usize,
    hi: usize,
}
// Safety: `BatchCtx` outlives all jobs of a batch (completion latch), and
// disjoint `lo..hi` ranges write disjoint `out` regions.
unsafe impl Send for Job {}

struct BatchCtx {
    cache: *const PagedKvCache,
    shape: AttnShape,
    layer: usize,
    queries: *const [DecodeQueryRaw],
    out: *mut f32,
    q_dim: usize,
    remaining: AtomicUsize,
    done: (Mutex<bool>, Condvar),
}
unsafe impl Sync for BatchCtx {}

struct DecodeQueryRaw {
    seq: crate::kvcache::SeqId,
    q_ptr: *const f32,
    q_len: usize,
}

/// Long-lived worker pool for the threaded attention tier.
pub struct ThreadPool {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    /// Spawn `n_threads` workers (>= 1).
    pub fn new(n_threads: usize) -> Self {
        let n_threads = n_threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(rx))
            })
            .collect();
        ThreadPool { tx, workers, n_threads }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Threaded decode attention over the batch: shards sequences across
    /// the pool and blocks until every result is written to `out`.
    pub fn decode_attention(
        &self,
        cache: &PagedKvCache,
        layer: usize,
        shape: AttnShape,
        queries: &[DecodeQuery],
        out: &mut [f32],
    ) {
        let q_dim = shape.q_dim();
        assert_eq!(out.len(), queries.len() * q_dim);
        if queries.is_empty() {
            return;
        }
        let raw: Vec<DecodeQueryRaw> = queries
            .iter()
            .map(|q| DecodeQueryRaw { seq: q.seq, q_ptr: q.q.as_ptr(), q_len: q.q.len() })
            .collect();

        // Chunk so each worker gets ~2 jobs (cheap dynamic balancing for
        // skewed context lengths).
        let n = queries.len();
        let chunk = n.div_ceil(self.n_threads * 2).max(1);
        let n_jobs = n.div_ceil(chunk);

        let ctx = BatchCtx {
            cache,
            shape,
            layer,
            queries: raw.as_slice(),
            out: out.as_mut_ptr(),
            q_dim,
            remaining: AtomicUsize::new(n_jobs),
            done: (Mutex::new(false), Condvar::new()),
        };

        for j in 0..n_jobs {
            let lo = j * chunk;
            let hi = ((j + 1) * chunk).min(n);
            self.tx.send(Job { ctx: &ctx, lo, hi }).expect("pool alive");
        }

        // Completion latch: wait for all jobs of *this* batch.
        let (lock, cvar) = &ctx.done;
        let mut finished = lock.lock().unwrap();
        while !*finished {
            finished = cvar.wait(finished).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channel ends the worker loops.
        let (tx, _) = channel();
        drop(std::mem::replace(&mut self.tx, tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // pool dropped
        };
        // Safety: see `Job`.
        let ctx = unsafe { &*job.ctx };
        let queries = unsafe { &*ctx.queries };
        let cache = unsafe { &*ctx.cache };
        for i in job.lo..job.hi {
            let q = &queries[i];
            let qs = unsafe { std::slice::from_raw_parts(q.q_ptr, q.q_len) };
            let dst = unsafe {
                std::slice::from_raw_parts_mut(ctx.out.add(i * ctx.q_dim), ctx.q_dim)
            };
            attend_one(cache, ctx.layer, ctx.shape, q.seq, qs, dst, Tier::Optimized);
        }
        if ctx.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let (lock, cvar) = &ctx.done;
            // Notify while *holding* the lock: the waiter cannot observe
            // `true` and destroy `ctx` until we release the guard, so the
            // condvar outlives this notify (it is a stack-scoped latch).
            let mut finished = lock.lock().unwrap();
            *finished = true;
            cvar.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpuattn::tests::{build_cache, oracle};
    use crate::cpuattn::{decode_attention, Tier};
    use crate::kvcache::SeqId;
    use crate::util::rng::Rng;

    #[test]
    fn threaded_matches_single_thread() {
        let shape = AttnShape { n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let mut rng = Rng::new(9);
        let lens: Vec<usize> = (0..17).map(|_| rng.range(1, 50)).collect();
        let (cache, dense) = build_cache(shape, &lens, 8, &mut rng);
        let qs: Vec<Vec<f32>> = lens
            .iter()
            .map(|_| (0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();

        let mut single = vec![0f32; queries.len() * shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut single, Tier::Optimized);

        for n_threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(n_threads);
            let mut out = vec![0f32; queries.len() * shape.q_dim()];
            pool.decode_attention(&cache, 0, shape, &queries, &mut out);
            assert_eq!(out, single, "n_threads={n_threads}");
        }

        // and against the oracle for good measure
        for (i, &len) in lens.iter().enumerate() {
            let (kd, vd) = &dense[i];
            let want = oracle(shape, &qs[i], kd, vd, len);
            let got = &single[i * shape.q_dim()..(i + 1) * shape.q_dim()];
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0));
            }
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let pool = ThreadPool::new(2);
        let shape = AttnShape { n_heads: 2, n_kv_heads: 1, head_dim: 8 };
        let cache = crate::kvcache::PagedKvCache::new(
            crate::kvcache::KvLayout::new(4, 2),
            1,
            shape.kv_dim(),
        );
        let mut out = [];
        pool.decode_attention(&cache, 0, shape, &[], &mut out);
    }

    #[test]
    fn pool_survives_many_batches() {
        let pool = ThreadPool::new(3);
        let shape = AttnShape { n_heads: 2, n_kv_heads: 1, head_dim: 8 };
        let mut rng = Rng::new(1);
        let (cache, _) = build_cache(shape, &[5, 5, 5], 4, &mut rng);
        let q: Vec<f32> = (0..shape.q_dim()).map(|_| rng.f32()).collect();
        for _ in 0..50 {
            let queries: Vec<DecodeQuery> =
                (0..3).map(|i| DecodeQuery { seq: i as SeqId, q: &q }).collect();
            let mut out = vec![0f32; 3 * shape.q_dim()];
            pool.decode_attention(&cache, 0, shape, &queries, &mut out);
            assert!(out.iter().all(|x| x.is_finite()));
        }
    }
}
