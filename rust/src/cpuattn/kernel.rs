//! The decode-attention kernel bodies: the §6.6 tier ladder.
//!
//! All tiers consume the cache as contiguous `[tokens × kv_dim]` BF16
//! runs (one run per KV block) and keep flash-decode running state, so
//! they stream the KV cache exactly once per query group — the §5.3
//! arithmetic intensity the performance model assumes (`I_cpu_attn ≈ 1`
//! FLOP/byte on the dot, ditto on the saxpby).

use super::{AttnShape, AttnTuning};
use crate::kvcache::{PagedKvCache, SeqId};
use crate::util::bf16::bf16_to_f32;

/// Kernel tier (§6.6's optimization ladder). The threaded rung shards
/// [`Tier::Optimized`] across a [`super::ThreadPool`]; within one thread
/// it is identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Straightforward loops, one query head at a time — whatever LLVM
    /// auto-vectorizes.
    Scalar,
    /// The portable hand-optimized kernel: GQA-grouped partitioned KV
    /// walks, staged f32 tiles, 8-lane unrolled dot/saxpby bodies.
    Unrolled,
    /// Explicit AVX2+FMA bodies on the BF16 rows (`simd.rs`), falling
    /// back to [`Tier::Unrolled`] when the host lacks the features or
    /// the build is not x86_64.
    Simd,
    /// Best available single-thread kernel: runtime-dispatches to the
    /// SIMD bodies where supported, the unrolled kernel otherwise. The
    /// engine and the thread pool use this.
    Optimized,
}

/// Attend one query against one sequence's cached context (all heads).
#[allow(clippy::too_many_arguments)]
pub(super) fn attend_one(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    seq: SeqId,
    q: &[f32],
    out: &mut [f32],
    tier: Tier,
    tuning: AttnTuning,
) {
    match tier {
        Tier::Scalar => attend_scalar(cache, layer, shape, seq, q, out),
        Tier::Unrolled => attend_unrolled(cache, layer, shape, seq, q, out, tuning),
        Tier::Simd | Tier::Optimized => {
            #[cfg(target_arch = "x86_64")]
            if super::simd::simd_available() {
                return super::simd::attend_simd(cache, layer, shape, seq, q, out, tuning);
            }
            attend_unrolled(cache, layer, shape, seq, q, out, tuning)
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar baseline ("auto-vectorized"): head-major loops, one KV pass per
// *query* head (so a GQA group re-reads its KV s times), plain indexing.
// The accumulator is a stack tile (not a per-head heap Vec) so the tier
// measures the algorithm, not the allocator.
// ---------------------------------------------------------------------------

fn attend_scalar(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    seq: SeqId,
    q: &[f32],
    out: &mut [f32],
) {
    let hd = shape.head_dim;
    assert!(hd <= MAX_HD, "head_dim {hd} exceeds kernel tile size");
    let kv_dim = shape.kv_dim();
    let group = shape.gqa_group();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut acc = [0f32; MAX_HD];
    for h in 0..shape.n_heads {
        let kvh = h / group;
        let qh = &q[h * hd..(h + 1) * hd];
        let mut m = f32::NEG_INFINITY;
        let mut denom = 0f32;
        acc[..hd].fill(0.0);
        cache.walk_context(seq, layer, |k_run, v_run, n| {
            for t in 0..n {
                let kt = &k_run[t * kv_dim + kvh * hd..t * kv_dim + (kvh + 1) * hd];
                let vt = &v_run[t * kv_dim + kvh * hd..t * kv_dim + (kvh + 1) * hd];
                let mut dot = 0f32;
                for d in 0..hd {
                    dot += qh[d] * bf16_to_f32(kt[d]);
                }
                let s = dot * scale;
                if s > m {
                    let corr = (m - s).exp();
                    for a in acc[..hd].iter_mut() {
                        *a *= corr;
                    }
                    denom *= corr;
                    m = s;
                }
                let w = (s - m).exp();
                denom += w;
                for d in 0..hd {
                    acc[d] += w * bf16_to_f32(vt[d]);
                }
            }
        });
        let inv = 1.0 / denom;
        for d in 0..hd {
            out[h * hd + d] = acc[d] * inv;
        }
    }
}

// ---------------------------------------------------------------------------
// Unrolled kernel (the portable fallback): one KV pass per *group* (all s
// query heads share the loaded K/V), stack-staged f32 tiles, 8-lane
// unrolled dot / saxpy bodies. The walk is partitioned KV-head-major —
// `tuning.partition` rows of one head's strip at a time, next row
// prefetched — exactly the loop structure of the SIMD tier, so the two
// differ only in the vector bodies.
// ---------------------------------------------------------------------------

/// Max head_dim the stack tiles support (covers all paper models: 128).
pub(super) const MAX_HD: usize = 256;

#[inline(always)]
fn dot_unrolled(a: &[f32], b: &[f32], n: usize) -> f32 {
    // 8-lane partial sums: independent accumulators keep the FMA chain
    // parallel (what the intrinsics version does with AVX registers).
    let mut s = [0f32; 8];
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        s[0] += a[i] * b[i];
        s[1] += a[i + 1] * b[i + 1];
        s[2] += a[i + 2] * b[i + 2];
        s[3] += a[i + 3] * b[i + 3];
        s[4] += a[i + 4] * b[i + 4];
        s[5] += a[i + 5] * b[i + 5];
        s[6] += a[i + 6] * b[i + 6];
        s[7] += a[i + 7] * b[i + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

#[inline(always)]
fn saxpy_unrolled(acc: &mut [f32], x: &[f32], w: f32, n: usize) {
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        acc[i] += w * x[i];
        acc[i + 1] += w * x[i + 1];
        acc[i + 2] += w * x[i + 2];
        acc[i + 3] += w * x[i + 3];
        acc[i + 4] += w * x[i + 4];
        acc[i + 5] += w * x[i + 5];
        acc[i + 6] += w * x[i + 6];
        acc[i + 7] += w * x[i + 7];
    }
    for i in chunks * 8..n {
        acc[i] += w * x[i];
    }
}

#[inline(always)]
fn upconvert(dst: &mut [f32], src: &[u16], n: usize) {
    // BF16 -> f32 is a 16-bit shift; written as a flat loop so the
    // compiler vectorizes the widening.
    for i in 0..n {
        dst[i] = f32::from_bits((src[i] as u32) << 16);
    }
}

fn attend_unrolled(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    seq: SeqId,
    q: &[f32],
    out: &mut [f32],
    tuning: AttnTuning,
) {
    let hd = shape.head_dim;
    assert!(hd <= MAX_HD, "head_dim {hd} exceeds kernel tile size");
    let kv_dim = shape.kv_dim();
    let group = shape.gqa_group();
    let scale = 1.0 / (hd as f32).sqrt();
    let nh = shape.n_heads;
    let part = tuning.partition.max(1);

    let mut m = vec![f32::NEG_INFINITY; nh];
    let mut denom = vec![0f32; nh];
    let mut acc = vec![0f32; nh * hd];

    let mut k_tile = [0f32; MAX_HD];
    let mut v_tile = [0f32; MAX_HD];

    cache.walk_context(seq, layer, |k_run, v_run, n| {
        let mut t0 = 0usize;
        while t0 < n {
            let t1 = (t0 + part).min(n);
            for kvh in 0..shape.n_kv_heads {
                for t in t0..t1 {
                    let off = t * kv_dim + kvh * hd;
                    if t + 1 < t1 {
                        super::simd::prefetch_row(&k_run[off + kv_dim..off + kv_dim + hd]);
                        super::simd::prefetch_row(&v_run[off + kv_dim..off + kv_dim + hd]);
                    }
                    upconvert(&mut k_tile, &k_run[off..off + hd], hd);
                    upconvert(&mut v_tile, &v_run[off..off + hd], hd);
                    for gi in 0..group {
                        let h = kvh * group + gi;
                        let qh = &q[h * hd..(h + 1) * hd];
                        let s = dot_unrolled(qh, &k_tile, hd) * scale;
                        let acch = &mut acc[h * hd..(h + 1) * hd];
                        if s > m[h] {
                            let corr = (m[h] - s).exp();
                            for a in acch.iter_mut() {
                                *a *= corr;
                            }
                            denom[h] *= corr;
                            m[h] = s;
                        }
                        let w = (s - m[h]).exp();
                        denom[h] += w;
                        saxpy_unrolled(acch, &v_tile, w, hd);
                    }
                }
            }
            t0 = t1;
        }
    });

    for h in 0..nh {
        let inv = 1.0 / denom[h];
        let src = &acc[h * hd..(h + 1) * hd];
        let dst = &mut out[h * hd..(h + 1) * hd];
        for d in 0..hd {
            dst[d] = src[d] * inv;
        }
    }
}

// ---------------------------------------------------------------------------
// Dense entry point (golden vectors, Fig.-10 bench): contexts laid out as
// [n_seq, l_max, kv_dim] BF16, per-sequence true lengths in `lens`.
// ---------------------------------------------------------------------------

/// Decode attention over dense BF16 context buffers. `q` is
/// `[n_seq, n_heads*head_dim]`; output is written per sequence into `out`.
pub fn decode_attention_dense(
    shape: AttnShape,
    q: &[f32],
    k_bits: &[u16],
    v_bits: &[u16],
    lens: &[usize],
    l_max: usize,
    out: &mut [f32],
    tier: Tier,
) {
    use crate::kvcache::KvLayout;
    let kv_dim = shape.kv_dim();
    let q_dim = shape.q_dim();
    assert_eq!(q.len(), lens.len() * q_dim);
    assert_eq!(k_bits.len(), lens.len() * l_max * kv_dim);
    assert_eq!(out.len(), lens.len() * q_dim);

    // Stage through a single-layer paged cache with block_size = l_max so
    // every sequence is one contiguous run — zero-cost adapter that keeps
    // one kernel implementation. BF16 bits go in verbatim via the bulk
    // run writer (no per-token f32 round trip).
    let mut cache =
        PagedKvCache::new(KvLayout::new(l_max, lens.len()), 1, kv_dim);
    for (i, &len) in lens.iter().enumerate() {
        let id = i as SeqId;
        cache.register(id);
        cache.grow(id, len);
        let base = i * l_max * kv_dim;
        cache.write_run(
            id,
            0,
            0,
            len,
            &k_bits[base..base + len * kv_dim],
            &v_bits[base..base + len * kv_dim],
        );
    }
    for (i, _) in lens.iter().enumerate() {
        attend_one(
            &cache,
            0,
            shape,
            i as SeqId,
            &q[i * q_dim..(i + 1) * q_dim],
            &mut out[i * q_dim..(i + 1) * q_dim],
            tier,
            AttnTuning::default(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32) * 0.1 - 1.0).collect();
        let b: Vec<f32> = (0..37).map(|i| 0.5 - (i as f32) * 0.05).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let fast = dot_unrolled(&a, &b, 37);
        assert!((naive - fast).abs() < 1e-4, "{naive} vs {fast}");
    }

    #[test]
    fn saxpy_unrolled_matches_naive() {
        let mut acc = vec![1.0f32; 19];
        let x: Vec<f32> = (0..19).map(|i| i as f32).collect();
        saxpy_unrolled(&mut acc, &x, 0.5, 19);
        for (i, a) in acc.iter().enumerate() {
            assert_eq!(*a, 1.0 + 0.5 * i as f32);
        }
    }

    #[test]
    fn upconvert_is_exact() {
        use crate::util::bf16::f32_to_bf16;
        let src: Vec<u16> = [-2.5f32, 0.0, 1.5, 100.0].iter().map(|&x| f32_to_bf16(x)).collect();
        let mut dst = [0f32; 4];
        upconvert(&mut dst, &src, 4);
        assert_eq!(dst, [-2.5, 0.0, 1.5, 100.0]);
    }
}
