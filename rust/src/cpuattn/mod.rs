//! CPU decode attention (§6.6) — the host-side half of the hybrid system.
//!
//! The paper's CPU Task (C): flash-decode attention over the paged BF16
//! KV cache, computed in f32. Four rungs reproduce (and extend) §6.6's
//! optimization ladder:
//!
//! * [`Tier::Scalar`] — the "auto-vectorized" baseline: straightforward
//!   loops, one query head at a time, whatever LLVM makes of them.
//! * [`Tier::Unrolled`] — the portable hand-optimized kernel:
//!   GQA-grouped KV walks (one cache pass serves all `s` query heads of
//!   a group), 8-lane unrolled dot/saxpby bodies shaped for the vector
//!   units, partitioned strips with software prefetch, and
//!   block-contiguous strides from the paged store.
//! * [`Tier::Simd`] — explicit `std::arch` AVX2+FMA bodies operating on
//!   the BF16 rows directly (see [`simd`]), behind runtime
//!   `is_x86_feature_detected!` dispatch with the unrolled kernel as
//!   the portable fallback. [`Tier::Optimized`] is the silent-upgrade
//!   alias the engine uses: SIMD where the host supports it, unrolled
//!   everywhere else.
//! * Threaded — the optimized kernel sharded over a work-stealing
//!   [`ThreadPool`] by sequence (scales until the memory controller
//!   saturates — Fig. 10's knee).
//!
//! Tuning knobs ([`AttnTuning`]) thread through every rung; the swept
//! evidence lives in `benches/fig10_cpu_attention.rs`, which maintains
//! the committed `BENCH_cpu_attention.json` artifact.
//!
//! Numerics: BF16 loads are up-converted to f32 (§5.3); the softmax is
//! the running-max/running-sum flash form, matching the JAX oracle
//! `kernels/ref.py::ref_decode_attention` bit-for-bit in structure.

mod kernel;
pub mod simd;
mod threaded;

pub use kernel::{decode_attention_dense, Tier};
pub use simd::simd_available;
pub use threaded::ThreadPool;

use crate::kvcache::{PagedKvCache, SeqId};

/// One decode query: a sequence and its current query vector
/// (`n_heads * head_dim` f32, laid out head-major).
pub struct DecodeQuery<'a> {
    pub seq: SeqId,
    pub q: &'a [f32],
}

/// Geometry the kernel needs (a subset of `ModelSpec`).
#[derive(Debug, Clone, Copy)]
pub struct AttnShape {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
}

/// Kernel tuning knobs, threaded through every tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnTuning {
    /// KV rows walked per partition: the unrolled/SIMD tiers process one
    /// KV head's strip for `partition` consecutive rows before moving to
    /// the next head, bounding the working set per sweep. mistral.rs's
    /// paged attention hard-codes 512; ours is swept in
    /// `benches/fig10_cpu_attention.rs`. Partitioning never changes the
    /// per-head update order, so results are bit-identical across values.
    pub partition: usize,
}

impl Default for AttnTuning {
    fn default() -> Self {
        AttnTuning { partition: 512 }
    }
}

/// Decode attention for a batch of queries against the paged cache, one
/// layer, at default tuning. Writes each result (`n_heads * head_dim`
/// f32) into `out` (concatenated, query-major). The single-thread tiers
/// run on the caller's thread; use [`ThreadPool::decode_attention`] for
/// the threaded rung.
pub fn decode_attention(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    queries: &[DecodeQuery],
    out: &mut [f32],
    tier: Tier,
) {
    decode_attention_tuned(cache, layer, shape, queries, out, tier, AttnTuning::default());
}

/// [`decode_attention`] with explicit tuning (the bench sweeps this).
pub fn decode_attention_tuned(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    queries: &[DecodeQuery],
    out: &mut [f32],
    tier: Tier,
    tuning: AttnTuning,
) {
    let q_dim = shape.q_dim();
    assert_eq!(out.len(), queries.len() * q_dim);
    assert_eq!(cache.kv_dim(), shape.kv_dim());
    for (qi, query) in queries.iter().enumerate() {
        assert_eq!(query.q.len(), q_dim);
        let dst = &mut out[qi * q_dim..(qi + 1) * q_dim];
        kernel::attend_one(cache, layer, shape, query.seq, query.q, dst, tier, tuning);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvLayout;
    use crate::util::bf16::bf16_round;
    use crate::util::rng::Rng;

    /// Pure-f64 oracle mirroring ref.py::ref_decode_attention.
    pub(crate) fn oracle(
        shape: AttnShape,
        q: &[f32],
        k_ctx: &[f32], // [len, kv_dim], already bf16-rounded
        v_ctx: &[f32],
        len: usize,
    ) -> Vec<f32> {
        let (nh, hd) = (shape.n_heads, shape.head_dim);
        let group = shape.gqa_group();
        let scale = 1.0 / (hd as f64).sqrt();
        let mut out = vec![0f32; nh * hd];
        for h in 0..nh {
            let kvh = h / group;
            let qh = &q[h * hd..(h + 1) * hd];
            let mut scores = vec![0f64; len];
            for t in 0..len {
                let kt = &k_ctx[t * shape.kv_dim() + kvh * hd..];
                let mut dot = 0f64;
                for d in 0..hd {
                    dot += qh[d] as f64 * kt[d] as f64;
                }
                scores[t] = dot * scale;
            }
            let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut denom = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - m).exp();
                denom += *s;
            }
            for t in 0..len {
                let vt = &v_ctx[t * shape.kv_dim() + kvh * hd..];
                let w = scores[t] / denom;
                for d in 0..hd {
                    out[h * hd + d] += (w * vt[d] as f64) as f32;
                }
            }
        }
        out
    }

    pub(crate) fn build_cache(
        shape: AttnShape,
        lens: &[usize],
        block_size: usize,
        rng: &mut Rng,
    ) -> (PagedKvCache, Vec<(Vec<f32>, Vec<f32>)>) {
        let total_blocks: usize =
            lens.iter().map(|&l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache =
            PagedKvCache::new(KvLayout::new(block_size, total_blocks), 1, shape.kv_dim());
        let mut dense = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            let id = i as SeqId;
            cache.register(id);
            cache.grow(id, len);
            let mut kd = Vec::new();
            let mut vd = Vec::new();
            for pos in 0..len {
                let k: Vec<f32> =
                    (0..shape.kv_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let v: Vec<f32> =
                    (0..shape.kv_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect();
                cache.write(id, 0, pos, &k, &v);
                kd.extend(k.iter().map(|&x| bf16_round(x)));
                vd.extend(v.iter().map(|&x| bf16_round(x)));
            }
            dense.push((kd, vd));
        }
        (cache, dense)
    }

    fn check_tier(tier: Tier) {
        let shape = AttnShape { n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let mut rng = Rng::new(42);
        let lens = [1usize, 5, 16, 33];
        let (cache, dense) = build_cache(shape, &lens, 16, &mut rng);
        let qs: Vec<Vec<f32>> = lens
            .iter()
            .map(|_| (0..shape.q_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect())
            .collect();
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();
        let mut out = vec![0f32; queries.len() * shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut out, tier);
        for (i, &len) in lens.iter().enumerate() {
            let (kd, vd) = &dense[i];
            let want = oracle(shape, &qs[i], kd, vd, len);
            let got = &out[i * shape.q_dim()..(i + 1) * shape.q_dim()];
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "tier {tier:?} seq {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn scalar_matches_oracle() {
        check_tier(Tier::Scalar);
    }

    #[test]
    fn unrolled_matches_oracle() {
        check_tier(Tier::Unrolled);
    }

    #[test]
    fn simd_matches_oracle() {
        // On non-AVX2 hosts this exercises the portable fallback path of
        // the dispatcher — still worth running.
        check_tier(Tier::Simd);
    }

    #[test]
    fn optimized_matches_oracle() {
        check_tier(Tier::Optimized);
    }

    #[test]
    fn partition_size_is_bit_invariant() {
        // Partitioning reorders the walk across heads, never within one
        // head's token sequence, so every partition size must produce
        // bit-identical output for every tier that honors it.
        let shape = AttnShape { n_heads: 8, n_kv_heads: 2, head_dim: 32 };
        let mut rng = Rng::new(21);
        let lens = [53usize, 9, 1];
        let (cache, _) = build_cache(shape, &lens, 8, &mut rng);
        let qs: Vec<Vec<f32>> = lens
            .iter()
            .map(|_| (0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect())
            .collect();
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();
        for tier in [Tier::Unrolled, Tier::Simd, Tier::Optimized] {
            let mut base = vec![0f32; queries.len() * shape.q_dim()];
            decode_attention(&cache, 0, shape, &queries, &mut base, tier);
            for partition in [1usize, 3, 8, 64, 4096] {
                let mut out = vec![0f32; queries.len() * shape.q_dim()];
                decode_attention_tuned(
                    &cache,
                    0,
                    shape,
                    &queries,
                    &mut out,
                    tier,
                    AttnTuning { partition },
                );
                assert_eq!(out, base, "tier {tier:?} partition {partition}");
            }
        }
    }

    #[test]
    fn tiers_agree_closely() {
        // Scalar and optimized reorder float ops; results must still agree
        // tightly because both accumulate in f32 over short contexts.
        let shape = AttnShape { n_heads: 8, n_kv_heads: 2, head_dim: 32 };
        let mut rng = Rng::new(3);
        let lens = [40usize, 7];
        let (cache, _) = build_cache(shape, &lens, 8, &mut rng);
        let q: Vec<f32> = (0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect();
        let mut a = vec![0f32; shape.q_dim()];
        let mut b = vec![0f32; shape.q_dim()];
        let query = [DecodeQuery { seq: 0, q: &q }];
        decode_attention(&cache, 0, shape, &query, &mut a, Tier::Scalar);
        decode_attention(&cache, 0, shape, &query, &mut b, Tier::Optimized);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn single_token_context_is_identity_over_v() {
        // len=1: softmax weight is 1, output == v (bf16-rounded).
        let shape = AttnShape { n_heads: 2, n_kv_heads: 1, head_dim: 4 };
        let mut rng = Rng::new(11);
        let (cache, dense) = build_cache(shape, &[1], 4, &mut rng);
        let q = vec![0.3f32; shape.q_dim()];
        let mut out = vec![0f32; shape.q_dim()];
        decode_attention(
            &cache,
            0,
            shape,
            &[DecodeQuery { seq: 0, q: &q }],
            &mut out,
            Tier::Optimized,
        );
        let v = &dense[0].1;
        for h in 0..2 {
            for d in 0..4 {
                assert!((out[h * 4 + d] - v[d]).abs() < 1e-6);
            }
        }
    }
}
