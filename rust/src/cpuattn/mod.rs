//! CPU decode attention (§6.6) — the host-side half of the hybrid system.
//!
//! The paper's CPU Task (C): flash-decode attention over the paged BF16
//! KV cache, computed in f32. Three tiers reproduce §6.6's optimization
//! ladder:
//!
//! * [`Tier::Scalar`] — the "auto-vectorized" baseline: straightforward
//!   loops, one query head at a time, whatever LLVM makes of them.
//! * [`Tier::Optimized`] — the hand-optimized kernel: GQA-grouped KV
//!   walks (one cache pass serves all `s` query heads of a group),
//!   8-lane unrolled dot/saxpby bodies shaped for the vector units, and
//!   block-contiguous strides from the paged store.
//! * [`Tier::Threaded`] — the optimized kernel sharded over worker
//!   threads by sequence (scales until the memory controller saturates —
//!   Fig. 10's knee).
//!
//! Numerics: BF16 loads are up-converted to f32 (§5.3); the softmax is
//! the running-max/running-sum flash form, matching the JAX oracle
//! `kernels/ref.py::ref_decode_attention` bit-for-bit in structure.

mod kernel;
mod threaded;

pub use kernel::{decode_attention_dense, Tier};
pub use threaded::ThreadPool;

use crate::kvcache::{PagedKvCache, SeqId};

/// One decode query: a sequence and its current query vector
/// (`n_heads * head_dim` f32, laid out head-major).
pub struct DecodeQuery<'a> {
    pub seq: SeqId,
    pub q: &'a [f32],
}

/// Geometry the kernel needs (a subset of `ModelSpec`).
#[derive(Debug, Clone, Copy)]
pub struct AttnShape {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
}

/// Decode attention for a batch of queries against the paged cache, one
/// layer. Writes each result (`n_heads * head_dim` f32) into `out`
/// (concatenated, query-major). The scalar/optimized tiers run on the
/// caller's thread; use [`ThreadPool::decode_attention`] for the threaded
/// tier.
pub fn decode_attention(
    cache: &PagedKvCache,
    layer: usize,
    shape: AttnShape,
    queries: &[DecodeQuery],
    out: &mut [f32],
    tier: Tier,
) {
    let q_dim = shape.q_dim();
    assert_eq!(out.len(), queries.len() * q_dim);
    assert_eq!(cache.kv_dim(), shape.kv_dim());
    for (qi, query) in queries.iter().enumerate() {
        assert_eq!(query.q.len(), q_dim);
        let dst = &mut out[qi * q_dim..(qi + 1) * q_dim];
        kernel::attend_one(cache, layer, shape, query.seq, query.q, dst, tier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvLayout;
    use crate::util::bf16::bf16_round;
    use crate::util::rng::Rng;

    /// Pure-f64 oracle mirroring ref.py::ref_decode_attention.
    pub(crate) fn oracle(
        shape: AttnShape,
        q: &[f32],
        k_ctx: &[f32], // [len, kv_dim], already bf16-rounded
        v_ctx: &[f32],
        len: usize,
    ) -> Vec<f32> {
        let (nh, hd) = (shape.n_heads, shape.head_dim);
        let group = shape.gqa_group();
        let scale = 1.0 / (hd as f64).sqrt();
        let mut out = vec![0f32; nh * hd];
        for h in 0..nh {
            let kvh = h / group;
            let qh = &q[h * hd..(h + 1) * hd];
            let mut scores = vec![0f64; len];
            for t in 0..len {
                let kt = &k_ctx[t * shape.kv_dim() + kvh * hd..];
                let mut dot = 0f64;
                for d in 0..hd {
                    dot += qh[d] as f64 * kt[d] as f64;
                }
                scores[t] = dot * scale;
            }
            let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut denom = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - m).exp();
                denom += *s;
            }
            for t in 0..len {
                let vt = &v_ctx[t * shape.kv_dim() + kvh * hd..];
                let w = scores[t] / denom;
                for d in 0..hd {
                    out[h * hd + d] += (w * vt[d] as f64) as f32;
                }
            }
        }
        out
    }

    pub(crate) fn build_cache(
        shape: AttnShape,
        lens: &[usize],
        block_size: usize,
        rng: &mut Rng,
    ) -> (PagedKvCache, Vec<(Vec<f32>, Vec<f32>)>) {
        let total_blocks: usize =
            lens.iter().map(|&l| l.div_ceil(block_size)).sum::<usize>() + 1;
        let mut cache =
            PagedKvCache::new(KvLayout::new(block_size, total_blocks), 1, shape.kv_dim());
        let mut dense = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            let id = i as SeqId;
            cache.register(id);
            cache.grow(id, len);
            let mut kd = Vec::new();
            let mut vd = Vec::new();
            for pos in 0..len {
                let k: Vec<f32> =
                    (0..shape.kv_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let v: Vec<f32> =
                    (0..shape.kv_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect();
                cache.write(id, 0, pos, &k, &v);
                kd.extend(k.iter().map(|&x| bf16_round(x)));
                vd.extend(v.iter().map(|&x| bf16_round(x)));
            }
            dense.push((kd, vd));
        }
        (cache, dense)
    }

    fn check_tier(tier: Tier) {
        let shape = AttnShape { n_heads: 4, n_kv_heads: 2, head_dim: 16 };
        let mut rng = Rng::new(42);
        let lens = [1usize, 5, 16, 33];
        let (cache, dense) = build_cache(shape, &lens, 16, &mut rng);
        let qs: Vec<Vec<f32>> = lens
            .iter()
            .map(|_| (0..shape.q_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect())
            .collect();
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();
        let mut out = vec![0f32; queries.len() * shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut out, tier);
        for (i, &len) in lens.iter().enumerate() {
            let (kd, vd) = &dense[i];
            let want = oracle(shape, &qs[i], kd, vd, len);
            let got = &out[i * shape.q_dim()..(i + 1) * shape.q_dim()];
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "tier {tier:?} seq {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn scalar_matches_oracle() {
        check_tier(Tier::Scalar);
    }

    #[test]
    fn optimized_matches_oracle() {
        check_tier(Tier::Optimized);
    }

    #[test]
    fn tiers_agree_closely() {
        // Scalar and optimized reorder float ops; results must still agree
        // tightly because both accumulate in f32 over short contexts.
        let shape = AttnShape { n_heads: 8, n_kv_heads: 2, head_dim: 32 };
        let mut rng = Rng::new(3);
        let lens = [40usize, 7];
        let (cache, _) = build_cache(shape, &lens, 8, &mut rng);
        let q: Vec<f32> = (0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect();
        let mut a = vec![0f32; shape.q_dim()];
        let mut b = vec![0f32; shape.q_dim()];
        let query = [DecodeQuery { seq: 0, q: &q }];
        decode_attention(&cache, 0, shape, &query, &mut a, Tier::Scalar);
        decode_attention(&cache, 0, shape, &query, &mut b, Tier::Optimized);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn single_token_context_is_identity_over_v() {
        // len=1: softmax weight is 1, output == v (bf16-rounded).
        let shape = AttnShape { n_heads: 2, n_kv_heads: 1, head_dim: 4 };
        let mut rng = Rng::new(11);
        let (cache, dense) = build_cache(shape, &[1], 4, &mut rng);
        let q = vec![0.3f32; shape.q_dim()];
        let mut out = vec![0f32; shape.q_dim()];
        decode_attention(
            &cache,
            0,
            shape,
            &[DecodeQuery { seq: 0, q: &q }],
            &mut out,
            Tier::Optimized,
        );
        let v = &dense[0].1;
        for h in 0..2 {
            for d in 0..4 {
                assert!((out[h * 4 + d] - v[d]).abs() < 1e-6);
            }
        }
    }
}
