//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! Python runs once (`make artifacts`); this module makes the Rust binary
//! self-contained afterwards. Interchange is **HLO text** (the image's
//! xla_extension 0.5.1 rejects jax≥0.5 serialized protos with 64-bit
//! instruction ids; the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! * [`manifest`] — parse `artifacts/manifest.json`: per-config artifact
//!   files, argument specs, weight table, golden-vector pointers.
//! * [`executor`] — PJRT CPU client + compiled executables with argument
//!   validation against the manifest specs.

mod executor;
mod manifest;

pub use executor::{to_f32, to_i32, Arg, Engine as PjrtEngine, Executable};
pub use manifest::{ArgSpec, ArtifactSpec, ConfigManifest, Manifest, RuntimeConfig};
