//! `artifacts/manifest.json` — the compile-time contract between the
//! Python AOT path and the Rust coordinator.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One executable argument: name, shape, dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled executable: HLO file + signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    /// (name, shape) per output, in tuple order.
    pub outputs: Vec<(String, Vec<usize>)>,
}

/// The executable model config (mirrors python/compile/config.py).
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    /// Compiled token-bucket size (static PJRT shape).
    pub n_tok: usize,
    /// Max context the decode path supports.
    pub max_ctx: usize,
}

impl RuntimeConfig {
    pub fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Cross-check against the Rust-side `ModelSpec` of the same name
    /// (the two are maintained in parallel; drift is a build error).
    pub fn check_against_spec(&self) -> Result<()> {
        let spec = crate::config::ModelSpec::by_name(&self.name)
            .with_context(|| format!("no ModelSpec named '{}'", self.name))?;
        let pairs = [
            ("vocab", self.vocab, spec.vocab),
            ("d_model", self.d_model, spec.d_model),
            ("n_layers", self.n_layers, spec.n_layers),
            ("n_heads", self.n_heads, spec.n_heads),
            ("n_kv_heads", self.n_kv_heads, spec.n_kv_heads),
            ("head_dim", self.head_dim, spec.head_dim),
            ("n_experts", self.n_experts, spec.n_experts),
            ("top_k", self.top_k, spec.top_k),
            ("d_ff", self.d_ff, spec.d_ff),
        ];
        for (what, a, b) in pairs {
            if a != b {
                bail!("config '{}' drift on {what}: manifest {a} vs ModelSpec {b}", self.name);
            }
        }
        Ok(())
    }
}

/// Everything the manifest records for one config.
#[derive(Debug, Clone)]
pub struct ConfigManifest {
    pub config: RuntimeConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// The raw `weights` object (consumed by `transfer::WeightFile`).
    pub weights: Json,
    /// Golden-vector file name, if exported for this config.
    pub golden: Option<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: String,
    pub configs: BTreeMap<String, ConfigManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e:?}"))?;
        let version = root.req("format_version").as_usize().context("format_version")?;
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }
        let mut configs = BTreeMap::new();
        for (name, entry) in root.req("configs").as_obj().context("configs")? {
            configs.insert(name.clone(), parse_config(name, entry)?);
        }
        Ok(Manifest { dir: dir.to_string(), configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigManifest> {
        self.configs
            .get(name)
            .with_context(|| format!("config '{name}' not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, file: &str) -> String {
        format!("{}/{file}", self.dir)
    }
}

fn parse_config(name: &str, entry: &Json) -> Result<ConfigManifest> {
    let c = entry.req("config");
    let g = |k: &str| -> Result<usize> {
        c.req(k).as_usize().with_context(|| format!("config.{k}"))
    };
    let config = RuntimeConfig {
        name: name.to_string(),
        vocab: g("vocab")?,
        d_model: g("d_model")?,
        n_layers: g("n_layers")?,
        n_heads: g("n_heads")?,
        n_kv_heads: g("n_kv_heads")?,
        head_dim: g("head_dim")?,
        n_experts: g("n_experts")?,
        top_k: g("top_k")?,
        d_ff: g("d_ff")?,
        rope_theta: c.req("rope_theta").as_f64().context("rope_theta")?,
        n_tok: g("n_tok")?,
        max_ctx: g("max_ctx")?,
    };

    let mut artifacts = BTreeMap::new();
    for (aname, a) in entry.req("artifacts").as_obj().context("artifacts")? {
        let file = a.req("file").as_str().context("file")?.to_string();
        let mut args = Vec::new();
        for arg in a.req("args").as_arr().context("args")? {
            let triple = arg.as_arr().context("arg triple")?;
            args.push(ArgSpec {
                name: triple[0].as_str().context("arg name")?.to_string(),
                shape: triple[1].as_usize_vec().context("arg shape")?,
                dtype: triple[2].as_str().context("arg dtype")?.to_string(),
            });
        }
        let mut outputs = Vec::new();
        for out in a.req("outputs").as_arr().context("outputs")? {
            let pair = out.as_arr().context("output pair")?;
            outputs.push((
                pair[0].as_str().context("output name")?.to_string(),
                pair[1].as_usize_vec().context("output shape")?,
            ));
        }
        artifacts.insert(
            aname.clone(),
            ArtifactSpec { name: aname.clone(), file, args, outputs },
        );
    }

    Ok(ConfigManifest {
        config,
        artifacts,
        weights: entry.req("weights").clone(),
        golden: entry.get("golden").and_then(|g| g.as_str()).map(String::from),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        std::path::Path::new("artifacts/manifest.json")
            .exists()
            .then(|| Manifest::load("artifacts").unwrap())
    }

    #[test]
    fn loads_and_cross_checks_tiny() {
        let Some(m) = manifest() else { return };
        let tiny = m.config("tiny").unwrap();
        tiny.config.check_against_spec().unwrap();
        assert_eq!(tiny.config.n_tok, 16);
        assert!(tiny.golden.is_some(), "tiny must ship golden vectors");
    }

    #[test]
    fn all_five_executables_present_with_files() {
        let Some(m) = manifest() else { return };
        for cfg in m.configs.values() {
            for name in ["embed", "task_a", "prefill_attn", "task_b", "head"] {
                let a = cfg
                    .artifacts
                    .get(name)
                    .unwrap_or_else(|| panic!("{}: missing {name}", cfg.config.name));
                assert!(
                    std::path::Path::new(&m.path(&a.file)).exists(),
                    "{} missing",
                    a.file
                );
                assert!(!a.args.is_empty() && !a.outputs.is_empty());
            }
        }
    }

    #[test]
    fn task_a_signature_matches_config() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("tiny").unwrap();
        let a = &cfg.artifacts["task_a"];
        let c = &cfg.config;
        assert_eq!(a.args[0].shape, vec![c.n_tok, c.d_model]); // x
        assert_eq!(a.args[1].shape, vec![c.n_tok]); // positions
        assert_eq!(a.args[3].shape, vec![c.d_model, c.q_dim()]); // wq
        assert_eq!(a.outputs[0].1, vec![c.n_tok, c.n_heads, c.head_dim]); // q
    }

    #[test]
    fn unknown_config_errors() {
        let Some(m) = manifest() else { return };
        assert!(m.config("huge").is_err());
    }
}
