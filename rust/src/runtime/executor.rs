//! PJRT CPU client + compiled executables.
//!
//! One [`Engine`] per process: it owns the `xla` crate's PJRT client and
//! the compiled executables for one model config. Executables validate
//! every call against the manifest's argument specs — shape bugs surface
//! as errors at the call site, not as garbage numerics.

use super::manifest::{ArgSpec, ArtifactSpec, ConfigManifest, Manifest, RuntimeConfig};
use anyhow::{bail, Context, Result};

/// A compiled PJRT executable + its manifest signature.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Argument payloads accepted by [`Executable::run`].
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Executable {
    /// Build (and validate) the literal for positional argument `idx` —
    /// the host→device staging copy. Hot-path callers prepare invariant
    /// arguments (layer weights) once and reuse them across calls via
    /// [`run_prepared`] (§Perf iteration 6).
    pub fn literal(&self, idx: usize, arg: &Arg) -> Result<xla::Literal> {
        let spec = self
            .spec
            .args
            .get(idx)
            .with_context(|| format!("{}: no argument {idx}", self.spec.name))?;
        make_literal(arg, spec)
    }

    /// Execute with positional arguments; returns the flattened output
    /// tuple as literals (callers decode with [`to_f32`]/[`to_i32`]).
    pub fn run(&self, args: &[Arg]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.spec.name,
                self.spec.args.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.spec.args) {
            literals.push(make_literal(arg, spec)?);
        }
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_prepared(&refs)
    }

    /// Execute with pre-staged literals (see [`literal`]). Borrowed so
    /// invariant weight literals are shared across calls without a deep
    /// `Literal::clone`.
    pub fn run_prepared(&self, literals: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if literals.len() != self.spec.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.spec.name,
                self.spec.args.len(),
                literals.len()
            );
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} result", self.spec.name))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let outs = tuple.to_tuple().context("decomposing output tuple")?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

fn make_literal(arg: &Arg, spec: &ArgSpec) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match (arg, spec.dtype.as_str()) {
        (Arg::F32(data), "float32") => {
            if data.len() != spec.elems() {
                bail!("arg {}: {} elems, want {}", spec.name, data.len(), spec.elems());
            }
            xla::Literal::vec1(data)
        }
        (Arg::I32(data), "int32") => {
            if data.len() != spec.elems() {
                bail!("arg {}: {} elems, want {}", spec.name, data.len(), spec.elems());
            }
            xla::Literal::vec1(data)
        }
        (_, dt) => bail!("arg {}: payload type does not match dtype {dt}", spec.name),
    };
    lit.reshape(&dims)
        .with_context(|| format!("reshaping arg {} to {:?}", spec.name, spec.shape))
}

/// Decode a literal as f32 (converting if the executable produced f64 —
/// XLA folds some ops to wider types).
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// The per-config PJRT engine: client + the five compiled executables.
pub struct Engine {
    pub config: RuntimeConfig,
    client: xla::PjRtClient,
    pub embed: Executable,
    pub task_a: Executable,
    pub prefill_attn: Executable,
    pub task_b: Executable,
    pub head: Executable,
}

impl Engine {
    /// Compile all executables of `config` from the manifest's HLO text.
    pub fn load(manifest: &Manifest, config: &str) -> Result<Engine> {
        let cm = manifest.config(config)?;
        cm.config.check_against_spec()?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |name: &str| -> Result<Executable> {
            compile_one(&client, manifest, cm, name)
        };
        Ok(Engine {
            embed: load("embed")?,
            task_a: load("task_a")?,
            prefill_attn: load("prefill_attn")?,
            task_b: load("task_b")?,
            head: load("head")?,
            config: cm.config.clone(),
            client,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn compile_one(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cm: &ConfigManifest,
    name: &str,
) -> Result<Executable> {
    let spec = cm
        .artifacts
        .get(name)
        .with_context(|| format!("artifact '{name}' not in manifest"))?
        .clone();
    let path = manifest.path(&spec.file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .with_context(|| format!("parsing HLO text {path}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .with_context(|| format!("compiling {name} ({path})"))?;
    Ok(Executable { spec, exe })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        std::path::Path::new("artifacts/manifest.json").exists().then(|| {
            let m = Manifest::load("artifacts").unwrap();
            Engine::load(&m, "tiny").unwrap()
        })
    }

    #[test]
    fn compiles_all_tiny_executables() {
        let Some(e) = engine() else { return };
        assert_eq!(e.platform(), "cpu");
        assert_eq!(e.config.n_tok, 16);
    }

    #[test]
    fn embed_gathers_rows() {
        let Some(e) = engine() else { return };
        // embedding arg is a full [vocab, h] table; use a ramp so row i
        // starts at i * h.
        let (vocab, h, n) = (e.config.vocab, e.config.d_model, e.config.n_tok);
        let table: Vec<f32> = (0..vocab * h).map(|i| i as f32).collect();
        let ids: Vec<i32> = (0..n as i32).map(|i| (i * 3) % vocab as i32).collect();
        let outs = e.embed.run(&[Arg::I32(&ids), Arg::F32(&table)]).unwrap();
        let x = to_f32(&outs[0]).unwrap();
        assert_eq!(x.len(), n * h);
        for (t, &id) in ids.iter().enumerate() {
            assert_eq!(x[t * h], (id as usize * h) as f32, "row start for token {t}");
        }
    }

    #[test]
    fn argument_validation_rejects_bad_shapes() {
        let Some(e) = engine() else { return };
        let bad = vec![0f32; 3];
        let ids = vec![0i32; e.config.n_tok];
        let err = e.embed.run(&[Arg::I32(&ids), Arg::F32(&bad)]);
        assert!(err.is_err());
        let err2 = e.embed.run(&[Arg::I32(&ids)]);
        assert!(err2.is_err());
    }

    #[test]
    fn head_argmax_matches_manual() {
        let Some(e) = engine() else { return };
        let (vocab, h, n) = (e.config.vocab, e.config.d_model, e.config.n_tok);
        // x = one-hot rows scaled; final_norm = ones; lm_head row r has a
        // single large entry at column (r % vocab).
        let mut x = vec![0f32; n * h];
        for t in 0..n {
            x[t * h + (t % h)] = 1.0;
        }
        let norm = vec![1f32; h];
        let mut lm = vec![0f32; h * vocab];
        for r in 0..h {
            lm[r * vocab + (r * 7) % vocab] = 5.0;
        }
        let outs = e.head.run(&[Arg::F32(&x), Arg::F32(&norm), Arg::F32(&lm)]).unwrap();
        let ids = to_i32(&outs[0]).unwrap();
        let logits = to_f32(&outs[1]).unwrap();
        assert_eq!(ids.len(), n);
        assert_eq!(logits.len(), n * vocab);
        for t in 0..n {
            // rmsnorm of a one-hot keeps the hot row dominant
            assert_eq!(ids[t] as usize, ((t % h) * 7) % vocab, "token {t}");
        }
    }
}
