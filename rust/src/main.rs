//! MoE-Lens CLI (Layer-3 leader entrypoint).
//!
//! Subcommands mirror the paper's three-stage methodology: `plan` runs
//! the Stage-1/Stage-2 performance models, `simulate` replays policies on
//! the paper-scale virtual machine, and `serve`/`profile` drive the real
//! PJRT engine on the executable configs.

use moe_lens::config::{GpuSpec, MachineSpec, ModelSpec, WorkloadSpec};
use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::metrics::RunReport;
use moe_lens::perfmodel::{Stage1Model, Stage2Model};
use moe_lens::sched::{AdmissionPolicy, PipelineProfiler, VictimPolicy};
use moe_lens::simhw::{SimConfig, SimMachine};
use moe_lens::transfer::LinkTiming;
use moe_lens::util::args::Args;
use moe_lens::workload::{ArrivalProcess, WorkloadGen};

fn usage() -> ! {
    eprintln!(
        "moe-lens — high-throughput MoE LLM serving under resource constraints

USAGE: moe-lens <COMMAND> [OPTIONS]

COMMANDS:
  serve      serve requests through the real PJRT engine
             --model tiny|small  --requests N  --prompt N  --gen N
             --kv-blocks N  --block-size N  --attn-threads N (0 = all cores)
             [--link-gbps F] [--trace-csv PATH]
             online mode (reports TTFT/TPOT/e2e p50+p99 and goodput):
             [--arrival poisson|burst|trace] [--arrival-rate F]
             [--burst-size N] [--arrival-trace PATH] [--arrival-seed N]
             [--slo-e2e SECS]
             scheduling policies (defaults reproduce FIFO/newest-first):
             [--admission fifo|slo] [--victim newest|weighted]
             (--admission slo drops requests past their deadline =
              arrival + --slo-e2e)
             execution pipeline (default on):
             [--pipeline N]   0 = legacy synchronous stepping; >=1 plans,
              packs, and embeds pass N+1 under pass N's layer loop and
              overlaps the LM head with next-pass weight prefetch
             [--service measured|instant]   measured (default) feeds an
              EWMA of observed pass times into SLO admission / weighted
              preemption; instant reproduces the pre-profiled behavior
             expert-granular residency (default off):
             [--pinned-experts N] [--zipf F] [--routing-seed N]   pin the
              N hottest experts per layer in HBM and stream only cold
              activated experts; routing follows a Zipf(F) trace
             multi-replica cluster simulation (virtual clock; any cluster
             flag switches serve onto the simulator, where --model takes
             simulator specs — default mixtral-8x7b):
             [--replicas N] [--router rr|jsq|p2c|deadline]
             [--fault-plan SPEC] [--kv-gb N] [--max-retries N]
             [--backoff-secs F]   SPEC = comma-separated crash@T:rI |
              drain@T:rI | slow@T+D*F:rI events, e.g.
              'crash@20:r1,slow@5+10*2:r0'; crashed replicas' queued and
              in-flight requests re-route to survivors with capped retry
  plan       print Stage-1/Stage-2 performance-model analysis
             --model <name> --gpu <name> --kv-gb N --p N --g N [--batch K]
             [--host-ms X]   also print the pass-pipeline view: decode
              iteration with X ms/pass of host plan/pack cost, pipelined
              (max(lanes, host)) vs synchronous (host + max(lanes))
             [--pinned N] [--zipf F] [--pass-tokens N]   expert-cache
              view: hit rate of the N hottest experts pinned per layer
              under Zipf(F) routing, the routed weight-sweep δ it buys,
              and the hit-rate-adjusted T_max / HRM decode iteration
  simulate   run the paper-scale hardware simulator
             --model <name> --workload mtbench|rag|aime --gen N --kv-gb N
             --policy moe-lens|moe-lightning|vllm  [--requests K]
  profile    run the pipeline profiler (Fig. 7) on paper constants
             --model <name> --gpu <name>
  models     list model/hardware/workload specs
"
    );
    std::process::exit(2);
}

fn main() {
    let args = Args::from_env();
    let r = match args.positional.first().map(|s| s.as_str()) {
        Some("models") => {
            cmd_models();
            Ok(())
        }
        Some("plan") => {
            cmd_plan(&args);
            Ok(())
        }
        Some("simulate") => {
            cmd_simulate(&args);
            Ok(())
        }
        Some("profile") => {
            cmd_profile(&args);
            Ok(())
        }
        Some("serve") => cmd_serve(&args),
        _ => usage(),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn model_arg(args: &Args) -> ModelSpec {
    let name = args.str_or("model", "mixtral-8x7b");
    ModelSpec::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown model '{name}' (try `moe-lens models`)");
        std::process::exit(2);
    })
}

fn machine_arg(args: &Args) -> MachineSpec {
    match args.get("gpu") {
        None => MachineSpec::paper_testbed(),
        Some(g) => {
            let gpu = GpuSpec::by_name(g).unwrap_or_else(|| {
                eprintln!("unknown GPU '{g}'");
                std::process::exit(2);
            });
            MachineSpec { gpu, ..MachineSpec::paper_testbed() }
        }
    }
}

fn cmd_models() {
    println!("models:");
    for m in ModelSpec::all() {
        println!(
            "  {:<14} params={:>6.1}B  size={:>6.1} GB  layers={:<3} experts={}x top-{}",
            m.name,
            m.param_count() as f64 / 1e9,
            m.model_bytes() as f64 / 1e9,
            m.n_layers,
            m.n_experts,
            m.top_k,
        );
    }
    println!("gpus:");
    for name in ["A40", "L40", "A100", "T4", "L4"] {
        let g = GpuSpec::by_name(name).unwrap();
        println!(
            "  {:<6} {:>5.0} TFLOPS bf16, {:>3} GB",
            g.name,
            g.bf16_flops / 1e12,
            g.mem_bytes >> 30
        );
    }
    println!("workloads:");
    for w in WorkloadSpec::all() {
        println!(
            "  {:<8} avg_p={:<5} max_p={:<5} gen={:?}  ({})",
            w.name, w.avg_prefill, w.max_prefill, w.gen_lengths, w.category
        );
    }
}

fn cmd_plan(args: &Args) {
    let model = model_arg(args);
    let machine = machine_arg(args);
    let kv_gb = args.u64_or("kv-gb", 100);
    let p = args.usize_or("p", 98);
    let g = args.usize_or("g", 32);
    let kv = kv_gb << 30;

    let s1 = Stage1Model::new(machine.clone(), model.clone());
    println!("== Stage 1 (theoretical upper bound) ==");
    println!(
        "  model {}  machine {} @ {:.1} GB/s PCIe",
        model.name,
        machine.gpu.name,
        machine.pcie_bw / 1e9
    );
    println!("  delta (weight sweep)      : {:.2} s", s1.delta());
    println!("  tokens to saturate GPU    : {:.0}", s1.tokens_to_saturate());
    println!("  PME(p={p}, g={g})           : {:.5}", s1.pme(p, g));
    println!("  T_max                     : {:.0} tok/s", s1.t_max(p, g, kv));
    println!(
        "  max GPU utilization       : {:.1} %",
        s1.max_gpu_utilization(p, g, kv) * 100.0
    );
    println!("  bound                     : {:?}", s1.bound(p, g, kv));
    println!(
        "  CPU mem bw required       : {:.1} GB/s",
        s1.cpu_mem_bw_required(kv) / 1e9
    );
    println!(
        "  CPU attn FLOPs required   : {:.0} GFLOP/s",
        s1.cpu_flops_required(kv) / 1e9
    );
    println!(
        "  Eq.7 overlap KV amplify   : {:.2}x",
        s1.effective_kv(p, g, kv) / kv as f64
    );

    let hrm = moe_lens::perfmodel::hrm::HrmModel::new(machine.clone(), model.clone());
    let s2 = Stage2Model::new(machine, model, 16);
    let k = args.f64_or("batch", s2.default_batch(p, g, kv));
    let pred = s2.predict(p, g, kv, k);
    println!("== Stage 2 (realistic, paged b=16, K={k:.0}) ==");
    println!("  q (prefills/iter)         : {:.2}", pred.q);
    println!("  T1 (memory-bound)         : {:.0} tok/s", pred.t1);
    println!("  T2 (GPU-bound)            : {:.0} tok/s", pred.t2);
    println!("  predicted throughput      : {:.0} gen tok/s", pred.throughput);
    println!("  predicted wall time       : {:.0} s", pred.wall_secs);
    println!(
        "  predicted GPU utilization : {:.1} %",
        pred.gpu_utilization * 100.0
    );
    println!("  regime                    : {:?}", pred.regime);

    // Expert-granular residency: what pinning the N hottest experts per
    // layer buys on the weight-sweep lane (--pinned N [--zipf F]).
    let pinned = args.usize_or("pinned", 0);
    if pinned > 0 {
        let zipf_s = args.f64_or("zipf", 1.0);
        let n_tokens = args.usize_or("pass-tokens", 4096);
        let s1m = &s2.stage1;
        let budget = moe_lens::transfer::ResidencyMap::budget_from_bytes(
            s1m.machine.gpu_mem_for_serving,
            s1m.model.expert_bytes(),
        );
        let need = s1m.model.n_layers * pinned;
        println!(
            "== Expert residency (pinned={pinned}/layer, zipf={zipf_s}, \
             {n_tokens} tok/pass) =="
        );
        println!(
            "  HBM expert budget         : {budget} experts ({} needed){}",
            need,
            if need > budget { "  ** EXCEEDS BUDGET **" } else { "" }
        );
        println!(
            "  expert cache hit rate     : {:.1} %",
            s1m.expert_hit_rate(zipf_s, pinned, n_tokens) * 100.0
        );
        println!(
            "  experts streamed / layer  : {:.2} of {}",
            s1m.experts_streamed(zipf_s, pinned, n_tokens),
            s1m.model.n_experts
        );
        println!(
            "  delta routed              : {:.2} s (dense {:.2} s)",
            s1m.delta_routed(zipf_s, pinned, n_tokens),
            s1m.delta()
        );
        println!(
            "  T_max routed              : {:.0} tok/s (dense {:.0})",
            s1m.t_max_routed(p, g, kv, zipf_s, pinned, n_tokens),
            s1m.t_max(p, g, kv)
        );
        let hplan = hrm.plan(p, g, kv);
        let (n, ctx) = (hplan.decode_seqs, p + g / 2);
        println!(
            "  HRM decode iter routed    : {:.4} s (dense {:.4} s, {n} seqs)",
            hrm.decode_iter_secs_routed(n, ctx, zipf_s, pinned),
            hrm.decode_iter_secs(n, ctx)
        );
    }

    // Host-side plan/pack cost composed into the decode iteration — the
    // cost-model view of the engine's double-buffered pass pipeline
    // (--host-ms, per-pass; calibrate from a trace's host_busy()).
    let host_secs = args.f64_or("host-ms", 0.0) / 1e3;
    if host_secs > 0.0 {
        let hplan = hrm.plan(p, g, kv);
        let (n, ctx) = (hplan.decode_seqs, p + g / 2);
        let sync = hrm.decode_iter_secs_with_host(n, ctx, host_secs, false);
        let pipe = hrm.decode_iter_secs_with_host(n, ctx, host_secs, true);
        println!("== Pass pipeline (host = {:.1} ms/pass) ==", host_secs * 1e3);
        println!("  decode batch (HRM plan)   : {n} seqs @ ctx {ctx}");
        println!("  sync iteration            : {:.4} s (host + max(lanes))", sync);
        println!("  pipelined iteration       : {:.4} s (max(lanes, host))", pipe);
        println!(
            "  host time hidden          : {:.1} %",
            100.0 * (sync - pipe) / host_secs
        );
    }
}

fn cmd_simulate(args: &Args) {
    let model = model_arg(args);
    let wl = WorkloadSpec::by_name(args.str_or("workload", "mtbench")).unwrap_or_else(|| {
        eprintln!("unknown workload");
        std::process::exit(2);
    });
    let g = args.usize_or("gen", wl.gen_lengths[0]);
    let max_gen = wl.gen_lengths.iter().copied().max().unwrap_or(0);
    if g == 0 || g > max_gen {
        eprintln!(
            "--gen {g} is outside workload '{}' published caps {:?} (max {max_gen})",
            wl.name, wl.gen_lengths
        );
        std::process::exit(2);
    }
    let kv_gb = args.u64_or("kv-gb", 70);
    let policy = args.str_or("policy", "moe-lens").to_string();
    let p = wl.avg_prefill;

    let (label, report): (String, RunReport) = match policy.as_str() {
        "moe-lens" => {
            let cfg = SimConfig::moe_lens(model.clone(), kv_gb);
            let s2 = Stage2Model::new(cfg.machine.clone(), model.clone(), cfg.block_size);
            let k = args.usize_or(
                "requests",
                (5.0 * g as f64 * s2.q(p, g, kv_gb << 30)) as usize,
            );
            let gen = WorkloadGen::new(wl, g, model.vocab.min(32_000));
            let reqs = gen.batch(k, 0, 42);
            let (_, report) = SimMachine::new(cfg).run(reqs);
            (
                format!("moe-lens {} {} g={g} kv={kv_gb}GB K={k}", model.name, wl.name),
                report,
            )
        }
        "moe-lightning" => {
            let sim = moe_lens::baselines::MoeLightningSim::new(model.clone(), kv_gb);
            let k = args.usize_or("requests", 5000);
            let (_, report) = sim.run_uniform(p, g, k);
            (
                format!("moe-lightning {} {} g={g} kv={kv_gb}GB K={k}", model.name, wl.name),
                report,
            )
        }
        "vllm" => {
            let sim = moe_lens::baselines::VllmSim::new(model.clone(), kv_gb);
            let k = args.usize_or("requests", 500);
            let (_, report) = sim.run_uniform(p, g, k);
            (
                format!("vllm {} {} g={g} kv={kv_gb}GB K={k}", model.name, wl.name),
                report,
            )
        }
        other => {
            eprintln!("unknown policy '{other}'");
            std::process::exit(2);
        }
    };
    report.print(&label);
}

fn cmd_profile(args: &Args) {
    let model = model_arg(args);
    let machine = machine_arg(args);
    let fit = PipelineProfiler::analytic(&machine, &model);
    println!("== Pipeline profile: {} on {} ==", model.name, machine.gpu.name);
    println!("  GPU time slope  : {:.3} us/token", fit.line.slope * 1e6);
    println!("  layer IO time   : {:.2} ms", fit.layer_io_secs * 1e3);
    println!("  n_real          : {} tokens", fit.n_real);
}

/// Multi-replica serving on the virtual clock: N simulated replicas
/// behind a router seam, with deterministic fault injection and re-route
/// recovery. The PJRT engine is a single machine, so the cluster runs on
/// the paper-scale simulator and `--model` takes simulator specs.
fn cmd_serve_cluster(args: &Args) -> anyhow::Result<()> {
    use moe_lens::cluster::{Cluster, ClusterConfig, FaultPlan, RouterPolicy};

    let mut sim = SimConfig::moe_lens(model_arg(args), args.u64_or("kv-gb", 70));
    let admission_name = args.str_or("admission", "fifo");
    sim.admission = AdmissionPolicy::parse(admission_name).unwrap_or_else(|| {
        eprintln!("unknown admission policy '{admission_name}' (fifo|slo)");
        std::process::exit(2);
    });
    let victim_name = args.str_or("victim", "newest");
    sim.victim = VictimPolicy::parse(victim_name).unwrap_or_else(|| {
        eprintln!("unknown victim policy '{victim_name}' (newest|weighted)");
        std::process::exit(2);
    });
    let replicas = args.usize_or("replicas", 2);
    if replicas == 0 {
        eprintln!("--replicas must be >= 1");
        std::process::exit(2);
    }
    let router_name = args.str_or("router", "rr");
    let router = RouterPolicy::parse(router_name).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let fault_spec = args.str_or("fault-plan", "none");
    let faults = FaultPlan::parse(fault_spec).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    let n = args.usize_or("requests", 64);
    let p = args.usize_or("prompt", 512);
    let g = args.usize_or("gen", 128);
    let rate = args.f64_or("arrival-rate", 4.0);
    let slo = args.f64_or("slo-e2e", f64::INFINITY);
    let mut arng = moe_lens::util::rng::Rng::new(args.u64_or("arrival-seed", 11));
    let times = ArrivalProcess::Poisson { rate }.times(n, &mut arng);
    let reqs = (0..n)
        .map(|i| moe_lens::model::Request::new(moe_lens::util::cast::usize_u64(i), vec![1; p], g));
    let arrivals =
        moe_lens::workload::with_deadlines(times.into_iter().zip(reqs).collect(), slo);

    let mut ccfg = ClusterConfig::new(sim, replicas).with_router(router).with_faults(faults);
    ccfg.max_retries = args.usize_or("max-retries", ccfg.max_retries);
    ccfg.backoff_secs = args.f64_or("backoff-secs", ccfg.backoff_secs);
    println!(
        "serving {n} online requests (poisson, {rate} req/s, p={p}, g={g}) \
         across {replicas} simulated replicas (router={router_name}, \
         fault-plan={fault_spec}, admission={admission_name}, \
         victim={victim_name})..."
    );
    let rep = Cluster::new(ccfg).run_online(arrivals, slo);
    for (i, (r, state)) in rep.reports.iter().zip(&rep.replica_states).enumerate() {
        r.print(&format!("replica {i} [{state:?}]"));
    }
    rep.stats.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    // Any cluster flag routes serve onto the multi-replica simulator —
    // the real engine below is inherently one machine.
    if args.has("replicas") || args.has("router") || args.has("fault-plan") {
        return cmd_serve_cluster(args);
    }
    let model = args.str_or("model", "small").to_string();
    let mut cfg = EngineConfig::for_model(&model);
    cfg.block_size = args.usize_or("block-size", cfg.block_size);
    cfg.kv_blocks = args.usize_or("kv-blocks", cfg.kv_blocks);
    cfg.attn_threads = args.usize_or("attn-threads", cfg.attn_threads);
    if let Some(gbps) = args.get("link-gbps") {
        cfg.timing = LinkTiming::Throttle(gbps.parse::<f64>().unwrap() * 1e9);
    }
    let admission_name = args.str_or("admission", "fifo");
    cfg.admission = AdmissionPolicy::parse(admission_name).unwrap_or_else(|| {
        eprintln!("unknown admission policy '{admission_name}' (fifo|slo)");
        std::process::exit(2);
    });
    let victim_name = args.str_or("victim", "newest");
    cfg.victim = VictimPolicy::parse(victim_name).unwrap_or_else(|| {
        eprintln!("unknown victim policy '{victim_name}' (newest|weighted)");
        std::process::exit(2);
    });
    cfg.pipeline_depth = args.usize_or("pipeline", cfg.pipeline_depth);
    let pipeline_depth = cfg.pipeline_depth;
    cfg.pinned_experts = args.usize_or("pinned-experts", 0);
    if let Some(z) = args.get("zipf") {
        let s = z.parse::<f64>().unwrap_or_else(|_| {
            eprintln!("bad --zipf '{z}' (expected a float)");
            std::process::exit(2);
        });
        cfg.routing = Some(moe_lens::workload::RoutingSpec::zipf(
            s,
            args.u64_or("routing-seed", 0),
        ));
    }
    cfg.measured_service = match args.str_or("service", "measured") {
        "measured" => true,
        "instant" => false,
        other => {
            eprintln!("unknown service model '{other}' (measured|instant)");
            std::process::exit(2);
        }
    };
    // SLO admission sheds against per-request deadlines, which the CLI
    // derives from --slo-e2e in online mode. Without them the flag would
    // silently behave exactly like FIFO — reject the combination instead.
    let slo_admission = matches!(cfg.admission, AdmissionPolicy::Slo { .. });
    let online = args.has("arrival") || args.has("arrival-rate");
    if slo_admission && (!online || !args.f64_or("slo-e2e", f64::INFINITY).is_finite()) {
        eprintln!(
            "--admission slo requires online mode with a finite --slo-e2e \
             (deadlines are set to arrival + --slo-e2e; without them nothing \
             can be shed and the policy degenerates to fifo)"
        );
        std::process::exit(2);
    }
    let mut engine = ServingEngine::load(cfg)?;

    let n = args.usize_or("requests", 16);
    let p = args.usize_or("prompt", engine.n_tok() / 4);
    let g = args.usize_or("gen", engine.n_tok() / 4);
    let vocab = engine.pjrt.config.vocab;
    let mut rng = moe_lens::util::rng::Rng::new(7);
    let reqs: Vec<moe_lens::model::Request> = (0..n)
        .map(|i| {
            let prompt: Vec<i32> = (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            moe_lens::model::Request::new(i as u64, prompt, g)
        })
        .collect();

    let trace = if args.has("arrival") || args.has("arrival-rate") {
        // --- Online mode: feed the scheduler from an arrival process and
        // report request-level latency (TTFT / TPOT / e2e / goodput).
        let mode = args.str_or("arrival", "poisson");
        let rate = args.f64_or("arrival-rate", 4.0);
        let mut arng = moe_lens::util::rng::Rng::new(args.u64_or("arrival-seed", 11));
        let times: Vec<f64> = match mode {
            "poisson" => ArrivalProcess::Poisson { rate }.times(n, &mut arng),
            "burst" => ArrivalProcess::Burst { rate, size: args.usize_or("burst-size", 4) }
                .times(n, &mut arng),
            "trace" => {
                let path = args.get("arrival-trace").unwrap_or_else(|| {
                    eprintln!("--arrival trace requires --arrival-trace PATH");
                    std::process::exit(2);
                });
                let text = std::fs::read_to_string(path)?;
                let times: Vec<f64> = text
                    .lines()
                    .map(str::trim)
                    .filter(|l| !l.is_empty() && !l.starts_with('#'))
                    .map(|l| {
                        // Reject non-finite values too: "nan"/"inf" parse
                        // as f64 but would poison the arrival sort.
                        match l.parse::<f64>() {
                            Ok(t) if t.is_finite() => t,
                            _ => {
                                eprintln!("bad arrival timestamp '{l}' in {path}");
                                std::process::exit(2);
                            }
                        }
                    })
                    .collect();
                // Run-relative seconds, same semantics as
                // `WorkloadGen::trace_arrivals` (non-finite values were
                // rejected above, so the helper's assert cannot fire).
                let mut times = moe_lens::workload::sort_and_rebase(times);
                times.truncate(n);
                times
            }
            other => {
                eprintln!("unknown arrival process '{other}' (poisson|burst|trace)");
                std::process::exit(2);
            }
        };
        let n_eff = times.len().min(reqs.len());
        let slo = args.f64_or("slo-e2e", f64::INFINITY);
        // Deadlines = arrival + SLO; the FIFO default ignores them, the
        // SLO admission policy sheds requests that cannot meet them.
        let arrivals: Vec<(f64, moe_lens::model::Request)> = moe_lens::workload::with_deadlines(
            times.into_iter().zip(reqs).take(n_eff).collect(),
            slo,
        );
        let process = if mode == "trace" {
            format!("trace {}", args.str_or("arrival-trace", "?"))
        } else {
            format!("{mode}, {rate} req/s")
        };
        println!(
            "serving {n_eff} online requests ({process}, p={p}, g={g}, \
             admission={admission_name}, victim={victim_name}, \
             pipeline={pipeline_depth}) on '{model}' via PJRT {}...",
            engine.pjrt.platform(),
        );
        let (trace, report, latency) = engine.run_online(arrivals, slo)?;
        report.print("real engine (online)");
        latency.print();
        trace
    } else {
        println!(
            "serving {n} requests (p={p}, g={g}, pipeline={pipeline_depth}) \
             on '{model}' via PJRT {}...",
            engine.pjrt.platform(),
        );
        let (trace, report) = engine.run(reqs)?;
        report.print("real engine");
        trace
    };
    let ps = engine.pipeline_stats();
    if ps.speculated > 0 {
        println!(
            "  pipeline: {} speculative plans, {} committed, {} replanned",
            ps.speculated, ps.committed, ps.replanned
        );
    }
    println!(
        "  link: {:.1} MB moved, achieved {:.2} GB/s (link clock)",
        engine.link().total_bytes() as f64 / 1e6,
        engine.link().achieved_bw() / 1e9
    );
    if let Some(path) = args.get("trace-csv") {
        std::fs::write(path, trace.to_csv())?;
        println!("  trace written to {path}");
    }
    Ok(())
}
