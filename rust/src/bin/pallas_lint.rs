//! `pallas-lint` — repo-native static analysis with a ratchet baseline.
//!
//! Scans `src/`, `benches/`, `tests/`, and `examples/` for violations of
//! the eleven repo-specific rules (see `moe_lens::analysis`) and compares
//! the per-file-per-rule counts against the committed
//! `lint-baseline.json`.
//!
//! Modes:
//! - `--check` (default): exit nonzero if any count increased over the
//!   baseline, or if the baseline is stale (counts above actual).
//! - `--deny-baseline` (with `--check`): additionally fail if the
//!   baseline carries *any* debt. The ratchet burned to zero in v2;
//!   this keeps it there — CI passes the flag so reintroducing debt via
//!   `--update-baseline` cannot land.
//! - `--list`: print every current violation (baselined or not).
//! - `--update-baseline`: rewrite the baseline from the actual counts,
//!   refusing to raise any entry.
//! - `--root <dir>`: crate root to scan (defaults to
//!   `$CARGO_MANIFEST_DIR`, which `cargo run` sets, then `.`). The root
//!   is canonicalized so baseline keys agree regardless of the invoking
//!   working directory.

use std::path::PathBuf;
use std::process::ExitCode;

use moe_lens::analysis::{self, Baseline, Regression, Violation};

enum Mode {
    Check,
    List,
    Update,
}

fn usage() {
    eprintln!(
        "usage: pallas-lint [--check | --list | --update-baseline] [--deny-baseline] [--root <dir>]\n\
         see the README's \"Static analysis & invariants\" section"
    );
}

fn main() -> ExitCode {
    let mut mode = Mode::Check;
    let mut deny_baseline = false;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => mode = Mode::Check,
            "--list" => mode = Mode::List,
            "--update-baseline" => mode = Mode::Update,
            "--deny-baseline" => deny_baseline = true,
            "--root" => match args.next() {
                Some(dir) => root = Some(PathBuf::from(dir)),
                None => {
                    eprintln!("pallas-lint: --root needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pallas-lint: unknown argument '{other}'");
                usage();
                return ExitCode::from(2);
            }
        }
    }
    let root = root
        .or_else(|| std::env::var_os("CARGO_MANIFEST_DIR").map(PathBuf::from))
        .unwrap_or_else(|| PathBuf::from("."));
    let root = match analysis::canonical_root(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: cannot canonicalize root {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    let violations = match analysis::scan_root(&root) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("pallas-lint: scan failed under {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let actual = analysis::counts(&violations);
    let baseline_path = root.join(analysis::BASELINE_FILE);

    match mode {
        Mode::List => {
            for v in &violations {
                println!("{}:{}: {} ({})", v.file, v.line, v.rule.name(), v.detail);
            }
            println!("{} violation(s) in {} file(s)", violations.len(), actual.len());
            ExitCode::SUCCESS
        }
        Mode::Update => {
            let old = if baseline_path.is_file() {
                match Baseline::load(&baseline_path) {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!("pallas-lint: {e}");
                        return ExitCode::from(2);
                    }
                }
            } else {
                Baseline::default()
            };
            match old.updated(&actual) {
                Ok(new) => {
                    if let Err(e) = std::fs::write(&baseline_path, new.to_pretty_json()) {
                        eprintln!("pallas-lint: cannot write {}: {e}", baseline_path.display());
                        return ExitCode::from(2);
                    }
                    println!(
                        "pallas-lint: baseline refreshed ({} violation(s) across {} file(s))",
                        new.total(),
                        new.files.len()
                    );
                    ExitCode::SUCCESS
                }
                Err(raised) => {
                    eprintln!("pallas-lint: refusing to raise baseline counts:");
                    print_deltas(&raised, &violations);
                    eprintln!("fix the new violations or suppress each site with");
                    eprintln!("`// pallas-lint: allow(<rule>)`, then rerun");
                    ExitCode::FAILURE
                }
            }
        }
        Mode::Check => {
            let base = match Baseline::load(&baseline_path) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("pallas-lint: {e}");
                    eprintln!("run `cargo run --bin pallas-lint -- --update-baseline` to create it");
                    return ExitCode::from(2);
                }
            };
            let report = base.check(&actual);
            if deny_baseline && !base.files.is_empty() {
                eprintln!(
                    "pallas-lint: --deny-baseline: the baseline carries {} violation(s) \
                     across {} file(s); the ratchet must stay at zero:",
                    base.total(),
                    base.files.len()
                );
                for (file, rules) in &base.files {
                    for (rule, n) in rules {
                        eprintln!("  {file} / {rule}: {n}");
                    }
                }
                return ExitCode::FAILURE;
            }
            if report.is_clean() {
                println!(
                    "pallas-lint: clean ({} baselined violation(s) across {} file(s))",
                    base.total(),
                    base.files.len()
                );
                return ExitCode::SUCCESS;
            }
            if !report.regressions.is_empty() {
                eprintln!("pallas-lint: new violations over the baseline:");
                print_deltas(&report.regressions, &violations);
            }
            if !report.stale.is_empty() {
                eprintln!("pallas-lint: stale baseline (counts above actual — debt paid down):");
                for r in &report.stale {
                    let (f, ru) = (&r.file, &r.rule);
                    eprintln!("  {f} / {ru}: baseline {}, actual {}", r.baseline, r.actual);
                }
                eprintln!("run `cargo run --bin pallas-lint -- --update-baseline` to refresh");
            }
            ExitCode::FAILURE
        }
    }
}

/// Print each raised (file, rule) pair with its current violation sites.
fn print_deltas(deltas: &[Regression], violations: &[Violation]) {
    for d in deltas {
        eprintln!("  {} / {}: baseline {}, actual {}", d.file, d.rule, d.baseline, d.actual);
        for v in violations {
            if v.file == d.file && v.rule.name() == d.rule {
                eprintln!("    {}:{}: {}", v.file, v.line, v.detail);
            }
        }
    }
}
