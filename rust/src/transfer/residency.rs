//! Expert-granular HBM residency: which experts stay resident on the GPU
//! across passes (pinned) and which stream from host memory per pass.
//!
//! The pinning policy is popularity-based: the `pinned_per_layer` hottest
//! experts of each layer (per the routing trace's rank order) are pinned.
//! The map is sized against a hard HBM expert budget derived from
//! `MachineSpec::gpu_mem_for_serving` — the always-on assert in
//! [`ResidencyMap::pin_hottest`] fires if a configuration would pin more
//! expert weights than the serving slice of HBM can hold.

use std::collections::BTreeSet;

use crate::workload::ExpertRouter;

/// Per-layer pinned-expert sets plus the budget they were checked against.
#[derive(Debug, Clone)]
pub struct ResidencyMap {
    pinned: Vec<BTreeSet<usize>>,
    pinned_per_layer: usize,
    budget_experts: usize,
}

impl ResidencyMap {
    /// How many whole experts fit in `hbm_bytes` of serving memory.
    pub fn budget_from_bytes(hbm_bytes: u64, expert_bytes: u64) -> usize {
        assert!(expert_bytes > 0, "expert_bytes must be positive");
        (hbm_bytes / expert_bytes) as usize
    }

    /// Pin the `pinned_per_layer` hottest experts of every layer.
    ///
    /// Always-on budget check: the residency map must never exceed the
    /// configured HBM expert budget.
    pub fn pin_hottest(
        router: &ExpertRouter,
        pinned_per_layer: usize,
        budget_experts: usize,
    ) -> ResidencyMap {
        assert!(
            pinned_per_layer <= router.n_experts(),
            "cannot pin {pinned_per_layer} of {} experts per layer",
            router.n_experts()
        );
        let total = router.n_layers() * pinned_per_layer;
        assert!(
            total <= budget_experts,
            "residency map exceeds HBM expert budget: {} layers x {pinned_per_layer} pinned \
             = {total} experts > budget of {budget_experts}",
            router.n_layers()
        );
        let pinned = (0..router.n_layers())
            .map(|layer| router.predicted(layer, pinned_per_layer))
            .collect();
        ResidencyMap { pinned, pinned_per_layer, budget_experts }
    }

    /// An empty (disabled) map: everything streams, legacy behavior.
    pub fn disabled(n_layers: usize) -> ResidencyMap {
        ResidencyMap {
            pinned: (0..n_layers).map(|_| BTreeSet::new()).collect(),
            pinned_per_layer: 0,
            budget_experts: 0,
        }
    }

    /// Expert-granular residency is active (`pinned_per_layer > 0`). When
    /// false, every code path must reduce exactly to dense layer
    /// streaming — the f64-identity guarantee.
    pub fn enabled(&self) -> bool {
        self.pinned_per_layer > 0
    }

    pub fn pinned_per_layer(&self) -> usize {
        self.pinned_per_layer
    }

    pub fn budget_experts(&self) -> usize {
        self.budget_experts
    }

    pub fn total_pinned(&self) -> usize {
        self.pinned.iter().map(|s| s.len()).sum()
    }

    pub fn is_resident(&self, layer: usize, expert: usize) -> bool {
        self.pinned[layer].contains(&expert)
    }

    pub fn pinned(&self, layer: usize) -> &BTreeSet<usize> {
        &self.pinned[layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::workload::RoutingSpec;

    fn router() -> ExpertRouter {
        ExpertRouter::new(&ModelSpec::mixtral_8x7b(), RoutingSpec::zipf(1.2, 17))
    }

    #[test]
    fn pins_the_hottest_experts() {
        let r = router();
        let map = ResidencyMap::pin_hottest(&r, 2, 64);
        assert!(map.enabled());
        assert_eq!(map.total_pinned(), 64);
        for layer in 0..r.n_layers() {
            let hot = &r.popularity(layer)[..2];
            for &e in hot {
                assert!(map.is_resident(layer, e));
            }
            assert_eq!(map.pinned(layer).len(), 2);
        }
    }

    #[test]
    fn disabled_map_pins_nothing() {
        let map = ResidencyMap::disabled(32);
        assert!(!map.enabled());
        assert_eq!(map.total_pinned(), 0);
        assert!(!map.is_resident(0, 0));
    }

    #[test]
    #[should_panic(expected = "exceeds HBM expert budget")]
    fn budget_overflow_panics() {
        // 32 layers x 2 pinned = 64 experts > budget of 45 (a 16 GB
        // serving slice at 352 MB per Mixtral-8x7B expert).
        let r = router();
        let _ = ResidencyMap::pin_hottest(&r, 2, 45);
    }

    #[test]
    fn budget_from_serving_bytes() {
        let e = ModelSpec::mixtral_8x7b().expert_bytes();
        assert_eq!(ResidencyMap::budget_from_bytes(16 << 30, e), 48);
        assert_eq!(ResidencyMap::budget_from_bytes(0, e), 0);
    }
}
