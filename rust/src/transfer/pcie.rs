//! The CPU→GPU link: a bandwidth-throttled, contention-aware byte mover.
//!
//! The paper's testbed measures 19.5 GB/s over PCIe 4.0 ×16 (§8.1); this
//! box has no GPU, so [`PcieLink`] gives every real byte copy a *timed*
//! cost on a configurable clock:
//!
//! * [`LinkTiming::Unthrottled`] — copy at memcpy speed (correctness runs).
//! * [`LinkTiming::Throttle`] — sleep so the copy matches a target
//!   bandwidth (scaled-down live timing experiments).
//! * [`LinkTiming::Virtual`]  — no sleeping; accumulate virtual seconds
//!   (the simulator's clock).
//!
//! Contention (§8.2's CPU-attention-vs-IO bandwidth competition) is
//! modeled by a slowdown factor the engine raises while CPU attention is
//! scanning the KV cache.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Clocking policy for the link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkTiming {
    Unthrottled,
    /// Target bandwidth in bytes/s; copies sleep to match.
    Throttle(f64),
    /// Bandwidth used only to account virtual time; no sleeping.
    Virtual(f64),
}

/// Bandwidth-throttled byte mover with transfer statistics.
pub struct PcieLink {
    timing: LinkTiming,
    /// Total bytes moved.
    bytes: AtomicU64,
    /// Accumulated transfer time in nanoseconds (virtual or slept).
    nanos: AtomicU64,
    /// Contention slowdown in percent (100 = none). §8.2 measures weight
    /// transfers stretching ~5s -> ~6s under heavy CPU attention (≈120).
    slowdown_pct: AtomicU64,
}

impl PcieLink {
    pub fn new(timing: LinkTiming) -> Self {
        PcieLink {
            timing,
            bytes: AtomicU64::new(0),
            nanos: AtomicU64::new(0),
            slowdown_pct: AtomicU64::new(100),
        }
    }

    pub fn timing(&self) -> LinkTiming {
        self.timing
    }

    /// Raise/lower the contention slowdown (engine hook; 1.0 = none).
    pub fn set_contention(&self, factor: f64) {
        assert!(factor >= 1.0);
        // Ordering: a standalone tuning knob — no other memory is
        // published with it, and a slightly stale factor only misprices
        // a transfer already in flight.
        self.slowdown_pct.store((factor * 100.0) as u64, Ordering::Relaxed);
    }

    pub fn contention(&self) -> f64 {
        // Ordering: see set_contention — stale reads are tolerable.
        self.slowdown_pct.load(Ordering::Relaxed) as f64 / 100.0
    }

    /// Time `nbytes` would take at the current settings.
    pub fn cost(&self, nbytes: u64) -> Duration {
        let bw = match self.timing {
            LinkTiming::Unthrottled => return Duration::ZERO,
            LinkTiming::Throttle(bw) | LinkTiming::Virtual(bw) => bw,
        };
        Duration::from_secs_f64(nbytes as f64 / bw * self.contention())
    }

    /// Move one packet: copy `src` into `dst` and charge its cost to the
    /// link clock (sleeping if throttled).
    pub fn transfer(&self, src: &[f32], dst: &mut [f32]) {
        assert_eq!(src.len(), dst.len());
        let nbytes = (src.len() * 4) as u64;
        let cost = self.cost(nbytes);
        dst.copy_from_slice(src);
        match self.timing {
            LinkTiming::Unthrottled => {}
            LinkTiming::Throttle(_) => std::thread::sleep(cost),
            LinkTiming::Virtual(_) => {}
        }
        // Ordering: independent monotonic telemetry counters; readers
        // only ever aggregate totals after the engine quiesces, so no
        // cross-counter consistency is needed.
        self.bytes.fetch_add(nbytes, Ordering::Relaxed);
        self.nanos.fetch_add(cost.as_nanos() as u64, Ordering::Relaxed); // Ordering: same counters
    }

    /// Charge a data-only transfer (no real copy — used for the small
    /// activation/KV sync transfers whose bytes live inside PJRT).
    pub fn charge(&self, nbytes: u64) -> Duration {
        let cost = self.cost(nbytes);
        if let LinkTiming::Throttle(_) = self.timing {
            std::thread::sleep(cost);
        }
        // Ordering: telemetry counters, as in `transfer`.
        self.bytes.fetch_add(nbytes, Ordering::Relaxed);
        self.nanos.fetch_add(cost.as_nanos() as u64, Ordering::Relaxed); // Ordering: same counters
        cost
    }

    pub fn total_bytes(&self) -> u64 {
        // Ordering: telemetry read after quiesce; see `transfer`.
        self.bytes.load(Ordering::Relaxed)
    }

    /// Total link-clock time spent transferring.
    pub fn total_time(&self) -> Duration {
        // Ordering: telemetry read after quiesce; see `transfer`.
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    /// Achieved bandwidth on the link clock (bytes/s).
    pub fn achieved_bw(&self) -> f64 {
        let t = self.total_time().as_secs_f64();
        if t > 0.0 {
            self.total_bytes() as f64 / t
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unthrottled_copies_and_counts() {
        let link = PcieLink::new(LinkTiming::Unthrottled);
        let src = vec![1.5f32; 1000];
        let mut dst = vec![0f32; 1000];
        link.transfer(&src, &mut dst);
        assert_eq!(dst, src);
        assert_eq!(link.total_bytes(), 4000);
        assert_eq!(link.total_time(), Duration::ZERO);
    }

    #[test]
    fn virtual_clock_accumulates_without_sleeping() {
        let link = PcieLink::new(LinkTiming::Virtual(1e9)); // 1 GB/s
        let src = vec![0f32; 250_000]; // 1 MB
        let mut dst = vec![0f32; 250_000];
        let t0 = std::time::Instant::now();
        for _ in 0..10 {
            link.transfer(&src, &mut dst);
        }
        assert!(t0.elapsed() < Duration::from_millis(500), "must not sleep");
        // 10 MB at 1 GB/s = 10 ms of virtual time
        let vt = link.total_time().as_secs_f64();
        assert!((vt - 0.01).abs() < 1e-6, "vt={vt}");
        assert!((link.achieved_bw() - 1e9).abs() < 1e3);
    }

    #[test]
    fn contention_stretches_transfers() {
        let link = PcieLink::new(LinkTiming::Virtual(19.5e9));
        let base = link.cost(94_000_000_000); // Mixtral-8x7B sweep ≈ 4.8s
        link.set_contention(1.25); // §8.2: ~5s -> ~6s
        let contended = link.cost(94_000_000_000);
        assert!((base.as_secs_f64() - 4.82).abs() < 0.05);
        assert!((contended.as_secs_f64() / base.as_secs_f64() - 1.25).abs() < 1e-6);
        link.set_contention(1.0);
        assert_eq!(link.cost(1000), base.mul_f64(1000.0 / 94e9));
    }

    #[test]
    fn throttle_actually_paces() {
        let link = PcieLink::new(LinkTiming::Throttle(100e6)); // 100 MB/s
        let src = vec![0f32; 250_000]; // 1 MB -> 10 ms
        let mut dst = vec![0f32; 250_000];
        let t0 = std::time::Instant::now();
        link.transfer(&src, &mut dst);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn charge_without_copy() {
        let link = PcieLink::new(LinkTiming::Virtual(1e9));
        let d = link.charge(2_000_000);
        assert!((d.as_secs_f64() - 0.002).abs() < 1e-9);
        assert_eq!(link.total_bytes(), 2_000_000);
    }
}
