//! The GPU-side weight buffer (§6.5): two layer-sized slots.
//!
//! "The size of the weight buffer is two times the model weight size
//! divided by the number of layers" — double buffering so layer `i+1`
//! streams in while layer `i` computes. Slots hand out interior
//! mutability through a mutex per slot (the data mover writes one slot
//! while the engine reads the other; the stage-boundary sync guarantees
//! they never alias a slot).

use std::sync::Mutex;

/// One staging slot: a layer-sized f32 buffer + which layer it holds.
struct Slot {
    data: Vec<f32>,
    /// Layer id resident in this slot, or `usize::MAX`.
    layer: usize,
}

/// Double-buffered weight staging area.
pub struct WeightBuffer {
    slots: [Mutex<Slot>; 2],
    layer_elems: usize,
}

impl WeightBuffer {
    /// `layer_elems`: f32 elements per layer (all layers equal-sized by
    /// construction of the export order).
    pub fn new(layer_elems: usize) -> Self {
        let mk = || Mutex::new(Slot { data: vec![0.0; layer_elems], layer: usize::MAX });
        WeightBuffer { slots: [mk(), mk()], layer_elems }
    }

    pub fn layer_elems(&self) -> usize {
        self.layer_elems
    }

    /// Total buffer footprint in bytes (the paper's "a few percent of the
    /// model": 2 × model/n_layers).
    pub fn footprint_bytes(&self) -> usize {
        2 * self.layer_elems * 4
    }

    /// Slot index layer `layer` stages through (even/odd alternation).
    pub fn slot_for(layer: usize) -> usize {
        layer % 2
    }

    /// Lock a slot, surfacing poisoning (a writer panicked mid-fill, so
    /// the staged weights cannot be trusted) with slot context.
    fn lock_slot(&self, idx: usize) -> std::sync::MutexGuard<'_, Slot> {
        match self.slots[idx].lock() {
            Ok(guard) => guard,
            Err(_) => panic!("weight buffer slot {idx} poisoned: a staging writer panicked"),
        }
    }

    /// Write `src` into the slot for `layer` via `write` (the data mover's
    /// packetized copy loop runs inside the closure).
    pub fn fill<F>(&self, layer: usize, mut write: F)
    where
        F: FnMut(&mut [f32]),
    {
        let mut slot = self.lock_slot(Self::slot_for(layer));
        slot.layer = usize::MAX; // invalid while partially written
        write(&mut slot.data);
        slot.layer = layer;
    }

    /// Read layer `layer`'s staged weights. Panics if the slot holds a
    /// different layer — a pipeline-ordering bug, not a runtime condition.
    pub fn read<R, F>(&self, layer: usize, read: F) -> R
    where
        F: FnOnce(&[f32]) -> R,
    {
        let slot = self.lock_slot(Self::slot_for(layer));
        assert_eq!(
            slot.layer, layer,
            "weight buffer slot {} holds layer {}, wanted {layer} (stage sync bug)",
            Self::slot_for(layer),
            slot.layer as i64,
        );
        read(&slot.data)
    }

    /// Which layer a slot currently holds (telemetry).
    pub fn resident(&self, slot: usize) -> Option<usize> {
        let l = self.lock_slot(slot).layer;
        (l != usize::MAX).then_some(l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_then_read_roundtrip() {
        let buf = WeightBuffer::new(8);
        buf.fill(0, |dst| dst.copy_from_slice(&[1.0; 8]));
        buf.fill(1, |dst| dst.copy_from_slice(&[2.0; 8]));
        buf.read(0, |d| assert!(d.iter().all(|&x| x == 1.0)));
        buf.read(1, |d| assert!(d.iter().all(|&x| x == 2.0)));
        assert_eq!(buf.resident(0), Some(0));
        assert_eq!(buf.resident(1), Some(1));
    }

    #[test]
    fn slots_alternate_by_layer_parity() {
        let buf = WeightBuffer::new(4);
        buf.fill(2, |d| d.fill(2.0));
        assert_eq!(buf.resident(0), Some(2));
        buf.fill(5, |d| d.fill(5.0));
        assert_eq!(buf.resident(1), Some(5));
        // layer 4 overwrites slot 0 (evicting layer 2)
        buf.fill(4, |d| d.fill(4.0));
        buf.read(4, |d| assert!(d.iter().all(|&x| x == 4.0)));
    }

    #[test]
    #[should_panic(expected = "stage sync bug")]
    fn reading_wrong_layer_panics() {
        let buf = WeightBuffer::new(4);
        buf.fill(0, |d| d.fill(1.0));
        buf.read(2, |_| ());
    }

    #[test]
    fn footprint_is_two_layers() {
        let buf = WeightBuffer::new(100);
        assert_eq!(buf.footprint_bytes(), 2 * 100 * 4);
        // Paper claim ("only a few percent of the original model size"):
        // Mixtral-8x7B layer ≈ 2.9 GB -> 2 layers ≈ 6% of 94 GB.
        let spec = crate::config::ModelSpec::mixtral_8x7b();
        let frac = 2.0 * spec.layer_bytes() as f64 / spec.model_bytes() as f64;
        assert!(frac < 0.08, "frac={frac}");
    }
}
