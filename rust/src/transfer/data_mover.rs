//! The Contiguous Data Mover (§6.5): a dedicated transfer thread.
//!
//! The execution pipeline pushes weight-transfer requests at *layer*
//! granularity; the mover internally packetizes them (default 100 MB —
//! the paper's empirical sweet spot) and issues one packet at a time, so
//! latency-sensitive compute transfers are never stuck behind a
//! multi-gigabyte weight enqueue (no head-of-line blocking).
//!
//! Synchronization with the pipeline happens only at stage boundaries:
//! [`DataMover::wait_layer`] blocks the engine until a layer is staged,
//! and [`DataMover::done_with`] returns the layer's slot to the mover.
//! The mover never overwrites a slot whose layer has not been consumed
//! (double-buffer back-pressure), so it can run arbitrarily far ahead of
//! the compute threads without clobbering live weights.
//!
//! Requests are *stage* indices on a single monotone stream: stage `s`
//! sources layer `s % n_layers` of the weight file. The synchronous
//! engine uses one pass per stream ([`DataMover::reset`] between passes,
//! stages ≡ layers); the pipelined engine never resets and lets stage
//! ids run across pass boundaries, so the §6.4 `+2` prefetch issued at a
//! pass's last layers streams the *next pass's* layer 0/1 while the LM
//! head computes — the head↔prefetch overlap of the double-buffered pass
//! pipeline.
//!
//! # Expert-granular mode
//!
//! With [`ExpertMode`], the unit of link accounting drops from layer to
//! expert. The engine posts each stage's exact activated-expert set via
//! [`DataMover::post_routing`] *before* enqueuing that stage's request;
//! stages requested ahead of their pass's planning (the cross-pass `+2`
//! prefetch) have no posted set, so the mover streams the
//! popularity-predicted top-N experts instead — §6.4's blind next-layer
//! prefetch becomes popularity-predicted. Either way, pinned experts
//! ([`ResidencyMap`]) never move. At the stage boundary,
//! [`DataMover::wait_layer_routed`] compares the set actually streamed
//! against the experts the pass really activated and *tops up* the
//! shortfall — mispredicted experts are charged to the link while the
//! stage blocks, i.e. as exposed IO.
//!
//! Modeling note: the compiled kernels read full dense `w1/w3/w2`
//! tensors (routing happens inside the kernel), so the staged slot is
//! always filled completely and token numerics are bit-identical in
//! every mode. Residency changes only *link accounting*: streamed
//! regions (dense tensors + cold activated experts) go through charged
//! link transactions; pinned and non-activated bytes are plain memcpys
//! standing in for "already HBM-resident / never fetched".

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use std::thread::JoinHandle;

use super::buffer::WeightBuffer;
use super::pcie::PcieLink;
use super::residency::ResidencyMap;
use super::weights::{LayerRegions, WeightFile};
use crate::workload::ExpertRouter;

/// A layer-granularity transfer request.
#[derive(Debug, Clone, Copy)]
pub struct TransferRequest {
    pub layer: usize,
}

/// Expert-granular streaming configuration: the routing oracle, the
/// pinned-set residency map, and how many experts to predict for stages
/// whose routing is not yet known.
#[derive(Clone)]
pub struct ExpertMode {
    pub router: Arc<ExpertRouter>,
    pub residency: Arc<ResidencyMap>,
    /// Top-N popularity prediction used for stages streamed before their
    /// pass's routing is posted (the cross-pass `+2` prefetch).
    pub predict_n: usize,
}

struct State {
    /// Layers fully staged and not yet evicted.
    ready: BTreeSet<usize>,
    /// Highest layer index consumed (+1), i.e. layers `< consumed` may be
    /// evicted. Monotone.
    consumed: usize,
    shutdown: bool,
    /// Exact activated-expert sets posted per stage (expert mode). Posted
    /// strictly before the stage's request is enqueued, so the worker's
    /// view is deterministic.
    routes: BTreeMap<usize, BTreeSet<usize>>,
    /// Experts actually streamed per staged stage (expert mode) — the set
    /// `wait_layer_routed` tops up against.
    streamed: BTreeMap<usize, BTreeSet<usize>>,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
}

impl Shared {
    /// Lock, recovering the guard from a poisoned mutex (a panicking
    /// engine thread must not wedge the mover's shutdown path).
    fn lock(&self) -> MutexGuard<'_, State> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn wait<'a>(&self, guard: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
        match self.cv.wait(guard) {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }
}

/// The mover thread + its request queue.
pub struct DataMover {
    tx: Option<Sender<TransferRequest>>,
    worker: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    link: Arc<PcieLink>,
    packet_elems: usize,
    /// Bytes of one expert's slices (expert mode only; 0 otherwise).
    expert_bytes: u64,
    mode: Option<ExpertMode>,
}

impl DataMover {
    /// Default packet size: 100 MB (§6.5).
    pub const DEFAULT_PACKET_BYTES: usize = 100 << 20;

    /// Spawn the mover over a weight file, staging buffer, and link. All
    /// three are shared with the engine via `Arc`. Streams whole layers
    /// (the legacy dense path).
    pub fn spawn(
        weights: Arc<WeightFile>,
        buffer: Arc<WeightBuffer>,
        link: Arc<PcieLink>,
        packet_bytes: usize,
    ) -> Self {
        Self::spawn_inner(weights, buffer, link, packet_bytes, None)
    }

    /// Spawn in expert-granular mode: pinned experts never stream, cold
    /// experts stream per activated (or predicted) set.
    pub fn spawn_expert(
        weights: Arc<WeightFile>,
        buffer: Arc<WeightBuffer>,
        link: Arc<PcieLink>,
        packet_bytes: usize,
        mode: ExpertMode,
    ) -> Self {
        Self::spawn_inner(weights, buffer, link, packet_bytes, Some(mode))
    }

    fn spawn_inner(
        weights: Arc<WeightFile>,
        buffer: Arc<WeightBuffer>,
        link: Arc<PcieLink>,
        packet_bytes: usize,
        mode: Option<ExpertMode>,
    ) -> Self {
        assert!(packet_bytes >= 4);
        let packet_elems = packet_bytes / 4;
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                ready: BTreeSet::new(),
                consumed: 0,
                shutdown: false,
                routes: BTreeMap::new(),
                streamed: BTreeMap::new(),
            }),
            cv: Condvar::new(),
        });
        // Per-layer dense/expert region tables (expert mode only).
        let regions: Option<Vec<LayerRegions>> = mode.as_ref().map(|m| {
            let n = m.router.n_experts();
            (0..weights.n_layers()).map(|l| weights.layer_regions(l, n)).collect()
        });
        let expert_bytes = regions
            .as_ref()
            .and_then(|r| r.first())
            .map(|r| r.expert_elems() as u64 * 4)
            .unwrap_or(0);
        let (tx, rx) = channel::<TransferRequest>();
        let worker = {
            let shared = Arc::clone(&shared);
            let link = Arc::clone(&link);
            let mode = mode.clone();
            // The mover owns this thread end-to-end: `shutdown()` closes
            // the channel and joins the handle stored in `self.worker`,
            // so lifetime/panic propagation is as disciplined as the
            // blessed seams without routing weights through ThreadPool.
            // pallas-lint: allow(thread-spawn-policy)
            std::thread::spawn(move || {
                let n_layers = weights.n_layers().max(1);
                while let Ok(req) = rx.recv() {
                    // Back-pressure: only two slots exist; filling stage S
                    // overwrites S-2's slot, so wait until S-2 is consumed.
                    let route: Option<BTreeSet<usize>>;
                    {
                        let mut st = shared.lock();
                        while !st.shutdown && req.layer >= 2 && st.consumed + 2 <= req.layer {
                            st = shared.wait(st);
                        }
                        if st.shutdown {
                            return;
                        }
                        if req.layer >= 2 {
                            st.ready.remove(&(req.layer - 2));
                        }
                        route = st.routes.get(&req.layer).cloned();
                    }
                    // Stage -> source layer: wraps so stage ids may run
                    // across pass boundaries (pipelined engine).
                    let layer = req.layer % n_layers;
                    let src = weights.layer_data(layer);
                    let mut streamed_set: Option<BTreeSet<usize>> = None;
                    match (&mode, &regions) {
                        (Some(m), Some(regs)) if m.residency.enabled() => {
                            // Expert-granular staging. Posted exact set, or
                            // the popularity-predicted top-N when the stage
                            // runs ahead of its pass's planning.
                            let target = route
                                .unwrap_or_else(|| m.router.predicted(layer, m.predict_n));
                            let streamed: BTreeSet<usize> = target
                                .iter()
                                .copied()
                                .filter(|&e| !m.residency.is_resident(layer, e))
                                .collect();
                            let reg = &regs[layer];
                            buffer.fill(req.layer, |dst| {
                                // Uncharged memcpys: pinned experts
                                // (HBM-resident) and cold experts nobody
                                // activated (never fetched) — staged only
                                // because the kernels read dense tensors.
                                for (e, ranges) in reg.expert.iter().enumerate() {
                                    if streamed.contains(&e) {
                                        continue;
                                    }
                                    for &(off, len) in ranges {
                                        dst[off..off + len]
                                            .copy_from_slice(&src[off..off + len]);
                                    }
                                }
                                // Charged, packetized link transactions:
                                // dense tensors + streamed experts.
                                let mut charged: Vec<(usize, usize)> = reg.dense.clone();
                                for &e in &streamed {
                                    charged.extend_from_slice(&reg.expert[e]);
                                }
                                for (off, len) in charged {
                                    let mut o = off;
                                    while o < off + len {
                                        let end = (o + packet_elems).min(off + len);
                                        link.transfer(&src[o..end], &mut dst[o..end]);
                                        o = end;
                                    }
                                }
                            });
                            streamed_set = Some(streamed);
                        }
                        _ => {
                            // Legacy dense path: the whole layer is one
                            // charged, packetized run.
                            buffer.fill(req.layer, |dst| {
                                let mut off = 0;
                                while off < src.len() {
                                    let end = (off + packet_elems).min(src.len());
                                    link.transfer(&src[off..end], &mut dst[off..end]);
                                    off = end;
                                }
                            });
                        }
                    }
                    let mut st = shared.lock();
                    if let Some(s) = streamed_set {
                        st.streamed.insert(req.layer, s);
                    }
                    st.ready.insert(req.layer);
                    shared.cv.notify_all();
                }
            })
        };
        DataMover {
            tx: Some(tx),
            worker: Some(worker),
            shared,
            link,
            packet_elems,
            expert_bytes,
            mode,
        }
    }

    pub fn packet_bytes(&self) -> usize {
        self.packet_elems * 4
    }

    /// Expert-granular streaming is active.
    pub fn expert_mode(&self) -> bool {
        self.mode.as_ref().map(|m| m.residency.enabled()).unwrap_or(false)
    }

    /// Enqueue a layer transfer (returns immediately — the §6.4 prefetch
    /// at the start of each stage).
    pub fn request(&self, layer: usize) {
        let Some(tx) = self.tx.as_ref() else {
            panic!("mover not running");
        };
        if tx.send(TransferRequest { layer }).is_err() {
            panic!("mover thread exited");
        }
    }

    /// Post a stage's exact activated-expert set. Must happen *before*
    /// [`DataMover::request`] for that stage — the channel send then
    /// orders the map write ahead of the worker's read, so accounting is
    /// deterministic. Stages requested ahead of planning are deliberately
    /// never posted (they stream the popularity prediction).
    pub fn post_routing(&self, stage: usize, activated: &BTreeSet<usize>) {
        let mut st = self.shared.lock();
        st.routes.insert(stage, activated.clone());
    }

    /// Stage-boundary sync: block until `layer` is fully staged.
    pub fn wait_layer(&self, layer: usize) {
        let mut st = self.shared.lock();
        while !st.ready.contains(&layer) {
            st = self.shared.wait(st);
        }
    }

    /// Expert-mode stage boundary: block until staged, then charge the
    /// link for any activated cold expert the stream missed (misprediction
    /// top-up — the bytes were staged with the slot, so this is
    /// accounting-only). Returns the top-up cost, incurred while the
    /// stage blocks: exposed IO.
    pub fn wait_layer_routed(&self, stage: usize, activated: &BTreeSet<usize>) -> Duration {
        if !self.expert_mode() {
            self.wait_layer(stage);
            return Duration::ZERO;
        }
        let missing: Vec<usize> = {
            let mut st = self.shared.lock();
            while !st.ready.contains(&stage) {
                st = self.shared.wait(st);
            }
            let Some(mode) = self.mode.as_ref() else {
                panic!("expert_mode() implies mode");
            };
            let layer = stage % mode.router.n_layers().max(1);
            let streamed = st.streamed.entry(stage).or_default();
            let missing: Vec<usize> = activated
                .iter()
                .copied()
                .filter(|&e| !mode.residency.is_resident(layer, e) && !streamed.contains(&e))
                .collect();
            streamed.extend(missing.iter().copied());
            missing
        };
        if missing.is_empty() {
            Duration::ZERO
        } else {
            self.link.charge(missing.len() as u64 * self.expert_bytes)
        }
    }

    /// Experts streamed for a staged stage (telemetry / tests).
    pub fn streamed_for(&self, stage: usize) -> Option<BTreeSet<usize>> {
        self.shared.lock().streamed.get(&stage).cloned()
    }

    /// Mark `layer` consumed: its slot may be reused for `layer + 2`.
    pub fn done_with(&self, layer: usize) {
        let mut st = self.shared.lock();
        st.consumed = st.consumed.max(layer + 1);
        // Routing/streaming records for consumed stages are dead.
        st.routes = st.routes.split_off(&(layer + 1));
        st.streamed = st.streamed.split_off(&(layer + 1));
        self.shared.cv.notify_all();
    }

    /// Non-blocking readiness check (telemetry / tests).
    pub fn is_ready(&self, layer: usize) -> bool {
        self.shared.lock().ready.contains(&layer)
    }

    /// Start a new pass: layer indices restart at 0, so the consumption
    /// cursor and readiness set reset. Callers must have consumed every
    /// outstanding request (the engine's per-pass epilogue guarantees it).
    pub fn reset(&self) {
        let mut st = self.shared.lock();
        st.ready.clear();
        st.consumed = 0;
        st.routes.clear();
        st.streamed.clear();
        self.shared.cv.notify_all();
    }
}

impl Drop for DataMover {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.cv.notify_all();
        }
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::transfer::pcie::LinkTiming;
    use crate::transfer::weights::{LayerView, TensorView};
    use crate::workload::RoutingSpec;

    fn toy_setup(n_layers: usize, layer_elems: usize) -> (Arc<WeightFile>, Arc<WeightBuffer>) {
        let mut data = Vec::new();
        let mut tensors = Vec::new();
        let mut layers = Vec::new();
        for li in 0..n_layers {
            let start = data.len();
            data.extend((0..layer_elems).map(|i| (li * 1000 + i) as f32));
            let t = TensorView {
                name: format!("layers.{li}.w"),
                shape: vec![layer_elems],
                offset: start,
                len: layer_elems,
            };
            layers.push(LayerView {
                layer: li,
                tensors: vec![t.clone()],
                start,
                end: start + layer_elems,
            });
            tensors.push(t);
        }
        (
            Arc::new(WeightFile::from_parts(data, tensors, layers)),
            Arc::new(WeightBuffer::new(layer_elems)),
        )
    }

    #[test]
    fn streams_layers_through_double_buffer() {
        let (wf, buf) = toy_setup(6, 64);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover =
            DataMover::spawn(Arc::clone(&wf), Arc::clone(&buf), Arc::clone(&link), 64);
        // Enqueue everything up front: back-pressure must keep the mover
        // from clobbering un-consumed layers.
        for layer in 0..6 {
            mover.request(layer);
        }
        for layer in 0..6 {
            mover.wait_layer(layer);
            buf.read(layer, |d| {
                assert_eq!(d[0], (layer * 1000) as f32);
                assert_eq!(d[63], (layer * 1000 + 63) as f32);
            });
            mover.done_with(layer);
        }
        // 6 layers x 64 f32
        assert_eq!(link.total_bytes(), 6 * 64 * 4);
    }

    #[test]
    fn packetization_counts_whole_layer() {
        let (wf, buf) = toy_setup(1, 100);
        let link = Arc::new(PcieLink::new(LinkTiming::Virtual(1e9)));
        // 16-byte packets: 100 f32 = 400 B -> 25 packets, still 400 B total
        let mover = DataMover::spawn(wf, Arc::clone(&buf), Arc::clone(&link), 16);
        mover.request(0);
        mover.wait_layer(0);
        assert_eq!(link.total_bytes(), 400);
        buf.read(0, |d| assert_eq!(d.len(), 100));
    }

    #[test]
    fn prefetch_overlaps_with_reader() {
        // VSLPipe's actual protocol: prefetch layer L+1 at the start of
        // stage L, consume at stage boundaries.
        let (wf, buf) = toy_setup(8, 1024);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(wf, Arc::clone(&buf), link, 256);
        mover.request(0);
        mover.request(1);
        for layer in 0..8 {
            mover.wait_layer(layer);
            if layer + 2 < 8 {
                mover.request(layer + 2);
            }
            buf.read(layer, |d| assert_eq!(d[0], (layer * 1000) as f32));
            mover.done_with(layer);
        }
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (wf, buf) = toy_setup(3, 16);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(wf, Arc::clone(&buf), link, 64);
        mover.request(0);
        mover.request(1);
        mover.request(2); // would overwrite layer 0's slot
        mover.wait_layer(1);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(mover.is_ready(0), "layer 0 must not be evicted before done_with");
        assert!(!mover.is_ready(2), "layer 2 must wait for layer 0's slot");
        buf.read(0, |d| assert_eq!(d[0], 0.0));
        mover.done_with(0);
        mover.wait_layer(2);
        assert!(!mover.is_ready(0), "staging layer 2 evicts layer 0");
        buf.read(2, |d| assert_eq!(d[0], 2000.0));
    }

    #[test]
    fn stage_stream_crosses_pass_boundaries() {
        // The pipelined engine's protocol: stage ids keep counting across
        // passes (stage s sources layer s % n_layers), with no reset. The
        // +2 prefetch at a pass's tail therefore stages the *next pass's*
        // first layers while the head would run.
        let n_layers = 3;
        let (wf, buf) = toy_setup(n_layers, 32);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(Arc::clone(&wf), Arc::clone(&buf), Arc::clone(&link), 128);
        mover.request(0);
        mover.request(1);
        let passes = 3;
        for stage in 0..passes * n_layers {
            mover.wait_layer(stage);
            buf.read(stage, |d| {
                assert_eq!(d[0], ((stage % n_layers) * 1000) as f32, "stage {stage}");
            });
            mover.done_with(stage);
            mover.request(stage + 2); // unconditional: runs into the next pass
        }
        // After the last consumed stage, the two prefetched stages for the
        // never-run next pass stream without blocking the mover.
        mover.wait_layer(passes * n_layers);
        mover.wait_layer(passes * n_layers + 1);
        assert_eq!(
            link.total_bytes() as usize,
            (passes * n_layers + 2) * 32 * 4,
            "every stage moved exactly once"
        );
    }

    #[test]
    fn drop_while_blocked_does_not_hang() {
        let (wf, buf) = toy_setup(4, 16);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(wf, buf, link, 64);
        for l in 0..4 {
            mover.request(l);
        }
        mover.wait_layer(1);
        drop(mover); // worker is blocked on back-pressure; Drop must join
    }

    // ---- expert-granular mode ----

    /// 2 layers of `tiny`-shaped expert tensors: per layer, a dense ln
    /// (8 elems) + w1/w3/w2 with 4 experts x 4 elems each.
    fn expert_setup() -> (Arc<WeightFile>, Arc<WeightBuffer>, ExpertMode) {
        let n_layers = 2;
        let n_experts = 4;
        let mut data = Vec::new();
        let mut tensors = Vec::new();
        let mut layers = Vec::new();
        for li in 0..n_layers {
            let start = data.len();
            let mut off = start;
            let mut push = |name: &str, len: usize, data: &mut Vec<f32>, off: &mut usize| {
                data.extend((0..len).map(|i| (li * 1000 + *off - start + i) as f32));
                let t = TensorView {
                    name: format!("layers.{li}.{name}"),
                    shape: vec![len],
                    offset: *off,
                    len,
                };
                *off += len;
                t
            };
            let lt = vec![
                push("ln1", 8, &mut data, &mut off),
                push("w1", 16, &mut data, &mut off),
                push("w3", 16, &mut data, &mut off),
                push("w2", 16, &mut data, &mut off),
            ];
            layers.push(LayerView { layer: li, tensors: lt.clone(), start, end: off });
            tensors.extend(lt);
        }
        let layer_elems = 8 + 48;
        let wf = Arc::new(WeightFile::from_parts(data, tensors, layers));
        let buf = Arc::new(WeightBuffer::new(layer_elems));
        // Router over a matching toy spec: 2 layers, 4 experts, top-1.
        let mut spec = ModelSpec::tiny();
        spec.n_layers = n_layers;
        spec.n_experts = n_experts;
        spec.top_k = 1;
        let router = Arc::new(ExpertRouter::new(&spec, RoutingSpec::zipf(1.2, 3)));
        let residency = Arc::new(ResidencyMap::pin_hottest(&router, 1, 8));
        (wf, buf, ExpertMode { router, residency, predict_n: 2 })
    }

    #[test]
    fn expert_mode_charges_only_streamed_regions() {
        let (wf, buf, mode) = expert_setup();
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let pinned0 = mode.residency.pinned(0).clone();
        let mover = DataMover::spawn_expert(
            Arc::clone(&wf),
            Arc::clone(&buf),
            Arc::clone(&link),
            4 * 64,
            mode,
        );
        // Post an exact set: two experts, one of them pinned.
        let mut activated = BTreeSet::new();
        let pinned_e = *pinned0.iter().next().expect("one pinned expert");
        activated.insert(pinned_e);
        activated.insert((pinned_e + 1) % 4);
        mover.post_routing(0, &activated);
        mover.request(0);
        let topup = mover.wait_layer_routed(0, &activated);
        assert_eq!(topup, Duration::ZERO, "posted set needs no top-up");
        // Charged: dense (8 elems) + 1 cold expert (12 elems) = 80 B.
        assert_eq!(link.total_bytes(), (8 + 12) * 4);
        assert_eq!(mover.streamed_for(0), Some([(pinned_e + 1) % 4].into()));
        // The slot is still staged completely — kernels read dense tensors.
        buf.read(0, |d| {
            assert_eq!(d.len(), 56);
            for (i, &x) in d.iter().enumerate() {
                assert_eq!(x, i as f32, "slot byte {i} must be staged");
            }
        });
        mover.done_with(0);
    }

    #[test]
    fn unposted_stage_streams_prediction_and_tops_up() {
        let (wf, buf, mode) = expert_setup();
        let link = Arc::new(PcieLink::new(LinkTiming::Virtual(1e9)));
        let router = Arc::clone(&mode.router);
        let residency = Arc::clone(&mode.residency);
        let mover =
            DataMover::spawn_expert(wf, Arc::clone(&buf), Arc::clone(&link), 4 * 64, mode);
        // No post_routing: the mover streams predicted(0, 2) minus pinned.
        mover.request(0);
        mover.wait_layer(0);
        let predicted = router.predicted(0, 2);
        let expect: BTreeSet<usize> = predicted
            .iter()
            .copied()
            .filter(|&e| !residency.is_resident(0, e))
            .collect();
        assert_eq!(mover.streamed_for(0), Some(expect.clone()));
        let before = link.total_bytes();
        assert_eq!(before, (8 + 12 * expect.len()) as u64 * 4);
        // Activate an expert outside prediction ∪ pinned: top-up charged.
        let cold = (0..4)
            .find(|e| !predicted.contains(e) && !residency.is_resident(0, *e))
            .expect("a mispredicted expert exists");
        let activated: BTreeSet<usize> = [cold].into();
        let topup = mover.wait_layer_routed(0, &activated);
        assert!(topup > Duration::ZERO);
        assert_eq!(link.total_bytes() - before, 12 * 4);
        // Top-up is idempotent: the set now includes the cold expert.
        assert_eq!(mover.wait_layer_routed(0, &activated), Duration::ZERO);
        mover.done_with(0);
    }

    #[test]
    fn disabled_residency_is_the_legacy_path() {
        let (wf, buf, mut mode) = expert_setup();
        mode.residency = Arc::new(ResidencyMap::disabled(2));
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn_expert(wf, buf, Arc::clone(&link), 4 * 64, mode);
        assert!(!mover.expert_mode());
        mover.request(0);
        mover.wait_layer_routed(0, &BTreeSet::new());
        // Whole layer charged, exactly like DataMover::spawn.
        assert_eq!(link.total_bytes(), 56 * 4);
        mover.done_with(0);
    }
}
