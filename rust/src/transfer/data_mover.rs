//! The Contiguous Data Mover (§6.5): a dedicated transfer thread.
//!
//! The execution pipeline pushes weight-transfer requests at *layer*
//! granularity; the mover internally packetizes them (default 100 MB —
//! the paper's empirical sweet spot) and issues one packet at a time, so
//! latency-sensitive compute transfers are never stuck behind a
//! multi-gigabyte weight enqueue (no head-of-line blocking).
//!
//! Synchronization with the pipeline happens only at stage boundaries:
//! [`DataMover::wait_layer`] blocks the engine until a layer is staged,
//! and [`DataMover::done_with`] returns the layer's slot to the mover.
//! The mover never overwrites a slot whose layer has not been consumed
//! (double-buffer back-pressure), so it can run arbitrarily far ahead of
//! the compute threads without clobbering live weights.
//!
//! Requests are *stage* indices on a single monotone stream: stage `s`
//! sources layer `s % n_layers` of the weight file. The synchronous
//! engine uses one pass per stream ([`DataMover::reset`] between passes,
//! stages ≡ layers); the pipelined engine never resets and lets stage
//! ids run across pass boundaries, so the §6.4 `+2` prefetch issued at a
//! pass's last layers streams the *next pass's* layer 0/1 while the LM
//! head computes — the head↔prefetch overlap of the double-buffered pass
//! pipeline.

use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::buffer::WeightBuffer;
use super::pcie::PcieLink;
use super::weights::WeightFile;

/// A layer-granularity transfer request.
#[derive(Debug, Clone, Copy)]
pub struct TransferRequest {
    pub layer: usize,
}

struct State {
    /// Layers fully staged and not yet evicted.
    ready: BTreeSet<usize>,
    /// Highest layer index consumed (+1), i.e. layers `< consumed` may be
    /// evicted. Monotone.
    consumed: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
}

/// The mover thread + its request queue.
pub struct DataMover {
    tx: Option<Sender<TransferRequest>>,
    worker: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    packet_elems: usize,
}

impl DataMover {
    /// Default packet size: 100 MB (§6.5).
    pub const DEFAULT_PACKET_BYTES: usize = 100 << 20;

    /// Spawn the mover over a weight file, staging buffer, and link. All
    /// three are shared with the engine via `Arc`.
    pub fn spawn(
        weights: Arc<WeightFile>,
        buffer: Arc<WeightBuffer>,
        link: Arc<PcieLink>,
        packet_bytes: usize,
    ) -> Self {
        assert!(packet_bytes >= 4);
        let packet_elems = packet_bytes / 4;
        let shared = Arc::new(Shared {
            state: Mutex::new(State { ready: BTreeSet::new(), consumed: 0, shutdown: false }),
            cv: Condvar::new(),
        });
        let (tx, rx) = channel::<TransferRequest>();
        let worker = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let n_layers = weights.n_layers().max(1);
                while let Ok(req) = rx.recv() {
                    // Back-pressure: only two slots exist; filling stage S
                    // overwrites S-2's slot, so wait until S-2 is consumed.
                    {
                        let mut st = shared.state.lock().unwrap();
                        while !st.shutdown && req.layer >= 2 && st.consumed + 2 <= req.layer {
                            st = shared.cv.wait(st).unwrap();
                        }
                        if st.shutdown {
                            return;
                        }
                        if req.layer >= 2 {
                            st.ready.remove(&(req.layer - 2));
                        }
                    }
                    // Stage -> source layer: wraps so stage ids may run
                    // across pass boundaries (pipelined engine).
                    let src = weights.layer_data(req.layer % n_layers);
                    buffer.fill(req.layer, |dst| {
                        // Packetized copy: one link transaction per packet.
                        let mut off = 0;
                        while off < src.len() {
                            let end = (off + packet_elems).min(src.len());
                            link.transfer(&src[off..end], &mut dst[off..end]);
                            off = end;
                        }
                    });
                    let mut st = shared.state.lock().unwrap();
                    st.ready.insert(req.layer);
                    shared.cv.notify_all();
                }
            })
        };
        DataMover { tx: Some(tx), worker: Some(worker), shared, packet_elems }
    }

    pub fn packet_bytes(&self) -> usize {
        self.packet_elems * 4
    }

    /// Enqueue a layer transfer (returns immediately — the §6.4 prefetch
    /// at the start of each stage).
    pub fn request(&self, layer: usize) {
        self.tx
            .as_ref()
            .expect("mover running")
            .send(TransferRequest { layer })
            .expect("mover thread alive");
    }

    /// Stage-boundary sync: block until `layer` is fully staged.
    pub fn wait_layer(&self, layer: usize) {
        let mut st = self.shared.state.lock().unwrap();
        while !st.ready.contains(&layer) {
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Mark `layer` consumed: its slot may be reused for `layer + 2`.
    pub fn done_with(&self, layer: usize) {
        let mut st = self.shared.state.lock().unwrap();
        st.consumed = st.consumed.max(layer + 1);
        self.shared.cv.notify_all();
    }

    /// Non-blocking readiness check (telemetry / tests).
    pub fn is_ready(&self, layer: usize) -> bool {
        self.shared.state.lock().unwrap().ready.contains(&layer)
    }

    /// Start a new pass: layer indices restart at 0, so the consumption
    /// cursor and readiness set reset. Callers must have consumed every
    /// outstanding request (the engine's per-pass epilogue guarantees it).
    pub fn reset(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.ready.clear();
        st.consumed = 0;
        self.shared.cv.notify_all();
    }
}

impl Drop for DataMover {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.cv.notify_all();
        }
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transfer::pcie::LinkTiming;
    use crate::transfer::weights::{LayerView, TensorView};

    fn toy_setup(n_layers: usize, layer_elems: usize) -> (Arc<WeightFile>, Arc<WeightBuffer>) {
        let mut data = Vec::new();
        let mut tensors = Vec::new();
        let mut layers = Vec::new();
        for li in 0..n_layers {
            let start = data.len();
            data.extend((0..layer_elems).map(|i| (li * 1000 + i) as f32));
            let t = TensorView {
                name: format!("layers.{li}.w"),
                shape: vec![layer_elems],
                offset: start,
                len: layer_elems,
            };
            layers.push(LayerView {
                layer: li,
                tensors: vec![t.clone()],
                start,
                end: start + layer_elems,
            });
            tensors.push(t);
        }
        (
            Arc::new(WeightFile::from_parts(data, tensors, layers)),
            Arc::new(WeightBuffer::new(layer_elems)),
        )
    }

    #[test]
    fn streams_layers_through_double_buffer() {
        let (wf, buf) = toy_setup(6, 64);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover =
            DataMover::spawn(Arc::clone(&wf), Arc::clone(&buf), Arc::clone(&link), 64);
        // Enqueue everything up front: back-pressure must keep the mover
        // from clobbering un-consumed layers.
        for layer in 0..6 {
            mover.request(layer);
        }
        for layer in 0..6 {
            mover.wait_layer(layer);
            buf.read(layer, |d| {
                assert_eq!(d[0], (layer * 1000) as f32);
                assert_eq!(d[63], (layer * 1000 + 63) as f32);
            });
            mover.done_with(layer);
        }
        // 6 layers x 64 f32
        assert_eq!(link.total_bytes(), 6 * 64 * 4);
    }

    #[test]
    fn packetization_counts_whole_layer() {
        let (wf, buf) = toy_setup(1, 100);
        let link = Arc::new(PcieLink::new(LinkTiming::Virtual(1e9)));
        // 16-byte packets: 100 f32 = 400 B -> 25 packets, still 400 B total
        let mover = DataMover::spawn(wf, Arc::clone(&buf), Arc::clone(&link), 16);
        mover.request(0);
        mover.wait_layer(0);
        assert_eq!(link.total_bytes(), 400);
        buf.read(0, |d| assert_eq!(d.len(), 100));
    }

    #[test]
    fn prefetch_overlaps_with_reader() {
        // VSLPipe's actual protocol: prefetch layer L+1 at the start of
        // stage L, consume at stage boundaries.
        let (wf, buf) = toy_setup(8, 1024);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(wf, Arc::clone(&buf), link, 256);
        mover.request(0);
        mover.request(1);
        for layer in 0..8 {
            mover.wait_layer(layer);
            if layer + 2 < 8 {
                mover.request(layer + 2);
            }
            buf.read(layer, |d| assert_eq!(d[0], (layer * 1000) as f32));
            mover.done_with(layer);
        }
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (wf, buf) = toy_setup(3, 16);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(wf, Arc::clone(&buf), link, 64);
        mover.request(0);
        mover.request(1);
        mover.request(2); // would overwrite layer 0's slot
        mover.wait_layer(1);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(mover.is_ready(0), "layer 0 must not be evicted before done_with");
        assert!(!mover.is_ready(2), "layer 2 must wait for layer 0's slot");
        buf.read(0, |d| assert_eq!(d[0], 0.0));
        mover.done_with(0);
        mover.wait_layer(2);
        assert!(!mover.is_ready(0), "staging layer 2 evicts layer 0");
        buf.read(2, |d| assert_eq!(d[0], 2000.0));
    }

    #[test]
    fn stage_stream_crosses_pass_boundaries() {
        // The pipelined engine's protocol: stage ids keep counting across
        // passes (stage s sources layer s % n_layers), with no reset. The
        // +2 prefetch at a pass's tail therefore stages the *next pass's*
        // first layers while the head would run.
        let n_layers = 3;
        let (wf, buf) = toy_setup(n_layers, 32);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(Arc::clone(&wf), Arc::clone(&buf), Arc::clone(&link), 128);
        mover.request(0);
        mover.request(1);
        let passes = 3;
        for stage in 0..passes * n_layers {
            mover.wait_layer(stage);
            buf.read(stage, |d| {
                assert_eq!(d[0], ((stage % n_layers) * 1000) as f32, "stage {stage}");
            });
            mover.done_with(stage);
            mover.request(stage + 2); // unconditional: runs into the next pass
        }
        // After the last consumed stage, the two prefetched stages for the
        // never-run next pass stream without blocking the mover.
        mover.wait_layer(passes * n_layers);
        mover.wait_layer(passes * n_layers + 1);
        assert_eq!(
            link.total_bytes() as usize,
            (passes * n_layers + 2) * 32 * 4,
            "every stage moved exactly once"
        );
    }

    #[test]
    fn drop_while_blocked_does_not_hang() {
        let (wf, buf) = toy_setup(4, 16);
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(wf, buf, link, 64);
        for l in 0..4 {
            mover.request(l);
        }
        mover.wait_layer(1);
        drop(mover); // worker is blocked on back-pressure; Drop must join
    }
}
