//! The pinned host-side weight store: `artifacts/weights_<cfg>.bin` plus
//! the manifest's tensor table.
//!
//! The file is one little-endian f32 stream in *streaming order*:
//! embedding, per-layer groups (`ln1, wq, wk, wv, wo, ln2, router, w1,
//! w3, w2`), final norm, LM head — the order the weight manager walks, so
//! a layer's tensors are contiguous and the data mover can move a whole
//! layer as one run (the "contiguous" in Contiguous Data Mover).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// One tensor's metadata + position in the host buffer.
#[derive(Debug, Clone)]
pub struct TensorView {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 elements.
    pub offset: usize,
    pub len: usize,
}

/// A layer's weights as manifest-ordered tensor views.
#[derive(Debug, Clone)]
pub struct LayerView {
    pub layer: usize,
    pub tensors: Vec<TensorView>,
    /// f32-element span [start, end) in the host buffer.
    pub start: usize,
    pub end: usize,
}

/// Element ranges of one layer (relative to the layer's start) split by
/// residency unit: `dense` ranges always stream; `expert[e]` ranges
/// stream only when expert `e` is activated and not pinned in HBM.
#[derive(Debug, Clone)]
pub struct LayerRegions {
    pub dense: Vec<(usize, usize)>,
    pub expert: Vec<Vec<(usize, usize)>>,
}

impl LayerRegions {
    /// Elements of one expert's slices (w1 + w3 + w2).
    pub fn expert_elems(&self) -> usize {
        self.expert
            .first()
            .map(|rs| rs.iter().map(|&(_, len)| len).sum())
            .unwrap_or(0)
    }

    /// Elements of the dense (non-expert) ranges.
    pub fn dense_elems(&self) -> usize {
        self.dense.iter().map(|&(_, len)| len).sum()
    }
}

/// The whole weight file resident in (what stands for pinned) host memory.
pub struct WeightFile {
    data: Vec<f32>,
    tensors: Vec<TensorView>,
    layers: Vec<LayerView>,
}

impl WeightFile {
    /// Load from the artifact directory given the manifest's `weights`
    /// object for one config.
    pub fn load(dir: &str, weights_manifest: &Json) -> Result<WeightFile> {
        let file = weights_manifest
            .req("file")
            .as_str()
            .context("weights.file")?
            .to_string();
        let nbytes = weights_manifest.req("bytes").as_usize().context("weights.bytes")?;
        let path = format!("{dir}/{file}");
        let raw = std::fs::read(&path).with_context(|| format!("reading {path}"))?;
        if raw.len() != nbytes {
            bail!("{path}: expected {nbytes} bytes, found {}", raw.len());
        }
        if raw.len() % 4 != 0 {
            bail!("{path}: not a whole number of f32s");
        }
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut tensors = Vec::new();
        for t in weights_manifest.req("tensors").as_arr().context("weights.tensors")? {
            let name = t.req("name").as_str().context("tensor.name")?.to_string();
            let shape: Vec<usize> = t
                .req("shape")
                .as_usize_vec()
                .context("tensor.shape")?;
            let offset_bytes = t.req("offset").as_usize().context("tensor.offset")?;
            let len: usize = shape.iter().product();
            tensors.push(TensorView { name, shape, offset: offset_bytes / 4, len });
        }

        // Group per-layer tensors ("layers.<i>.<name>") into LayerViews.
        let mut layers: Vec<LayerView> = Vec::new();
        for t in &tensors {
            if let Some(rest) = t.name.strip_prefix("layers.") {
                let li: usize = rest
                    .split('.')
                    .next()
                    .and_then(|s| s.parse().ok())
                    .with_context(|| format!("bad layer tensor name {}", t.name))?;
                if layers.len() <= li {
                    layers.resize_with(li + 1, || LayerView {
                        layer: 0,
                        tensors: Vec::new(),
                        start: usize::MAX,
                        end: 0,
                    });
                }
                let lv = &mut layers[li];
                lv.layer = li;
                lv.start = lv.start.min(t.offset);
                lv.end = lv.end.max(t.offset + t.len);
                lv.tensors.push(t.clone());
            }
        }
        for lv in &layers {
            // streaming order => each layer's span must be contiguous
            let span: usize = lv.end - lv.start;
            let sum: usize = lv.tensors.iter().map(|t| t.len).sum();
            if span != sum {
                bail!("layer {} tensors are not contiguous ({span} != {sum})", lv.layer);
            }
        }
        Ok(WeightFile { data, tensors, layers })
    }

    /// Build directly from parts (tests).
    pub fn from_parts(data: Vec<f32>, tensors: Vec<TensorView>, layers: Vec<LayerView>) -> Self {
        WeightFile { data, tensors, layers }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_elems(&self) -> usize {
        self.data.len()
    }

    pub fn layer(&self, i: usize) -> &LayerView {
        &self.layers[i]
    }

    /// The contiguous f32 run backing layer `i` — the data mover's source.
    pub fn layer_data(&self, i: usize) -> &[f32] {
        let lv = &self.layers[i];
        &self.data[lv.start..lv.end]
    }

    pub fn tensor(&self, name: &str) -> Result<&TensorView> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("tensor '{name}' not in weight file"))
    }

    /// A named tensor's data (host view).
    pub fn tensor_data(&self, name: &str) -> Result<&[f32]> {
        let t = self.tensor(name)?;
        Ok(&self.data[t.offset..t.offset + t.len])
    }

    /// Split layer `i` into dense vs per-expert element ranges, all
    /// relative to the layer's start (so they index both [`layer_data`]
    /// and the staged GPU slot). The expert tensors (`w1`, `w3`, `w2`)
    /// are stored expert-dimension-outermost, so expert `e` owns the
    /// `e`-th equal slice of each; everything else (attention, norms,
    /// router) is dense and always streamed.
    pub fn layer_regions(&self, i: usize, n_experts: usize) -> LayerRegions {
        assert!(n_experts > 0);
        let lv = &self.layers[i];
        let mut dense = Vec::new();
        let mut expert: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n_experts];
        for t in &lv.tensors {
            let rel = t.offset - lv.start;
            let base = t.name.rsplit('.').next().unwrap_or(&t.name);
            if matches!(base, "w1" | "w3" | "w2") {
                assert!(
                    t.len % n_experts == 0,
                    "expert tensor {} ({} elems) not divisible by {n_experts} experts",
                    t.name,
                    t.len
                );
                let per = t.len / n_experts;
                for (e, ranges) in expert.iter_mut().enumerate() {
                    ranges.push((rel + e * per, per));
                }
            } else {
                dense.push((rel, t.len));
            }
        }
        LayerRegions { dense, expert }
    }

    /// A tensor's data within a *layer-local* buffer previously filled from
    /// [`layer_data`] (i.e., the GPU weight-buffer view of the tensor).
    pub fn tensor_in_layer<'a>(&self, layer: usize, name: &str, buf: &'a [f32]) -> Result<&'a [f32]> {
        let lv = &self.layers[layer];
        let full = format!("layers.{layer}.{name}");
        let t = lv
            .tensors
            .iter()
            .find(|t| t.name == full)
            .with_context(|| format!("tensor '{full}' not in layer {layer}"))?;
        let lo = t.offset - lv.start;
        Ok(&buf[lo..lo + t.len])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> WeightFile {
        // 2 layers, each with tensors a (2 elems) and b (3 elems).
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mk = |name: &str, off: usize, len: usize| TensorView {
            name: name.into(),
            shape: vec![len],
            offset: off,
            len,
        };
        let tensors = vec![
            mk("embedding", 0, 2),
            mk("layers.0.a", 2, 2),
            mk("layers.0.b", 4, 3),
            mk("layers.1.a", 7, 2),
            mk("layers.1.b", 9, 3),
        ];
        let layers = vec![
            LayerView { layer: 0, tensors: tensors[1..3].to_vec(), start: 2, end: 7 },
            LayerView { layer: 1, tensors: tensors[3..5].to_vec(), start: 7, end: 12 },
        ];
        WeightFile::from_parts(data, tensors, layers)
    }

    #[test]
    fn layer_data_is_contiguous_span() {
        let w = toy();
        assert_eq!(w.layer_data(0), &[2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(w.layer_data(1), &[7.0, 8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn tensor_lookup() {
        let w = toy();
        assert_eq!(w.tensor_data("embedding").unwrap(), &[0.0, 1.0]);
        assert_eq!(w.tensor_data("layers.1.b").unwrap(), &[9.0, 10.0, 11.0]);
        assert!(w.tensor_data("nope").is_err());
    }

    #[test]
    fn tensor_in_layer_resolves_into_staged_buffer() {
        let w = toy();
        let staged: Vec<f32> = w.layer_data(1).to_vec();
        let b = w.tensor_in_layer(1, "b", &staged).unwrap();
        assert_eq!(b, &[9.0, 10.0, 11.0]);
        let a = w.tensor_in_layer(1, "a", &staged).unwrap();
        assert_eq!(a, &[7.0, 8.0]);
    }

    #[test]
    fn layer_regions_partition_the_layer() {
        // One layer: dense ln (2 elems), expert tensors w1/w3 with 2
        // experts (4 elems each), dense tail (1 elem).
        let data: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let mk = |name: &str, off: usize, len: usize| TensorView {
            name: name.into(),
            shape: vec![len],
            offset: off,
            len,
        };
        let tensors = vec![
            mk("layers.0.ln1", 0, 2),
            mk("layers.0.w1", 2, 4),
            mk("layers.0.w3", 6, 4),
            mk("layers.0.ln2", 10, 1),
        ];
        let layers = vec![LayerView { layer: 0, tensors: tensors.clone(), start: 0, end: 11 }];
        let w = WeightFile::from_parts(data, tensors, layers);
        let r = w.layer_regions(0, 2);
        assert_eq!(r.dense, vec![(0, 2), (10, 1)]);
        assert_eq!(r.expert, vec![vec![(2, 2), (6, 2)], vec![(4, 2), (8, 2)]]);
        assert_eq!(r.expert_elems(), 4);
        assert_eq!(r.dense_elems(), 3);
        // dense + n_experts * expert covers the whole span
        assert_eq!(r.dense_elems() + 2 * r.expert_elems(), 11);
    }

    #[test]
    fn loads_real_tiny_artifact() {
        // Smoke-load the actual AOT output when present (CI always builds
        // artifacts first; guard anyway to keep unit tests hermetic).
        let manifest_path = "artifacts/manifest.json";
        if !std::path::Path::new(manifest_path).exists() {
            return;
        }
        let text = std::fs::read_to_string(manifest_path).unwrap();
        let manifest = Json::parse(&text).unwrap();
        let wm = manifest.req("configs").req("tiny").req("weights");
        let w = WeightFile::load("artifacts", wm).unwrap();
        assert_eq!(w.n_layers(), 2);
        // embedding: vocab 512 x d_model 64
        assert_eq!(w.tensor("embedding").unwrap().shape, vec![512, 64]);
        // every layer span must match ModelSpec::layer_bytes / 4
        let spec = crate::config::ModelSpec::tiny();
        let expect = (spec.layer_bytes() / spec.weight_bytes as u64) as usize;
        assert_eq!(w.layer_data(0).len(), expect);
    }
}
