//! Weight layout, weight buffer, and the Contiguous Data Mover (§6.5).
//!
//! The paper stores all model weights in pinned CPU memory and streams
//! them to the GPU on demand; the weight buffer on the GPU holds two
//! layers (double buffering), and a dedicated data-mover thread packetizes
//! layer-granularity requests into ~100 MB transfers to keep the link
//! saturated without head-of-line blocking latency-sensitive compute
//! transfers.
//!
//! On this box the "GPU" is the PJRT CPU client, so the H2D copy is a
//! memcpy through [`PcieLink`] — a bandwidth-throttled byte mover whose
//! clock can be scaled (or disabled) so the same mechanism serves the real
//! engine and timing experiments.

mod buffer;
mod data_mover;
mod pcie;
mod residency;
mod weights;

pub use buffer::WeightBuffer;
pub use data_mover::{DataMover, ExpertMode, TransferRequest};
pub use pcie::{LinkTiming, PcieLink};
pub use residency::ResidencyMap;
pub use weights::{LayerRegions, LayerView, TensorView, WeightFile};
