//! Golden vectors exported by `python/compile/aot.py` — the cross-layer
//! correctness contract: the JAX oracle's numbers, replayed against the
//! Rust kernels and the full engine by `cargo test`.

use crate::util::bf16::f32_to_bf16;
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Oracle vectors for the CPU decode-attention kernel.
#[derive(Debug, Clone)]
pub struct DecodeAttnGolden {
    pub nd: usize,
    pub l_max: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// [nd, n_heads*head_dim] f32 queries.
    pub q: Vec<f32>,
    /// [nd, l_max, kv_dim] BF16 bits (exported as bf16-rounded f32).
    pub k_bits: Vec<u16>,
    pub v_bits: Vec<u16>,
    pub ctx_lens: Vec<usize>,
    /// Expected [nd, n_heads*head_dim].
    pub out: Vec<f32>,
}

/// One packed forward pass through the whole model.
#[derive(Debug, Clone)]
pub struct ForwardGolden {
    pub ids: Vec<i32>,
    pub positions: Vec<i32>,
    pub seg_ids: Vec<i32>,
    pub p0: usize,
    pub p1: usize,
    /// Expected next-token ids at the last row of each packed sequence.
    pub next_ids: Vec<i32>,
    /// Expected logits at sequence 0's last row.
    pub logits_seq0_last: Vec<f32>,
}

/// End-to-end greedy generation.
#[derive(Debug, Clone)]
pub struct GenerationGolden {
    pub prompts: Vec<Vec<i32>>,
    pub steps: usize,
    /// Expected generated tokens per prompt.
    pub tokens: Vec<Vec<i32>>,
}

/// The full golden file.
#[derive(Debug, Clone)]
pub struct Golden {
    pub decode_attn: DecodeAttnGolden,
    pub forward: ForwardGolden,
    pub generation: GenerationGolden,
}

fn f32s(j: &Json) -> Result<Vec<f32>> {
    j.as_f32_vec().context("expected number array")
}

fn i32s(j: &Json) -> Result<Vec<i32>> {
    Ok(j.as_arr()
        .context("expected array")?
        .iter()
        .map(|x| x.as_i64().unwrap() as i32)
        .collect())
}

impl Golden {
    /// Load `<dir>/<file>` (the manifest's `golden` entry).
    pub fn load(dir: &str, file: &str) -> Result<Golden> {
        let path = format!("{dir}/{file}");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e:?}"))?;

        let d = root.req("decode_attn");
        let to_bits = |j: &Json| -> Result<Vec<u16>> {
            Ok(f32s(j)?.into_iter().map(f32_to_bf16).collect())
        };
        let decode_attn = DecodeAttnGolden {
            nd: d.req("nd").as_usize().context("nd")?,
            l_max: d.req("l_max").as_usize().context("l_max")?,
            n_heads: d.req("n_heads").as_usize().context("n_heads")?,
            n_kv_heads: d.req("n_kv_heads").as_usize().context("n_kv_heads")?,
            head_dim: d.req("head_dim").as_usize().context("head_dim")?,
            q: f32s(d.req("q"))?,
            k_bits: to_bits(d.req("k_bf16"))?,
            v_bits: to_bits(d.req("v_bf16"))?,
            ctx_lens: d.req("ctx_lens").as_usize_vec().context("ctx_lens")?,
            out: f32s(d.req("out"))?,
        };

        let f = root.req("forward");
        let forward = ForwardGolden {
            ids: i32s(f.req("ids"))?,
            positions: i32s(f.req("positions"))?,
            seg_ids: i32s(f.req("seg_ids"))?,
            p0: f.req("p0").as_usize().context("p0")?,
            p1: f.req("p1").as_usize().context("p1")?,
            next_ids: i32s(f.req("next_ids"))?,
            logits_seq0_last: f32s(f.req("logits_seq0_last"))?,
        };

        let g = root.req("generation");
        let prompts = g
            .req("prompts")
            .as_arr()
            .context("prompts")?
            .iter()
            .map(i32s)
            .collect::<Result<Vec<_>>>()?;
        let tokens = g
            .req("tokens")
            .as_arr()
            .context("tokens")?
            .iter()
            .map(i32s)
            .collect::<Result<Vec<_>>>()?;
        let generation = GenerationGolden {
            prompts,
            steps: g.req("steps").as_usize().context("steps")?,
            tokens,
        };

        Ok(Golden { decode_attn, forward, generation })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpuattn::{decode_attention_dense, AttnShape, Tier};

    fn golden() -> Option<Golden> {
        std::path::Path::new("artifacts/golden_tiny.json")
            .exists()
            .then(|| Golden::load("artifacts", "golden_tiny.json").unwrap())
    }

    #[test]
    fn shapes_are_consistent() {
        let Some(g) = golden() else { return };
        let d = &g.decode_attn;
        let q_dim = d.n_heads * d.head_dim;
        let kv_dim = d.n_kv_heads * d.head_dim;
        assert_eq!(d.q.len(), d.nd * q_dim);
        assert_eq!(d.k_bits.len(), d.nd * d.l_max * kv_dim);
        assert_eq!(d.out.len(), d.nd * q_dim);
        assert_eq!(d.ctx_lens.len(), d.nd);
        assert_eq!(g.forward.ids.len(), g.forward.seg_ids.len());
        assert_eq!(g.generation.prompts.len(), g.generation.tokens.len());
        assert!(g.generation.tokens.iter().all(|t| t.len() == g.generation.steps));
    }

    /// THE §6.6 correctness gate: the Rust CPU decode-attention kernel vs
    /// the JAX oracle's exported vectors, all tiers.
    #[test]
    fn cpu_attention_matches_jax_oracle() {
        let Some(g) = golden() else { return };
        let d = &g.decode_attn;
        let shape = AttnShape {
            n_heads: d.n_heads,
            n_kv_heads: d.n_kv_heads,
            head_dim: d.head_dim,
        };
        for tier in [Tier::Scalar, Tier::Unrolled, Tier::Simd, Tier::Optimized] {
            let mut out = vec![0f32; d.out.len()];
            decode_attention_dense(
                shape, &d.q, &d.k_bits, &d.v_bits, &d.ctx_lens, d.l_max, &mut out, tier,
            );
            for (i, (a, b)) in out.iter().zip(&d.out).enumerate() {
                assert!(
                    (a - b).abs() <= 2e-4 * b.abs().max(1.0),
                    "{tier:?} elem {i}: rust {a} vs jax {b}"
                );
            }
        }
    }
}
