//! Model-level types shared by the engine, scheduler, and simulator:
//! serving requests, sequence lifecycle state, and the golden-vector
//! loader that cross-validates the Rust engine against the JAX oracle.

mod golden;
mod request;

pub use golden::{DecodeAttnGolden, ForwardGolden, GenerationGolden, Golden};
pub use request::{Request, SeqPhase, Sequence};
