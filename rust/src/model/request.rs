//! Serving requests and live sequence state.

use crate::kvcache::SeqId;

/// An inference request: a prompt and a generation budget.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: SeqId,
    pub prompt: Vec<i32>,
    /// Maximum tokens to generate (the Table-3 caps).
    pub max_gen: usize,
    /// Optional EOS token: generation stops early when produced (§8.1's
    /// "terminate generation when the EOS token is reached" mode).
    pub eos: Option<i32>,
    /// Optional end-to-end deadline, in seconds on the run clock (the
    /// same clock arrival timestamps use). `None` = no SLO: the request
    /// is never shed and carries no preemption-slack information. The
    /// SLO-aware admission and weighted victim policies read this; the
    /// FIFO/newest defaults ignore it.
    pub deadline: Option<f64>,
}

impl Request {
    pub fn new(id: SeqId, prompt: Vec<i32>, max_gen: usize) -> Self {
        assert!(!prompt.is_empty() && max_gen > 0);
        Request { id, prompt, max_gen, eos: None, deadline: None }
    }

    pub fn with_eos(mut self, eos: i32) -> Self {
        self.eos = Some(eos);
        self
    }

    /// Attach an absolute end-to-end deadline (run-clock seconds).
    pub fn with_deadline(mut self, deadline: f64) -> Self {
        assert!(deadline.is_finite(), "deadline must be finite (omit it for none)");
        self.deadline = Some(deadline);
        self
    }
}

/// Lifecycle phase of a scheduled sequence (§6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting in the Prefill Scheduler's queue.
    Queued,
    /// Prompt being processed (possibly chunked across passes).
    Prefilling,
    /// In the Decode Scheduler's active set.
    Decoding,
    /// Generation finished; resources reclaimed.
    Finished,
}

/// A request in flight.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub req: Request,
    pub phase: SeqPhase,
    /// Prompt tokens already prefilled (chunked prefill cursor).
    pub prefilled: usize,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    /// Times this sequence was preempted (telemetry + §6.2 re-prefill).
    pub preemptions: usize,
    /// When the request entered the scheduler (run-clock seconds; 0 for
    /// closed batches). The weighted victim policy breaks score ties
    /// youngest-first on this.
    pub arrival: f64,
}

impl Sequence {
    pub fn new(req: Request) -> Self {
        Sequence::new_at(req, 0.0)
    }

    /// A sequence arriving at run-clock time `arrival`.
    pub fn new_at(req: Request, arrival: f64) -> Self {
        Sequence {
            req,
            phase: SeqPhase::Queued,
            prefilled: 0,
            generated: Vec::new(),
            preemptions: 0,
            arrival,
        }
    }

    pub fn id(&self) -> SeqId {
        self.req.id
    }

    /// Tokens the prefill stage still has to process. After a preemption
    /// this includes previously generated tokens (they are replayed as
    /// prompt — §6.2: "their earlier progress has already been partially
    /// completed").
    pub fn pending_prefill(&self) -> usize {
        self.full_prompt_len() - self.prefilled
    }

    /// Prompt + already-generated tokens (the effective prompt after
    /// preemption). Prefilling this context makes the model's last-row
    /// output the *next* new token, for fresh and re-prefilled sequences
    /// alike.
    pub fn full_prompt_len(&self) -> usize {
        self.req.prompt.len() + self.generated.len()
    }

    /// Token at *logical* position `pos` of the full (prompt ++ generated)
    /// stream — what a prefill chunk feeds the model.
    pub fn token_at(&self, pos: usize) -> i32 {
        if pos < self.req.prompt.len() {
            self.req.prompt[pos]
        } else {
            self.generated[pos - self.req.prompt.len()]
        }
    }

    /// Remaining generation budget.
    pub fn remaining_gen(&self) -> usize {
        self.req.max_gen - self.generated.len()
    }

    /// Whether the sequence is done after appending `tok`.
    pub fn push_generated(&mut self, tok: i32) -> bool {
        self.generated.push(tok);
        let eos_hit = self.req.eos == Some(tok);
        let budget_out = self.generated.len() >= self.req.max_gen;
        if eos_hit || budget_out {
            self.phase = SeqPhase::Finished;
            true
        } else {
            false
        }
    }

    /// Preempt: forget KV progress, requeue as prefill of prompt+prefix.
    pub fn preempt(&mut self) {
        self.phase = SeqPhase::Queued;
        self.prefilled = 0;
        self.preemptions += 1;
    }

    /// Whether the system has done any work for this sequence yet — the
    /// rejected (shed untouched) vs. expired (dropped mid-flight)
    /// distinction the drop accounting reports.
    pub fn started(&self) -> bool {
        self.prefilled > 0 || !self.generated.is_empty() || self.preemptions > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_lifecycle() {
        let mut s = Sequence::new(Request::new(1, vec![1, 2, 3], 2));
        assert_eq!(s.pending_prefill(), 3);
        s.prefilled = 3;
        s.phase = SeqPhase::Decoding;
        assert!(!s.push_generated(7));
        assert!(s.push_generated(8));
        assert_eq!(s.phase, SeqPhase::Finished);
        assert_eq!(s.generated, vec![7, 8]);
    }

    #[test]
    fn eos_terminates_early() {
        let mut s = Sequence::new(Request::new(1, vec![1], 100).with_eos(0));
        s.phase = SeqPhase::Decoding;
        assert!(!s.push_generated(5));
        assert!(s.push_generated(0));
        assert_eq!(s.generated.len(), 2);
    }

    #[test]
    fn deadline_and_arrival_plumbing() {
        let r = Request::new(3, vec![1, 2], 8).with_deadline(42.5);
        assert_eq!(r.deadline, Some(42.5));
        assert_eq!(Request::new(3, vec![1, 2], 8).deadline, None);
        let s = Sequence::new_at(r, 7.25);
        assert_eq!(s.arrival, 7.25);
        assert_eq!(Sequence::new(Request::new(0, vec![1], 1)).arrival, 0.0);
    }

    #[test]
    #[should_panic(expected = "deadline must be finite")]
    fn non_finite_deadline_panics() {
        Request::new(0, vec![1], 1).with_deadline(f64::NAN);
    }

    #[test]
    fn started_tracks_any_progress() {
        let mut s = Sequence::new(Request::new(1, vec![1, 2], 4));
        assert!(!s.started());
        s.prefilled = 1;
        assert!(s.started());
        s.prefilled = 0;
        s.preemptions = 1;
        assert!(s.started());
        s.preemptions = 0;
        s.generated.push(9);
        assert!(s.started());
    }

    #[test]
    fn preemption_replays_generated_prefix() {
        let mut s = Sequence::new(Request::new(1, vec![10, 11], 8));
        s.prefilled = 2;
        s.phase = SeqPhase::Decoding;
        s.push_generated(20);
        s.push_generated(21);
        s.preempt();
        // prompt(2) + generated(2): the whole generated prefix is replayed
        // so the re-prefill's last-row output is the *next* token.
        assert_eq!(s.full_prompt_len(), 4);
        assert_eq!(s.pending_prefill(), 4);
        assert_eq!(s.token_at(0), 10);
        assert_eq!(s.token_at(2), 20);
        assert_eq!(s.token_at(3), 21);
        assert_eq!(s.preemptions, 1);
    }
}
