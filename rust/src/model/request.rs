//! Serving requests and live sequence state.

use crate::kvcache::SeqId;

/// An inference request: a prompt and a generation budget.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: SeqId,
    pub prompt: Vec<i32>,
    /// Maximum tokens to generate (the Table-3 caps).
    pub max_gen: usize,
    /// Optional EOS token: generation stops early when produced (§8.1's
    /// "terminate generation when the EOS token is reached" mode).
    pub eos: Option<i32>,
}

impl Request {
    pub fn new(id: SeqId, prompt: Vec<i32>, max_gen: usize) -> Self {
        assert!(!prompt.is_empty() && max_gen > 0);
        Request { id, prompt, max_gen, eos: None }
    }

    pub fn with_eos(mut self, eos: i32) -> Self {
        self.eos = Some(eos);
        self
    }
}

/// Lifecycle phase of a scheduled sequence (§6.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting in the Prefill Scheduler's queue.
    Queued,
    /// Prompt being processed (possibly chunked across passes).
    Prefilling,
    /// In the Decode Scheduler's active set.
    Decoding,
    /// Generation finished; resources reclaimed.
    Finished,
}

/// A request in flight.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub req: Request,
    pub phase: SeqPhase,
    /// Prompt tokens already prefilled (chunked prefill cursor).
    pub prefilled: usize,
    /// Generated tokens so far.
    pub generated: Vec<i32>,
    /// Times this sequence was preempted (telemetry + §6.2 re-prefill).
    pub preemptions: usize,
}

impl Sequence {
    pub fn new(req: Request) -> Self {
        Sequence { req, phase: SeqPhase::Queued, prefilled: 0, generated: Vec::new(), preemptions: 0 }
    }

    pub fn id(&self) -> SeqId {
        self.req.id
    }

    /// Tokens the prefill stage still has to process. After a preemption
    /// this includes previously generated tokens (they are replayed as
    /// prompt — §6.2: "their earlier progress has already been partially
    /// completed").
    pub fn pending_prefill(&self) -> usize {
        self.full_prompt_len() - self.prefilled
    }

    /// Prompt + already-generated tokens (the effective prompt after
    /// preemption). Prefilling this context makes the model's last-row
    /// output the *next* new token, for fresh and re-prefilled sequences
    /// alike.
    pub fn full_prompt_len(&self) -> usize {
        self.req.prompt.len() + self.generated.len()
    }

    /// Token at *logical* position `pos` of the full (prompt ++ generated)
    /// stream — what a prefill chunk feeds the model.
    pub fn token_at(&self, pos: usize) -> i32 {
        if pos < self.req.prompt.len() {
            self.req.prompt[pos]
        } else {
            self.generated[pos - self.req.prompt.len()]
        }
    }

    /// Remaining generation budget.
    pub fn remaining_gen(&self) -> usize {
        self.req.max_gen - self.generated.len()
    }

    /// Whether the sequence is done after appending `tok`.
    pub fn push_generated(&mut self, tok: i32) -> bool {
        self.generated.push(tok);
        let eos_hit = self.req.eos == Some(tok);
        let budget_out = self.generated.len() >= self.req.max_gen;
        if eos_hit || budget_out {
            self.phase = SeqPhase::Finished;
            true
        } else {
            false
        }
    }

    /// Preempt: forget KV progress, requeue as prefill of prompt+prefix.
    pub fn preempt(&mut self) {
        self.phase = SeqPhase::Queued;
        self.prefilled = 0;
        self.preemptions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_lifecycle() {
        let mut s = Sequence::new(Request::new(1, vec![1, 2, 3], 2));
        assert_eq!(s.pending_prefill(), 3);
        s.prefilled = 3;
        s.phase = SeqPhase::Decoding;
        assert!(!s.push_generated(7));
        assert!(s.push_generated(8));
        assert_eq!(s.phase, SeqPhase::Finished);
        assert_eq!(s.generated, vec![7, 8]);
    }

    #[test]
    fn eos_terminates_early() {
        let mut s = Sequence::new(Request::new(1, vec![1], 100).with_eos(0));
        s.phase = SeqPhase::Decoding;
        assert!(!s.push_generated(5));
        assert!(s.push_generated(0));
        assert_eq!(s.generated.len(), 2);
    }

    #[test]
    fn preemption_replays_generated_prefix() {
        let mut s = Sequence::new(Request::new(1, vec![10, 11], 8));
        s.prefilled = 2;
        s.phase = SeqPhase::Decoding;
        s.push_generated(20);
        s.push_generated(21);
        s.preempt();
        // prompt(2) + generated(2): the whole generated prefix is replayed
        // so the re-prefill's last-row output is the *next* token.
        assert_eq!(s.full_prompt_len(), 4);
        assert_eq!(s.pending_prefill(), 4);
        assert_eq!(s.token_at(0), 10);
        assert_eq!(s.token_at(2), 20);
        assert_eq!(s.token_at(3), 21);
        assert_eq!(s.preemptions, 1);
    }
}
