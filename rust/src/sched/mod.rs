//! The Resource-Aware Scheduler (§6.2) and Pipeline Profiler (§6.3).
//!
//! The scheduler overlaps prefill and decode in one pass plan per
//! iteration, switching between *Normal Inference Mode* (both schedulers
//! issue concurrently) and *Preemption Mode* (newest decode sequences are
//! evicted and re-queued as prefill, old sequences are prioritized). It
//! is engine-agnostic: the real VSLPipe engine and the `simhw` simulator
//! drive the same planner against a [`PagedLayout`].

mod profiler;
mod resource_aware;

pub use profiler::{PipelineProfiler, ProfileFit};
pub use resource_aware::{PassPlan, SchedConfig, SchedMode, Scheduler};
