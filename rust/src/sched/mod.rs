//! The Resource-Aware Scheduler (§6.2), Pipeline Profiler (§6.3), and
//! the pluggable scheduling policies (admission + preemption victim).
//!
//! The scheduler overlaps prefill and decode in one pass plan per
//! iteration, switching between *Normal Inference Mode* (both schedulers
//! issue concurrently) and *Preemption Mode* (decode sequences are
//! evicted by the configured [`VictimPolicy`] and re-queued as prefill).
//! Queue admission follows the configured [`AdmissionPolicy`]: FIFO, or
//! SLO-aware shedding against per-request deadlines using the
//! [`ServiceModel`] cost estimates. It is engine-agnostic: the real
//! VSLPipe engine and the `simhw` simulator drive the same planner
//! against a [`PagedLayout`].
//!
//! [`PagedLayout`]: crate::kvcache::PagedLayout

mod policy;
mod profiler;
mod resource_aware;

pub use policy::{
    AdmissionPolicy, DropReason, ServiceEstimator, ServiceModel, VictimPolicy,
    DEFAULT_SLO_HEADROOM,
};
pub use profiler::{PipelineProfiler, ProfileFit};
pub use resource_aware::{PassPlan, SchedConfig, SchedMode, Scheduler};
