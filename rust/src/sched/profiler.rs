//! The Pipeline Profiler (§6.3, Fig. 7).
//!
//! Estimates `n_real` — the token count at which GPU GEMM time catches up
//! with per-layer weight-transfer time — by measuring GPU compute time at
//! several token counts, fitting a line, and intersecting it with the
//! measured weight-transfer time. The Resource-Aware Scheduler caps each
//! pass at `n_real` so prefill admission never over-commits the pipeline.

use crate::util::cast::{f64_usize, usize_f64};
use crate::util::stats::{line_fit, LineFit};

/// The fitted profile.
#[derive(Debug, Clone)]
pub struct ProfileFit {
    /// GPU time (s) ≈ slope * tokens + intercept.
    pub line: LineFit,
    /// Per-layer weight-transfer time (s).
    pub layer_io_secs: f64,
    /// Token threshold where GPU compute covers the transfer.
    pub n_real: usize,
}

/// Generic profiler: measurement closures abstract the clock, so the same
/// code profiles the live PJRT engine (wall time) and the `simhw` machine
/// (analytic time).
pub struct PipelineProfiler {
    /// Token counts to sample (Fig. 7 samples a handful of points).
    pub sample_points: Vec<usize>,
    /// Repetitions per point (median taken).
    pub reps: usize,
}

impl Default for PipelineProfiler {
    fn default() -> Self {
        PipelineProfiler { sample_points: vec![256, 512, 1024, 2048, 4096], reps: 3 }
    }
}

impl PipelineProfiler {
    pub fn with_points(points: Vec<usize>) -> Self {
        assert!(points.len() >= 2, "need >= 2 points for a line fit");
        PipelineProfiler { sample_points: points, reps: 3 }
    }

    /// Run the profile. `gpu_time(n)` measures GPU compute seconds for a
    /// pass of `n` tokens; `layer_io_secs` is the measured time to move
    /// one layer of weights.
    pub fn profile<F>(&self, mut gpu_time: F, layer_io_secs: f64) -> ProfileFit
    where
        F: FnMut(usize) -> f64,
    {
        assert!(!self.sample_points.is_empty(), "profiler needs sample points");
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &n in &self.sample_points {
            let mut samples: Vec<f64> = (0..self.reps).map(|_| gpu_time(n)).collect();
            samples.sort_by(f64::total_cmp);
            xs.push(usize_f64(n));
            ys.push(samples[samples.len() / 2]);
        }
        let line = line_fit(&xs, &ys);
        // Intersect: slope * n + intercept = layer_io_secs.
        let n_real = if line.slope <= 0.0 {
            // Degenerate (measurement noise floor): fall back to the
            // largest sampled point — the GPU never catches the IO.
            self.sample_points[self.sample_points.len() - 1]
        } else {
            f64_usize(((layer_io_secs - line.intercept) / line.slope).max(1.0))
        };
        ProfileFit { line, layer_io_secs, n_real }
    }

    /// Analytic profile from hardware constants — what Eq. 2 predicts; the
    /// measured fit should land near this (Fig. 7's "estimate then refine").
    pub fn analytic(
        machine: &crate::config::MachineSpec,
        model: &crate::config::ModelSpec,
    ) -> ProfileFit {
        let per_layer_flops = model.flops_per_token() / usize_f64(model.n_layers);
        let slope = per_layer_flops / machine.gpu.bf16_flops;
        let layer_io = machine.transfer_secs(model.layer_bytes());
        let n_real = f64_usize(layer_io / slope);
        ProfileFit {
            line: LineFit { slope, intercept: 0.0, r2: 1.0 },
            layer_io_secs: layer_io,
            n_real,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MachineSpec, ModelSpec};

    #[test]
    fn recovers_a_synthetic_line() {
        let p = PipelineProfiler::with_points(vec![100, 200, 400, 800]);
        // gpu_time = 2ms + 10us/token; layer_io = 10ms
        let fit = p.profile(|n| 0.002 + 1e-5 * n as f64, 0.010);
        assert!((fit.line.slope - 1e-5).abs() < 1e-8);
        assert!((fit.line.intercept - 0.002).abs() < 1e-6);
        // n_real: (0.010 - 0.002) / 1e-5 = 800
        assert!((fit.n_real as i64 - 800).abs() <= 1);
    }

    #[test]
    fn noisy_measurements_use_median() {
        let mut call = 0usize;
        let p = PipelineProfiler::with_points(vec![100, 1000]);
        let fit = p.profile(
            |n| {
                call += 1;
                let noise = if call % 3 == 0 { 0.05 } else { 0.0 }; // outlier
                1e-5 * n as f64 + noise
            },
            0.02,
        );
        // median kills the single outlier per point
        assert!((fit.line.slope - 1e-5).abs() < 2e-6, "slope={}", fit.line.slope);
    }

    #[test]
    fn degenerate_fit_falls_back() {
        let p = PipelineProfiler::with_points(vec![10, 20, 30]);
        let fit = p.profile(|_| 0.001, 0.5); // flat: slope 0
        assert_eq!(fit.n_real, 30);
    }

    #[test]
    fn analytic_matches_eq2_magnitude() {
        // Paper (§5.1): Mixtral-8x7B on A40 at nominal 32 GB/s needs
        // ~19.2k tokens to saturate GPU compute; the per-layer profile
        // gives the same number (both sides divide by n_layers).
        let fit = PipelineProfiler::analytic(
            &MachineSpec::nominal(crate::config::GpuSpec::a40()),
            &ModelSpec::mixtral_8x7b(),
        );
        let expect = 19_200.0;
        let rel = (fit.n_real as f64 - expect).abs() / expect;
        assert!(rel < 0.25, "n_real={} (expected ~19.2k)", fit.n_real);
    }

    #[test]
    fn paper_testbed_n_real_is_lower_at_measured_bandwidth() {
        // At the measured 19.5 GB/s the threshold shrinks proportionally.
        let nominal = PipelineProfiler::analytic(
            &MachineSpec::nominal(crate::config::GpuSpec::a40()),
            &ModelSpec::mixtral_8x7b(),
        );
        let measured = PipelineProfiler::analytic(
            &MachineSpec::paper_testbed(),
            &ModelSpec::mixtral_8x7b(),
        );
        assert!(measured.n_real > nominal.n_real, "slower link => larger n_real");
    }
}
