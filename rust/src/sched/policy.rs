//! Pluggable scheduling policies: SLO-aware admission control and
//! preemption victim selection.
//!
//! The paper's thesis (§6.2–§6.3) is that throughput limits come from
//! resource-aware scheduling; under *overload* the binding resource is
//! the request queue itself. FIFO admission lets the queue grow without
//! bound, so every request eventually blows through its deadline and
//! goodput collapses. [`AdmissionPolicy::Slo`] sheds requests whose
//! remaining deadline slack cannot cover their predicted service time
//! (from the same analytic cost model the simulator runs on), keeping
//! the admitted set feasible and goodput pinned near the hardware limit.
//!
//! [`VictimPolicy`] generalizes §6.2's newest-first preemption: the
//! weighted variant victimizes the decoding sequence with the most
//! deadline slack net of its replay cost, which rotates preemption pain
//! across the batch instead of starving the newest sequences
//! (MoE-Lightning-style request-latency fairness, arXiv:2411.11217).

use crate::config::{MachineSpec, ModelSpec};
use crate::model::{Request, Sequence};
use crate::util::cast::usize_f64;

/// Safety margin applied to the predicted service time before admitting
/// against a deadline. The analytic estimate ignores memory-controller
/// contention (§8.2, bounded by `simhw::CONTENTION_KAPPA` = 25%) and
/// prefill pass quantization; admitting at exactly zero predicted slack
/// would let every steady-state admission finish *just* past its
/// deadline.
pub const DEFAULT_SLO_HEADROOM: f64 = 1.15;

/// Virtual deadline offset for deadline-free sequences in the weighted
/// victim score: they are treated as `deadline = arrival + PATIENCE`.
/// Large enough (~31 years) that any real deadline sorts as more urgent,
/// small enough that f64 keeps sub-microsecond resolution when run-clock
/// seconds are subtracted — the *relative* slack between two patient
/// sequences (who has been delayed more, who is closer to finishing)
/// still drives rotation.
pub const NO_DEADLINE_PATIENCE: f64 = 1e9;

/// How the Prefill Scheduler treats the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum AdmissionPolicy {
    /// Admit strictly in arrival order and never shed — PR-1 behavior.
    #[default]
    Fifo,
    /// Deadline-aware: at every planning step, drop queued requests whose
    /// deadline cannot cover `headroom ×` their predicted remaining
    /// service time. Requests without a deadline are never shed.
    Slo {
        /// Multiplier on the predicted service time (≥ 1.0); see
        /// [`DEFAULT_SLO_HEADROOM`].
        headroom: f64,
    },
}

impl AdmissionPolicy {
    /// The SLO policy with the default safety headroom.
    pub fn slo() -> Self {
        AdmissionPolicy::Slo { headroom: DEFAULT_SLO_HEADROOM }
    }

    /// Parse a CLI name (`fifo` | `slo`).
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "slo" => Some(AdmissionPolicy::slo()),
            _ => None,
        }
    }
}

/// How the Decode Scheduler picks preemption victims (§6.2's preemption
/// mode evicts until the surviving working set fits the KV cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VictimPolicy {
    /// Evict the most recently admitted sequence (largest id) — PR-1
    /// behavior. Under sustained cache pressure the newest sequences are
    /// starved: each re-prefill re-enters the decode set still newest,
    /// so the same sequences absorb every eviction.
    #[default]
    Newest,
    /// Evict the sequence with the highest deadline slack net of its
    /// re-prefill cost. Progress feeds back into the score (a sequence
    /// closer to finishing has less predicted work left, hence more
    /// slack), so victims rotate across the batch and the
    /// preemption-induced latency tail collapses. Sequences without
    /// deadlines fall back to cheapest-replay, youngest-first.
    Weighted,
}

impl VictimPolicy {
    /// Parse a CLI name (`newest` | `weighted`).
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "newest" => Some(VictimPolicy::Newest),
            "weighted" => Some(VictimPolicy::Weighted),
            _ => None,
        }
    }
}

/// Linear per-request service-time estimate used by the SLO admission
/// and weighted victim policies. Derived from the same constants the
/// HRM/Stage-2 cost model runs on: a pass moves the full weight set
/// (δ seconds) and processes up to `n_real` tokens, so prefill costs
/// `δ / n_real` per token and each generated token costs one δ-long
/// decode iteration.
///
/// The default (all zeros) predicts instant service: SLO admission then
/// sheds only requests whose deadline has already passed — the right
/// conservative default for the real engine, whose wall-clock pass times
/// are not known until profiled.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ServiceModel {
    /// Predicted seconds per prefill (prompt) token.
    pub prefill_secs_per_token: f64,
    /// Predicted seconds per decode iteration (one generated token).
    pub decode_secs_per_iter: f64,
}

impl ServiceModel {
    pub fn new(prefill_secs_per_token: f64, decode_secs_per_iter: f64) -> Self {
        ServiceModel { prefill_secs_per_token, decode_secs_per_iter }
    }

    /// The zero model: every request predicted to finish instantly.
    pub fn instant() -> Self {
        ServiceModel::default()
    }

    /// From a full weight-sweep time δ and the pipeline token budget
    /// (`n_real`) — the §6.3 identity the simulator's clock runs on.
    pub fn from_costs(delta_secs: f64, token_budget: usize) -> Self {
        ServiceModel {
            prefill_secs_per_token: delta_secs / usize_f64(token_budget.max(1)),
            decode_secs_per_iter: delta_secs,
        }
    }

    /// Analytic estimate from hardware constants (Eq. 2's `n_real` and
    /// the weight-sweep δ).
    pub fn analytic(machine: &MachineSpec, model: &ModelSpec) -> Self {
        let delta = machine.transfer_secs(model.model_bytes());
        let fit = super::PipelineProfiler::analytic(machine, model);
        ServiceModel::from_costs(delta, fit.n_real)
    }

    /// Predicted service time for a fresh (unstarted) request.
    pub fn predicted_service(&self, req: &Request) -> f64 {
        usize_f64(req.prompt.len()) * self.prefill_secs_per_token
            + usize_f64(req.max_gen) * self.decode_secs_per_iter
    }

    /// Predicted time to finish a live sequence from its current state:
    /// remaining (re-)prefill plus remaining decode iterations.
    pub fn predicted_remaining(&self, seq: &Sequence) -> f64 {
        usize_f64(seq.pending_prefill()) * self.prefill_secs_per_token
            + usize_f64(seq.remaining_gen()) * self.decode_secs_per_iter
    }

    /// Predicted cost of replaying a sequence's full context after a
    /// preemption (the §6.2 re-prefill).
    pub fn replay_cost(&self, seq: &Sequence) -> f64 {
        usize_f64(seq.full_prompt_len()) * self.prefill_secs_per_token
    }

    /// Fraction of a decode iteration the replayed prefill would occupy —
    /// the CPU-side occupancy proxy the §8.2 memory-controller contention
    /// model stretches IO by. A replay that fits well inside one weight
    /// sweep barely contends; a replay as long as the sweep itself
    /// saturates the controller (capped at 1.0, like
    /// `simhw::LaneCosts::io_contended`).
    pub fn replay_occupancy(&self, seq: &Sequence) -> f64 {
        if self.decode_secs_per_iter <= 0.0 {
            return 0.0;
        }
        (usize_f64(seq.full_prompt_len()) * self.prefill_secs_per_token
            / self.decode_secs_per_iter)
            .min(1.0)
    }

    /// [`Self::replay_cost`] stretched by the §8.2 memory-controller IO
    /// contention the re-prefill itself induces: the replay's weight
    /// traffic shares the controller with its own attention reads, so its
    /// effective cost is `replay_cost × (1 + κ·occupancy)` with the same
    /// `simhw::CONTENTION_KAPPA` the simulator's pass clock uses. This is
    /// the price the weighted victim policy and crash-replay re-routing
    /// charge — an uncontended estimate systematically undercharges long
    /// contexts and picks them as cheap victims when they are not.
    pub fn replay_cost_contended(&self, seq: &Sequence) -> f64 {
        self.replay_cost(seq)
            * (1.0 + crate::simhw::CONTENTION_KAPPA * self.replay_occupancy(seq))
    }
}

/// Online EWMA of *observed* engine pass times → a [`ServiceModel`]
/// (ROADMAP: "measured engine service model"). The real engine cannot
/// know its wall-clock pass costs until it runs, so its SLO admission
/// shipped with the instant default (sheds only already-expired
/// requests). Feeding each completed pass into this estimator gives the
/// admission and weighted-victim policies the same kind of measured
/// estimate the simulator derives analytically:
///
/// * `decode_secs_per_iter` ← EWMA of the duration of decode-bearing
///   passes (a pass is one decode iteration for every active sequence —
///   the engine analog of the simulator's full weight-sweep δ);
/// * `prefill_secs_per_token` ← EWMA of `duration / total_tokens` (the
///   marginal per-token pipeline cost, the analog of δ / n_real).
#[derive(Debug, Clone, Copy)]
pub struct ServiceEstimator {
    /// EWMA smoothing factor in (0, 1]; higher = more reactive.
    alpha: f64,
    /// EWMA of decode-bearing pass durations (seconds).
    decode_iter: Option<f64>,
    /// EWMA of per-token pass cost (seconds / token).
    per_token: Option<f64>,
}

impl ServiceEstimator {
    /// Default smoothing: ~last 8 passes dominate the estimate.
    pub const DEFAULT_ALPHA: f64 = 0.25;

    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        ServiceEstimator { alpha, decode_iter: None, per_token: None }
    }

    fn fold(alpha: f64, acc: &mut Option<f64>, sample: f64) {
        *acc = Some(match *acc {
            None => sample,
            Some(prev) => prev + alpha * (sample - prev),
        });
    }

    /// Feed one completed pass. Zero-duration or empty passes (shed-only
    /// bookkeeping records) carry no timing signal and are ignored.
    pub fn observe(&mut self, prefill_tokens: usize, decode_tokens: usize, duration: f64) {
        let total = prefill_tokens + decode_tokens;
        if total == 0 || !(duration > 0.0) {
            return;
        }
        Self::fold(self.alpha, &mut self.per_token, duration / usize_f64(total));
        if decode_tokens > 0 {
            Self::fold(self.alpha, &mut self.decode_iter, duration);
        }
    }

    /// The measured model, once at least one timed pass was observed.
    /// Before any decode-bearing pass, decode cost falls back to the
    /// per-token EWMA — a deliberate *under*-estimate (a decode iteration
    /// sweeps the full weight set, a prefill token shares it): during
    /// startup it errs toward admitting (FIFO-like) instead of letting a
    /// single long prefill pass masquerade as the per-iteration decode
    /// cost and spuriously shed whole generation budgets.
    pub fn model(&self) -> Option<ServiceModel> {
        let per_token = self.per_token?;
        let decode = self.decode_iter.unwrap_or(per_token);
        Some(ServiceModel {
            prefill_secs_per_token: per_token,
            decode_secs_per_iter: decode,
        })
    }
}

impl Default for ServiceEstimator {
    fn default() -> Self {
        ServiceEstimator::new(Self::DEFAULT_ALPHA)
    }
}

/// Why the scheduler removed a request without finishing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// Shed before any work was done: the deadline could never be met.
    Rejected,
    /// Dropped after it had started (partial prefill or a preemption
    /// replay): the remaining slack no longer covers the remaining work.
    Expired,
}

impl DropReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            DropReason::Rejected => "rejected",
            DropReason::Expired => "expired",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        assert_eq!(AdmissionPolicy::parse("fifo"), Some(AdmissionPolicy::Fifo));
        assert_eq!(
            AdmissionPolicy::parse("slo"),
            Some(AdmissionPolicy::Slo { headroom: DEFAULT_SLO_HEADROOM })
        );
        assert_eq!(AdmissionPolicy::parse("nope"), None);
        assert_eq!(VictimPolicy::parse("newest"), Some(VictimPolicy::Newest));
        assert_eq!(VictimPolicy::parse("weighted"), Some(VictimPolicy::Weighted));
        assert_eq!(VictimPolicy::parse(""), None);
    }

    #[test]
    fn defaults_are_pr1_policies() {
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Fifo);
        assert_eq!(VictimPolicy::default(), VictimPolicy::Newest);
        assert_eq!(ServiceModel::default(), ServiceModel::instant());
    }

    #[test]
    fn service_prediction_math() {
        let m = ServiceModel::from_costs(5.0, 1000);
        let req = Request::new(1, vec![7; 200], 32);
        // prefill: 200 * 5ms = 1 s; decode: 32 * 5 s = 160 s.
        let p = m.predicted_service(&req);
        assert!((p - 161.0).abs() < 1e-9, "{p}");

        let mut seq = Sequence::new(req);
        assert!((m.predicted_remaining(&seq) - 161.0).abs() < 1e-9);
        // Half-prefilled: 100 tokens left, still 32 decodes.
        seq.prefilled = 100;
        assert!((m.predicted_remaining(&seq) - 160.5).abs() < 1e-9);
        // 10 tokens generated: replay covers prompt + generated.
        for _ in 0..10 {
            seq.push_generated(1);
        }
        assert!((m.predicted_remaining(&seq) - (110.0 * 0.005 + 22.0 * 5.0)).abs() < 1e-9);
        assert!((m.replay_cost(&seq) - 210.0 * 0.005).abs() < 1e-9);
    }

    #[test]
    fn contended_replay_stretches_long_contexts_superlinearly() {
        // δ = 5 s over 1000 tokens → 5 ms/token prefill, 5 s/iter decode.
        let m = ServiceModel::from_costs(5.0, 1000);
        let short = Sequence::new(Request::new(1, vec![1; 100], 8));
        let long = Sequence::new(Request::new(2, vec![1; 800], 8));
        // Occupancy: 100 tokens replay in 0.5 s of a 5 s sweep → 0.1;
        // 800 tokens → 0.8. Neither caps.
        assert!((m.replay_occupancy(&short) - 0.1).abs() < 1e-12);
        assert!((m.replay_occupancy(&long) - 0.8).abs() < 1e-12);
        // Contended = uncontended × (1 + κ·occupancy).
        let kappa = crate::simhw::CONTENTION_KAPPA;
        assert!(
            (m.replay_cost_contended(&short) - 0.5 * (1.0 + kappa * 0.1)).abs() < 1e-12
        );
        assert!(
            (m.replay_cost_contended(&long) - 4.0 * (1.0 + kappa * 0.8)).abs() < 1e-12
        );
        // The stretch is superlinear in context length: the long context
        // pays a strictly larger *ratio* over its uncontended cost.
        let r_short = m.replay_cost_contended(&short) / m.replay_cost(&short);
        let r_long = m.replay_cost_contended(&long) / m.replay_cost(&long);
        assert!(r_long > r_short);
        // Occupancy saturates at one full sweep.
        let huge = Sequence::new(Request::new(3, vec![1; 5000], 8));
        assert_eq!(m.replay_occupancy(&huge), 1.0);
        // A zero decode model (instant service) never divides by zero.
        assert_eq!(ServiceModel::instant().replay_occupancy(&huge), 0.0);
        assert_eq!(ServiceModel::instant().replay_cost_contended(&huge), 0.0);
    }

    #[test]
    fn instant_model_predicts_zero() {
        let m = ServiceModel::instant();
        let req = Request::new(1, vec![1; 50], 10);
        assert_eq!(m.predicted_service(&req), 0.0);
        assert_eq!(m.predicted_remaining(&Sequence::new(req)), 0.0);
    }

    #[test]
    fn estimator_converges_on_steady_pass_times() {
        let mut e = ServiceEstimator::default();
        assert!(e.model().is_none(), "no observations yet");
        // Shed-only / empty passes carry no signal.
        e.observe(0, 0, 0.5);
        e.observe(10, 0, 0.0);
        assert!(e.model().is_none());
        // Steady mixed passes: 100 tokens in 0.2 s.
        for _ in 0..64 {
            e.observe(60, 40, 0.2);
        }
        let m = e.model().unwrap();
        assert!((m.decode_secs_per_iter - 0.2).abs() < 1e-9, "{m:?}");
        assert!((m.prefill_secs_per_token - 0.002).abs() < 1e-9, "{m:?}");
    }

    #[test]
    fn estimator_tracks_a_level_shift() {
        let mut e = ServiceEstimator::new(0.5);
        for _ in 0..32 {
            e.observe(0, 50, 1.0);
        }
        for _ in 0..32 {
            e.observe(0, 50, 3.0);
        }
        let m = e.model().unwrap();
        assert!((m.decode_secs_per_iter - 3.0).abs() < 1e-6, "{m:?}");
    }

    #[test]
    fn estimator_prefill_only_runs_fall_back_for_decode() {
        // Before any decode-bearing pass, decode cost falls back to the
        // per-token EWMA — better than predicting instant service, but an
        // under-estimate by design so startup never sheds a request on
        // the strength of one long prefill pass.
        let mut e = ServiceEstimator::default();
        e.observe(100, 0, 0.4);
        let m = e.model().unwrap();
        assert!((m.decode_secs_per_iter - 0.004).abs() < 1e-12);
        assert!((m.prefill_secs_per_token - 0.004).abs() < 1e-12);
        // The first decode-bearing pass replaces the fallback.
        e.observe(0, 50, 1.0);
        assert_eq!(e.model().unwrap().decode_secs_per_iter, 1.0);
    }

    #[test]
    #[should_panic(expected = "alpha must be in (0, 1]")]
    fn estimator_rejects_bad_alpha() {
        ServiceEstimator::new(0.0);
    }

    #[test]
    fn analytic_model_matches_profiler_constants() {
        let machine = MachineSpec::paper_testbed();
        let model = ModelSpec::mixtral_8x7b();
        let m = ServiceModel::analytic(&machine, &model);
        let delta = machine.transfer_secs(model.model_bytes());
        assert!((m.decode_secs_per_iter - delta).abs() < 1e-12);
        assert!(m.prefill_secs_per_token > 0.0);
        assert!(m.prefill_secs_per_token < m.decode_secs_per_iter);
    }
}
