//! Prefill + Decode schedulers and their two interaction modes (§6.2).

use std::collections::{BTreeMap, VecDeque};

use super::policy::{AdmissionPolicy, DropReason, ServiceModel, VictimPolicy};
use crate::kvcache::{PagedLayout, SeqId};
use crate::model::{Request, SeqPhase, Sequence};
use crate::util::cast::usize_f64;

/// Scheduler tuning.
#[derive(Debug, Clone, Copy)]
pub struct SchedConfig {
    /// Pipeline token budget per pass — the profiler's `n_real` (§6.3):
    /// scheduling more tokens than this over-commits GPU compute.
    pub token_budget: usize,
    /// Max prefill tokens per sequence per pass (the engine's compiled
    /// bucket bounds a chunk; the simulator uses larger chunks).
    pub max_chunk: usize,
    /// Only admit a sequence when its *whole* remaining prompt fits this
    /// pass. The real engine requires this: the packed flash-attention
    /// kernel sees one bucket, so a chunk continued next pass could not
    /// attend to its own earlier tokens. The simulator (no numerics)
    /// chunks freely.
    pub atomic_prefill: bool,
    /// Queue admission policy (default FIFO — PR-1 behavior).
    pub admission: AdmissionPolicy,
    /// Preemption victim policy (default newest-first — PR-1 behavior).
    pub victim: VictimPolicy,
    /// Service-time estimates backing the SLO admission and weighted
    /// victim policies (default: instant — policies degrade gracefully).
    pub service: ServiceModel,
}

impl SchedConfig {
    pub fn new(token_budget: usize, max_chunk: usize) -> Self {
        SchedConfig {
            token_budget,
            max_chunk,
            atomic_prefill: false,
            admission: AdmissionPolicy::default(),
            victim: VictimPolicy::default(),
            service: ServiceModel::default(),
        }
    }

    pub fn atomic(mut self) -> Self {
        self.atomic_prefill = true;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }

    pub fn with_victim(mut self, victim: VictimPolicy) -> Self {
        self.victim = victim;
        self
    }

    pub fn with_service(mut self, service: ServiceModel) -> Self {
        self.service = service;
        self
    }
}

/// Mode the §6.2 state machine ended the pass planning in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    Normal,
    Preemption,
}

/// One prefill chunk scheduled this pass.
#[derive(Debug, Clone, Copy)]
pub struct PrefillChunk {
    pub id: SeqId,
    /// First logical token position of the chunk.
    pub start: usize,
    pub len: usize,
    /// Whether this chunk completes the sequence's (re-)prefill, i.e. its
    /// last row yields the sequence's next generated token.
    pub completes: bool,
}

/// One pass's work, with KV slots already reserved in the layout.
#[derive(Debug, Clone, Default)]
pub struct PassPlan {
    /// Decode: (sequence, KV position of the token being fed).
    pub decode: Vec<(SeqId, usize)>,
    pub prefill: Vec<PrefillChunk>,
    pub preempted: Vec<SeqId>,
    /// Requests the SLO admission policy shed while planning this pass
    /// (their KV blocks are already released). Empty under FIFO.
    pub dropped: Vec<(SeqId, DropReason)>,
    pub mode: Option<SchedMode>,
}

impl PassPlan {
    pub fn decode_tokens(&self) -> usize {
        self.decode.len()
    }

    pub fn prefill_tokens(&self) -> usize {
        self.prefill.iter().map(|c| c.len).sum()
    }

    pub fn total_tokens(&self) -> usize {
        self.decode_tokens() + self.prefill_tokens()
    }

    pub fn is_empty(&self) -> bool {
        self.decode.is_empty() && self.prefill.is_empty()
    }

    /// The per-layer activated-expert sets of this pass under `router`'s
    /// routing trace: the union over every scheduled token row (decode
    /// rows feed one position each, prefill chunks a position range).
    pub fn routed(&self, router: &crate::workload::ExpertRouter) -> crate::workload::PassRouting {
        let decode = self.decode.iter().copied();
        let prefill = self
            .prefill
            .iter()
            .flat_map(|c| (c.start..c.start + c.len).map(move |pos| (c.id, pos)));
        router.route_rows(decode.chain(prefill))
    }
}

/// The combined Prefill + Decode scheduler.
pub struct Scheduler {
    pub cfg: SchedConfig,
    /// Prefill Scheduler: waiting (incl. preempted) sequences, FIFO with
    /// preempted sequences at the front (they are "older").
    queue: VecDeque<Sequence>,
    /// Decode Scheduler: active sequences, keyed by id; iteration order is
    /// id order, which is admission order (oldest first).
    decoding: BTreeMap<SeqId, Sequence>,
    finished: Vec<Sequence>,
    preemptions: usize,
    /// Requests shed before any work (SLO admission).
    rejected: usize,
    /// Requests dropped after starting (slack ran out mid-flight).
    expired: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Self {
        assert!(cfg.token_budget >= 1 && cfg.max_chunk >= 1);
        Scheduler {
            cfg,
            queue: VecDeque::new(),
            decoding: BTreeMap::new(),
            finished: Vec::new(),
            preemptions: 0,
            rejected: 0,
            expired: 0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.submit_at(req, 0.0);
    }

    /// Enqueue a request arriving at run-clock time `now` (the weighted
    /// victim policy tie-breaks on arrival age).
    pub fn submit_at(&mut self, req: Request, now: f64) {
        self.queue.push_back(Sequence::new_at(req, now));
    }

    pub fn submit_all(&mut self, reqs: impl IntoIterator<Item = Request>) {
        for r in reqs {
            self.submit(r);
        }
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn active_decode(&self) -> usize {
        self.decoding.len()
    }

    pub fn finished(&self) -> &[Sequence] {
        &self.finished
    }

    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    pub fn total_preemptions(&self) -> usize {
        self.preemptions
    }

    /// Requests shed by SLO admission before any work was done.
    pub fn total_rejected(&self) -> usize {
        self.rejected
    }

    /// Requests dropped after starting (deadline slack ran out).
    pub fn total_expired(&self) -> usize {
        self.expired
    }

    pub fn is_done(&self) -> bool {
        self.queue.is_empty() && self.decoding.is_empty()
    }

    /// Plan one pass at run-clock time 0 — closed-batch entry point.
    pub fn plan(&mut self, kv: &mut PagedLayout) -> PassPlan {
        self.plan_at(kv, 0.0)
    }

    /// Plan one pass at run-clock time `now`. Reserves KV slots in `kv`
    /// for everything scheduled; releases the blocks of preempted and
    /// SLO-shed sequences.
    pub fn plan_at(&mut self, kv: &mut PagedLayout, now: f64) -> PassPlan {
        let mut plan = PassPlan::default();

        // --- SLO admission: shed queued requests whose deadline cannot
        // cover their predicted remaining service, releasing any blocks
        // held by partial prefills before the decode feasibility check.
        if let AdmissionPolicy::Slo { headroom } = self.cfg.admission {
            self.shed_infeasible(kv, now, headroom, &mut plan);
        }

        // --- Decode Scheduler: estimate blocks for all active sequences,
        // preempt (victim policy; newest first by default) until the rest
        // fit.
        let mut mode = SchedMode::Normal;
        loop {
            let need: usize = self
                .decoding
                .keys()
                .map(|&id| {
                    let t = kv.table(id);
                    kv.layout().blocks_for(t.len + 1) - t.blocks.len()
                })
                .sum();
            if need <= kv.free_blocks() {
                break;
            }
            mode = SchedMode::Preemption;
            let victim = self.select_victim(now, kv);
            let Some(mut seq) = self.decoding.remove(&victim) else {
                panic!("victim {victim} not in the decode set")
            };
            kv.release(victim);
            seq.preempt();
            self.preemptions += 1;
            plan.preempted.push(victim);
            // Preempted sequences go to the *front* of the prefill queue:
            // they are older than anything still waiting.
            self.queue.push_front(seq);
        }

        // Schedule every surviving decode sequence (oldest first).
        for (&id, _) in self.decoding.iter() {
            let Some(pos) = kv.grow(id, 1) else {
                panic!("decode grow failed after pre-checked block estimate (seq {id})")
            };
            plan.decode.push((id, pos));
        }

        // --- Prefill Scheduler: fill the remaining pipeline budget, but
        // only in normal mode (§6.2: preemption halts new admissions; the
        // preempted sequences themselves still re-prefill — that is what
        // hides the preemption cost).
        let budget = self.cfg.token_budget.saturating_sub(plan.decode.len());
        let admit_new = mode == SchedMode::Normal;
        loop {
            self.admit(kv, budget, admit_new, &mut plan);
            if !plan.is_empty() || self.queue.is_empty() || !self.decoding.is_empty() {
                break;
            }
            // Anti-livelock: nothing is decoding, nothing could be
            // admitted, yet sequences wait — queued partial prefills must
            // be hoarding the blocks. Evict the *youngest* block-holding
            // one (its prefill restarts later) and retry.
            let holder = (0..self.queue.len())
                .rev()
                .find(|&i| kv.len(self.queue[i].id()) > 0);
            match holder {
                Some(i) => {
                    let seq = &mut self.queue[i];
                    kv.release(seq.id());
                    seq.preempt();
                    self.preemptions += 1;
                    plan.preempted.push(seq.id());
                }
                None => panic!(
                    "prefill chunk cannot fit in an empty KV cache: \
                     max_chunk {} vs capacity {} tokens — misconfigured layout",
                    self.cfg.max_chunk,
                    kv.layout().capacity_tokens()
                ),
            }
        }

        plan.mode = Some(if plan.preempted.is_empty() { SchedMode::Normal } else { SchedMode::Preemption });
        plan
    }

    /// Pick the decode sequence to evict in preemption mode.
    fn select_victim(&self, now: f64, kv: &PagedLayout) -> SeqId {
        match self.cfg.victim {
            // Newest = largest id (ids are assigned in admission order).
            VictimPolicy::Newest => {
                let Some(&id) = self.decoding.keys().next_back() else {
                    panic!("select_victim on an empty decode set")
                };
                id
            }
            // Highest deadline slack net of replay cost. A sequence that
            // progresses on schedule keeps constant slack (the clock and
            // its remaining work shrink together); one that was delayed
            // or preempted loses slack and is protected next time, so
            // victims rotate and preemption delay is equalized instead of
            // concentrated on the newest sequences. Deadline-free
            // sequences score against a virtual `arrival + PATIENCE`
            // deadline: they always evict before deadline-carrying ones,
            // and the same slack feedback rotates within them. Ties fall
            // to youngest (largest arrival, then largest id), which
            // reduces to newest-first for identical closed-batch
            // sequences.
            //
            // Block-boundary credit: eviction reclaims *whole* KV blocks,
            // so a sequence one token past a boundary frees nearly a full
            // spare block beyond its token count. The replay charge is
            // scaled by the victim's block-fill fraction (tokens held /
            // slots reclaimed): paying the same replay for more reclaimed
            // capacity is a better trade, so low-fill sequences score
            // higher. Equal-length candidates keep identical scores, so
            // the slack/tie-break behavior above is unchanged for uniform
            // batches.
            VictimPolicy::Weighted => {
                let service = self.cfg.service;
                let block = kv.layout().block_size;
                let mut best_key = (f64::NEG_INFINITY, f64::NEG_INFINITY, 0);
                let mut best_id: Option<SeqId> = None;
                for (&id, seq) in self.decoding.iter() {
                    let deadline = seq
                        .req
                        .deadline
                        .unwrap_or(seq.arrival + super::policy::NO_DEADLINE_PATIENCE);
                    let fill = if kv.contains(id) {
                        let t = kv.table(id);
                        let slots = (t.blocks.len() * block).max(1);
                        (usize_f64(t.len) / usize_f64(slots)).min(1.0)
                    } else {
                        1.0
                    };
                    // The replay charge is the *contended* re-prefill
                    // cost (§8.2: the replay's weight traffic shares the
                    // memory controller with its own attention reads), so
                    // long contexts pay a superlinear penalty and are
                    // protected relative to the uncontended estimate.
                    let score = deadline
                        - now
                        - service.predicted_remaining(seq)
                        - service.replay_cost_contended(seq) * fill;
                    let key = (score, seq.arrival, id);
                    if best_id.is_none() || key > best_key {
                        best_key = key;
                        best_id = Some(id);
                    }
                }
                let Some(id) = best_id else {
                    panic!("select_victim on an empty decode set")
                };
                id
            }
        }
    }

    /// The SLO admission sweep: drop every queued sequence whose deadline
    /// cannot cover `headroom ×` its predicted remaining service time,
    /// releasing any KV blocks it held. Never-started requests count as
    /// rejected; partially served ones (chunked prefill in flight or a
    /// preemption replay) as expired.
    fn shed_infeasible(
        &mut self,
        kv: &mut PagedLayout,
        now: f64,
        headroom: f64,
        plan: &mut PassPlan,
    ) {
        let service = self.cfg.service;
        let mut kept: VecDeque<Sequence> = VecDeque::with_capacity(self.queue.len());
        while let Some(seq) = self.queue.pop_front() {
            let infeasible = seq
                .req
                .deadline
                .is_some_and(|d| now + headroom * service.predicted_remaining(&seq) > d);
            if !infeasible {
                kept.push_back(seq);
                continue;
            }
            if kv.contains(seq.id()) {
                kv.release(seq.id());
            }
            let reason =
                if seq.started() { DropReason::Expired } else { DropReason::Rejected };
            match reason {
                DropReason::Rejected => self.rejected += 1,
                DropReason::Expired => self.expired += 1,
            }
            plan.dropped.push((seq.id(), reason));
        }
        self.queue = kept;
    }

    /// One admission sweep of the Prefill Scheduler (FIFO, chunked).
    fn admit(
        &mut self,
        kv: &mut PagedLayout,
        mut budget: usize,
        admit_new: bool,
        plan: &mut PassPlan,
    ) {
        let mut requeue: VecDeque<Sequence> = VecDeque::new();
        while budget > 0 {
            let Some(mut seq) = self.queue.pop_front() else { break };
            let is_repreempt = seq.preemptions > 0;
            if !admit_new && !is_repreempt {
                requeue.push_front(seq);
                break; // FIFO: nothing behind a blocked head may jump it
            }
            let chunk = seq.pending_prefill().min(self.cfg.max_chunk).min(budget);
            // Always-on: a zero chunk here means a done sequence sat in the
            // prefill queue — scheduling it would spin the pass loop forever.
            assert!(chunk > 0);
            if self.cfg.atomic_prefill && chunk < seq.pending_prefill() {
                assert!(
                    seq.pending_prefill() <= self.cfg.max_chunk,
                    "sequence {}: prompt+generated ({}) exceeds the compiled \
                     bucket ({}) — atomic prefill cannot ever schedule it",
                    seq.id(),
                    seq.pending_prefill(),
                    self.cfg.max_chunk
                );
                // Not enough budget left this pass; keep FIFO order.
                requeue.push_front(seq);
                break;
            }
            if !kv.contains(seq.id()) {
                kv.register(seq.id());
            }
            match kv.grow(seq.id(), chunk) {
                Some(start) => {
                    seq.phase = SeqPhase::Prefilling;
                    let completes = seq.prefilled + chunk == seq.full_prompt_len();
                    plan.prefill.push(PrefillChunk { id: seq.id(), start, len: chunk, completes });
                    seq.prefilled += chunk;
                    budget -= chunk;
                    if completes {
                        // Hand off to the Decode Scheduler after the pass;
                        // park in `decoding` now so ids keep age order.
                        seq.phase = SeqPhase::Decoding;
                        self.decoding.insert(seq.id(), seq);
                    } else {
                        // Partially prefilled: back to the queue front. The
                        // loop pops it right back up, so the head sequence
                        // keeps chunking until the pass budget or its
                        // prompt is exhausted. (The seed `break`-ed here —
                        // correct only when the chunk was capped by the
                        // budget; a `max_chunk`-capped chunk stranded the
                        // rest of the pass budget, under-filling `n_real`
                        // whenever max_chunk < token_budget.)
                        self.queue.push_front(seq);
                    }
                }
                None => {
                    // No blocks: grow is atomic (nothing to roll back);
                    // drop an empty registration, requeue, stop admitting.
                    if kv.contains(seq.id()) && kv.len(seq.id()) == 0 {
                        kv.release(seq.id());
                    }
                    requeue.push_front(seq);
                    break;
                }
            }
        }
        while let Some(s) = requeue.pop_front() {
            self.queue.push_front(s);
        }
    }

    /// Apply pass results: `tokens` holds (seq, generated token) for every
    /// decode row and every completing prefill chunk. Finished sequences'
    /// blocks are released (the Decode Scheduler's GC). Returns the ids of
    /// the sequences that finished this pass, in token order — the online
    /// serving loop stamps completion timestamps from these.
    pub fn complete(
        &mut self,
        tokens: &[(SeqId, i32)],
        kv: &mut PagedLayout,
    ) -> Vec<SeqId> {
        let mut newly_finished = Vec::new();
        for &(id, tok) in tokens {
            let Some(seq) = self.decoding.get_mut(&id) else {
                panic!("token for unknown sequence {id}")
            };
            if seq.push_generated(tok) {
                let Some(seq) = self.decoding.remove(&id) else {
                    panic!("finished sequence {id} vanished from the decode set")
                };
                kv.release(id);
                self.finished.push(seq);
                newly_finished.push(id);
            }
        }
        newly_finished
    }

    /// Look up a live sequence (decode set or queue) — engine helper for
    /// assembling token batches.
    pub fn sequence(&self, id: SeqId) -> Option<&Sequence> {
        self.decoding
            .get(&id)
            .or_else(|| self.queue.iter().find(|s| s.id() == id))
    }

    // --- Snapshot/commit planning (the engine's double-buffered pass
    // pipeline). A speculative clone of the planner-visible state lets a
    // host worker plan pass N+1 while pass N executes; the engine commits
    // the clone back iff pass N completed exactly as predicted (budget
    // finishes only — an EOS finish, a shed, or a new arrival invalidates
    // the speculation and falls back to synchronous replanning).

    /// Clone the planner-visible state: queue, decode set, and the policy
    /// counters. The finished archive stays behind — it is irrelevant to
    /// planning and commits must never clobber real generated tokens with
    /// speculative placeholders.
    pub fn speculate(&self) -> Scheduler {
        Scheduler {
            cfg: self.cfg,
            queue: self.queue.clone(),
            decoding: self.decoding.clone(),
            finished: Vec::new(),
            preemptions: self.preemptions,
            rejected: self.rejected,
            expired: self.expired,
        }
    }

    /// Install a speculative successor produced by [`speculate`] +
    /// [`complete_speculative`] + [`plan_at`], keeping the real finished
    /// archive. The caller guarantees the prediction was validated (the
    /// actual finished set matched) and every placeholder token was
    /// patched with the real value first.
    ///
    /// [`speculate`]: Self::speculate
    /// [`complete_speculative`]: Self::complete_speculative
    /// [`plan_at`]: Self::plan_at
    pub fn commit(&mut self, next: Scheduler) {
        // Always-on: once per committed pass; dropping a speculative finish
        // here would silently lose a completed request from the archive.
        assert!(next.finished.is_empty(), "speculative finishes are discarded");
        self.queue = next.queue;
        self.decoding = next.decoding;
        self.preemptions = next.preemptions;
        self.rejected = next.rejected;
        self.expired = next.expired;
    }

    /// Speculative twin of [`complete`](Self::complete): apply the
    /// *expected* yields of the pass currently executing, with placeholder
    /// token values (0) and budget-only termination. EOS finishes cannot
    /// be predicted before the LM head runs — when one fires, the actual
    /// finished set diverges from the returned prediction and the caller
    /// discards the speculation.
    ///
    /// Returns `(finished, placeholders)`: the predicted finished ids
    /// (sorted) and, for every *surviving* yielder, the `(id, generated
    /// index, logical token position)` of the placeholder the caller must
    /// patch with the real token at commit time.
    pub fn complete_speculative(
        &mut self,
        yields: &[SeqId],
        kv: &mut PagedLayout,
    ) -> (Vec<SeqId>, Vec<(SeqId, usize, usize)>) {
        let mut finished = Vec::new();
        let mut placeholders = Vec::new();
        for &id in yields {
            let Some(seq) = self.decoding.get_mut(&id) else {
                panic!("yield for unknown sequence {id}")
            };
            let gen_idx = seq.generated.len();
            let logical_pos = seq.req.prompt.len() + gen_idx;
            seq.generated.push(0);
            if seq.generated.len() >= seq.req.max_gen {
                let Some(mut seq) = self.decoding.remove(&id) else {
                    panic!("finished sequence {id} vanished from the decode set")
                };
                seq.phase = SeqPhase::Finished;
                kv.release(id);
                finished.push(id);
            } else {
                placeholders.push((id, gen_idx, logical_pos));
            }
        }
        finished.sort_unstable();
        (finished, placeholders)
    }

    /// Tear down a replica's live working set after a crash or forced
    /// shutdown: removes every queued and decoding sequence (queue order
    /// first, then the decode set in id order), releases their KV blocks,
    /// and resets started ones to a replayable state via
    /// [`Sequence::preempt`] — their re-prefill elsewhere is priced
    /// exactly like a preemption-victim replay. Leaves the scheduler
    /// drained (`is_done()`), so a degraded shutdown does not trip the
    /// undrained-scheduler guard in the serving loops. Finished sequences
    /// and drop counters stay behind; the per-policy preemption counter
    /// is *not* bumped (a crash is not a scheduling decision).
    pub fn extract_live(&mut self, kv: &mut PagedLayout) -> Vec<Sequence> {
        let mut out = Vec::with_capacity(self.queue.len() + self.decoding.len());
        while let Some(mut seq) = self.queue.pop_front() {
            if kv.contains(seq.id()) {
                kv.release(seq.id());
            }
            if seq.started() {
                seq.preempt();
            }
            out.push(seq);
        }
        while let Some((id, mut seq)) = self.decoding.pop_first() {
            kv.release(id);
            seq.preempt();
            out.push(seq);
        }
        out
    }

    /// Re-enqueue a sequence extracted from another scheduler (crash
    /// re-route). Joins the back of the prefill queue; a preempted
    /// sequence keeps its replay state, so admission treats it like a
    /// local preemption victim (it may re-prefill even in preemption
    /// mode).
    pub fn resubmit(&mut self, seq: Sequence) {
        self.queue.push_back(seq);
    }

    /// Total predicted seconds of work live in this scheduler (queue +
    /// decode set) under `service` — the backlog estimate deadline-aware
    /// cluster routing ranks replicas by.
    pub fn live_predicted_secs(&self, service: &ServiceModel) -> f64 {
        self.queue
            .iter()
            .chain(self.decoding.values())
            .map(|s| service.predicted_remaining(s))
            .sum()
    }

    /// Replace a placeholder generated token (see
    /// [`complete_speculative`](Self::complete_speculative)) with the real
    /// value, wherever the sequence now lives (decode set, or the queue if
    /// the speculative plan preempted it).
    pub fn patch_generated(&mut self, id: SeqId, gen_idx: usize, token: i32) {
        let seq = self
            .decoding
            .get_mut(&id)
            .or_else(|| self.queue.iter_mut().find(|s| s.id() == id))
            .unwrap_or_else(|| panic!("placeholder patch for dead sequence {id}"));
        // Always-on: patching a non-placeholder overwrites a real token.
        assert_eq!(seq.generated[gen_idx], 0, "patch site must be a placeholder");
        seq.generated[gen_idx] = token;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvLayout;
    use crate::util::prop;

    fn sched(budget: usize, chunk: usize) -> Scheduler {
        Scheduler::new(SchedConfig::new(budget, chunk))
    }

    fn kv(block: usize, n: usize) -> PagedLayout {
        PagedLayout::new(KvLayout::new(block, n))
    }

    fn run_all(s: &mut Scheduler, kv: &mut PagedLayout, tok: i32) -> (usize, usize) {
        // Drive to completion with a constant generated token; returns
        // (passes, max total tokens in any pass).
        let mut passes = 0;
        let mut max_tokens = 0;
        while !s.is_done() {
            let plan = s.plan(kv);
            assert!(!plan.is_empty() || !s.is_done(), "livelock");
            max_tokens = max_tokens.max(plan.total_tokens());
            let mut toks = Vec::new();
            for &(id, _) in &plan.decode {
                toks.push((id, tok));
            }
            for c in plan.prefill.iter().filter(|c| c.completes) {
                toks.push((c.id, tok));
            }
            s.complete(&toks, kv);
            passes += 1;
            assert!(passes < 100_000, "runaway");
        }
        (passes, max_tokens)
    }

    #[test]
    fn single_sequence_lifecycle() {
        let mut s = sched(64, 64);
        let mut layout = kv(4, 64);
        s.submit(Request::new(0, vec![1, 2, 3], 4));
        // pass 1: prefill completes, yields first token
        let plan = s.plan(&mut layout);
        assert_eq!(plan.prefill.len(), 1);
        assert!(plan.prefill[0].completes);
        assert_eq!(plan.decode.len(), 0);
        s.complete(&[(0, 9)], &mut layout);
        // passes 2..4: decode
        for step in 0..3 {
            let plan = s.plan(&mut layout);
            assert_eq!(plan.decode.len(), 1, "step {step}");
            assert_eq!(plan.decode[0].1, 3 + step); // KV grows by one
            s.complete(&[(0, 9)], &mut layout);
        }
        assert!(s.is_done());
        assert_eq!(s.finished()[0].generated, vec![9, 9, 9, 9]);
        assert_eq!(layout.used_blocks(), 0, "GC must release blocks");
    }

    #[test]
    fn prefill_decode_overlap_in_steady_state() {
        let mut s = sched(8, 8);
        let mut layout = kv(4, 1000);
        for i in 0..20 {
            s.submit(Request::new(i, vec![1; 4], 8));
        }
        // after a few passes both lanes are active at once
        let mut saw_overlap = false;
        for _ in 0..10 {
            let plan = s.plan(&mut layout);
            if plan.decode_tokens() > 0 && plan.prefill_tokens() > 0 {
                saw_overlap = true;
            }
            assert!(plan.total_tokens() <= 8, "token budget respected");
            let mut toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 1)).collect();
            toks.extend(plan.prefill.iter().filter(|c| c.completes).map(|c| (c.id, 1)));
            s.complete(&toks, &mut layout);
        }
        assert!(saw_overlap, "prefill and decode must co-schedule");
    }

    #[test]
    fn chunked_prefill_spans_passes() {
        let mut s = sched(4, 4);
        let mut layout = kv(4, 100);
        s.submit(Request::new(0, vec![7; 10], 2));
        let p1 = s.plan(&mut layout);
        assert_eq!(p1.prefill[0].len, 4);
        assert!(!p1.prefill[0].completes);
        s.complete(&[], &mut layout);
        let p2 = s.plan(&mut layout);
        assert_eq!(p2.prefill[0].start, 4);
        assert!(!p2.prefill[0].completes);
        s.complete(&[], &mut layout);
        let p3 = s.plan(&mut layout);
        assert_eq!(p3.prefill[0].len, 2);
        assert!(p3.prefill[0].completes);
    }

    #[test]
    fn preemption_mode_evicts_newest_and_requeues() {
        let mut s = sched(100, 100);
        // Tight cache: 6 blocks of 4 slots = 24 token slots.
        let mut layout = kv(4, 6);
        // Two sequences, prompts of 8 -> 2 blocks each; gen long enough to
        // overflow.
        s.submit(Request::new(0, vec![1; 8], 32));
        s.submit(Request::new(1, vec![1; 8], 32));
        let p = s.plan(&mut layout);
        assert_eq!(p.prefill_tokens(), 16); // both admitted (4 blocks)
        s.complete(&[(0, 5), (1, 5)], &mut layout);
        // decode grows each seq: 8->9 needs a 3rd block each; 2 free: fine
        let mut preempted_seen = false;
        for _ in 0..30 {
            let plan = s.plan(&mut layout);
            if !plan.preempted.is_empty() {
                preempted_seen = true;
                // newest (id 1) is the victim
                assert_eq!(plan.preempted, vec![1]);
                assert_eq!(plan.mode, Some(SchedMode::Preemption));
                break;
            }
            let mut toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 5)).collect();
            toks.extend(plan.prefill.iter().filter(|c| c.completes).map(|c| (c.id, 5)));
            s.complete(&toks, &mut layout);
        }
        assert!(preempted_seen, "tight cache must trigger preemption");
        layout.check_invariants();
    }

    #[test]
    fn everything_finishes_even_under_thrashing() {
        let mut s = sched(16, 16);
        let mut layout = kv(2, 10); // 20 token slots, very tight
        for i in 0..6 {
            s.submit(Request::new(i, vec![2; 3], 5));
        }
        let (passes, max_tokens) = run_all(&mut s, &mut layout, 3);
        assert_eq!(s.finished().len(), 6);
        assert!(max_tokens <= 16);
        assert!(passes > 3);
        assert_eq!(layout.used_blocks(), 0);
        for f in s.finished() {
            assert_eq!(f.generated.len(), 5);
        }
    }

    #[test]
    fn eos_finishes_early() {
        let mut s = sched(32, 32);
        let mut layout = kv(4, 32);
        s.submit(Request::new(0, vec![1, 2], 100).with_eos(0));
        let plan = s.plan(&mut layout);
        assert!(plan.prefill[0].completes);
        s.complete(&[(0, 0)], &mut layout); // EOS immediately
        assert!(s.is_done());
        assert_eq!(s.finished()[0].generated, vec![0]);
    }

    #[test]
    fn head_sequence_chunks_fill_the_pass_budget() {
        // Non-atomic mode with max_chunk < token_budget: the seed stopped
        // after one chunk of the head sequence ("budget exhausted for it
        // this pass anyway"), stranding budget whenever the chunk was
        // capped by max_chunk instead. The head must keep chunking.
        let mut s = sched(10, 4);
        let mut layout = kv(4, 100);
        s.submit(Request::new(0, vec![7; 10], 2));
        let p1 = s.plan(&mut layout);
        assert_eq!(p1.prefill_tokens(), 10, "whole prompt fits the budget");
        let lens: Vec<usize> = p1.prefill.iter().map(|c| c.len).collect();
        assert_eq!(lens, vec![4, 4, 2]);
        assert_eq!(p1.prefill[0].start, 0);
        assert_eq!(p1.prefill[1].start, 4);
        assert_eq!(p1.prefill[2].start, 8);
        assert!(p1.prefill[2].completes && !p1.prefill[0].completes);
        s.complete(&[(0, 1)], &mut layout);
        assert_eq!(s.active_decode(), 1);
    }

    #[test]
    fn budget_left_after_head_flows_to_next_sequence() {
        let mut s = sched(10, 4);
        let mut layout = kv(4, 100);
        s.submit(Request::new(0, vec![7; 6], 2));
        s.submit(Request::new(1, vec![7; 6], 2));
        let p1 = s.plan(&mut layout);
        // Head chunks 4 + 2 (completes), then the next sequence gets the
        // remaining 4 budget tokens.
        assert_eq!(p1.prefill_tokens(), 10);
        let per_seq: Vec<(SeqId, usize)> =
            p1.prefill.iter().map(|c| (c.id, c.len)).collect();
        assert_eq!(per_seq, vec![(0, 4), (0, 2), (1, 4)]);
    }

    #[test]
    fn slo_admission_sheds_infeasible_requests() {
        let cfg = SchedConfig::new(100, 100)
            .with_admission(AdmissionPolicy::Slo { headroom: 1.0 })
            .with_service(ServiceModel::from_costs(1.0, 10));
        let mut s = Scheduler::new(cfg);
        let mut layout = kv(4, 64);
        // Predicted service: 5 * 0.1 + 2 * 1.0 = 2.5 s.
        s.submit(Request::new(0, vec![1; 5], 2).with_deadline(2.0)); // hopeless
        s.submit(Request::new(1, vec![1; 5], 2).with_deadline(10.0)); // fine
        s.submit(Request::new(2, vec![1; 5], 2)); // no deadline: never shed
        let plan = s.plan_at(&mut layout, 0.0);
        assert_eq!(plan.dropped, vec![(0, DropReason::Rejected)]);
        assert_eq!(s.total_rejected(), 1);
        assert_eq!(s.total_expired(), 0);
        assert_eq!(plan.prefill.len(), 2, "survivors admitted this pass");
        run_all(&mut s, &mut layout, 1);
        assert_eq!(s.finished().len(), 2);
        assert_eq!(layout.used_blocks(), 0);
    }

    #[test]
    fn slo_admission_expires_started_sequences_and_releases_blocks() {
        let cfg = SchedConfig::new(4, 4)
            .with_admission(AdmissionPolicy::Slo { headroom: 1.0 })
            .with_service(ServiceModel::from_costs(1.0, 10));
        let mut s = Scheduler::new(cfg);
        let mut layout = kv(4, 64);
        s.submit(Request::new(0, vec![1; 8], 1).with_deadline(100.0));
        let p1 = s.plan_at(&mut layout, 0.0);
        assert_eq!(p1.prefill_tokens(), 4, "partial prefill in flight");
        assert!(layout.used_blocks() > 0);
        s.complete(&[], &mut layout);
        // The clock jumps past the last instant the deadline is coverable.
        let p2 = s.plan_at(&mut layout, 1000.0);
        assert_eq!(p2.dropped, vec![(0, DropReason::Expired)]);
        assert!(p2.is_empty());
        assert_eq!(s.total_expired(), 1);
        assert!(s.is_done());
        assert_eq!(layout.used_blocks(), 0, "shed partial prefill must release blocks");
    }

    #[test]
    fn fifo_admission_never_sheds_even_with_deadlines() {
        let mut s = sched(100, 100);
        let mut layout = kv(4, 64);
        s.submit(Request::new(0, vec![1; 5], 2).with_deadline(0.0));
        let plan = s.plan_at(&mut layout, 1e9);
        assert!(plan.dropped.is_empty());
        assert_eq!(plan.prefill.len(), 1);
        assert_eq!(s.total_rejected() + s.total_expired(), 0);
    }

    #[test]
    fn weighted_victim_evicts_the_most_slack() {
        let cfg = SchedConfig::new(100, 100)
            .with_victim(VictimPolicy::Weighted)
            .with_service(ServiceModel::from_costs(1.0, 100));
        let mut s = Scheduler::new(cfg);
        let mut layout = kv(4, 6); // 24 token slots: tight
        s.submit(Request::new(0, vec![1; 8], 32).with_deadline(10_000.0)); // loose
        s.submit(Request::new(1, vec![1; 8], 32).with_deadline(50.0)); // tight
        let p = s.plan(&mut layout);
        assert_eq!(p.prefill_tokens(), 16);
        s.complete(&[(0, 5), (1, 5)], &mut layout);
        for _ in 0..30 {
            let plan = s.plan(&mut layout);
            if !plan.preempted.is_empty() {
                // Newest-first would evict id 1; weighted protects the
                // tight deadline and evicts the loose sequence instead.
                assert_eq!(plan.preempted, vec![0]);
                return;
            }
            let toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 5)).collect();
            s.complete(&toks, &mut layout);
        }
        panic!("tight cache must trigger preemption");
    }

    #[test]
    fn weighted_victim_without_deadlines_matches_newest_first() {
        // No deadlines and equal arrivals: the weighted tie-break (largest
        // arrival, then largest id) reduces to newest-first, keeping the
        // default behavior reachable from the weighted policy.
        let cfg = SchedConfig::new(100, 100)
            .with_victim(VictimPolicy::Weighted)
            .with_service(ServiceModel::from_costs(1.0, 100));
        let mut s = Scheduler::new(cfg);
        let mut layout = kv(4, 6);
        s.submit(Request::new(0, vec![1; 8], 32));
        s.submit(Request::new(1, vec![1; 8], 32));
        s.plan(&mut layout);
        s.complete(&[(0, 5), (1, 5)], &mut layout);
        for _ in 0..30 {
            let plan = s.plan(&mut layout);
            if !plan.preempted.is_empty() {
                assert_eq!(plan.preempted, vec![1], "newest id is the victim");
                return;
            }
            let toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 5)).collect();
            s.complete(&toks, &mut layout);
        }
        panic!("tight cache must trigger preemption");
    }

    #[test]
    fn weighted_victim_prefers_block_boundary_crossers() {
        // Two sequences with identical deadlines/arrivals/remaining work;
        // seq 1 sits exactly on a block boundary (fill 1.0), seq 0 is one
        // token past one (low fill: replaying it reclaims almost a full
        // spare block "for free"). Without the block credit the linear
        // score would evict seq 1 (one fewer replay token); the credit
        // must flip the choice to seq 0, whose eviction reclaims more
        // slots per replayed token.
        let cfg = SchedConfig::new(100, 100)
            .with_victim(VictimPolicy::Weighted)
            .with_service(ServiceModel::from_costs(1.0, 100));
        let mut s = Scheduler::new(cfg);
        let mut layout = kv(8, 5); // 40 token slots
        s.submit(Request::new(0, vec![1; 9], 32)); // 9 tokens -> 2 blocks, fill 9/16
        s.submit(Request::new(1, vec![1; 8], 32)); // 8 tokens -> 1 block,  fill 8/8
        let p = s.plan(&mut layout);
        assert_eq!(p.prefill_tokens(), 17);
        s.complete(&[(0, 5), (1, 5)], &mut layout);
        // Decode grows both: 10 tokens (2 blocks) + 9 tokens (2 blocks).
        // 5-block cache -> next growth preempts.
        for _ in 0..30 {
            let plan = s.plan(&mut layout);
            if !plan.preempted.is_empty() {
                assert_eq!(
                    plan.preempted[0], 0,
                    "low-fill sequence frees more slots per replayed token"
                );
                layout.check_invariants();
                return;
            }
            let toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 5)).collect();
            s.complete(&toks, &mut layout);
        }
        panic!("tight cache must trigger preemption");
    }

    #[test]
    fn contended_replay_flips_the_victim_at_equal_slack() {
        // Two decoding sequences engineered so their *uncontended*
        // weighted-victim scores tie exactly (all quantities dyadic, so
        // f64 arithmetic is exact): service from_costs(1.0, 16) gives
        // prefill 0.0625 s/token; at victim time both have generated one
        // token, so seq 0 (prompt 4, full context 5) carries replay
        // penalty 5·0.0625 = 0.3125 and seq 1 (prompt 12, full context
        // 13) carries 13·0.0625 = 0.8125, both at fill 1.0; the deadlines
        // differ by exactly the penalty gap (0.5), and remaining work is
        // identical. Under the old uncontended pricing the scores tie and
        // the tie-break (largest id) evicts seq 1. The §8.2 contention
        // stretch is superlinear in context length — occupancy 5/16 vs
        // 13/16 — so the contended penalties (0.33691… vs 0.97754…) break
        // the tie the *other* way: the long context is protected and
        // seq 0 is the victim.
        let service = ServiceModel::from_costs(1.0, 16);
        let cfg = SchedConfig::new(100, 100)
            .with_victim(VictimPolicy::Weighted)
            .with_service(service);
        let mut s = Scheduler::new(cfg);
        let mut layout = kv(4, 4); // 16 slots: exactly the two prompts
        s.submit(Request::new(0, vec![1; 4], 32).with_deadline(100.0));
        s.submit(Request::new(1, vec![1; 12], 32).with_deadline(100.5));
        let p = s.plan(&mut layout);
        assert_eq!(p.prefill_tokens(), 16);
        s.complete(&[(0, 5), (1, 5)], &mut layout);
        // Check the tie really is exact under uncontended pricing, and
        // really is broken under contended pricing.
        let (s0, s1) = (s.sequence(0).unwrap(), s.sequence(1).unwrap());
        let unc0 = 100.0 - service.predicted_remaining(s0) - service.replay_cost(s0);
        let unc1 = 100.5 - service.predicted_remaining(s1) - service.replay_cost(s1);
        assert_eq!(unc0.to_bits(), unc1.to_bits(), "uncontended scores must tie exactly");
        assert!(service.replay_cost_contended(s1) - service.replay_cost(s1)
            > service.replay_cost_contended(s0) - service.replay_cost(s0));
        // First decode growth needs one new block per sequence with zero
        // free: preemption fires immediately.
        let plan = s.plan(&mut layout);
        assert_eq!(plan.mode, Some(SchedMode::Preemption));
        assert_eq!(
            plan.preempted[0], 0,
            "contended replay pricing must protect the long context"
        );
        layout.check_invariants();
    }

    #[test]
    fn extract_live_drains_everything_and_releases_blocks() {
        let mut s = sched(8, 4);
        let mut layout = kv(4, 100);
        s.submit(Request::new(0, vec![1; 4], 8)); // will be decoding
        s.submit(Request::new(1, vec![1; 10], 8)); // partial prefill
        s.submit(Request::new(2, vec![1; 4], 8)); // untouched in queue
        let p = s.plan(&mut layout);
        assert_eq!(p.prefill_tokens(), 8);
        s.complete(&[(0, 5)], &mut layout);
        assert_eq!(s.active_decode(), 1);
        assert!(layout.used_blocks() > 0);

        let live = s.extract_live(&mut layout);
        // Queue order first (1 partial, 2 untouched), then the decode set.
        let ids: Vec<SeqId> = live.iter().map(|q| q.id()).collect();
        assert_eq!(ids, vec![1, 2, 0]);
        assert!(s.is_done(), "extraction must leave the scheduler drained");
        assert_eq!(layout.used_blocks(), 0, "extraction must release all blocks");
        // Started sequences are reset to replayable state; the untouched
        // one is not marked preempted (it would spuriously jump admission
        // gates at the destination).
        let by_id =
            |id: SeqId| live.iter().find(|q| q.id() == id).unwrap();
        assert_eq!(by_id(1).prefilled, 0);
        assert!(by_id(1).preemptions > 0);
        assert_eq!(by_id(2).preemptions, 0);
        assert!(by_id(0).preemptions > 0);
        assert_eq!(by_id(0).generated, vec![5], "generated tokens survive the crash");
        assert_eq!(by_id(0).pending_prefill(), 5, "replay covers prompt + generated");

        // A resubmitted sequence finishes normally on another scheduler.
        let mut dst = sched(64, 64);
        let mut dst_kv = kv(4, 100);
        for seq in live {
            dst.resubmit(seq);
        }
        run_all(&mut dst, &mut dst_kv, 9);
        assert_eq!(dst.finished().len(), 3);
        let f0 = dst.finished().iter().find(|q| q.id() == 0).unwrap();
        assert_eq!(f0.generated.len(), 8, "replayed sequence completes its budget");
        assert_eq!(f0.generated[0], 5, "pre-crash tokens are preserved, not regenerated");
    }

    #[test]
    fn live_predicted_secs_sums_queue_and_decode_backlog() {
        let service = ServiceModel::from_costs(1.0, 10); // 0.1/token, 1.0/iter
        let mut s = sched(4, 4);
        let mut layout = kv(4, 100);
        assert_eq!(s.live_predicted_secs(&service), 0.0);
        s.submit(Request::new(0, vec![1; 4], 2));
        s.submit(Request::new(1, vec![1; 4], 3));
        // Queued: (4·0.1 + 2) + (4·0.1 + 3) = 5.8.
        assert!((s.live_predicted_secs(&service) - 5.8).abs() < 1e-12);
        let p = s.plan(&mut layout);
        assert_eq!(p.prefill_tokens(), 4, "budget admits only the head");
        s.complete(&[(0, 7)], &mut layout);
        // Seq 0 decoding (1 generated: 0.1 replay-prefill debt + 1 iter),
        // seq 1 still queued.
        assert!((s.live_predicted_secs(&service) - (1.1 + 3.4)).abs() < 1e-12);
    }

    #[test]
    fn speculative_complete_matches_real_complete_on_budget_finishes() {
        let mut s = sched(64, 64);
        let mut layout = kv(4, 64);
        s.submit(Request::new(0, vec![1; 3], 1)); // finishes on first token
        s.submit(Request::new(1, vec![1; 3], 4)); // survives
        let plan = s.plan(&mut layout);
        let yields: Vec<SeqId> =
            plan.prefill.iter().filter(|c| c.completes).map(|c| c.id).collect();
        assert_eq!(yields, vec![0, 1]);

        let mut spec = s.speculate();
        let mut spec_kv = layout.clone();
        let (pred_finished, placeholders) =
            spec.complete_speculative(&yields, &mut spec_kv);
        assert_eq!(pred_finished, vec![0]);
        assert_eq!(placeholders, vec![(1, 0, 3)]);

        // Real completion with the same yields agrees.
        let mut actual = s.complete(&[(0, 7), (1, 9)], &mut layout);
        actual.sort_unstable();
        assert_eq!(actual, pred_finished);
        assert_eq!(spec_kv.used_blocks(), layout.used_blocks());

        // The clone plans the next pass; patching + committing leaves the
        // real scheduler in the state a synchronous replan would produce.
        let spec_plan = spec.plan_at(&mut spec_kv, 0.0);
        spec.patch_generated(1, 0, 9);
        let real_plan = s.plan_at(&mut layout, 0.0);
        assert_eq!(spec_plan.decode, real_plan.decode);
        assert_eq!(spec_plan.prefill_tokens(), real_plan.prefill_tokens());
        s.commit(spec);
        assert_eq!(s.active_decode(), 1);
        assert_eq!(s.sequence(1).unwrap().generated, vec![9]);
        // Real finished archive survived the commit.
        assert_eq!(s.finished().len(), 1);
        assert_eq!(s.finished()[0].id(), 0);
        assert_eq!(s.finished()[0].generated, vec![7]);
    }

    #[test]
    fn eos_finish_diverges_from_speculative_prediction() {
        let mut s = sched(64, 64);
        let mut layout = kv(4, 64);
        s.submit(Request::new(0, vec![1; 3], 10).with_eos(5));
        let plan = s.plan(&mut layout);
        assert!(plan.prefill[0].completes);
        let mut spec = s.speculate();
        let mut spec_kv = layout.clone();
        let (pred, _) = spec.complete_speculative(&[0], &mut spec_kv);
        assert!(pred.is_empty(), "budget says it survives");
        // The head emits EOS: the actual finished set differs, which is
        // the signal to discard the speculation.
        let actual = s.complete(&[(0, 5)], &mut layout);
        assert_eq!(actual, vec![0]);
        assert_ne!(actual, pred);
    }

    #[test]
    #[should_panic(expected = "placeholder patch for dead sequence")]
    fn patching_a_dead_sequence_panics() {
        let mut s = sched(8, 8);
        s.patch_generated(42, 0, 1);
    }

    #[test]
    fn prop_scheduler_conserves_sequences_and_blocks() {
        prop::check("scheduler_conservation", |rng| {
            let n_req = rng.range(1, 12);
            let mut s = sched(rng.range(4, 32), rng.range(2, 8));
            // Feasibility (the paper's standing assumption): one sequence's
            // full p+g footprint must fit in CPU memory. p+g <= 10 below,
            // so keep capacity (block * n_blocks) >= 12.
            let mut layout = kv(rng.range(1, 5), rng.range(14, 40));
            for i in 0..n_req {
                let p = rng.range(1, 6);
                let g = rng.range(1, 6);
                s.submit(Request::new(i as SeqId, vec![1; p], g));
            }
            let mut guard = 0;
            while !s.is_done() {
                let plan = s.plan(&mut layout);
                layout.check_invariants();
                let mut toks: Vec<_> =
                    plan.decode.iter().map(|&(id, _)| (id, 1)).collect();
                toks.extend(
                    plan.prefill.iter().filter(|c| c.completes).map(|c| (c.id, 1)),
                );
                s.complete(&toks, &mut layout);
                guard += 1;
                assert!(guard < 10_000, "must terminate");
            }
            assert_eq!(s.finished().len(), n_req, "no sequence lost");
            assert_eq!(layout.used_blocks(), 0, "no block leaked");
        });
    }
}
