//! Miniature property-testing driver (no `proptest` offline).
//!
//! Runs a closure over many seeded random cases and, on failure, reports
//! the failing seed so the case can be replayed deterministically:
//! `PROP_SEED=<seed> cargo test <name>`.

use super::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `f` over `cases` seeded RNGs; panic with the failing seed on error.
///
/// `f` should panic (assert!) when the property is violated.
pub fn check<F: FnMut(&mut Rng)>(name: &str, mut f: F) {
    if let Ok(seed) = std::env::var("PROP_SEED") {
        let seed: u64 = seed.parse().expect("PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        f(&mut rng);
        return;
    }
    let cases = default_cases();
    for case in 0..cases {
        // Derive a per-case seed that is stable across runs.
        let seed = 0x5EED_0000_0000 + case;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case} (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("reverse-twice", |rng| {
            let n = rng.range(0, 50);
            let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", |rng| {
                let x = rng.below(100);
                assert!(x > 1000, "x={x} is not > 1000");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "got: {msg}");
        assert!(msg.contains("always-fails"));
    }
}
