//! Small numeric/statistics helpers: least-squares line fit (the pipeline
//! profiler, paper Fig. 7), means, and prediction-accuracy scoring
//! (the paper's "94% accuracy" metric, §8.1).

/// Least-squares fit `y = slope * x + intercept`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LineFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Fit a line to (x, y) samples. Panics on fewer than 2 points.
pub fn line_fit(xs: &[f64], ys: &[f64]) -> LineFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least 2 points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    assert!(sxx > 0.0, "degenerate x values");
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (slope * x + intercept);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    // pallas-lint: allow(float-eq) — degenerate fit: zero variance is a perfect line
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LineFit { slope, intercept, r2 }
}

/// The paper's accuracy metric: `1 - |pred - measured| / measured`,
/// clamped at 0. Averaged over cells it yields the "94% accuracy" claim.
pub fn prediction_accuracy(predicted: f64, measured: f64) -> f64 {
    // pallas-lint: allow(float-eq) — the metric's 0/0 case is defined by exact zeros
    if measured == 0.0 {
        return if predicted == 0.0 { 1.0 } else { 0.0 }; // pallas-lint: allow(float-eq)
    }
    (1.0 - (predicted - measured).abs() / measured).max(0.0)
}

/// q-quantile (q in [0, 1]) over an unsorted slice by *rounded linear
/// rank*: the sample at index `round((n-1)·q)` of the sorted copy — no
/// interpolation, and an even-sized p50 takes the upper of the two middle
/// samples (round-half-up). 0 for an empty slice. Used for the
/// online-serving TTFT/TPOT/e2e percentiles.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN samples"));
    let idx = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    v[idx]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let f = line_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        let ys = [0.1, 0.9, 2.2, 2.8, 4.1];
        let f = line_fit(&xs, &ys);
        assert!(f.r2 > 0.97 && f.r2 < 1.0);
        assert!((f.slope - 1.0).abs() < 0.1);
    }

    #[test]
    fn accuracy_metric() {
        assert!((prediction_accuracy(94.0, 100.0) - 0.94).abs() < 1e-12);
        assert!((prediction_accuracy(106.0, 100.0) - 0.94).abs() < 1e-12);
        assert_eq!(prediction_accuracy(300.0, 100.0), 0.0);
        assert_eq!(prediction_accuracy(0.0, 0.0), 1.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.99), 5.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }
}
