//! Checked numeric conversions for accounting and cost-model code.
//!
//! The lane-accounting bugs fixed in PRs 1–2 (u64 underflow wrapping to a
//! huge value, double-counted lanes) share a root cause: silent `as`
//! conversions that truncate or lose precision without a trace. The
//! `pallas-lint` `unchecked-cast` rule steers accounting code here: every
//! helper either proves the conversion exact or panics loudly at the
//! conversion site instead of corrupting a metric downstream.
//!
//! All helpers are `#[inline]` single-compare guards — cheap enough for
//! per-pass accounting paths (they are deliberately *not* used in
//! per-token kernels).

/// Largest integer magnitude an `f64` represents exactly (2^53).
pub const F64_EXACT_MAX: u64 = 1 << 53;

/// Exact `usize -> f64`. Panics if the value exceeds 2^53 (where `f64`
/// starts rounding integers) — token/pass/block counts never do.
#[inline]
pub fn usize_f64(x: usize) -> f64 {
    assert!(x as u64 <= F64_EXACT_MAX, "usize {x} not exactly representable as f64");
    x as f64
}

/// Exact `u64 -> f64`. Panics above 2^53 — byte capacities up to 8 PiB
/// convert exactly.
#[inline]
pub fn u64_f64(x: u64) -> f64 {
    assert!(x <= F64_EXACT_MAX, "u64 {x} not exactly representable as f64");
    x as f64
}

/// Checked `f64 -> usize` truncation (toward zero, like `as usize`).
/// Panics on NaN, negative values, or magnitudes at/above 2^53 — the
/// regimes where `as` silently produces 0, saturates, or rounds.
#[inline]
pub fn f64_usize(x: f64) -> usize {
    assert!(
        x.is_finite() && x >= 0.0 && x < F64_EXACT_MAX as f64,
        "f64 {x} out of exact usize range"
    );
    x as usize
}

/// Lossless `usize -> u64` (usize is at most 64 bits on every supported
/// target).
#[inline]
pub fn usize_u64(x: usize) -> u64 {
    x as u64
}

/// Checked `u64 -> usize`. Panics if the value exceeds `usize::MAX`
/// (possible on 32-bit targets) instead of truncating.
#[inline]
pub fn u64_usize(x: u64) -> usize {
    usize::try_from(x).unwrap_or_else(|_| panic!("u64 {x} overflows usize"))
}

/// Lossless `u32 -> usize` (every supported target has at least 32-bit
/// pointers). Used for KV block ids, which are `u32` in page tables to
/// halve their memory footprint.
#[inline]
pub fn u32_usize(x: u32) -> usize {
    x as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_roundtrips() {
        assert_eq!(usize_f64(0), 0.0);
        assert_eq!(usize_f64(123_456), 123_456.0);
        assert_eq!(u64_f64(1 << 53), 9_007_199_254_740_992.0);
        assert_eq!(f64_usize(0.0), 0);
        assert_eq!(f64_usize(7.9), 7, "truncates toward zero like `as`");
        assert_eq!(usize_u64(42), 42);
        assert_eq!(u64_usize(42), 42);
    }

    #[test]
    #[should_panic(expected = "not exactly representable")]
    fn huge_u64_to_f64_panics() {
        u64_f64((1 << 53) + 1);
    }

    #[test]
    #[should_panic(expected = "out of exact usize range")]
    fn negative_f64_to_usize_panics() {
        f64_usize(-1.0);
    }

    #[test]
    #[should_panic(expected = "out of exact usize range")]
    fn nan_to_usize_panics() {
        f64_usize(f64::NAN);
    }
}
