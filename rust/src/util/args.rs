//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse an iterator of raw arguments (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), FLAG_SET.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a boolean, got '{v}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_flags() {
        // NB: a bare `--flag` must come last or use `--flag=true`; a
        // following non-flag token is consumed as its value.
        let a = parse("serve extra --model tiny --steps=12 --verbose");
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.usize_or("steps", 0), 12);
        assert!(a.has("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b value");
        assert_eq!(a.get("a"), Some(FLAG_SET));
        assert_eq!(a.get("b"), Some("value"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("x", 2.5), 2.5);
        assert!(!a.bool_or("flag", false));
    }

    #[test]
    fn negative_number_values() {
        let a = parse("--offset=-5");
        assert_eq!(a.f64_or("offset", 0.0), -5.0);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        let a = parse("--n abc");
        a.usize_or("n", 0);
    }
}
