//! BF16 storage helpers (no `half` crate offline).
//!
//! The paper stores the KV cache in BF16 and up-converts to FP32 for the
//! CPU attention computation (§5.3). BF16 is the top 16 bits of an f32, so
//! conversion is a shift; we use round-to-nearest-even on the store path
//! (what JAX's `astype(bfloat16)` does), which the golden vectors encode.

/// Round an f32 to the nearest BF16 (ties to even), returned as raw bits.
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserve sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    let guard = (bits >> 15) & 1; // highest dropped bit
    let sticky = bits & 0x7FFF; // remaining dropped bits
    let lsb = (bits >> 16) & 1; // lsb of the kept mantissa
    let mut hi = (bits >> 16) as u16;
    // Round up when past halfway, or exactly halfway and the kept lsb is
    // odd (ties-to-even).
    if guard == 1 && (sticky != 0 || lsb == 1) {
        hi = hi.wrapping_add(1);
    }
    hi
}

/// Expand BF16 bits to f32 (exact).
#[inline(always)]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round-trip an f32 through BF16 (the KV-cache store+load numerics).
#[inline(always)]
pub fn bf16_round(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

/// Convert a slice in place to BF16-rounded f32 values.
pub fn round_slice(xs: &mut [f32]) {
    for x in xs {
        *x = bf16_round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 65280.0] {
            assert_eq!(bf16_round(x), x, "{x} should be bf16-exact");
        }
    }

    #[test]
    fn rounds_to_nearest() {
        // bf16 has 7 explicit mantissa bits: at exponent 0 the step is 2^-7.
        let step = 1.0078125f32; // 1 + 2^-7: exactly representable
        assert_eq!(bf16_round(step), step);
        // 1 + 2^-8 is exactly halfway between 1.0 and 1 + 2^-7;
        // ties-to-even keeps the even mantissa (1.0).
        assert_eq!(bf16_round(1.00390625), 1.0);
        // just past halfway rounds up
        assert_eq!(bf16_round(1.005859375), step); // 1 + 3*2^-9
        // below halfway rounds down
        assert_eq!(bf16_round(1.001953125), 1.0); // 1 + 2^-9
        // halfway above an odd mantissa rounds *up* to the even one
        assert_eq!(bf16_round(1.01171875), 1.015625); // 1+3*2^-8 -> 1+2^-6
    }

    #[test]
    fn rounding_error_bounded() {
        // relative error of bf16 is <= 2^-8
        let mut x = 0.001f32;
        while x < 1e6 {
            let r = bf16_round(x);
            assert!(((r - x) / x).abs() <= 1.0 / 256.0, "{x} -> {r}");
            x *= 1.7;
        }
    }

    #[test]
    fn negative_symmetry() {
        for i in 0..1000 {
            let x = (i as f32) * 0.137 + 0.01;
            assert_eq!(bf16_round(-x), -bf16_round(x));
        }
    }

    #[test]
    fn matches_jax_semantics_examples() {
        // values checked against jnp.float32(jnp.bfloat16(x))
        assert_eq!(bf16_round(1.000123), 1.0);
        assert_eq!(bf16_round(3.14159265), 3.140625);
        assert_eq!(bf16_round(-2.71828), -2.71875);
    }

    #[test]
    fn special_values() {
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_round(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert_eq!(bf16_round(-0.0).to_bits(), (-0.0f32).to_bits());
    }
}
