//! Substrate utilities replacing unavailable third-party crates
//! (offline environment — see DESIGN.md §3).

pub mod args;
pub mod bench;
pub mod bf16;
pub mod cast;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
