//! Local benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets are `harness = false` binaries that use this
//! module: warmup + timed iterations with median/mean/p10/p90 reporting,
//! plus table/CSV printers so every bench regenerates its paper table or
//! figure series in a uniform format (consumed by EXPERIMENTS.md).

use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wall times.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(mut samples: Vec<Duration>) -> Stats {
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let pick = |q: f64| samples[((n - 1) as f64 * q).round() as usize];
        Stats {
            iters: n,
            mean: total / n as u32,
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Time `f` for roughly `budget` (after `warmup` iterations), at least
/// `min_iters` and at most `max_iters` samples.
pub fn bench<F: FnMut()>(warmup: usize, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < budget || samples.len() < 3) && samples.len() < 10_000 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Stats::from_samples(samples)
}

/// One-shot measurement.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Fixed-width table printer for paper-shaped output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Also emit machine-readable CSV (prefixed so logs stay greppable).
    pub fn print_csv(&self, tag: &str) {
        println!("CSV,{tag},{}", self.headers.join(","));
        for row in &self.rows {
            println!("CSV,{tag},{}", row.join(","));
        }
    }
}

/// Standard bench banner so bench_output.txt is self-describing.
pub fn banner(id: &str, what: &str) {
    println!();
    println!("=== {id}: {what} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let s = bench(1, Duration::from_millis(5), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.p10 <= s.p90);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
        t.print_csv("test");
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_secs(2)).contains("s"));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_nanos(5)).contains("ns"));
    }
}
