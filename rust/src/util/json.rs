//! Minimal JSON parser + writer (no `serde` in the offline crate set).
//!
//! Parses the AOT `manifest.json` / `golden_*.json` files and serializes
//! bench/metric output. Supports the full JSON grammar; numbers are f64
//! (ints round-trip exactly up to 2^53, far above any id we store).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful message — for required manifest keys.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a numeric array into f32s (golden tensors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        let arr = self.as_arr()?;
        arr.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                // pallas-lint: allow(float-eq) — exact integrality test picks the int form
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience: build `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: numeric array.
pub fn nums(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").as_str(), Some("x"));
        assert_eq!(v.req("a").as_arr().unwrap()[2].req("b"), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"a\"b","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn f32_vec_accessor() {
        let v = Json::parse("[1, 2.5, -3e-2]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -0.03]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f32_vec().is_none());
    }

    #[test]
    fn big_int_roundtrip() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_i64(), Some(9007199254740992));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
