//! Seeded pseudo-random number generation (no `rand` crate offline).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! small, statistically solid combination. Deterministic across runs so
//! workload generation, weight init, and property tests are reproducible.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to fill the state; never all-zero.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal sample with the given mean/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential sample with the given rate (mean `1/rate`) — the
    /// inter-arrival gap of a Poisson process.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential rate must be positive");
        // 1 - f64() ∈ (0, 1]: ln is finite, result is ≥ 0.
        -(1.0 - self.f64()).ln() / rate
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let rate = 4.0;
        let xs: Vec<f64> = (0..n).map(|_| r.exponential(rate)).collect();
        assert!(xs.iter().all(|&x| x >= 0.0 && x.is_finite()));
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            let x = r.range(3, 6);
            assert!((3..=6).contains(&x));
            saw_lo |= x == 3;
            saw_hi |= x == 6;
        }
        assert!(saw_lo && saw_hi);
    }
}
