//! MoE-Lens: high-throughput MoE LLM serving under resource constraints.
//!
//! Reproduction of *MoE-Lens: Towards the Hardware Limit of High-Throughput
//! MoE LLM Serving Under Resource Constraints* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas stack. This crate is Layer 3: the
//! coordinator that owns scheduling, the paged KV cache, weight streaming,
//! CPU decode attention, and the PJRT runtime that executes the AOT-lowered
//! Layer-1/2 artifacts. See DESIGN.md for the system inventory.

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod cpuattn;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod simhw;
pub mod transfer;
pub mod util;
pub mod workload;
