//! Seedable, Zipf-parameterized per-layer expert-routing traces.
//!
//! "Towards MoE Deployment" (PAPERS.md) measures heavily Zipf-skewed,
//! temporally stable expert popularity in deployed MoE models. This
//! module attaches such a routing trace to requests *functionally*: the
//! top-k expert set of any `(request, token position, layer)` triple is a
//! pure deterministic function of the routing seed, so the engine, the
//! simulator, and the speculative planner can each evaluate the same
//! trace independently and agree expert-for-expert without shipping
//! per-token tensors around.
//!
//! Popularity is rank-based: rank `r` carries weight `1 / (r+1)^s`
//! (`s = 0` ⇒ uniform), and a per-layer seeded permutation maps ranks to
//! expert ids so the hot experts differ across layers (as observed in
//! practice). [`ExpertRouter::popularity`] exposes the hot→cold order per
//! layer — the pinning policy and the popularity-predicted prefetch both
//! read it.

use std::collections::BTreeSet;

use crate::config::ModelSpec;
use crate::kvcache::SeqId;
use crate::util::rng::Rng;

/// Routing-trace parameters: a Zipf skew exponent and the trace seed.
/// Follows the workload seeding idiom (`seed ^ salt`) so disjoint streams
/// never collide with the batch/arrival generators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoutingSpec {
    /// Zipf skew exponent `s`: rank `r` has weight `1/(r+1)^s`.
    /// `0.0` = uniform routing (every expert equally likely).
    pub zipf_s: f64,
    /// Seed of the routing trace (mixed per token, layer, and request).
    pub seed: u64,
}

/// Salt XORed into the routing seed, after the `0xB417C0DE` (batch) /
/// `0xA881_0B5E` (arrivals) idiom.
pub const ROUTING_SALT: u64 = 0x0E_C5E7_0E_C5E7;

impl RoutingSpec {
    /// Uniform routing with a fixed seed — the identity-preserving
    /// default.
    pub fn uniform() -> RoutingSpec {
        RoutingSpec { zipf_s: 0.0, seed: 0 }
    }

    pub fn zipf(s: f64, seed: u64) -> RoutingSpec {
        assert!(s >= 0.0 && s.is_finite(), "Zipf exponent must be finite and >= 0");
        RoutingSpec { zipf_s: s, seed }
    }

    /// Exact-zero sentinel, like `HostPlanCost::is_zero`: `0.0` is the
    /// constructed "uniform" value, not a computed quantity.
    pub fn is_uniform(&self) -> bool {
        self.zipf_s == 0.0 // pallas-lint: allow(float-eq)
    }
}

/// Normalized Zipf rank weights: `w[r] ∝ 1/(r+1)^s`, summing to 1.
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    assert!(n > 0);
    let mut w: Vec<f64> = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    for x in &mut w {
        *x /= total;
    }
    w
}

/// Per-rank probability that a rank is in some token's top-k draw
/// (sampling without replacement is approximated by k independent draws
/// with rejection, matching [`ExpertRouter::experts_for`] in expectation):
/// `q_r = 1 - (1 - w_r)^k`.
pub fn rank_inclusion_probs(weights: &[f64], top_k: usize) -> Vec<f64> {
    assert!(top_k >= 1 && top_k <= weights.len());
    weights.iter().map(|&w| 1.0 - (1.0 - w).powi(top_k as i32)).collect()
}

/// Per-rank probability that a rank is activated by *at least one* of
/// `n_tokens` tokens in a pass: `a_r = 1 - (1 - q_r)^n`.
pub fn rank_activation_probs(weights: &[f64], top_k: usize, n_tokens: usize) -> Vec<f64> {
    rank_inclusion_probs(weights, top_k)
        .into_iter()
        .map(|q| 1.0 - (1.0 - q).powi(n_tokens.min(i32::MAX as usize) as i32))
        .collect()
}

/// SplitMix64 finalizer (same constants as `util::rng`'s seeding stage) —
/// used to mix the (seed, request, position, layer) coordinates into an
/// independent per-token stream seed.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The deterministic routing oracle for one model + routing spec.
#[derive(Debug, Clone)]
pub struct ExpertRouter {
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
    spec: RoutingSpec,
    /// Cumulative rank weights (inverse-CDF sampling).
    cum: Vec<f64>,
    /// Per-layer rank → expert-id permutation (hot experts differ per
    /// layer). `perm[layer][rank]`.
    perm: Vec<Vec<usize>>,
}

impl ExpertRouter {
    pub fn new(model: &ModelSpec, spec: RoutingSpec) -> ExpertRouter {
        assert!(
            model.top_k >= 1 && model.top_k <= model.n_experts,
            "top_k {} must lie in [1, n_experts={}]",
            model.top_k,
            model.n_experts
        );
        let weights = zipf_weights(model.n_experts, spec.zipf_s);
        let mut cum = Vec::with_capacity(model.n_experts);
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            cum.push(acc);
        }
        // Per-layer rank→expert permutation, seeded off the trace seed so
        // the same spec always maps the same experts hot.
        let perm: Vec<Vec<usize>> = (0..model.n_layers)
            .map(|layer| {
                let mut ids: Vec<usize> = (0..model.n_experts).collect();
                let mut rng = Rng::new(mix64(
                    (spec.seed ^ ROUTING_SALT)
                        .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(layer as u64)),
                ));
                rng.shuffle(&mut ids);
                ids
            })
            .collect();
        ExpertRouter {
            n_layers: model.n_layers,
            n_experts: model.n_experts,
            top_k: model.top_k,
            spec,
            cum,
            perm,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn top_k(&self) -> usize {
        self.top_k
    }

    pub fn spec(&self) -> RoutingSpec {
        self.spec
    }

    /// Expert ids of one layer in hot → cold popularity order (rank 0
    /// first). The pinning policy and the popularity-predicted prefetch
    /// read this.
    pub fn popularity(&self, layer: usize) -> &[usize] {
        &self.perm[layer]
    }

    /// The `n` most popular experts of a layer, as a set — the predicted
    /// activation set used when a transfer must be staged before the
    /// pass's routing is known.
    pub fn predicted(&self, layer: usize, n: usize) -> BTreeSet<usize> {
        self.perm[layer].iter().copied().take(n.min(self.n_experts)).collect()
    }

    /// Expected number of distinct experts a pass of `n_tokens` tokens
    /// activates in one layer (rank-activation model).
    pub fn expected_activated(&self, n_tokens: usize) -> f64 {
        let w = zipf_weights(self.n_experts, self.spec.zipf_s);
        rank_activation_probs(&w, self.top_k, n_tokens).iter().sum()
    }

    /// How many experts to predict for a stage streamed before its pass's
    /// routing is known: the expected activation count, rounded up. Both
    /// the engine and the simulator derive the prediction width through
    /// this so their byte accounting mirrors exactly.
    pub fn predicted_count(&self, n_tokens: usize) -> usize {
        (self.expected_activated(n_tokens.max(1)).ceil() as usize).clamp(1, self.n_experts)
    }

    /// The top-k expert set of one token — sorted, distinct, and a pure
    /// function of `(spec.seed, req, pos, layer)`. Same seed ⇒
    /// bit-identical traces.
    pub fn experts_for(&self, req: SeqId, pos: usize, layer: usize) -> Vec<usize> {
        let stream = mix64(
            (self.spec.seed ^ ROUTING_SALT)
                .wrapping_add(req.wrapping_mul(0xA24B_AED4_963E_E407))
                .wrapping_add((pos as u64).wrapping_mul(0x9FB2_1C65_1E98_DF25))
                .wrapping_add((layer as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)),
        );
        let mut rng = Rng::new(stream);
        let mut picked: Vec<usize> = Vec::with_capacity(self.top_k);
        while picked.len() < self.top_k {
            let u = rng.f64();
            let rank = self.cum.partition_point(|&c| c < u).min(self.n_experts - 1);
            let e = self.perm[layer][rank];
            if !picked.contains(&e) {
                picked.push(e);
            }
        }
        picked.sort_unstable();
        picked
    }

    /// Union the activated expert sets of a pass's token rows, per layer.
    /// `rows` are `(request id, logical token position)` pairs — decode
    /// rows feed one position each; prefill chunks feed a position range.
    pub fn route_rows<I>(&self, rows: I) -> PassRouting
    where
        I: IntoIterator<Item = (SeqId, usize)>,
    {
        let mut per_layer: Vec<BTreeSet<usize>> =
            (0..self.n_layers).map(|_| BTreeSet::new()).collect();
        for (req, pos) in rows {
            for (layer, set) in per_layer.iter_mut().enumerate() {
                set.extend(self.experts_for(req, pos, layer));
            }
        }
        PassRouting { per_layer }
    }
}

/// The activated-expert sets of one pass, per layer — the routing state
/// the engine's speculate/commit snapshot carries and the simulator
/// recomputes on the virtual clock.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PassRouting {
    pub per_layer: Vec<BTreeSet<usize>>,
}

impl PassRouting {
    pub fn is_empty(&self) -> bool {
        self.per_layer.iter().all(|s| s.is_empty())
    }

    /// Activated experts of one layer (empty set past the known layers —
    /// callers treat unknown as "predict").
    pub fn activated(&self, layer: usize) -> Option<&BTreeSet<usize>> {
        self.per_layer.get(layer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(s: f64, seed: u64) -> ExpertRouter {
        ExpertRouter::new(&ModelSpec::mixtral_8x7b(), RoutingSpec::zipf(s, seed))
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let a = router(1.1, 42);
        let b = router(1.1, 42);
        for layer in 0..4 {
            assert_eq!(a.popularity(layer), b.popularity(layer));
            for req in 0..20u64 {
                for pos in 0..8 {
                    assert_eq!(
                        a.experts_for(req, pos, layer),
                        b.experts_for(req, pos, layer),
                        "req {req} pos {pos} layer {layer}"
                    );
                }
            }
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let a = router(1.1, 42);
        let b = router(1.1, 43);
        let differs = (0..50u64).any(|req| {
            (0..8).any(|pos| a.experts_for(req, pos, 0) != b.experts_for(req, pos, 0))
        });
        assert!(differs, "seed must steer the trace");
    }

    #[test]
    fn expert_sets_are_sorted_distinct_topk() {
        let r = router(1.3, 7);
        for req in 0..30u64 {
            for layer in 0..r.n_layers() {
                let e = r.experts_for(req, req as usize % 11, layer);
                assert_eq!(e.len(), r.top_k());
                assert!(e.windows(2).all(|w| w[0] < w[1]), "sorted+distinct: {e:?}");
                assert!(e.iter().all(|&x| x < r.n_experts()));
            }
        }
    }

    #[test]
    fn zipf_skew_concentrates_on_hot_experts() {
        let r = router(1.5, 11);
        let layer = 3;
        let hot = r.popularity(layer)[0];
        let cold = r.popularity(layer)[r.n_experts() - 1];
        let (mut hot_hits, mut cold_hits) = (0usize, 0usize);
        for req in 0..400u64 {
            let e = r.experts_for(req, 0, layer);
            hot_hits += usize::from(e.contains(&hot));
            cold_hits += usize::from(e.contains(&cold));
        }
        assert!(
            hot_hits > 3 * cold_hits.max(1),
            "hot {hot_hits} vs cold {cold_hits}: skew must concentrate mass"
        );
    }

    #[test]
    fn uniform_routing_spreads_mass() {
        let r = router(0.0, 11);
        assert!(r.spec().is_uniform());
        let mut hits = vec![0usize; r.n_experts()];
        for req in 0..800u64 {
            for &e in &r.experts_for(req, 0, 0) {
                hits[e] += 1;
            }
        }
        let (min, max) = (hits.iter().min().unwrap(), hits.iter().max().unwrap());
        assert!(*max < 2 * *min, "uniform spread: {hits:?}");
    }

    #[test]
    fn hot_experts_differ_across_layers() {
        let r = router(1.2, 9);
        let heads: BTreeSet<usize> = (0..r.n_layers()).map(|l| r.popularity(l)[0]).collect();
        assert!(heads.len() > 1, "per-layer permutation must vary the hot expert");
    }

    #[test]
    fn route_rows_unions_per_layer() {
        let r = router(1.2, 5);
        let routing = r.route_rows([(0u64, 0usize), (1, 0), (2, 0)]);
        assert_eq!(routing.per_layer.len(), r.n_layers());
        for layer in 0..r.n_layers() {
            let set = routing.activated(layer).unwrap();
            assert!(set.len() >= r.top_k(), "union of 3 tokens covers >= top_k");
            let mut expect = BTreeSet::new();
            for req in 0..3u64 {
                expect.extend(r.experts_for(req, 0, layer));
            }
            assert_eq!(*set, expect);
        }
        assert!(PassRouting::default().is_empty());
        assert!(!routing.is_empty());
    }

    #[test]
    fn zipf_weight_math() {
        let w = zipf_weights(8, 0.0);
        assert!(w.iter().all(|&x| (x - 0.125).abs() < 1e-12), "uniform weights");
        let w = zipf_weights(8, 1.0);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w[0] > w[7] * 7.9 && w[0] < w[7] * 8.1, "1/r ratio");
        let q = rank_inclusion_probs(&w, 2);
        assert!(q.iter().all(|&x| x > 0.0 && x < 1.0));
        assert!(q[0] > q[7]);
        let a1 = rank_activation_probs(&w, 2, 1);
        let a64 = rank_activation_probs(&w, 2, 64);
        for r in 0..8 {
            assert!((a1[r] - q[r]).abs() < 1e-12, "n=1 activation is inclusion");
            assert!(a64[r] > a1[r], "more tokens activate more");
        }
    }
}
