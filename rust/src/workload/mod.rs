//! Workload generators (§7, Table 3).
//!
//! The real datasets (MTBench, RAG-12000, AIME-2024) are offline-
//! unavailable; the paper's evaluation depends on them only as (prompt
//! length, generation cap) distributions, so each generator draws prompt
//! lengths from a clipped lognormal fitted to the dataset's published
//! (avg, max) and fills prompts with seeded random token ids
//! (DESIGN.md §1).

use crate::config::WorkloadSpec;
use crate::kvcache::SeqId;
use crate::model::Request;
use crate::util::rng::Rng;

/// Generator over one workload family.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub spec: &'static WorkloadSpec,
    /// Generation cap for this run (one of `spec.gen_lengths`).
    pub max_gen: usize,
    /// Vocabulary to draw token ids from.
    pub vocab: usize,
    /// lognormal parameters fitted to (avg, max).
    mu: f64,
    sigma: f64,
}

impl WorkloadGen {
    pub fn new(spec: &'static WorkloadSpec, max_gen: usize, vocab: usize) -> Self {
        assert!(
            spec.gen_lengths.contains(&max_gen) || max_gen > 0,
            "unusual generation cap {max_gen}"
        );
        // Fit: mean = exp(mu + sigma^2/2); put the max at ~3 sigma.
        // sigma from the max/avg ratio keeps the clipped tail small.
        let ratio = spec.max_prefill as f64 / spec.avg_prefill as f64;
        let sigma = (ratio.ln() / 3.0).clamp(0.1, 1.5);
        let mu = (spec.avg_prefill as f64).ln() - sigma * sigma / 2.0;
        WorkloadGen { spec, max_gen, vocab, mu, sigma }
    }

    /// One prompt length: clipped lognormal in [1, max_prefill].
    pub fn prompt_len(&self, rng: &mut Rng) -> usize {
        let l = rng.lognormal(self.mu, self.sigma).round() as usize;
        l.clamp(1, self.spec.max_prefill)
    }

    /// Generate a batch of `k` requests with ids starting at `base_id`.
    pub fn batch(&self, k: usize, base_id: SeqId, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed ^ 0xB417C0DE);
        (0..k)
            .map(|i| {
                let p = self.prompt_len(&mut rng);
                let prompt: Vec<i32> =
                    (0..p).map(|_| rng.range(1, self.vocab - 1) as i32).collect();
                Request::new(base_id + i as SeqId, prompt, self.max_gen)
            })
            .collect()
    }

    /// Average prompt length of the generator (should track `spec.avg`).
    pub fn empirical_avg(&self, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let total: usize = (0..n).map(|_| self.prompt_len(&mut rng)).sum();
        total as f64 / n as f64
    }
}

/// Draw per-request *actual* generation lengths under EOS termination:
/// geometric with mean ~`mean_frac * max_gen`, capped at `max_gen`
/// (models §8.1's EOS mode; the paper reports an extra 5.3x-vs-baseline
/// when enabled).
pub fn eos_gen_len(max_gen: usize, mean_frac: f64, rng: &mut Rng) -> usize {
    assert!((0.0..=1.0).contains(&mean_frac));
    if mean_frac >= 1.0 {
        return max_gen;
    }
    let mean = (max_gen as f64 * mean_frac).max(1.0);
    let p = 1.0 / mean;
    let mut len = 1;
    while len < max_gen && !rng.chance(p) {
        len += 1;
    }
    len
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AIME, MTBENCH, RAG};

    #[test]
    fn mtbench_lengths_track_table3() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        let avg = g.empirical_avg(20_000, 1);
        assert!(
            (avg - 98.0).abs() / 98.0 < 0.15,
            "avg {avg} should be near Table 3's 98"
        );
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let l = g.prompt_len(&mut rng);
            assert!((1..=450).contains(&l));
        }
    }

    #[test]
    fn rag_is_prefill_heavy_and_aime_is_not() {
        let rag = WorkloadGen::new(&RAG, 128, 2048);
        let aime = WorkloadGen::new(&AIME, 512, 2048);
        assert!(rag.empirical_avg(5000, 3) > 5.0 * aime.empirical_avg(5000, 3));
    }

    #[test]
    fn batches_are_deterministic_and_valid() {
        let g = WorkloadGen::new(&MTBENCH, 64, 512);
        let a = g.batch(50, 100, 7);
        let b = g.batch(50, 100, 7);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.id, y.id);
        }
        assert_eq!(a[0].id, 100);
        assert_eq!(a[49].id, 149);
        for r in &a {
            assert!(r.prompt.iter().all(|&t| t >= 1 && (t as usize) < 512));
            assert_eq!(r.max_gen, 64);
        }
    }

    #[test]
    fn eos_mode_shortens_mean_generation() {
        let mut rng = Rng::new(5);
        let n = 5000;
        let total: usize = (0..n).map(|_| eos_gen_len(256, 0.5, &mut rng)).sum();
        let mean = total as f64 / n as f64;
        assert!(mean > 64.0 && mean < 160.0, "mean={mean}");
        assert_eq!(eos_gen_len(256, 1.0, &mut rng), 256);
    }
}
