//! Workload generators (§7, Table 3).
//!
//! The real datasets (MTBench, RAG-12000, AIME-2024) are offline-
//! unavailable; the paper's evaluation depends on them only as (prompt
//! length, generation cap) distributions, so each generator draws prompt
//! lengths from a clipped lognormal fitted to the dataset's published
//! (avg, max) and fills prompts with seeded random token ids
//! (DESIGN.md §1).

use crate::config::WorkloadSpec;
use crate::kvcache::SeqId;
use crate::model::Request;
use crate::util::rng::Rng;

pub mod routing;

// The workload families live in `config`; re-export them here so callers
// generating Table-3 traffic (benches, examples) need only one import.
pub use crate::config::{AIME, MTBENCH, RAG};
pub use routing::{ExpertRouter, PassRouting, RoutingSpec};

/// Generator over one workload family.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub spec: &'static WorkloadSpec,
    /// Generation cap for this run (one of `spec.gen_lengths`).
    pub max_gen: usize,
    /// Vocabulary to draw token ids from.
    pub vocab: usize,
    /// lognormal parameters fitted to (avg, max).
    mu: f64,
    sigma: f64,
}

impl WorkloadGen {
    pub fn new(spec: &'static WorkloadSpec, max_gen: usize, vocab: usize) -> Self {
        // Guard against caps outside the dataset's published range. (The
        // seed predicate `contains(..) || max_gen > 0` was a tautology for
        // every positive cap, so this never fired.)
        let max_published = spec.gen_lengths.iter().copied().max().unwrap_or(0);
        assert!(
            max_gen > 0 && max_gen <= max_published,
            "unusual generation cap {max_gen} for workload '{}' \
             (published caps: {:?})",
            spec.name,
            spec.gen_lengths
        );
        // Fit: mean = exp(mu + sigma^2/2); put the max at ~3 sigma.
        // sigma from the max/avg ratio keeps the clipped tail small.
        let ratio = spec.max_prefill as f64 / spec.avg_prefill as f64;
        let sigma = (ratio.ln() / 3.0).clamp(0.1, 1.5);
        let mu = (spec.avg_prefill as f64).ln() - sigma * sigma / 2.0;
        WorkloadGen { spec, max_gen, vocab, mu, sigma }
    }

    /// One prompt length: clipped lognormal in [1, max_prefill].
    pub fn prompt_len(&self, rng: &mut Rng) -> usize {
        let l = rng.lognormal(self.mu, self.sigma).round() as usize;
        l.clamp(1, self.spec.max_prefill)
    }

    /// Generate a batch of `k` requests with ids starting at `base_id`.
    pub fn batch(&self, k: usize, base_id: SeqId, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed ^ 0xB417C0DE);
        (0..k)
            .map(|i| {
                let p = self.prompt_len(&mut rng);
                let prompt: Vec<i32> =
                    (0..p).map(|_| rng.range(1, self.vocab - 1) as i32).collect();
                Request::new(base_id + i as SeqId, prompt, self.max_gen)
            })
            .collect()
    }

    /// Average prompt length of the generator (should track `spec.avg`).
    pub fn empirical_avg(&self, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let total: usize = (0..n).map(|_| self.prompt_len(&mut rng)).sum();
        total as f64 / n as f64
    }
}

/// An arrival process for online serving: how request timestamps are
/// spaced. Rates are *average requests per second* in every variant, so
/// sweeping `rate` compares like with like across processes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson process: i.i.d. exponential inter-arrival gaps.
    Poisson { rate: f64 },
    /// Bursty arrivals: groups of `size` back-to-back requests, with
    /// exponential gaps between bursts sized so the long-run request rate
    /// is still `rate`.
    Burst { rate: f64, size: usize },
}

impl ArrivalProcess {
    /// Draw `k` arrival timestamps (seconds since run start, ascending).
    pub fn times(&self, k: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(k);
        match *self {
            ArrivalProcess::Poisson { rate } => {
                assert!(rate > 0.0, "arrival rate must be positive");
                let mut t = 0.0;
                for _ in 0..k {
                    t += rng.exponential(rate);
                    out.push(t);
                }
            }
            ArrivalProcess::Burst { rate, size } => {
                assert!(rate > 0.0, "arrival rate must be positive");
                assert!(size >= 1, "burst size must be >= 1");
                let burst_rate = rate / size as f64;
                let mut t = 0.0;
                while out.len() < k {
                    t += rng.exponential(burst_rate);
                    for _ in 0..size.min(k - out.len()) {
                        out.push(t);
                    }
                }
            }
        }
        out
    }
}

/// Sort a timestamp trace ascending and rebase so the first arrival is
/// t = 0 — production logs carry absolute clocks, and an un-rebased
/// offset would make the serving loop idle until it. Panics on
/// non-finite timestamps (CLI callers validate with a friendly error
/// first). Shared by [`WorkloadGen::trace_arrivals`] and the
/// `serve --arrival trace` CLI path so the two cannot drift.
pub fn sort_and_rebase(mut times: Vec<f64>) -> Vec<f64> {
    assert!(
        times.iter().all(|t| t.is_finite()),
        "arrival trace contains a non-finite timestamp"
    );
    times.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN arrival times"));
    if let Some(&t0) = times.first() {
        for t in &mut times {
            *t -= t0;
        }
    }
    times
}

impl WorkloadGen {
    /// Generate `k` requests with arrival timestamps from `process` — the
    /// online-serving companion of [`WorkloadGen::batch`]. Deterministic
    /// in `seed`; request ids ascend in arrival order (the scheduler's
    /// preemption policy treats larger ids as younger).
    pub fn arrivals(
        &self,
        process: &ArrivalProcess,
        k: usize,
        base_id: SeqId,
        seed: u64,
    ) -> Vec<(f64, Request)> {
        let reqs = self.batch(k, base_id, seed);
        let mut rng = Rng::new(seed ^ 0xA881_0B5E);
        process.times(k, &mut rng).into_iter().zip(reqs).collect()
    }

    /// Trace-driven arrivals: pair an explicit timestamp trace (e.g.
    /// replayed from a production log) with generated requests. Timestamps
    /// are sorted ascending and rebased so the first arrival is t = 0 —
    /// production logs carry absolute clocks, and an un-rebased offset
    /// would make the serving loop idle until it. Ids ascend in arrival
    /// order. Non-finite timestamps panic.
    pub fn trace_arrivals(
        &self,
        times: &[f64],
        base_id: SeqId,
        seed: u64,
    ) -> Vec<(f64, Request)> {
        let rebased = sort_and_rebase(times.to_vec());
        let reqs = self.batch(rebased.len(), base_id, seed);
        rebased.into_iter().zip(reqs).collect()
    }
}

/// Partition one arrival stream across `n` replica streams,
/// deterministically in `seed` — the same split is reproducible across
/// the engine, the cluster simulator, and the benches. Requests are
/// assigned in global time order by a seeded uniform draw (a stateless
/// hash-route: no queue feedback, which is exactly what the cluster
/// `Router` seam is for), so each stream stays time-sorted and the union
/// of the streams is the input stream. Panics on non-finite timestamps,
/// like [`sort_and_rebase`].
pub fn split_arrivals(
    arrivals: Vec<(f64, Request)>,
    n: usize,
    seed: u64,
) -> Vec<Vec<(f64, Request)>> {
    assert!(n >= 1, "cannot split across zero replicas");
    assert!(
        arrivals.iter().all(|(t, _)| t.is_finite()),
        "arrival trace contains a non-finite timestamp"
    );
    let mut sorted = arrivals;
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("non-NaN arrival times"));
    let mut rng = Rng::new(seed ^ 0x5711_7A11);
    let mut streams: Vec<Vec<(f64, Request)>> = (0..n).map(|_| Vec::new()).collect();
    for (t, r) in sorted {
        let slot = usize::try_from(rng.below(n as u64)).expect("replica index fits usize");
        streams[slot].push((t, r));
    }
    streams
}

/// First duplicated request id in an arrival stream, if any. Online
/// serving requires unique ids: the per-request latency tracker keys on
/// them, and a duplicate would silently overwrite the first request's
/// timings. The engine surfaces this as an error and the simulator
/// panics; both check through this one helper.
pub fn duplicate_id(arrivals: &[(f64, Request)]) -> Option<SeqId> {
    let mut ids: Vec<SeqId> = arrivals.iter().map(|(_, r)| r.id).collect();
    ids.sort_unstable();
    ids.windows(2).find(|w| w[0] == w[1]).map(|w| w[0])
}

/// Attach a relative end-to-end SLO to every request of an arrival
/// stream: `deadline = arrival + slo_e2e` on the run clock. The
/// SLO-aware admission policy sheds requests that can no longer meet
/// their deadline; the FIFO default ignores it. An infinite (or
/// non-finite) SLO leaves the stream deadline-free.
pub fn with_deadlines(
    arrivals: Vec<(f64, Request)>,
    slo_e2e: f64,
) -> Vec<(f64, Request)> {
    if !slo_e2e.is_finite() {
        return arrivals;
    }
    arrivals
        .into_iter()
        .map(|(t, r)| {
            let deadline = t + slo_e2e;
            (t, r.with_deadline(deadline))
        })
        .collect()
}

/// Draw per-request *actual* generation lengths under EOS termination:
/// geometric with mean ~`mean_frac * max_gen`, capped at `max_gen`
/// (models §8.1's EOS mode; the paper reports an extra 5.3x-vs-baseline
/// when enabled).
pub fn eos_gen_len(max_gen: usize, mean_frac: f64, rng: &mut Rng) -> usize {
    assert!((0.0..=1.0).contains(&mean_frac));
    if mean_frac >= 1.0 {
        return max_gen;
    }
    let mean = (max_gen as f64 * mean_frac).max(1.0);
    let p = 1.0 / mean;
    let mut len = 1;
    while len < max_gen && !rng.chance(p) {
        len += 1;
    }
    len
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AIME, MTBENCH, RAG};

    #[test]
    fn mtbench_lengths_track_table3() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        let avg = g.empirical_avg(20_000, 1);
        assert!(
            (avg - 98.0).abs() / 98.0 < 0.15,
            "avg {avg} should be near Table 3's 98"
        );
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let l = g.prompt_len(&mut rng);
            assert!((1..=450).contains(&l));
        }
    }

    #[test]
    fn rag_is_prefill_heavy_and_aime_is_not() {
        let rag = WorkloadGen::new(&RAG, 128, 2048);
        let aime = WorkloadGen::new(&AIME, 512, 2048);
        assert!(rag.empirical_avg(5000, 3) > 5.0 * aime.empirical_avg(5000, 3));
    }

    #[test]
    fn batches_are_deterministic_and_valid() {
        let g = WorkloadGen::new(&MTBENCH, 64, 512);
        let a = g.batch(50, 100, 7);
        let b = g.batch(50, 100, 7);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.id, y.id);
        }
        assert_eq!(a[0].id, 100);
        assert_eq!(a[49].id, 149);
        for r in &a {
            assert!(r.prompt.iter().all(|&t| t >= 1 && (t as usize) < 512));
            assert_eq!(r.max_gen, 64);
        }
    }

    #[test]
    #[should_panic(expected = "unusual generation cap")]
    fn zero_generation_cap_panics() {
        WorkloadGen::new(&MTBENCH, 0, 2048);
    }

    #[test]
    #[should_panic(expected = "unusual generation cap")]
    fn oversized_generation_cap_panics() {
        // MTBench's largest published cap is 256; 10k is "unusual".
        WorkloadGen::new(&MTBENCH, 10_000, 2048);
    }

    #[test]
    fn in_range_caps_are_accepted() {
        // Published caps and anything below the largest published cap.
        for &g in MTBENCH.gen_lengths {
            WorkloadGen::new(&MTBENCH, g, 2048);
        }
        WorkloadGen::new(&MTBENCH, 100, 2048);
        WorkloadGen::new(&AIME, 1, 2048);
    }

    #[test]
    fn poisson_arrivals_are_ascending_and_rate_accurate() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        let k = 4000;
        let arrivals = g.arrivals(&ArrivalProcess::Poisson { rate: 50.0 }, k, 0, 9);
        assert_eq!(arrivals.len(), k);
        for w in arrivals.windows(2) {
            assert!(w[0].0 <= w[1].0, "timestamps ascend");
            assert!(w[0].1.id < w[1].1.id, "ids ascend in arrival order");
        }
        // Mean inter-arrival ~ 1/50 s => last timestamp ~ k/50 = 80 s.
        let span = arrivals.last().unwrap().0;
        assert!((span - 80.0).abs() / 80.0 < 0.15, "span {span}");
        // Deterministic in the seed.
        let again = g.arrivals(&ArrivalProcess::Poisson { rate: 50.0 }, k, 0, 9);
        assert_eq!(arrivals.len(), again.len());
        assert!(arrivals.iter().zip(&again).all(|(a, b)| a.0 == b.0 && a.1.id == b.1.id));
    }

    #[test]
    fn burst_arrivals_share_timestamps_within_a_burst() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        let arrivals = g.arrivals(&ArrivalProcess::Burst { rate: 40.0, size: 4 }, 401, 0, 3);
        assert_eq!(arrivals.len(), 401);
        // Full bursts: groups of 4 share one timestamp.
        for chunk in arrivals.chunks(4).take(100) {
            assert!(chunk.iter().all(|(t, _)| *t == chunk[0].0));
        }
        // Long-run request rate still ~40 req/s: 401 requests ~ 10 s.
        let span = arrivals.last().unwrap().0;
        assert!((span - 10.0).abs() / 10.0 < 0.35, "span {span}");
    }

    #[test]
    fn trace_arrivals_sort_pair_and_rebase() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        let arrivals = g.trace_arrivals(&[3.0, 1.0, 2.0], 100, 5);
        let times: Vec<f64> = arrivals.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![0.0, 1.0, 2.0]);
        assert_eq!(arrivals[0].1.id, 100);
        assert_eq!(arrivals[2].1.id, 102);
        // Absolute (epoch-style) clocks rebase to run-relative seconds.
        let epoch = g.trace_arrivals(&[1_753_660_001.0, 1_753_660_000.0], 0, 5);
        assert_eq!(epoch[0].0, 0.0);
        assert_eq!(epoch[1].0, 1.0);
    }

    #[test]
    #[should_panic(expected = "non-finite timestamp")]
    fn trace_arrivals_reject_nan() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        g.trace_arrivals(&[1.0, f64::NAN], 0, 5);
    }

    #[test]
    fn split_arrivals_is_deterministic_and_conserves_the_stream() {
        let g = WorkloadGen::new(&MTBENCH, 32, 2048);
        let arrivals = g.arrivals(&ArrivalProcess::Poisson { rate: 20.0 }, 200, 0, 7);
        let a = split_arrivals(arrivals.clone(), 3, 42);
        let b = split_arrivals(arrivals.clone(), 3, 42);
        assert_eq!(a.len(), 3);
        for (sa, sb) in a.iter().zip(&b) {
            let ia: Vec<SeqId> = sa.iter().map(|(_, r)| r.id).collect();
            let ib: Vec<SeqId> = sb.iter().map(|(_, r)| r.id).collect();
            assert_eq!(ia, ib, "same seed must reproduce the same split");
        }
        // A different seed routes differently (with 200 requests over 3
        // streams, an identical split would be a broken RNG).
        let c = split_arrivals(arrivals.clone(), 3, 43);
        assert!(
            a.iter()
                .zip(&c)
                .any(|(sa, sc)| sa.iter().map(|(_, r)| r.id).ne(sc.iter().map(|(_, r)| r.id))),
            "different seeds must produce different splits"
        );
        // Conservation: the union of the streams is the input stream, and
        // every stream is individually time-sorted.
        let mut union: Vec<SeqId> = a.iter().flatten().map(|(_, r)| r.id).collect();
        union.sort_unstable();
        let mut want: Vec<SeqId> = arrivals.iter().map(|(_, r)| r.id).collect();
        want.sort_unstable();
        assert_eq!(union, want);
        for stream in &a {
            assert!(stream.windows(2).all(|w| w[0].0 <= w[1].0), "streams stay sorted");
            assert!(!stream.is_empty(), "200 over 3: every replica gets traffic");
        }
        // n = 1 is the identity split (time-sorted).
        let one = split_arrivals(arrivals.clone(), 1, 42);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len(), arrivals.len());
    }

    #[test]
    #[should_panic(expected = "non-finite timestamp")]
    fn split_arrivals_rejects_non_finite_times() {
        split_arrivals(vec![(f64::INFINITY, Request::new(0, vec![1], 1))], 2, 0);
    }

    #[test]
    fn duplicate_id_detection() {
        let mk = |id: SeqId| Request::new(id, vec![1], 1);
        assert_eq!(duplicate_id(&[]), None);
        assert_eq!(duplicate_id(&[(0.0, mk(1)), (1.0, mk(2))]), None);
        assert_eq!(
            duplicate_id(&[(0.0, mk(3)), (1.0, mk(1)), (2.0, mk(3))]),
            Some(3)
        );
    }

    #[test]
    fn with_deadlines_offsets_from_arrival() {
        let arrivals =
            vec![(0.0, Request::new(0, vec![1], 1)), (2.5, Request::new(1, vec![1], 1))];
        let with = with_deadlines(arrivals.clone(), 10.0);
        assert_eq!(with[0].1.deadline, Some(10.0));
        assert_eq!(with[1].1.deadline, Some(12.5));
        // Infinite SLO = no deadlines.
        let open = with_deadlines(arrivals, f64::INFINITY);
        assert_eq!(open[0].1.deadline, None);
        assert_eq!(open[1].1.deadline, None);
    }

    #[test]
    fn eos_mode_shortens_mean_generation() {
        let mut rng = Rng::new(5);
        let n = 5000;
        let total: usize = (0..n).map(|_| eos_gen_len(256, 0.5, &mut rng)).sum();
        let mean = total as f64 / n as f64;
        assert!(mean > 64.0 && mean < 160.0, "mean={mean}");
        assert_eq!(eos_gen_len(256, 1.0, &mut rng), 256);
    }
}
