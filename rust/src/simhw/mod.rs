//! Discrete-event hardware simulator (§7's testbed, virtualized).
//!
//! The paper's machine (A40 + Xeon-8380 socket + PCIe 4.0) does not exist
//! on this box, so paper-scale experiments run on an analytic machine
//! with the *same* scheduler, paged-KV layout, and pipeline policy as the
//! real engine, but a virtual clock driven by hardware constants
//! (DESIGN.md §1). Per iteration the three overlapped lanes are costed:
//!
//! * IO   — one full weight sweep `δ = model / B_IO`, stretched by memory
//!          -controller contention when CPU attention is heavy (§8.2);
//! * GPU  — scheduled tokens × activated FLOPs / C_GPU;
//! * CPU  — decode-attention KV scan at the kernel's achieved bandwidth.
//!
//! With prefill/decode overlap (MoE-Lens) the iteration takes the max of
//! the lanes; the baselines compose them differently (`baselines`).

use crate::config::{MachineSpec, ModelSpec};
use crate::kvcache::{KvLayout, PagedLayout};
use crate::metrics::{PassRecord, RunReport, Trace};
use crate::model::Request;
use crate::sched::{SchedConfig, Scheduler};

/// Memory-controller contention coefficient: fraction of IO slowdown per
/// unit of CPU-attention lane occupancy. Calibrated to §8.2's observation
/// (weight sweeps stretch ~5 s → ~6 s under heavy attention ⇒ ~0.25).
pub const CONTENTION_KAPPA: f64 = 0.25;

/// One simulated deployment.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub machine: MachineSpec,
    pub model: ModelSpec,
    /// CPU-memory budget for the KV cache, bytes (the paper sweeps
    /// 70–210 GB).
    pub kv_bytes: u64,
    /// Paged-KV block size in token slots (§5.5; 16 in the evaluation).
    pub block_size: usize,
    /// Fraction of CPU memory bandwidth the decode-attention kernel
    /// achieves (1/3.1 for the auto-vectorized baseline, ~0.8 for the
    /// hand-optimized kernel — Fig. 10).
    pub cpu_attn_eff: f64,
    /// Pipeline token budget per pass; `None` derives `n_real`
    /// analytically from the machine/model (§6.3).
    pub token_budget: Option<usize>,
}

impl SimConfig {
    /// The paper's default MoE-Lens deployment for a (model, kv) pair.
    pub fn moe_lens(model: ModelSpec, kv_gb: u64) -> Self {
        SimConfig {
            machine: MachineSpec::paper_testbed(),
            model,
            kv_bytes: kv_gb << 30,
            block_size: 16,
            cpu_attn_eff: 0.8,
            token_budget: None,
        }
    }

    pub fn n_blocks(&self) -> usize {
        (self.kv_bytes / (self.block_size as u64 * self.model.kv_bytes_per_token()))
            as usize
    }

    pub fn kv_layout(&self) -> KvLayout {
        KvLayout::new(self.block_size, self.n_blocks().max(1))
    }

    /// Effective token budget (`n_real`).
    pub fn effective_token_budget(&self) -> usize {
        self.token_budget.unwrap_or_else(|| {
            crate::sched::PipelineProfiler::analytic(&self.machine, &self.model).n_real
        })
    }
}

/// Lane costs of one simulated iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneCosts {
    pub io: f64,
    pub gpu: f64,
    pub cpu: f64,
    /// IO after memory-controller contention.
    pub io_contended: f64,
}

/// Cost model shared by the MoE-Lens policy and the baselines.
pub struct CostModel<'a> {
    pub machine: &'a MachineSpec,
    pub model: &'a ModelSpec,
    pub cpu_attn_eff: f64,
}

impl<'a> CostModel<'a> {
    /// Full weight-sweep time δ.
    pub fn delta(&self) -> f64 {
        self.machine.transfer_secs(self.model.model_bytes())
    }

    /// GPU GEMM time for `n` tokens.
    pub fn gpu_time(&self, n_tokens: usize) -> f64 {
        n_tokens as f64 * self.model.flops_per_token() / self.machine.gpu.bf16_flops
    }

    /// CPU decode-attention time for a total of `kv_tokens` context tokens
    /// scanned this iteration.
    pub fn cpu_attn_time(&self, kv_tokens: u64) -> f64 {
        let bytes = kv_tokens as f64 * self.model.kv_bytes_per_token() as f64;
        bytes / (self.machine.host.mem_bw * self.cpu_attn_eff)
    }

    /// Compose one overlapped iteration (§8.2 contention included).
    pub fn overlapped_iter(&self, n_tokens: usize, kv_tokens: u64) -> LaneCosts {
        let io = self.delta();
        let gpu = self.gpu_time(n_tokens);
        let cpu = self.cpu_attn_time(kv_tokens);
        // CPU attention and the DMA engine contend at the memory
        // controller: stretch IO by its lane occupancy.
        let occupancy = (cpu / io.max(1e-12)).min(1.0);
        let io_contended = io * (1.0 + CONTENTION_KAPPA * occupancy);
        LaneCosts { io, gpu, cpu, io_contended }
    }
}

/// The MoE-Lens policy on the simulated machine: resource-aware scheduler
/// with prefill/decode overlap, VSLPipe-style lane overlap per iteration.
pub struct SimMachine {
    pub cfg: SimConfig,
    pub sched: Scheduler,
    pub kv: PagedLayout,
}

impl SimMachine {
    pub fn new(cfg: SimConfig) -> Self {
        let layout = cfg.kv_layout();
        let budget = cfg.effective_token_budget();
        let sched = Scheduler::new(SchedConfig::new(budget, budget));
        SimMachine { cfg, sched, kv: PagedLayout::new(layout) }
    }

    /// Run a request batch to completion; returns the execution trace.
    pub fn run(&mut self, requests: Vec<Request>) -> (Trace, RunReport) {
        let n_req = requests.len();
        self.sched.submit_all(requests);
        let mut trace = Trace::new(self.kv.layout().n_blocks);
        let costs = CostModel {
            machine: &self.cfg.machine,
            model: &self.cfg.model,
            cpu_attn_eff: self.cfg.cpu_attn_eff,
        };

        let mut now = 0.0f64;
        let mut pass_id = 0usize;
        while !self.sched.is_done() {
            let plan = self.sched.plan(&mut self.kv);
            // Context tokens scanned by CPU attention: each decode token
            // attends over its sequence's full cache.
            let kv_scanned: u64 =
                plan.decode.iter().map(|&(id, _)| self.kv.len(id) as u64).sum();
            let lanes = costs.overlapped_iter(plan.total_tokens(), kv_scanned);
            let dur = lanes.io_contended.max(lanes.gpu).max(lanes.cpu);
            now += dur;

            // All decode rows + completing prefill chunks yield one token.
            // Token *values* are immaterial to the simulator: requests
            // carry their effective generation length in `max_gen`.
            let mut toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 1i32)).collect();
            toks.extend(plan.prefill.iter().filter(|c| c.completes).map(|c| (c.id, 1i32)));
            let generated = toks.len();
            let finished = self.sched.complete(&toks, &mut self.kv);

            trace.push(PassRecord {
                pass_id,
                t_end: now,
                duration: dur,
                prefill_tokens: plan.prefill_tokens(),
                decode_tokens: plan.decode_tokens(),
                generated,
                finished,
                preempted: plan.preempted.len(),
                io_time: lanes.io_contended,
                gpu_time: lanes.gpu,
                cpu_time: lanes.cpu,
                kv_blocks_used: self.kv.used_blocks(),
                active_decode: self.sched.active_decode(),
            });
            pass_id += 1;
            assert!(pass_id < 5_000_000, "simulation runaway");
        }
        let report = RunReport::from_trace(&trace, n_req);
        (trace, report)
    }
}

/// Convenience: run the MoE-Lens policy for a uniform (p, g) batch.
pub fn run_uniform(
    cfg: SimConfig,
    p: usize,
    g: usize,
    k: usize,
) -> (Trace, RunReport) {
    let reqs: Vec<Request> =
        (0..k).map(|i| Request::new(i as u64, vec![1; p], g)).collect();
    SimMachine::new(cfg).run(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::Stage2Model;

    fn small_sim(kv_gb: u64) -> SimConfig {
        SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), kv_gb)
    }

    #[test]
    fn completes_and_counts_tokens() {
        let (trace, report) = run_uniform(small_sim(70), 98, 32, 200);
        assert_eq!(report.requests, 200);
        assert_eq!(report.generated_tokens, 200 * 32);
        assert!(report.wall_secs > 0.0);
        assert!(trace.passes.len() >= 32, "at least g decode iterations");
    }

    #[test]
    fn bigger_kv_cache_is_not_slower() {
        let (_, r70) = run_uniform(small_sim(70), 98, 128, 400);
        let (_, r210) = run_uniform(small_sim(210), 98, 128, 400);
        assert!(
            r210.generation_throughput >= r70.generation_throughput * 0.95,
            "210GB {} vs 70GB {}",
            r210.generation_throughput,
            r70.generation_throughput
        );
    }

    #[test]
    fn throughput_within_stage2_model_envelope() {
        // §8.1: the Stage-2 model predicts the simulated system closely
        // (the sim and model share constants but not mechanisms: the sim
        // runs the real scheduler with paging, chunking, preemption).
        // K must oversubscribe the cache so Eq. 10's steady-state pipeline
        // form applies (the paper's evaluation regime: K = 5gq or larger).
        let (p, g, kv_gb, k) = (98usize, 64usize, 70u64, 20_000usize);
        let (_, report) = run_uniform(small_sim(kv_gb), p, g, k);
        let s2 = Stage2Model::new(
            MachineSpec::paper_testbed(),
            ModelSpec::mixtral_8x7b(),
            16,
        );
        let pred = s2.predict(p, g, kv_gb << 30, k as f64);
        let acc = crate::util::stats::prediction_accuracy(
            pred.throughput,
            report.generation_throughput,
        );
        assert!(
            acc > 0.7,
            "model {} vs sim {} (acc {acc})",
            pred.throughput,
            report.generation_throughput
        );
    }

    #[test]
    fn longer_generation_lowers_throughput() {
        // §8.1: "System throughput decreases with longer generation
        // lengths for a fixed prompt length" (PME effect).
        let (_, g32) = run_uniform(small_sim(70), 98, 32, 300);
        let (_, g256) = run_uniform(small_sim(70), 98, 256, 300);
        assert!(
            g32.processed_throughput > g256.processed_throughput,
            "{} vs {}",
            g32.processed_throughput,
            g256.processed_throughput
        );
    }

    #[test]
    fn tight_cache_triggers_preemptions_loose_does_not() {
        let mut tight = small_sim(70);
        tight.kv_bytes = 2 << 30; // 2 GB: thrash
        let (_, r_tight) = run_uniform(tight, 98, 256, 64);
        let (_, r_loose) = run_uniform(small_sim(210), 98, 32, 64);
        assert!(r_tight.preemptions > 0);
        assert_eq!(r_loose.preemptions, 0);
    }

    #[test]
    fn contention_stretches_io() {
        let costs = CostModel {
            machine: &MachineSpec::paper_testbed(),
            model: &ModelSpec::mixtral_8x7b(),
            cpu_attn_eff: 0.8,
        };
        let quiet = costs.overlapped_iter(1000, 0);
        let heavy = costs.overlapped_iter(1000, 3_000_000);
        assert_eq!(quiet.io_contended, quiet.io);
        assert!(heavy.io_contended > heavy.io);
        assert!(heavy.io_contended <= heavy.io * (1.0 + CONTENTION_KAPPA) + 1e-9);
    }
}
