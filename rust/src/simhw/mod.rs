//! Discrete-event hardware simulator (§7's testbed, virtualized).
//!
//! The paper's machine (A40 + Xeon-8380 socket + PCIe 4.0) does not exist
//! on this box, so paper-scale experiments run on an analytic machine
//! with the *same* scheduler, paged-KV layout, and pipeline policy as the
//! real engine, but a virtual clock driven by hardware constants
//! (DESIGN.md §1). Per iteration the three overlapped lanes are costed:
//!
//! * IO   — one full weight sweep `δ = model / B_IO`, stretched by memory
//!          -controller contention when CPU attention is heavy (§8.2);
//! * GPU  — scheduled tokens × activated FLOPs / C_GPU;
//! * CPU  — decode-attention KV scan at the kernel's achieved bandwidth.
//!
//! With prefill/decode overlap (MoE-Lens) the iteration takes the max of
//! the lanes; the baselines compose them differently (`baselines`).

use std::collections::{BTreeSet, VecDeque};

use crate::config::{MachineSpec, ModelSpec};
use crate::kvcache::{KvLayout, PagedLayout};
use crate::metrics::{LatencyStats, PassRecord, RequestTracker, RunReport, Trace};
use crate::model::Request;
use crate::sched::{AdmissionPolicy, PassPlan, SchedConfig, Scheduler, ServiceModel, VictimPolicy};
use crate::transfer::ResidencyMap;
use crate::util::cast::{u64_f64, u64_usize, usize_f64, usize_u64};
use crate::workload::{duplicate_id, ExpertRouter, RoutingSpec};

/// Memory-controller contention coefficient: fraction of IO slowdown per
/// unit of CPU-attention lane occupancy. Calibrated to §8.2's observation
/// (weight sweeps stretch ~5 s → ~6 s under heavy attention ⇒ ~0.25).
pub const CONTENTION_KAPPA: f64 = 0.25;

/// Host-side plan/pack/embed cost per pass, mirroring the engine's
/// plan → pack → gather phase on the virtual clock: `base + per_token ×
/// scheduled tokens`. Defaults to zero (pre-pipeline traces are exactly
/// reproduced); set it to model the inter-pass host gap the
/// double-buffered pass pipeline hides.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HostPlanCost {
    pub base_secs: f64,
    pub per_token_secs: f64,
}

impl HostPlanCost {
    pub fn new(base_secs: f64, per_token_secs: f64) -> Self {
        assert!(base_secs >= 0.0 && per_token_secs >= 0.0);
        HostPlanCost { base_secs, per_token_secs }
    }

    /// Cost of planning/packing/embedding a pass of `tokens` tokens.
    pub fn cost(&self, tokens: usize) -> f64 {
        self.base_secs + self.per_token_secs * usize_f64(tokens)
    }

    pub fn is_zero(&self) -> bool {
        // pallas-lint: allow(float-eq) — exact-zero sentinel for "no host cost configured"
        self.base_secs == 0.0 && self.per_token_secs == 0.0
    }
}

/// One simulated deployment.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub machine: MachineSpec,
    pub model: ModelSpec,
    /// CPU-memory budget for the KV cache, bytes (the paper sweeps
    /// 70–210 GB).
    pub kv_bytes: u64,
    /// Paged-KV block size in token slots (§5.5; 16 in the evaluation).
    pub block_size: usize,
    /// Fraction of CPU memory bandwidth the decode-attention kernel
    /// achieves (1/3.1 for the auto-vectorized baseline, ~0.8 for the
    /// hand-optimized kernel — Fig. 10).
    pub cpu_attn_eff: f64,
    /// Pipeline token budget per pass; `None` derives `n_real`
    /// analytically from the machine/model (§6.3).
    pub token_budget: Option<usize>,
    /// Queue admission policy (default FIFO — PR-1 behavior).
    pub admission: AdmissionPolicy,
    /// Preemption victim policy (default newest-first — PR-1 behavior).
    pub victim: VictimPolicy,
    /// Double-buffered pass pipelining, mirroring the engine's semantics:
    /// 0 = synchronous (host cost, if any, is fully exposed each pass);
    /// ≥ 1 = the next pass is planned immediately after the previous one
    /// completes, hiding up to one execution window of host cost, with
    /// the engine's replan rules (FIFO only; an unpredicted EOS finish
    /// exposes the full replanning cost). Default 0: existing traces are
    /// reproduced exactly.
    pub pipeline_depth: usize,
    /// Per-pass host plan/pack/embed cost (default zero).
    pub host_plan: HostPlanCost,
    /// Expert-routing trace (`None` = uniform routing, default seed).
    /// Only read when [`pinned_experts`](Self::pinned_experts) is nonzero.
    pub routing: Option<RoutingSpec>,
    /// Experts pinned in HBM per layer (popularity order). `0` disables
    /// expert-granular residency: every pass sweeps the full model and
    /// pre-refactor traces are f64-identical.
    pub pinned_experts: usize,
}

impl SimConfig {
    /// The paper's default MoE-Lens deployment for a (model, kv) pair.
    pub fn moe_lens(model: ModelSpec, kv_gb: u64) -> Self {
        SimConfig {
            machine: MachineSpec::paper_testbed(),
            model,
            kv_bytes: kv_gb << 30,
            block_size: 16,
            cpu_attn_eff: 0.8,
            token_budget: None,
            admission: AdmissionPolicy::default(),
            victim: VictimPolicy::default(),
            pipeline_depth: 0,
            host_plan: HostPlanCost::default(),
            routing: None,
            pinned_experts: 0,
        }
    }

    pub fn n_blocks(&self) -> usize {
        u64_usize(self.kv_bytes / (usize_u64(self.block_size) * self.model.kv_bytes_per_token()))
    }

    pub fn kv_layout(&self) -> KvLayout {
        KvLayout::new(self.block_size, self.n_blocks().max(1))
    }

    /// Effective token budget (`n_real`).
    pub fn effective_token_budget(&self) -> usize {
        self.token_budget.unwrap_or_else(|| {
            crate::sched::PipelineProfiler::analytic(&self.machine, &self.model).n_real
        })
    }
}

/// Lane costs of one simulated iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneCosts {
    pub io: f64,
    pub gpu: f64,
    pub cpu: f64,
    /// IO after memory-controller contention.
    pub io_contended: f64,
}

impl LaneCosts {
    /// All four lanes stretched by `k` — the transient-slowdown fault the
    /// cluster's `FaultPlan` injects at pass boundaries. Scaling every
    /// lane together preserves the five-lane partition (the exclusive
    /// decomposition is computed from the scaled lanes, so
    /// `lanes_total == duration` still holds), and `k = 1.0` is
    /// bit-identical (IEEE multiplication by 1.0 is exact), so the
    /// no-fault path reproduces existing traces f64-for-f64.
    pub fn scaled(self, k: f64) -> LaneCosts {
        LaneCosts {
            io: self.io * k,
            gpu: self.gpu * k,
            cpu: self.cpu * k,
            io_contended: self.io_contended * k,
        }
    }
}

/// Cost model shared by the MoE-Lens policy and the baselines.
pub struct CostModel<'a> {
    pub machine: &'a MachineSpec,
    pub model: &'a ModelSpec,
    pub cpu_attn_eff: f64,
}

impl<'a> CostModel<'a> {
    /// Full weight-sweep time δ.
    pub fn delta(&self) -> f64 {
        self.machine.transfer_secs(self.model.model_bytes())
    }

    /// GPU GEMM time for `n` tokens.
    pub fn gpu_time(&self, n_tokens: usize) -> f64 {
        usize_f64(n_tokens) * self.model.flops_per_token() / self.machine.gpu.bf16_flops
    }

    /// CPU decode-attention time for a total of `kv_tokens` context tokens
    /// scanned this iteration.
    pub fn cpu_attn_time(&self, kv_tokens: u64) -> f64 {
        let bytes = u64_f64(kv_tokens) * u64_f64(self.model.kv_bytes_per_token());
        bytes / (self.machine.host.mem_bw * self.cpu_attn_eff)
    }

    /// Compose one overlapped iteration (§8.2 contention included).
    pub fn overlapped_iter(&self, n_tokens: usize, kv_tokens: u64) -> LaneCosts {
        self.overlapped_iter_bytes(n_tokens, kv_tokens, self.model.model_bytes())
    }

    /// [`overlapped_iter`](Self::overlapped_iter) with an explicit weight
    /// sweep size — expert-granular residency shrinks the per-pass sweep
    /// below `model_bytes()` when pinned experts skip the link.
    pub fn overlapped_iter_bytes(
        &self,
        n_tokens: usize,
        kv_tokens: u64,
        weight_bytes: u64,
    ) -> LaneCosts {
        let io = self.machine.transfer_secs(weight_bytes);
        let gpu = self.gpu_time(n_tokens);
        let cpu = self.cpu_attn_time(kv_tokens);
        // CPU attention and the DMA engine contend at the memory
        // controller: stretch IO by its lane occupancy.
        let occupancy = (cpu / io.max(1e-12)).min(1.0);
        let io_contended = io * (1.0 + CONTENTION_KAPPA * occupancy);
        LaneCosts { io, gpu, cpu, io_contended }
    }
}

/// Expert-granular residency state mirrored on the virtual clock: the
/// same router, pinned set, and prediction width the engine's data mover
/// runs with, so simulated IO per pass matches the mover's protocol.
struct SimExpert {
    router: ExpertRouter,
    residency: ResidencyMap,
    predict_n: usize,
}

impl SimExpert {
    /// Weight bytes streamed over the link for one pass under the data
    /// mover's protocol: pinned experts never cross the link; layers whose
    /// exact routing was posted before their transfer stream
    /// `activated \ pinned`; and on pipelined passes after the first, the
    /// two §6.4 +2-prefetched layers were requested before routing was
    /// known, so they stream `predicted \ pinned` plus the exposed top-up
    /// `activated \ (pinned ∪ predicted)`.
    fn pass_bytes(&self, plan: &PassPlan, model: &ModelSpec, prefetched_head: bool) -> u64 {
        let routing = plan.routed(&self.router);
        let mut bytes =
            model.model_bytes() - usize_u64(model.n_layers) * model.layer_bytes();
        for (layer, activated) in routing.per_layer.iter().enumerate() {
            let mut streamed: BTreeSet<usize> = activated
                .iter()
                .copied()
                .filter(|&e| !self.residency.is_resident(layer, e))
                .collect();
            if prefetched_head && layer < 2 {
                streamed.extend(
                    self.router
                        .predicted(layer, self.predict_n)
                        .into_iter()
                        .filter(|&e| !self.residency.is_resident(layer, e)),
                );
            }
            bytes += model.layer_dense_bytes()
                + usize_u64(streamed.len()) * model.expert_bytes();
        }
        bytes
    }
}

/// The MoE-Lens policy on the simulated machine: resource-aware scheduler
/// with prefill/decode overlap, VSLPipe-style lane overlap per iteration.
pub struct SimMachine {
    pub cfg: SimConfig,
    pub sched: Scheduler,
    pub kv: PagedLayout,
    expert: Option<SimExpert>,
}

impl SimMachine {
    pub fn new(cfg: SimConfig) -> Self {
        let layout = cfg.kv_layout();
        let budget = cfg.effective_token_budget();
        // Service-time estimates for the SLO/weighted policies, from the
        // same constants the virtual clock runs on: a pass sweeps the
        // weights once (δ) and carries up to `budget` tokens.
        let delta = cfg.machine.transfer_secs(cfg.model.model_bytes());
        let sched = Scheduler::new(
            SchedConfig::new(budget, budget)
                .with_admission(cfg.admission)
                .with_victim(cfg.victim)
                .with_service(ServiceModel::from_costs(delta, budget)),
        );
        // Expert-granular residency mirrors the engine's gate exactly:
        // active only with a nonzero pinned set, so the default config
        // reproduces pre-refactor traces f64-identically.
        let expert = if cfg.pinned_experts > 0 {
            let spec = cfg.routing.unwrap_or_else(RoutingSpec::uniform);
            let router = ExpertRouter::new(&cfg.model, spec);
            let hbm_budget = ResidencyMap::budget_from_bytes(
                cfg.machine.gpu_mem_for_serving,
                cfg.model.expert_bytes(),
            );
            let residency =
                ResidencyMap::pin_hottest(&router, cfg.pinned_experts, hbm_budget);
            let predict_n = router.predicted_count(budget);
            Some(SimExpert { router, residency, predict_n })
        } else {
            None
        };
        SimMachine { cfg, sched, kv: PagedLayout::new(layout), expert }
    }

    /// Run a closed request batch to completion; returns the execution
    /// trace. This is the arrival-driven loop with every request arriving
    /// at t = 0 (and no latency tracking — closed-batch benches don't pay
    /// the per-token bookkeeping).
    pub fn run(&mut self, requests: Vec<Request>) -> (Trace, RunReport) {
        let arrivals: Vec<(f64, Request)> =
            requests.into_iter().map(|r| (0.0, r)).collect();
        self.serve(arrivals, None)
    }

    /// Run a timed arrival stream on the virtual clock: `(arrival_secs,
    /// request)` pairs. Requests are admitted once the clock passes their
    /// arrival time; an idle system jumps straight to the next arrival.
    /// Returns the trace, the run report, and per-request latency stats
    /// (TTFT / TPOT / e2e / goodput against `slo_e2e`). Deterministic: the
    /// clock is virtual, so latency experiments are exactly reproducible.
    pub fn run_online(
        &mut self,
        arrivals: Vec<(f64, Request)>,
        slo_e2e: f64,
    ) -> (Trace, RunReport, LatencyStats) {
        let (trace, report, stats, _) = self.run_online_tracked(arrivals, slo_e2e);
        (trace, report, stats)
    }

    /// [`run_online`](Self::run_online), additionally returning the raw
    /// per-request [`RequestTracker`] — equivalence tests compare
    /// first-token/finish orderings across pipeline configurations with
    /// it.
    pub fn run_online_tracked(
        &mut self,
        arrivals: Vec<(f64, Request)>,
        slo_e2e: f64,
    ) -> (Trace, RunReport, LatencyStats, RequestTracker) {
        let mut tracker = RequestTracker::new();
        let (trace, report) = self.serve(arrivals, Some(&mut tracker));
        let stats = tracker.stats(trace.wall_secs(), slo_e2e);
        (trace, report, stats, tracker)
    }

    /// Start a stepping run: fresh trace, zeroed virtual clock, and the
    /// pipelining mode resolved from the config. [`serve`](Self::serve)
    /// drives one of these to completion; the cluster driver interleaves
    /// N of them, each on its own replica-local clock.
    pub(crate) fn begin_run(&self) -> PassState {
        // Double-buffered pass pipelining (mirrors the engine): with
        // depth ≥ 1 the next pass is planned immediately after the
        // previous one completes — before newly due arrivals are
        // submitted, exactly like the engine's speculative commit — and
        // up to one execution window of its host plan/pack/embed cost
        // hides under the previous pass. Speculation follows the engine's
        // rules: FIFO admission only, and an EOS finish the budget could
        // not predict forces a fully exposed replan.
        let pipelined = self.cfg.pipeline_depth > 0;
        let speculate =
            pipelined && matches!(self.sched.cfg.admission, AdmissionPolicy::Fifo);
        PassState {
            trace: Trace::new(self.kv.layout().n_blocks),
            now: 0.0,
            pass_id: 0,
            prepared: None,
            pipelined,
            speculate,
        }
    }

    /// Whether the machine still has work that consumes virtual time: a
    /// non-drained scheduler, or a speculatively planned pass waiting to
    /// execute.
    pub(crate) fn has_live_work(&self, st: &PassState) -> bool {
        !self.sched.is_done() || st.prepared.is_some()
    }

    /// Plan and execute one scheduler pass at the state's virtual clock,
    /// advancing it and appending a [`PassRecord`]. Returns the pass
    /// duration, or `None` when planning shed everything (no pass, no
    /// virtual time — the scheduler is then drained and the caller idles
    /// to the next arrival or exits). `slowdown` stretches every lane by
    /// that factor — the cluster's transient-fault injection — and 1.0 is
    /// bit-identical, so single-machine runs reproduce existing traces.
    pub(crate) fn step_pass(
        &mut self,
        st: &mut PassState,
        mut tracker: Option<&mut RequestTracker>,
        slowdown: f64,
    ) -> Option<f64> {
        let (plan, host_exposed) = match st.prepared.take() {
            // Speculatively planned: the hidden share of its host cost
            // was already booked (as host_overlap_time) on the pass it
            // ran under; only the exposed tail remains.
            Some((plan, exposed)) => (plan, exposed),
            None => {
                let plan = self.sched.plan_at(&mut self.kv, st.now);
                // Synchronous (or replanned) pass: the whole host cost
                // is exposed. Depth 0 with the zero default reproduces
                // the pre-pipeline trace exactly.
                let h = self.cfg.host_plan.cost(plan.total_tokens());
                (plan, h)
            }
        };
        if let Some(tr) = tracker.as_deref_mut() {
            for &(id, reason) in &plan.dropped {
                tr.dropped(id, st.now, reason);
            }
        }
        if plan.is_empty() {
            // Everything queued was shed while planning — nothing to
            // execute; no pass, no virtual time.
            return None;
        }
        // Context tokens scanned by CPU attention: each decode token
        // attends over its sequence's full cache.
        let kv_scanned: u64 =
            plan.decode.iter().map(|&(id, _)| usize_u64(self.kv.len(id))).sum();
        // Expert-granular residency shrinks the weight sweep: pinned
        // experts never cross the link and only activated (or +2
        // predicted) cold experts stream. Disabled (`None`) takes the
        // full-model sweep — bit-for-bit the pre-refactor cost.
        let sweep_bytes = match &self.expert {
            Some(ex) => {
                ex.pass_bytes(&plan, &self.cfg.model, st.pipelined && st.pass_id > 0)
            }
            None => self.cfg.model.model_bytes(),
        };
        let costs = CostModel {
            machine: &self.cfg.machine,
            model: &self.cfg.model,
            cpu_attn_eff: self.cfg.cpu_attn_eff,
        };
        let lanes = costs
            .overlapped_iter_bytes(plan.total_tokens(), kv_scanned, sweep_bytes)
            .scaled(slowdown);
        let exec = lanes.io_contended.max(lanes.gpu).max(lanes.cpu);
        let dur = host_exposed + exec;
        st.now += dur;

        // All decode rows + completing prefill chunks yield one token.
        // Token *values* are immaterial to the simulator: requests
        // carry their effective generation length in `max_gen`.
        let mut toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 1i32)).collect();
        toks.extend(plan.prefill.iter().filter(|c| c.completes).map(|c| (c.id, 1i32)));
        let generated = toks.len();
        if let Some(tr) = tracker.as_deref_mut() {
            for &(id, _) in &toks {
                tr.token(id, st.now);
            }
        }
        // Budget-predictable finishes (what the engine's speculative
        // planner can foresee before the LM head runs); any extra
        // actual finish is an EOS surprise that invalidates the
        // speculation.
        let predicted_finishes = if st.speculate {
            toks.iter()
                .filter(|&&(id, _)| {
                    self.sched.sequence(id).is_some_and(|s| {
                        s.generated.len() + 1 >= s.req.max_gen
                    })
                })
                .count()
        } else {
            0
        };
        let finished = self.sched.complete(&toks, &mut self.kv);
        let eos_surprise = st.speculate && finished.len() != predicted_finishes;
        if let Some(tr) = tracker.as_deref_mut() {
            for &id in &finished {
                tr.finished(id, st.now);
            }
        }

        // Lane accounting mirrors the engine's exclusive decomposition:
        // `overlap` is the window where GPU GEMMs and CPU attention are
        // both busy; gpu/cpu report the exclusive remainders (total GPU
        // busy = gpu_time + overlap_time). The IO lane books only the
        // *exposed* part of the contended sweep — the tail sticking
        // out past the compute it overlaps — so the four lanes
        // partition `dur = max(io, gpu, cpu)` exactly. (The seed
        // booked the full contended sweep, so `lanes_total()`
        // exceeded `duration` on every overlapped pass and the
        // stacked Fig.-13 lane plots over-filled the bar.)
        let both_busy = lanes.gpu.min(lanes.cpu);
        let compute = lanes.gpu.max(lanes.cpu);
        st.trace.push(PassRecord {
            pass_id: st.pass_id,
            t_end: st.now,
            duration: dur,
            prefill_tokens: plan.prefill_tokens(),
            decode_tokens: plan.decode_tokens(),
            generated,
            finished: finished.len(),
            preempted: plan.preempted.len(),
            io_time: (lanes.io_contended - compute).max(0.0),
            gpu_time: lanes.gpu - both_busy,
            cpu_time: lanes.cpu - both_busy,
            overlap_time: both_busy,
            host_time: host_exposed,
            // Incremented below if the *next* pass's planning hides
            // under this pass's execution window.
            host_overlap_time: 0.0,
            kv_blocks_used: self.kv.used_blocks(),
            active_decode: self.sched.active_decode(),
        });
        st.pass_id += 1;
        assert!(st.pass_id < 5_000_000, "simulation runaway");

        // Speculate the next pass under the engine's commit rules:
        // plan it *now* (arrivals landing during this pass join one
        // pass later, exactly like the engine), unless an EOS
        // surprise forces the synchronous replan path. Up to one
        // execution window of the next plan's host cost hides under
        // this pass — book that share on *this* record's shadow lane
        // (the pass whose layer loop hid the work, matching the
        // engine's attribution and the `host_overlap_time` docs).
        if st.speculate && !eos_surprise && !self.sched.is_done() {
            let next = self.sched.plan_at(&mut self.kv, st.now);
            // Always-on: once per pass, and a shed/empty speculative
            // plan would silently desync the simulator from the engine.
            assert!(
                next.dropped.is_empty() && !next.is_empty(),
                "FIFO plans never shed, and a live scheduler plans work"
            );
            let h = self.cfg.host_plan.cost(next.total_tokens());
            let hidden = h.min(exec);
            st.trace.passes.last_mut().expect("pass just pushed").host_overlap_time +=
                hidden;
            st.prepared = Some((next, h - hidden));
        }
        Some(dur)
    }

    /// The arrival-driven serving loop behind [`run`](Self::run) and
    /// [`run_online`](Self::run_online); latency stamping only happens
    /// when a tracker is supplied. A thin driver over the stepping
    /// primitives ([`begin_run`](Self::begin_run) /
    /// [`step_pass`](Self::step_pass)) the cluster simulator also uses —
    /// byte-for-byte the same pass arithmetic, so a 1-replica cluster is
    /// f64-identical to this loop.
    fn serve(
        &mut self,
        mut arrivals: Vec<(f64, Request)>,
        mut tracker: Option<&mut RequestTracker>,
    ) -> (Trace, RunReport) {
        assert!(
            self.sched.is_done(),
            "serving requires a drained scheduler: sequences submitted \
             outside the arrival stream have no arrival record to track"
        );
        if let Some(dup) = duplicate_id(&arrivals) {
            panic!(
                "duplicate request id {dup} in arrival stream — per-request \
                 latency tracking requires unique ids"
            );
        }
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("non-NaN arrival times"));
        let n_req = arrivals.len();
        let mut pending: VecDeque<(f64, Request)> = arrivals.into();
        let mut st = self.begin_run();
        loop {
            while pending.front().is_some_and(|(t, _)| *t <= st.now) {
                let (t, r) = pending.pop_front().unwrap();
                if let Some(tr) = tracker.as_deref_mut() {
                    tr.arrived(r.id, t);
                }
                self.sched.submit_at(r, t);
            }
            if !self.has_live_work(&st) {
                match pending.front() {
                    // Idle: advance the virtual clock to the next arrival.
                    Some(&(t, _)) => {
                        st.now = st.now.max(t);
                        continue;
                    }
                    None => break,
                }
            }
            self.step_pass(&mut st, tracker.as_deref_mut(), 1.0);
        }
        let report = RunReport::from_trace(&st.trace, n_req);
        (st.trace, report)
    }
}

/// Between-pass state of the stepping serving loop
/// ([`SimMachine::step_pass`]): the virtual clock, the pass counter, the
/// trace under construction, and the speculatively planned next pass when
/// pipelining is on.
pub(crate) struct PassState {
    pub trace: Trace,
    pub now: f64,
    pub pass_id: usize,
    /// (plan, exposed host cost remaining after the hidden share was
    /// attributed to the pass that hid it).
    prepared: Option<(crate::sched::PassPlan, f64)>,
    pipelined: bool,
    speculate: bool,
}

/// Convenience: run the MoE-Lens policy for a uniform (p, g) batch.
pub fn run_uniform(
    cfg: SimConfig,
    p: usize,
    g: usize,
    k: usize,
) -> (Trace, RunReport) {
    let reqs: Vec<Request> =
        (0..k).map(|i| Request::new(usize_u64(i), vec![1; p], g)).collect();
    SimMachine::new(cfg).run(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::Stage2Model;
    use crate::util::rng::Rng;
    use crate::workload::ArrivalProcess;

    fn small_sim(kv_gb: u64) -> SimConfig {
        SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), kv_gb)
    }

    fn poisson_arrivals(rate: f64, k: usize, p: usize, g: usize, seed: u64) -> Vec<(f64, Request)> {
        let mut rng = Rng::new(seed);
        ArrivalProcess::Poisson { rate }
            .times(k, &mut rng)
            .into_iter()
            .enumerate()
            .map(|(i, t)| (t, Request::new(i as u64, vec![1; p], g)))
            .collect()
    }

    #[test]
    fn closed_batch_is_online_with_zero_arrivals() {
        // The tentpole invariant: `run` is `run_online` with every request
        // arriving at t = 0 — identical pass structure and virtual clock.
        let reqs: Vec<Request> =
            (0..100).map(|i| Request::new(i, vec![1; 98], 32)).collect();
        let (t1, r1) = SimMachine::new(small_sim(70)).run(reqs.clone());
        let arrivals: Vec<(f64, Request)> =
            reqs.into_iter().map(|r| (0.0, r)).collect();
        let (t2, r2, lat) =
            SimMachine::new(small_sim(70)).run_online(arrivals, f64::INFINITY);
        assert_eq!(t1.passes.len(), t2.passes.len());
        assert_eq!(r1.generated_tokens, r2.generated_tokens);
        assert!((r1.wall_secs - r2.wall_secs).abs() < 1e-9);
        assert_eq!(lat.completed, 100);
        for (a, b) in t1.passes.iter().zip(&t2.passes) {
            assert_eq!(a.prefill_tokens, b.prefill_tokens);
            assert_eq!(a.decode_tokens, b.decode_tokens);
            assert_eq!(a.finished, b.finished);
        }
    }

    #[test]
    fn online_arrivals_finish_under_tight_kv() {
        // Mid-stream admission + the preemption path: a 2 GB cache cannot
        // hold the batch, yet every request must finish and release its
        // blocks (§6.2's preempt → re-prefill recovery, now with online
        // arrivals in flight).
        let mut cfg = small_sim(70);
        cfg.kv_bytes = 2 << 30;
        let arrivals = poisson_arrivals(20.0, 64, 98, 256, 4);
        let mut sim = SimMachine::new(cfg);
        let (trace, report, lat) = sim.run_online(arrivals, f64::INFINITY);
        assert_eq!(report.requests, 64);
        assert_eq!(lat.completed, 64);
        assert_eq!(report.generated_tokens, 64 * 256);
        assert!(report.preemptions > 0, "tight cache must preempt");
        assert_eq!(trace.passes.last().unwrap().kv_blocks_used, 0);
        assert!(lat.ttft_p50 > 0.0);
        assert!(lat.e2e_p99 >= lat.e2e_p50);
        assert!(lat.e2e_p50 >= lat.ttft_p50);
    }

    #[test]
    fn ttft_and_tpot_rise_with_arrival_rate() {
        // Queueing theory smoke test: a higher arrival rate cannot reduce
        // time-to-first-token or time-per-output-token (deterministic on
        // the virtual clock, so this is exact, not statistical).
        let run_at = |rate: f64| {
            let arrivals = poisson_arrivals(rate, 1200, 98, 32, 7);
            SimMachine::new(small_sim(70))
                .run_online(arrivals, f64::INFINITY)
                .2
        };
        let slow = run_at(2.0);
        let fast = run_at(2000.0);
        assert_eq!(slow.completed, 1200);
        assert_eq!(fast.completed, 1200);
        assert!(
            fast.ttft_p50 >= slow.ttft_p50,
            "p50 TTFT: {} at 2000 req/s vs {} at 2 req/s",
            fast.ttft_p50,
            slow.ttft_p50
        );
        assert!(fast.ttft_p99 >= slow.ttft_p99);
        // Decode iterations under load are stretched by memory-controller
        // contention, never shortened.
        assert!(fast.tpot_p50 >= slow.tpot_p50 * 0.999);
    }

    #[test]
    fn goodput_counts_only_within_slo() {
        let arrivals = poisson_arrivals(50.0, 300, 98, 32, 11);
        let (_, _, open) = SimMachine::new(small_sim(70))
            .run_online(arrivals.clone(), f64::INFINITY);
        let (_, _, tight) =
            SimMachine::new(small_sim(70)).run_online(arrivals, open.e2e_p50);
        assert_eq!(open.completed, 300);
        // The p50 deadline admits roughly half the completions.
        assert!(tight.goodput_rps < open.goodput_rps);
        assert!(tight.goodput_rps > 0.0);
    }

    #[test]
    fn completes_and_counts_tokens() {
        let (trace, report) = run_uniform(small_sim(70), 98, 32, 200);
        assert_eq!(report.requests, 200);
        assert_eq!(report.generated_tokens, 200 * 32);
        assert!(report.wall_secs > 0.0);
        assert!(trace.passes.len() >= 32, "at least g decode iterations");
    }

    #[test]
    fn bigger_kv_cache_is_not_slower() {
        let (_, r70) = run_uniform(small_sim(70), 98, 128, 400);
        let (_, r210) = run_uniform(small_sim(210), 98, 128, 400);
        assert!(
            r210.generation_throughput >= r70.generation_throughput * 0.95,
            "210GB {} vs 70GB {}",
            r210.generation_throughput,
            r70.generation_throughput
        );
    }

    #[test]
    fn throughput_within_stage2_model_envelope() {
        // §8.1: the Stage-2 model predicts the simulated system closely
        // (the sim and model share constants but not mechanisms: the sim
        // runs the real scheduler with paging, chunking, preemption).
        // K must oversubscribe the cache so Eq. 10's steady-state pipeline
        // form applies (the paper's evaluation regime: K = 5gq or larger).
        let (p, g, kv_gb, k) = (98usize, 64usize, 70u64, 20_000usize);
        let (_, report) = run_uniform(small_sim(kv_gb), p, g, k);
        let s2 = Stage2Model::new(
            MachineSpec::paper_testbed(),
            ModelSpec::mixtral_8x7b(),
            16,
        );
        let pred = s2.predict(p, g, kv_gb << 30, k as f64);
        let acc = crate::util::stats::prediction_accuracy(
            pred.throughput,
            report.generation_throughput,
        );
        assert!(
            acc > 0.7,
            "model {} vs sim {} (acc {acc})",
            pred.throughput,
            report.generation_throughput
        );
    }

    #[test]
    fn longer_generation_lowers_throughput() {
        // §8.1: "System throughput decreases with longer generation
        // lengths for a fixed prompt length" (PME effect).
        let (_, g32) = run_uniform(small_sim(70), 98, 32, 300);
        let (_, g256) = run_uniform(small_sim(70), 98, 256, 300);
        assert!(
            g32.processed_throughput > g256.processed_throughput,
            "{} vs {}",
            g32.processed_throughput,
            g256.processed_throughput
        );
    }

    #[test]
    fn tight_cache_triggers_preemptions_loose_does_not() {
        let mut tight = small_sim(70);
        tight.kv_bytes = 2 << 30; // 2 GB: thrash
        let (_, r_tight) = run_uniform(tight, 98, 256, 64);
        let (_, r_loose) = run_uniform(small_sim(210), 98, 32, 64);
        assert!(r_tight.preemptions > 0);
        assert_eq!(r_loose.preemptions, 0);
    }

    #[test]
    fn lanes_partition_pass_duration_exactly() {
        // Satellite regression: io/gpu/cpu/overlap are documented as
        // mutually exclusive spans partitioning the pass. The seed booked
        // the full contended IO sweep while duration took the lane max,
        // so lanes_total() > duration on every overlapped pass.
        let mut cfg = small_sim(70);
        cfg.kv_bytes = 2 << 30; // tight: cover preemption passes too
        let arrivals = poisson_arrivals(20.0, 64, 98, 128, 4);
        let (trace, _, _) =
            SimMachine::new(cfg).run_online(arrivals, f64::INFINITY);
        assert!(trace.passes.len() > 50);
        for p in &trace.passes {
            assert!(
                (p.lanes_total() - p.duration).abs() < 1e-9,
                "pass {}: lanes_total {} vs duration {}",
                p.pass_id,
                p.lanes_total(),
                p.duration
            );
            assert!(p.io_time >= 0.0 && p.gpu_time >= 0.0);
            assert!(p.cpu_time >= 0.0 && p.overlap_time >= 0.0);
            // GPU/CPU busy never exceed the pass wall clock.
            assert!(p.gpu_busy() <= p.duration + 1e-12);
            assert!(p.cpu_busy() <= p.duration + 1e-12);
        }
    }

    #[test]
    fn pipelining_with_zero_host_cost_is_f64_identical() {
        // Acceptance: with the default zero host cost, turning the pass
        // pipeline on cannot perturb a closed-batch trace at all — plans
        // are deterministic and host time contributes nothing, so every
        // record matches f64-for-f64.
        let reqs: Vec<Request> =
            (0..60).map(|i| Request::new(i, vec![1; 98], 16)).collect();
        let (t0, r0) = SimMachine::new(small_sim(70)).run(reqs.clone());
        let mut cfg = small_sim(70);
        cfg.pipeline_depth = 1;
        let (t1, r1) = SimMachine::new(cfg).run(reqs);
        assert_eq!(t0.passes.len(), t1.passes.len());
        assert_eq!(r0.generated_tokens, r1.generated_tokens);
        for (a, b) in t0.passes.iter().zip(&t1.passes) {
            assert_eq!(a.t_end, b.t_end, "pass {}", a.pass_id);
            assert_eq!(a.duration, b.duration, "pass {}", a.pass_id);
            assert_eq!(a.prefill_tokens, b.prefill_tokens, "pass {}", a.pass_id);
            assert_eq!(a.decode_tokens, b.decode_tokens, "pass {}", a.pass_id);
            assert_eq!(a.finished, b.finished, "pass {}", a.pass_id);
            assert_eq!(a.kv_blocks_used, b.kv_blocks_used, "pass {}", a.pass_id);
            assert_eq!(a.io_time, b.io_time, "pass {}", a.pass_id);
            assert_eq!(a.host_time, 0.0);
            assert_eq!(b.host_time, 0.0);
        }
    }

    #[test]
    fn pipelining_hides_host_time_and_keeps_lane_partition() {
        // Acceptance: with a real host plan/pack cost, pipelining must
        // expose strictly less host time than the synchronous schedule on
        // the same workload (only the prologue pass pays in full), finish
        // sooner, do identical work, and keep |lanes_total - duration| <
        // 1e-9 on every pass.
        let host = HostPlanCost::new(0.05, 1e-5);
        let reqs: Vec<Request> =
            (0..120).map(|i| Request::new(i, vec![1; 98], 32)).collect();
        let run = |depth: usize| {
            let mut cfg = small_sim(70);
            cfg.pipeline_depth = depth;
            cfg.host_plan = host;
            SimMachine::new(cfg).run(reqs.clone())
        };
        let (t_sync, r_sync) = run(0);
        let (t_pipe, r_pipe) = run(1);

        let exposed = |t: &Trace| t.passes.iter().map(|p| p.host_time).sum::<f64>();
        let hidden = |t: &Trace| t.passes.iter().map(|p| p.host_overlap_time).sum::<f64>();
        assert!(exposed(&t_sync) > 0.0);
        assert_eq!(hidden(&t_sync), 0.0, "synchronous runs hide nothing");
        assert!(
            exposed(&t_pipe) < exposed(&t_sync),
            "pipelined exposed host {:.4}s must undercut synchronous {:.4}s",
            exposed(&t_pipe),
            exposed(&t_sync)
        );
        assert!(hidden(&t_pipe) > 0.0, "the overlap must actually hide work");
        assert!(r_pipe.wall_secs < r_sync.wall_secs);

        // Same work, pass for pass (host cost shifts time, not structure).
        assert_eq!(t_sync.passes.len(), t_pipe.passes.len());
        assert_eq!(r_sync.generated_tokens, r_pipe.generated_tokens);
        for (a, b) in t_sync.passes.iter().zip(&t_pipe.passes) {
            assert_eq!(a.prefill_tokens, b.prefill_tokens, "pass {}", a.pass_id);
            assert_eq!(a.decode_tokens, b.decode_tokens, "pass {}", a.pass_id);
            assert_eq!(a.finished, b.finished, "pass {}", a.pass_id);
        }
        // Five-lane partition invariant on both traces.
        for t in [&t_sync, &t_pipe] {
            for p in &t.passes {
                assert!(
                    (p.lanes_total() - p.duration).abs() < 1e-9,
                    "pass {}: lanes {} vs duration {}",
                    p.pass_id,
                    p.lanes_total(),
                    p.duration
                );
                assert!(p.host_time >= 0.0 && p.host_overlap_time >= 0.0);
            }
        }
        // Per-pass host accounting conserves the total host work.
        let total = |t: &Trace| exposed(t) + hidden(t);
        assert!((total(&t_pipe) - total(&t_sync)).abs() < 1e-9);
    }

    #[test]
    fn eos_surprises_fall_back_to_exposed_replans() {
        // Requests whose EOS fires on the sim's constant token (1) finish
        // before their budget — unpredictable for the speculative
        // planner, so the following pass pays its full host cost.
        let host = HostPlanCost::new(0.05, 0.0);
        let mk = |eos: bool| -> Vec<Request> {
            (0..40)
                .map(|i| {
                    let r = Request::new(i, vec![1; 98], 32);
                    if eos && i % 2 == 0 { r.with_eos(1) } else { r }
                })
                .collect()
        };
        let run = |reqs: Vec<Request>| {
            let mut cfg = small_sim(70);
            cfg.pipeline_depth = 1;
            cfg.host_plan = host;
            SimMachine::new(cfg).run(reqs).0
        };
        let smooth = run(mk(false));
        let surprised = run(mk(true));
        let exposed_after_prologue = |t: &Trace| {
            t.passes.iter().skip(1).map(|p| p.host_time).sum::<f64>()
        };
        // EOS-at-first-token sequences finish the moment they complete
        // prefill — every such pass diverges from the budget prediction
        // and replans, exposing host cost the smooth run hides.
        assert!(exposed_after_prologue(&smooth) < 1e-12, "{smooth:?}");
        assert!(exposed_after_prologue(&surprised) > 0.0);
    }

    #[test]
    #[should_panic(expected = "duplicate request id")]
    fn duplicate_request_ids_are_rejected() {
        let arrivals = vec![
            (0.0, Request::new(1, vec![1; 4], 2)),
            (0.5, Request::new(1, vec![1; 4], 2)),
        ];
        SimMachine::new(small_sim(70)).run_online(arrivals, f64::INFINITY);
    }

    #[test]
    fn contention_stretches_io() {
        let costs = CostModel {
            machine: &MachineSpec::paper_testbed(),
            model: &ModelSpec::mixtral_8x7b(),
            cpu_attn_eff: 0.8,
        };
        let quiet = costs.overlapped_iter(1000, 0);
        let heavy = costs.overlapped_iter(1000, 3_000_000);
        assert_eq!(quiet.io_contended, quiet.io);
        assert!(heavy.io_contended > heavy.io);
        assert!(heavy.io_contended <= heavy.io * (1.0 + CONTENTION_KAPPA) + 1e-9);
    }

    #[test]
    fn uniform_routing_with_zero_pinning_is_f64_identical() {
        // The refactor's identity contract: announcing a routing trace
        // while keeping pinned_experts = 0 must leave the virtual clock
        // bit-for-bit untouched (the residency gate is off, so every pass
        // sweeps the full model exactly as before).
        let base = small_sim(70);
        let mut routed = small_sim(70);
        routed.routing = Some(RoutingSpec::uniform());
        routed.pinned_experts = 0;
        let (t0, r0) = run_uniform(base, 98, 32, 300);
        let (t1, r1) = run_uniform(routed, 98, 32, 300);
        assert_eq!(r0.wall_secs.to_bits(), r1.wall_secs.to_bits());
        assert_eq!(t0.passes.len(), t1.passes.len());
        for (a, b) in t0.passes.iter().zip(&t1.passes) {
            assert_eq!(a.t_end.to_bits(), b.t_end.to_bits());
            assert_eq!(a.duration.to_bits(), b.duration.to_bits());
            assert_eq!(a.io_time.to_bits(), b.io_time.to_bits());
            assert_eq!(a.generated, b.generated);
        }
    }

    #[test]
    fn pinning_hot_experts_cuts_io_under_skew() {
        // Zipf-skewed routing concentrates activations on a few experts
        // per layer; pinning the hottest one per layer must strictly
        // shrink the streamed sweep (and thus exposed IO) versus the
        // blind full-model stream, without changing token accounting.
        let mut blind = small_sim(70);
        blind.routing = Some(RoutingSpec::zipf(1.2, 7));
        let mut pinned = blind.clone();
        pinned.pinned_experts = 1;
        let (tb, rb) = run_uniform(blind, 98, 32, 300);
        let (tp, rp) = run_uniform(pinned, 98, 32, 300);
        assert_eq!(rb.generated_tokens, rp.generated_tokens);
        let io = |t: &Trace| t.passes.iter().map(|p| p.io_time).sum::<f64>();
        assert!(
            io(&tp) < io(&tb),
            "pinned exposed IO {} must undercut blind {}",
            io(&tp),
            io(&tb)
        );
        assert!(rp.wall_secs < rb.wall_secs);
    }

    #[test]
    fn pipelined_residency_matches_unpinned_token_accounting() {
        // The +2-prefetched head layers stream predicted experts and top
        // up the misses; scheduling decisions (token counts, finishes)
        // must not depend on the residency map.
        let mut cfg = small_sim(70);
        cfg.pipeline_depth = 1;
        cfg.host_plan = HostPlanCost::new(1e-3, 1e-6);
        cfg.routing = Some(RoutingSpec::zipf(1.0, 11));
        cfg.pinned_experts = 1;
        let (trace, report) = run_uniform(cfg, 98, 32, 300);
        assert_eq!(report.generated_tokens, 300 * 32);
        for p in &trace.passes {
            assert!(
                (p.lanes_total() - p.duration).abs() < 1e-9,
                "pass {}: lanes {} vs duration {}",
                p.pass_id,
                p.lanes_total(),
                p.duration
            );
        }
    }

    #[test]
    #[should_panic(expected = "exceeds HBM expert budget")]
    fn pinned_set_over_hbm_budget_is_rejected() {
        // 16 GB of serving HBM holds 48 Mixtral-8x7B experts; pinning two
        // per layer across 32 layers asks for 64 and must panic loudly.
        let mut cfg = small_sim(70);
        cfg.pinned_experts = 2;
        SimMachine::new(cfg);
    }
}
