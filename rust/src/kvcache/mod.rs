//! Paged KV cache (§2, §5.5).
//!
//! The KV cache lives in **CPU memory** (the paper's defining resource
//! constraint) and is organized vLLM-style into fixed-size blocks of `b`
//! token slots. Two pieces:
//!
//! * [`layout`] — block allocator + per-sequence page tables. Pure
//!   capacity accounting, shared by the real engine and the `simhw`
//!   simulator (which never materializes data).
//! * [`store`] — the BF16 data pools behind the layout, written by the
//!   engine (K/V offloaded from "GPU" task A) and scanned by the CPU
//!   decode-attention kernel (`cpuattn`).

pub mod layout;
pub mod store;

pub use layout::{BlockAllocator, KvLayout, PagedLayout, PageTable, SeqId};
pub use store::PagedKvCache;
