//! BF16 data pools behind the paged layout: the real engine's KV cache.
//!
//! Values are stored as raw BF16 bits (`u16`) — the paper's storage format
//! (§5.3) — and up-converted to f32 by the CPU attention kernel. Each
//! layer owns one K pool and one V pool; a block's data is contiguous
//! (`block_size × kv_dim` elements), which is what lets the optimized
//! kernel walk the cache with long unit-stride runs.

use super::layout::{KvLayout, PagedLayout, SeqId};
use crate::util::bf16::f32_to_bf16;
use crate::util::cast::{u32_usize, u64_usize};

/// Per-layer K/V pools.
struct LayerPool {
    k: Vec<u16>,
    v: Vec<u16>,
}

/// The full paged KV cache: layout + data.
pub struct PagedKvCache {
    layout: PagedLayout,
    pools: Vec<LayerPool>,
    /// Elements per token slot (`n_kv_heads * head_dim`).
    kv_dim: usize,
}

impl PagedKvCache {
    pub fn new(layout: KvLayout, n_layers: usize, kv_dim: usize) -> Self {
        let pool_len = layout.n_blocks * layout.block_size * kv_dim;
        let pools = (0..n_layers)
            .map(|_| LayerPool { k: vec![0; pool_len], v: vec![0; pool_len] })
            .collect();
        PagedKvCache { layout: PagedLayout::new(layout), pools, kv_dim }
    }

    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }

    pub fn n_layers(&self) -> usize {
        self.pools.len()
    }

    /// The layout half, for scheduler queries (free blocks, lengths, ...).
    pub fn layout(&self) -> &PagedLayout {
        &self.layout
    }

    pub fn layout_mut(&mut self) -> &mut PagedLayout {
        &mut self.layout
    }

    /// Install a layout evolved elsewhere (the pipelined engine's
    /// committed speculative plan). Block ids are layer-invariant indices
    /// into the data pools and the speculative clone evolved from this
    /// cache's own layout via the deterministic allocator, so the pools
    /// stay consistent — data written under the old layout remains
    /// addressable wherever the new layout kept the page tables.
    pub fn replace_layout(&mut self, layout: PagedLayout) -> PagedLayout {
        // Always-on: once per committed pass, and a geometry mismatch
        // would silently misaddress every pool access afterwards.
        assert_eq!(layout.layout(), self.layout.layout(), "geometry must match");
        std::mem::replace(&mut self.layout, layout)
    }

    pub fn register(&mut self, id: SeqId) {
        self.layout.register(id);
    }

    /// Reserve `extra` token slots on `id` (all layers at once — block ids
    /// are layer-invariant). Returns the first reserved position.
    pub fn grow(&mut self, id: SeqId, extra: usize) -> Option<usize> {
        self.layout.grow(id, extra)
    }

    pub fn release(&mut self, id: SeqId) -> usize {
        self.layout.release(id)
    }

    /// Write one token's K/V for one layer at position `pos` (previously
    /// reserved via [`grow`]). `k`/`v` are f32 and are BF16-rounded on
    /// store, matching JAX `astype(bfloat16)` semantics.
    pub fn write(&mut self, id: SeqId, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let bs = self.layout.layout().block_size;
        let (block, slot) = self.layout.table(id).locate(pos, bs);
        let base = (u32_usize(block) * bs + slot) * self.kv_dim;
        let pool = &mut self.pools[layer];
        for i in 0..self.kv_dim {
            pool.k[base + i] = f32_to_bf16(k[i]);
            pool.v[base + i] = f32_to_bf16(v[i]);
        }
    }

    /// Bulk write of `n` consecutive tokens' K/V (raw BF16 bits) starting
    /// at position `pos` (previously reserved via [`grow`]). Runs are
    /// split at block boundaries and copied with `copy_from_slice`; bits
    /// are stored verbatim, so staging adapters that already hold BF16
    /// avoid the per-token f32 round-trip of [`write`].
    pub fn write_run(
        &mut self,
        id: SeqId,
        layer: usize,
        pos: usize,
        n: usize,
        k_bits: &[u16],
        v_bits: &[u16],
    ) {
        assert_eq!(k_bits.len(), n * self.kv_dim);
        assert_eq!(v_bits.len(), n * self.kv_dim);
        let bs = self.layout.layout().block_size;
        let kv_dim = self.kv_dim;
        let table = self.layout.table(id);
        let pool = &mut self.pools[layer];
        let mut done = 0usize;
        while done < n {
            let (block, slot) = table.locate(pos + done, bs);
            let seg = (bs - slot).min(n - done);
            let dst = (u64_usize(u64::from(block)) * bs + slot) * kv_dim;
            let src = done * kv_dim;
            let len = seg * kv_dim;
            pool.k[dst..dst + len].copy_from_slice(&k_bits[src..src + len]);
            pool.v[dst..dst + len].copy_from_slice(&v_bits[src..src + len]);
            done += seg;
        }
    }

    /// Visit the context of `id` in layer `layer` as contiguous per-block
    /// runs: `f(k_run, v_run, tokens_in_run)` where each run is
    /// `tokens_in_run * kv_dim` BF16 elements. This is the access pattern
    /// the optimized CPU attention kernel exploits.
    pub fn walk_context<F>(&self, id: SeqId, layer: usize, mut f: F)
    where
        F: FnMut(&[u16], &[u16], usize),
    {
        let bs = self.layout.layout().block_size;
        let table = self.layout.table(id);
        let pool = &self.pools[layer];
        let mut remaining = table.len;
        for &block in &table.blocks {
            if remaining == 0 {
                break;
            }
            let run = remaining.min(bs);
            let base = u32_usize(block) * bs * self.kv_dim;
            let len = run * self.kv_dim;
            f(&pool.k[base..base + len], &pool.v[base..base + len], run);
            remaining -= run;
        }
    }

    /// Gather the full (dense) context of `id` for one layer as f32 —
    /// test/oracle helper, not a hot path.
    pub fn gather_context(&self, id: SeqId, layer: usize) -> (Vec<f32>, Vec<f32>) {
        use crate::util::bf16::bf16_to_f32;
        let mut k = Vec::new();
        let mut v = Vec::new();
        self.walk_context(id, layer, |kr, vr, _| {
            k.extend(kr.iter().map(|&b| bf16_to_f32(b)));
            v.extend(vr.iter().map(|&b| bf16_to_f32(b)));
        });
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bf16::bf16_round;
    use crate::util::rng::Rng;

    fn cache() -> PagedKvCache {
        PagedKvCache::new(KvLayout::new(4, 8), 2, 6)
    }

    #[test]
    fn write_then_gather_roundtrips_bf16() {
        let mut c = cache();
        c.register(1);
        c.grow(1, 3);
        let mut rng = Rng::new(7);
        let mut expect_k = Vec::new();
        let mut expect_v = Vec::new();
        for pos in 0..3 {
            let k: Vec<f32> = (0..6).map(|_| rng.f32() * 3.0 - 1.5).collect();
            let v: Vec<f32> = (0..6).map(|_| rng.f32() * 3.0 - 1.5).collect();
            c.write(1, 0, pos, &k, &v);
            c.write(1, 1, pos, &v, &k); // layers are independent
            expect_k.extend(k.iter().map(|&x| bf16_round(x)));
            expect_v.extend(v.iter().map(|&x| bf16_round(x)));
        }
        let (k0, v0) = c.gather_context(1, 0);
        let (k1, v1) = c.gather_context(1, 1);
        assert_eq!(k0, expect_k);
        assert_eq!(v0, expect_v);
        assert_eq!(k1, expect_v);
        assert_eq!(v1, expect_k);
    }

    #[test]
    fn walk_context_runs_respect_block_boundaries() {
        let mut c = cache();
        c.register(9);
        c.grow(9, 10); // 3 blocks: runs of 4, 4, 2
        for pos in 0..10 {
            let k = vec![pos as f32; 6];
            c.write(9, 0, pos, &k, &k);
        }
        let mut runs = Vec::new();
        c.walk_context(9, 0, |kr, _, n| {
            assert_eq!(kr.len(), n * 6);
            runs.push(n);
        });
        assert_eq!(runs, vec![4, 4, 2]);
    }

    #[test]
    fn write_run_matches_per_token_writes_across_blocks() {
        use crate::util::bf16::f32_to_bf16;
        let mut a = cache();
        let mut b = cache();
        for c in [&mut a, &mut b] {
            c.register(1);
            c.register(2);
            c.grow(1, 3);
            c.grow(2, 2);
            c.grow(1, 7); // seq 1 spans non-adjacent blocks: 4 + 4 + 2 slots
        }
        let mut rng = Rng::new(5);
        let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..10)
            .map(|_| {
                let k: Vec<f32> = (0..6).map(|_| rng.f32() * 4.0 - 2.0).collect();
                let v: Vec<f32> = (0..6).map(|_| rng.f32() * 4.0 - 2.0).collect();
                (k, v)
            })
            .collect();
        for (pos, (k, v)) in toks.iter().enumerate() {
            a.write(1, 0, pos, k, v);
        }
        let k_bits: Vec<u16> =
            toks.iter().flat_map(|(k, _)| k.iter().map(|&x| f32_to_bf16(x))).collect();
        let v_bits: Vec<u16> =
            toks.iter().flat_map(|(_, v)| v.iter().map(|&x| f32_to_bf16(x))).collect();
        // one bulk call covering all three discontiguous blocks, plus a
        // partial overwrite starting mid-block
        b.write_run(1, 0, 0, 10, &k_bits, &v_bits);
        assert_eq!(a.gather_context(1, 0), b.gather_context(1, 0));
        b.write_run(1, 0, 3, 4, &k_bits[..4 * 6], &v_bits[..4 * 6]);
        for (pos, (k, v)) in toks.iter().take(4).enumerate() {
            a.write(1, 0, pos + 3, k, v);
        }
        assert_eq!(a.gather_context(1, 0), b.gather_context(1, 0));
    }

    #[test]
    fn interleaved_sequences_stay_isolated() {
        let mut c = cache();
        c.register(1);
        c.register(2);
        c.grow(1, 2);
        c.grow(2, 2);
        c.grow(1, 3); // interleaved growth -> interleaved blocks
        for pos in 0..5 {
            c.write(1, 0, pos, &vec![1.0; 6], &vec![1.0; 6]);
        }
        for pos in 0..2 {
            c.write(2, 0, pos, &vec![2.0; 6], &vec![2.0; 6]);
        }
        let (k1, _) = c.gather_context(1, 0);
        let (k2, _) = c.gather_context(2, 0);
        assert!(k1.iter().all(|&x| x == 1.0));
        assert!(k2.iter().all(|&x| x == 2.0));
        assert_eq!(k1.len(), 5 * 6);
        assert_eq!(k2.len(), 2 * 6);
    }

    #[test]
    fn release_recycles_data_blocks_safely() {
        let mut c = PagedKvCache::new(KvLayout::new(2, 2), 1, 2);
        c.register(1);
        c.grow(1, 4);
        c.write(1, 0, 3, &[9.0, 9.0], &[9.0, 9.0]);
        c.release(1);
        c.register(2);
        c.grow(2, 4);
        // stale data from seq 1 may remain but must be overwritable
        for pos in 0..4 {
            c.write(2, 0, pos, &[5.0, 5.0], &[5.0, 5.0]);
        }
        let (k, v) = c.gather_context(2, 0);
        assert!(k.iter().chain(v.iter()).all(|&x| x == 5.0));
    }
}
