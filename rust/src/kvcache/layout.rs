//! Block allocator + page tables: the capacity half of the paged KV cache.
//!
//! Block ids are *layer-invariant*: a sequence's token `t` occupies the
//! same (block, slot) coordinate in every layer's pool, so one allocation
//! covers all layers and the allocator's arithmetic matches
//! `ModelSpec::kv_bytes_per_token` (which already counts all layers).

use std::collections::BTreeMap;

use crate::util::cast::u32_usize;

/// Sequence identifier (assigned by the scheduler).
pub type SeqId = u64;

/// Static geometry of the paged cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLayout {
    /// Token slots per block (`b` in Eq. 8).
    pub block_size: usize,
    /// Total blocks (`N` in Eq. 8).
    pub n_blocks: usize,
}

impl KvLayout {
    pub fn new(block_size: usize, n_blocks: usize) -> Self {
        assert!(block_size >= 1 && n_blocks >= 1);
        KvLayout { block_size, n_blocks }
    }

    /// Blocks needed to hold `len` tokens: `⌈len/b⌉`.
    pub fn blocks_for(&self, len: usize) -> usize {
        len.div_ceil(self.block_size)
    }

    /// Total token slots.
    pub fn capacity_tokens(&self) -> usize {
        self.block_size * self.n_blocks
    }

    /// Lifetime block cost of a (p, g) sequence — the per-sequence term of
    /// Eq. 8's denominator. Used by the scheduler to decide admission.
    pub fn lifetime_blocks(&self, p: usize, g: usize) -> usize {
        (0..=g).map(|i| self.blocks_for(p + i)).sum()
    }
}

/// Free-list block allocator.
///
/// `Clone` is deliberate: speculative pass planning (the engine's
/// double-buffered pipeline) clones the whole layout, plans the next pass
/// on the clone, and commits it back iff the prediction held. Allocation
/// is deterministic (LIFO free list), so identical operation sequences on
/// a clone produce identical block assignments.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    layout: KvLayout,
    free: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(layout: KvLayout) -> Self {
        // LIFO free list; ids handed out ascending initially.
        let free = (0..layout.n_blocks as u32).rev().collect();
        BlockAllocator { layout, free }
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.layout.n_blocks - self.free.len()
    }

    pub fn alloc(&mut self) -> Option<u32> {
        self.free.pop()
    }

    pub fn release(&mut self, block: u32) {
        // A foreign block id corrupts every later alloc, so the bounds
        // check stays on in release builds (one compare per released
        // block). The double-free scan is O(free-list) and release runs
        // per block per finished sequence, so it stays debug-only.
        assert!(u32_usize(block) < self.layout.n_blocks);
        debug_assert!(!self.free.contains(&block), "double free of block {block}");
        self.free.push(block);
    }
}

/// Per-sequence page table: the ordered blocks backing its KV entries.
#[derive(Debug, Clone, Default)]
pub struct PageTable {
    pub blocks: Vec<u32>,
    /// Tokens currently cached.
    pub len: usize,
}

impl PageTable {
    /// (block, slot) coordinate of token `t`.
    pub fn locate(&self, t: usize, block_size: usize) -> (u32, usize) {
        // Hot per-token path: debug-only by design (the block index below
        // still bounds-checks in release).
        debug_assert!(t < self.len);
        (self.blocks[t / block_size], t % block_size)
    }
}

/// Page-table registry + allocator: the layout-only paged cache.
///
/// The engine pairs this with [`super::store::PagedKvCache`]'s data pools;
/// the simulator uses it alone. Cloning snapshots the full allocation
/// state (see [`BlockAllocator`]) for speculative pass planning.
#[derive(Debug, Clone)]
pub struct PagedLayout {
    alloc: BlockAllocator,
    tables: BTreeMap<SeqId, PageTable>,
}

impl PagedLayout {
    pub fn new(layout: KvLayout) -> Self {
        PagedLayout { alloc: BlockAllocator::new(layout), tables: BTreeMap::new() }
    }

    pub fn layout(&self) -> KvLayout {
        self.alloc.layout()
    }

    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    pub fn used_blocks(&self) -> usize {
        self.alloc.used_blocks()
    }

    pub fn n_seqs(&self) -> usize {
        self.tables.len()
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.tables.contains_key(&id)
    }

    pub fn table(&self, id: SeqId) -> &PageTable {
        &self.tables[&id]
    }

    pub fn len(&self, id: SeqId) -> usize {
        self.tables.get(&id).map_or(0, |t| t.len)
    }

    pub fn is_empty(&self) -> bool {
        self.tables.is_empty()
    }

    pub fn seq_ids(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.tables.keys().copied()
    }

    /// Register a new (empty) sequence. Panics on duplicate ids — the
    /// scheduler owns id assignment.
    pub fn register(&mut self, id: SeqId) {
        let prev = self.tables.insert(id, PageTable::default());
        assert!(prev.is_none(), "sequence {id} already registered");
    }

    /// Whether `extra` more tokens can be appended to `id` with the blocks
    /// currently free (the Decode Scheduler's §6.2 pre-check).
    pub fn can_grow(&self, id: SeqId, extra: usize) -> bool {
        let t = &self.tables[&id];
        let layout = self.alloc.layout();
        let need = layout.blocks_for(t.len + extra) - t.blocks.len();
        need <= self.alloc.free_blocks()
    }

    /// Reserve slots for `extra` tokens on `id`, allocating blocks as
    /// needed. Returns the first reserved position, or `None` (with no
    /// partial allocation) if the cache lacks blocks — the preemption
    /// trigger.
    pub fn grow(&mut self, id: SeqId, extra: usize) -> Option<usize> {
        let layout = self.alloc.layout();
        let Some(t) = self.tables.get_mut(&id) else {
            panic!("grow: unknown sequence {id}")
        };
        let target = layout.blocks_for(t.len + extra);
        let need = target - t.blocks.len();
        if need > self.alloc.free.len() {
            return None;
        }
        for _ in 0..need {
            let Some(block) = self.alloc.alloc() else {
                panic!("free list exhausted after fit check ({need} blocks)")
            };
            t.blocks.push(block);
        }
        let first = t.len;
        t.len += extra;
        Some(first)
    }

    /// Drop a sequence and release its blocks (decode-completion GC or
    /// preemption eviction). Returns how many blocks were freed.
    pub fn release(&mut self, id: SeqId) -> usize {
        let Some(t) = self.tables.remove(&id) else {
            panic!("release: unknown sequence {id}")
        };
        let n = t.blocks.len();
        for b in t.blocks {
            self.alloc.release(b);
        }
        n
    }

    /// Invariant check (used by property tests): every block is either
    /// free or owned by exactly one sequence.
    pub fn check_invariants(&self) {
        let layout = self.alloc.layout();
        let mut owner = vec![None::<SeqId>; layout.n_blocks];
        for (&id, t) in &self.tables {
            assert!(
                t.blocks.len() == layout.blocks_for(t.len),
                "seq {id}: {} blocks for len {}",
                t.blocks.len(),
                t.len
            );
            for &b in &t.blocks {
                assert!(owner[u32_usize(b)].is_none(), "block {b} double-owned");
                owner[u32_usize(b)] = Some(id);
            }
        }
        for &b in &self.alloc.free {
            assert!(owner[u32_usize(b)].is_none(), "free block {b} is owned");
            owner[u32_usize(b)] = Some(u64::MAX);
        }
        assert!(owner.iter().all(|o| o.is_some()), "leaked block");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn blocks_for_rounds_up() {
        let l = KvLayout::new(16, 100);
        assert_eq!(l.blocks_for(0), 0);
        assert_eq!(l.blocks_for(1), 1);
        assert_eq!(l.blocks_for(16), 1);
        assert_eq!(l.blocks_for(17), 2);
        assert_eq!(l.capacity_tokens(), 1600);
    }

    #[test]
    fn lifetime_blocks_matches_eq8_denominator() {
        let l = KvLayout::new(16, 1);
        let (p, g) = (98usize, 32usize);
        let manual: usize = (0..=g).map(|i| (p + i).div_ceil(16)).sum();
        assert_eq!(l.lifetime_blocks(p, g), manual);
    }

    #[test]
    fn grow_and_release_roundtrip() {
        let mut c = PagedLayout::new(KvLayout::new(4, 8));
        c.register(1);
        assert_eq!(c.grow(1, 5), Some(0)); // 2 blocks
        assert_eq!(c.used_blocks(), 2);
        assert_eq!(c.grow(1, 3), Some(5)); // fills block 2
        assert_eq!(c.used_blocks(), 2);
        assert_eq!(c.len(1), 8);
        assert_eq!(c.release(1), 2);
        assert_eq!(c.free_blocks(), 8);
        c.check_invariants();
    }

    #[test]
    fn grow_fails_atomically_when_full() {
        let mut c = PagedLayout::new(KvLayout::new(4, 2));
        c.register(1);
        c.register(2);
        assert!(c.grow(1, 4).is_some());
        assert!(c.grow(2, 4).is_some());
        // no free blocks: growing past the block boundary must fail whole
        assert!(!c.can_grow(1, 1));
        assert_eq!(c.grow(1, 1), None);
        assert_eq!(c.len(1), 4, "failed grow must not change length");
        c.check_invariants();
    }

    #[test]
    fn can_grow_within_partial_block_needs_no_alloc() {
        let mut c = PagedLayout::new(KvLayout::new(4, 1));
        c.register(7);
        assert!(c.grow(7, 2).is_some());
        assert_eq!(c.free_blocks(), 0);
        assert!(c.can_grow(7, 2)); // slots 2..4 are in the owned block
        assert!(!c.can_grow(7, 3));
    }

    #[test]
    fn locate_coordinates() {
        let mut c = PagedLayout::new(KvLayout::new(4, 4));
        c.register(1);
        c.grow(1, 10);
        let t = c.table(1);
        let (b0, s0) = t.locate(0, 4);
        let (b9, s9) = t.locate(9, 4);
        assert_eq!((b0, s0), (t.blocks[0], 0));
        assert_eq!((b9, s9), (t.blocks[2], 1));
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn duplicate_register_panics() {
        let mut c = PagedLayout::new(KvLayout::new(4, 4));
        c.register(1);
        c.register(1);
    }

    #[test]
    fn prop_alloc_release_never_leaks() {
        prop::check("kvcache_layout", |rng| {
            let bs = rng.range(1, 9);
            let nb = rng.range(1, 65);
            let mut c = PagedLayout::new(KvLayout::new(bs, nb));
            let mut live: Vec<SeqId> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        c.register(next_id);
                        live.push(next_id);
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = *rng.choose(&live);
                        let extra = rng.range(1, 2 * bs + 2);
                        let before = c.len(id);
                        match c.grow(id, extra) {
                            Some(first) => assert_eq!(first, before),
                            None => assert_eq!(c.len(id), before),
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.below(live.len() as u64) as usize;
                        // Order-preserving removal: the seeded replay of
                        // this property walk must visit sequences in a
                        // stable order (nondeterministic-order rule).
                        let id = live.remove(i);
                        c.release(id);
                    }
                    _ => {}
                }
                c.check_invariants();
            }
        });
    }
}
