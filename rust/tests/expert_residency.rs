//! Cross-layer contracts of expert-granular weight residency:
//!
//! 1. Routing traces are deterministic — same seed, bit-identical expert
//!    sets across independently constructed routers.
//! 2. The identity gate — uniform routing with `pinned_experts = 0` is
//!    f64-identical to the pre-refactor dense-streaming behavior, in the
//!    simulator and the analytic models.
//! 3. The HBM budget — a pinned set that exceeds the expert budget panics
//!    loudly (always-on assert, not a debug check).
//! 4. The engine (when artifacts exist) — expert-granular streaming is an
//!    IO-accounting change only: generated tokens are identical to the
//!    dense engine because every expert slot is fully staged.

use moe_lens::config::{MachineSpec, ModelSpec};
use moe_lens::perfmodel::{Stage1Model, hrm::HrmModel};
use moe_lens::simhw::{run_uniform, SimConfig};
use moe_lens::transfer::ResidencyMap;
use moe_lens::workload::{ExpertRouter, RoutingSpec};

#[test]
fn routing_is_bit_identical_across_router_instances() {
    let spec = ModelSpec::mixtral_8x7b();
    let a = ExpertRouter::new(&spec, RoutingSpec::zipf(1.2, 42));
    let b = ExpertRouter::new(&spec, RoutingSpec::zipf(1.2, 42));
    for req in [0u64, 7, 1 << 40] {
        for pos in [0usize, 1, 511] {
            for layer in [0usize, 15, 31] {
                assert_eq!(
                    a.experts_for(req, pos, layer),
                    b.experts_for(req, pos, layer),
                    "req {req} pos {pos} layer {layer}"
                );
            }
        }
    }
    // Different seeds diverge somewhere (sanity that the seed matters).
    let c = ExpertRouter::new(&spec, RoutingSpec::zipf(1.2, 43));
    let diverges = (0..64).any(|pos| {
        a.experts_for(0, pos, 0) != c.experts_for(0, pos, 0)
    });
    assert!(diverges, "seed must steer the routing trace");
}

#[test]
fn disabled_residency_is_f64_identical_across_the_stack() {
    // Simulator: announcing a routing trace with pinned = 0 must leave
    // every pass record bit-for-bit untouched.
    let base = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
    let mut routed = base.clone();
    routed.routing = Some(RoutingSpec::uniform());
    routed.pinned_experts = 0;
    let (t0, r0) = run_uniform(base, 98, 32, 400);
    let (t1, r1) = run_uniform(routed, 98, 32, 400);
    assert_eq!(r0.wall_secs.to_bits(), r1.wall_secs.to_bits());
    assert_eq!(r0.generated_tokens, r1.generated_tokens);
    assert_eq!(t0.passes.len(), t1.passes.len());
    for (a, b) in t0.passes.iter().zip(&t1.passes) {
        assert_eq!(a.t_end.to_bits(), b.t_end.to_bits());
        assert_eq!(a.duration.to_bits(), b.duration.to_bits());
        assert_eq!(a.io_time.to_bits(), b.io_time.to_bits());
        assert_eq!(a.gpu_time.to_bits(), b.gpu_time.to_bits());
        assert_eq!(a.cpu_time.to_bits(), b.cpu_time.to_bits());
    }

    // Analytic models: the routed δ collapses to the dense δ at pinned 0.
    let s1 = Stage1Model::new(MachineSpec::paper_testbed(), ModelSpec::mixtral_8x7b());
    assert_eq!(s1.delta_routed(1.2, 0, 4096).to_bits(), s1.delta().to_bits());
    let hrm = HrmModel::new(MachineSpec::paper_testbed(), ModelSpec::mixtral_8x7b());
    assert_eq!(
        hrm.decode_iter_secs_routed(128, 130, 1.2, 0).to_bits(),
        hrm.decode_iter_secs(128, 130).to_bits()
    );
}

#[test]
fn residency_never_exceeds_the_hbm_budget() {
    // Within budget: 16 GB of serving HBM holds 48 Mixtral experts, so
    // one pinned expert per layer (32 total) fits.
    let spec = ModelSpec::mixtral_8x7b();
    let router = ExpertRouter::new(&spec, RoutingSpec::zipf(1.0, 1));
    let budget = ResidencyMap::budget_from_bytes(16 << 30, spec.expert_bytes());
    assert_eq!(budget, 48);
    let map = ResidencyMap::pin_hottest(&router, 1, budget);
    assert_eq!(map.total_pinned(), 32);
    for layer in 0..spec.n_layers {
        assert_eq!(map.pinned(layer).len(), 1);
    }
}

#[test]
#[should_panic(expected = "exceeds HBM expert budget")]
fn over_budget_pinned_set_panics() {
    let spec = ModelSpec::mixtral_8x7b();
    let router = ExpertRouter::new(&spec, RoutingSpec::zipf(1.0, 1));
    let budget = ResidencyMap::budget_from_bytes(16 << 30, spec.expert_bytes());
    // Two per layer needs 64 slots; the 48-expert budget must refuse.
    ResidencyMap::pin_hottest(&router, 2, budget);
}

// -- Engine-level numerics (requires `make artifacts`, skipped otherwise,
// as in the unit tests — CI always builds artifacts first). --------------

mod engine {
    use moe_lens::engine::{EngineConfig, ServingEngine};
    use moe_lens::model::Request;
    use moe_lens::workload::RoutingSpec;

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    fn run(cfg: EngineConfig) -> Vec<Vec<i32>> {
        let mut eng = ServingEngine::load(cfg).unwrap();
        let p = eng.n_tok() / 4;
        let g = eng.n_tok() / 4;
        let vocab = eng.pjrt.config.vocab;
        let mut rng = moe_lens::util::rng::Rng::new(13);
        let reqs: Vec<Request> = (0..6)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
                Request::new(i as u64, prompt, g)
            })
            .collect();
        eng.run(reqs).unwrap();
        let mut fin = eng.sched.take_finished();
        fin.sort_by_key(|s| s.id());
        fin.into_iter().map(|s| s.generated).collect()
    }

    #[test]
    fn expert_streaming_never_changes_tokens() {
        if !have_artifacts() {
            return;
        }
        // Expert-granular residency only changes what the *link* is
        // charged for — every expert slot is fully staged before compute,
        // so generated tokens must match the dense engine exactly, both
        // synchronous and pipelined.
        for depth in [0usize, 1] {
            let mut dense = EngineConfig::for_model("tiny");
            dense.pipeline_depth = depth;
            let mut routed = EngineConfig::for_model("tiny");
            routed.pipeline_depth = depth;
            routed.pinned_experts = 1;
            routed.routing = Some(RoutingSpec::zipf(1.2, 5));
            assert_eq!(run(dense), run(routed), "pipeline depth {depth}");
        }
    }
}
