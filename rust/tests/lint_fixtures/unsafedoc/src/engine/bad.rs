// Fixture: unsafe blocks and impls without a Safety comment must fire.
pub fn read_raw(p: *const u32) -> u32 {
    unsafe { *p }
}

pub struct RawHolder(pub *const u32);

unsafe impl Send for RawHolder {}

#[cfg(test)]
mod tests {
    #[test]
    fn unsafe_in_tests_is_not_exempt() {
        let x = 7u32;
        // an unsound block corrupts test verdicts too, so no test carve-out
        let y = unsafe { *(&x as *const u32) };
        assert_eq!(y, 7);
    }
}
