// Fixture: documented unsafe sites and declaration-side unsafe are clean.
pub fn same_line(p: *const u32) -> u32 {
    unsafe { *p } // Safety: caller passes a live, aligned pointer
}

pub fn line_above(p: *const u32) -> u32 {
    // Safety: caller passes a live, aligned pointer
    unsafe { *p }
}

pub fn block_above(p: *const u32) -> u32 {
    // Safety: the pointer is produced from a reference two frames up and
    // outlives this call; alignment is guaranteed by the source type.
    unsafe { *p }
}

/// # Safety
/// `p` must be live and aligned.
pub unsafe fn decl_side(p: *const u32) -> u32 {
    // Safety: forwarded contract — see the function's Safety section.
    unsafe { *p }
}

/// # Safety
/// Implementors promise their bytes are plain old data.
pub unsafe trait PlainOldData {}

pub struct DocumentedHolder(pub *const u32);

// Safety: the pointer is only dereferenced under the owner's lock.
unsafe impl Send for DocumentedHolder {}
