// Fixture: allow suppresses undocumented-unsafe at audited sites.
pub fn audited(p: *const u32) -> u32 {
    // pallas-lint: allow(undocumented-unsafe) — audited in review
    unsafe { *p }
}
