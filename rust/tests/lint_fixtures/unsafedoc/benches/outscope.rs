// Fixture: the rule scopes to src/ only — bench code is exempt.
pub fn bench_peek(p: *const u32) -> u32 {
    unsafe { *p }
}
