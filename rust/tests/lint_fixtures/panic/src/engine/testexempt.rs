// Fixture: unwrap/expect inside #[cfg(test)] are exempt.
pub fn len(xs: &[i64]) -> usize {
    xs.len()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let xs = [1i64, 2];
        assert_eq!(*xs.first().unwrap(), 1);
        assert_eq!(*xs.last().expect("nonempty"), 2);
    }
}
