// Fixture: allow suppresses panic-policy at audited sites.
pub fn head(xs: &[i64]) -> i64 {
    // pallas-lint: allow(panic-policy) — caller guarantees nonempty
    *xs.first().unwrap()
}
