// Fixture: unwrap/expect in a library hot path must fire.
pub fn first_plus_one(xs: &[i64]) -> i64 {
    let head = xs.first().unwrap();
    let tail = xs.last().expect("nonempty");
    let soft = xs.get(1).copied().unwrap_or(0);
    head + tail + soft
}
