// Fixture: model/ is not an accounting module — casts do not fire.
pub fn dims(n: usize) -> f64 {
    n as f64
}
