// Fixture: unchecked narrowing casts in an accounting module must fire.
pub fn mix(n: usize, t: f64, b: u64) -> f64 {
    let x = n as f64;
    let y = t as usize;
    let z = b as u64 + y as u64;
    x + z as f64
}
