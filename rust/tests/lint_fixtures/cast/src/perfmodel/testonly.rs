// Fixture: casts inside #[cfg(test)] are exempt.
pub fn id(n: usize) -> usize {
    n
}

#[cfg(test)]
mod tests {
    #[test]
    fn casts_in_tests_are_fine() {
        let n = 3usize;
        assert_eq!(n as f64 as usize, n);
        assert_eq!(n as u64, 3);
    }
}
