// Fixture: provably-safe casts may carry an allow.
pub fn ratio(n: usize, total: usize) -> f64 {
    // pallas-lint: allow(unchecked-cast) — both operands bounded by the pass budget
    n as f64 / total as f64
}
