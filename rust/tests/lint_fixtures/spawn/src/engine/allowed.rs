//! Fixture: an allow directive suppresses the rule.

pub fn watchdog() {
    // detached by design: the process exits without joining telemetry
    // pallas-lint: allow(thread-spawn-policy)
    std::thread::spawn(|| {});
}
