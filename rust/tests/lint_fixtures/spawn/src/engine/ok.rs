//! Fixture: the blessed seams (`PlannerWorker`, `ThreadPool`) and scoped
//! threads are clean.

pub struct PlannerWorker {
    pub id: usize,
}

impl PlannerWorker {
    pub fn spawn(self) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let _ = self.id;
        })
    }
}

pub struct ThreadPool {
    pub workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let workers = (0..n).map(|_| std::thread::spawn(|| {})).collect();
        ThreadPool { workers }
    }
}

pub fn scoped_fanout(xs: &mut [u64]) {
    std::thread::scope(|s| {
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}
