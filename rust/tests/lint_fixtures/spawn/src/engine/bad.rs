//! Fixture: an ad-hoc thread outside the blessed seams fires.

pub fn fire_and_forget() {
    std::thread::spawn(move || {
        let _ = 1 + 1;
    });
}
