//! Fixture: #[cfg(test)] regions are exempt.

#[cfg(test)]
mod tests {
    #[test]
    fn helper_thread_in_tests_is_fine() {
        let h = std::thread::spawn(|| 2 + 2);
        assert_eq!(h.join().ok(), Some(4));
    }
}
