//! Fixture: benches/ is outside the thread-spawn-policy scope (the rule
//! covers src/ only — bench drivers own their thread lifetimes).

fn main() {
    let h = std::thread::spawn(|| {});
    let _ = h.join();
}
