//! Fixture: justified orderings are clean.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize, bytes: &AtomicU64) -> u64 {
    counter.fetch_add(1, Ordering::Relaxed); // Ordering: telemetry counter, nothing reads it for sync
    // Ordering: pairs the release in `publish` with the acquire here so
    // the payload write happens-before this load.
    let n = bytes.load(Ordering::Acquire);
    n
}
