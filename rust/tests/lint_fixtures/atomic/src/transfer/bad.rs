//! Fixture: undocumented relaxed-family orderings fire.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize, bytes: &AtomicU64) -> u64 {
    counter.fetch_add(1, Ordering::Relaxed);
    // this mentions Ordering::AcqRel but is path syntax, not a doc
    bytes.fetch_sub(8, Ordering::AcqRel);
    bytes.load(Ordering::SeqCst)
}
