//! Fixture: #[cfg(test)] regions are exempt.

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn relaxed_in_tests_is_fine() {
        let c = AtomicUsize::new(0);
        c.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }
}
