//! Fixture: an allow directive suppresses the rule.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize) {
    // pallas-lint: allow(atomic-ordering)
    counter.fetch_add(1, Ordering::Relaxed);
}
