//! Fixture: model/ is outside the atomic-ordering scope.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(counter: &AtomicUsize) {
    counter.fetch_add(1, Ordering::Relaxed);
}
