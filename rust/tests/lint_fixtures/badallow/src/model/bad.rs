//! Fixture: an allow directive naming an unknown rule is a hard error
//! (a typo would otherwise suppress nothing silently).

pub fn compare(x: f64) -> bool {
    // pallas-lint: allow(flaot-eq)
    x == 0.5
}
