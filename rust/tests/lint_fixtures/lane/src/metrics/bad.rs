// Fixture: a lane field missing from the partition must fire twice
// (lanes_total and to_csv) — the PR 1/PR 2 drift bug class — and a lane
// summed into the CSV row but unnamed in the header string fires once.
pub struct PassRecord {
    pub io_time: f64,
    pub gpu_time: f64,
    pub leaked_time: f64,
    pub kv_blocks_used: usize,
}

impl PassRecord {
    pub fn lanes_total(&self) -> f64 {
        self.io_time + self.gpu_time
    }

    pub fn to_csv(&self) -> String {
        format!("io_time,kv\n{},{},{}", self.io_time, self.gpu_time, self.kv_blocks_used)
    }
}
