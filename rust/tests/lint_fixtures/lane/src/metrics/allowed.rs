// Fixture: a deliberate shadow lane carries an allow (like the real
// host_overlap_time).
pub struct PassRecord {
    pub io_time: f64,
    pub shadow_time: f64, // pallas-lint: allow(lane-partition) — shadow, not a lane
}

impl PassRecord {
    pub fn lanes_total(&self) -> f64 {
        self.io_time
    }

    pub fn to_csv(&self) -> String {
        format!("io_time,shadow_time\n{},{}", self.io_time, self.shadow_time)
    }
}
