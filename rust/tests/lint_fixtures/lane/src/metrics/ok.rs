// Fixture: a complete partition — every lane in both functions.
pub struct PassRecord {
    pub io_time: f64,
    pub gpu_time: f64,
}

impl PassRecord {
    pub fn lanes_total(&self) -> f64 {
        self.io_time + self.gpu_time
    }

    pub fn to_csv(&self) -> String {
        format!("io_time,gpu_time\n{},{}", self.io_time, self.gpu_time)
    }
}
