//! Fixture: order-preserving removal, stable time-keyed sorts with an id
//! tiebreak, and pure retain predicates are the clean cluster idiom.

pub struct Retry {
    pub id: u64,
    pub due: f64,
    pub live: bool,
}

pub fn drain(queue: &mut Vec<Retry>, i: usize) -> Retry {
    queue.remove(i)
}

pub fn rank(queue: &mut [Retry]) {
    queue.sort_by(|a, b| {
        a.due
            .partial_cmp(&b.due)
            .expect("finite retry deadlines")
            .then_with(|| a.id.cmp(&b.id))
    });
}

pub fn sweep(queue: &mut Vec<Retry>) {
    queue.retain(|r| r.live && r.id > 0);
}
