//! Fixture: iteration-order hazards in the cluster driver fire — retry
//! and routing order feed the deterministic replay.

pub struct Retry {
    pub id: u64,
    pub due: f64,
    pub live: bool,
}

pub fn drain(queue: &mut Vec<Retry>, i: usize) -> Retry {
    queue.swap_remove(i)
}

pub fn rank(queue: &mut [Retry]) {
    queue.sort_unstable_by(|a, b| a.due.total_cmp(&b.due));
}

pub fn sweep(queue: &mut Vec<Retry>) -> usize {
    let mut failed = 0usize;
    queue.retain(|r| {
        if !r.live {
            failed += 1;
        }
        r.live
    });
    failed
}
