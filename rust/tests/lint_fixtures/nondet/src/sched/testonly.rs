//! Fixture: #[cfg(test)] regions are exempt.

#[cfg(test)]
mod tests {
    #[test]
    fn swap_remove_in_tests_is_fine() {
        let mut v = vec![1u64, 2, 3];
        assert_eq!(v.swap_remove(0), 1);
        let mut seen = 0usize;
        v.retain(|_| {
            seen += 1;
            true
        });
        assert_eq!(seen, 2);
    }
}
