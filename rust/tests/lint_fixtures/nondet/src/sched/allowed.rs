//! Fixture: an allow directive suppresses the rule.

pub fn drain(items: &mut Vec<u64>, i: usize) -> u64 {
    // order is re-established by the caller's sort below
    // pallas-lint: allow(nondeterministic-order)
    items.swap_remove(i)
}
