//! Fixture: order-preserving removal, int-keyed sorts, and pure retain
//! predicates are clean.

pub struct Item {
    pub id: u64,
    pub live: bool,
}

pub fn drain(items: &mut Vec<Item>, i: usize) -> Item {
    items.remove(i)
}

pub fn rank(items: &mut [Item]) {
    items.sort_unstable_by_key(|it| it.id);
    items.sort_unstable_by(|a, b| b.id.cmp(&a.id));
}

pub fn sweep(items: &mut Vec<Item>) {
    items.retain(|it| it.live && it.id > 0);
}
