//! Fixture: iteration-order hazards fire.

pub struct Item {
    pub id: u64,
    pub cost: f64,
    pub live: bool,
}

pub fn drain(items: &mut Vec<Item>, i: usize) -> Item {
    items.swap_remove(i)
}

pub fn rank(items: &mut [Item]) {
    items.sort_unstable_by(|a, b| a.cost.total_cmp(&b.cost));
}

pub fn sweep(items: &mut Vec<Item>) -> usize {
    let mut dropped = 0usize;
    items.retain(|it| {
        if !it.live {
            dropped += 1;
        }
        it.live
    });
    dropped
}
