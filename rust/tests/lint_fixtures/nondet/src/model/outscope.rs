//! Fixture: model/ is outside the nondeterministic-order scope.

pub fn drain(items: &mut Vec<u64>, i: usize) -> u64 {
    items.swap_remove(i)
}
