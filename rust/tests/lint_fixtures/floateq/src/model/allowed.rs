// Fixture: exact-zero sentinels may carry an allow.
pub fn is_unset(t: f64) -> bool {
    t == 0.0 // pallas-lint: allow(float-eq) — exact sentinel, never computed
}
