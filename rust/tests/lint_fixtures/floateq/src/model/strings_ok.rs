// Fixture: float equality in strings/comments must not fire.
// A comment saying x == 0.0 is not a violation.
pub fn describe() -> &'static str {
    "the guard `t == 0.0` is fine inside a string, as is != 1.5"
}

pub fn tolerant(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-9
}

pub fn integer_compare(n: usize) -> bool {
    n == 0
}
