// Fixture: direct float equality must fire.
pub fn degenerate(t: f64, eps: f64) -> bool {
    let zeroed = t == 0.0;
    let off = eps != 0.5;
    zeroed || off
}
