//! Fixture: a comma-list allow suppresses both rules on the site.

pub fn quantized(y: f64) -> f64 {
    let q = y as f32;
    // pallas-lint: allow(precision-laundering, unchecked-cast)
    q as f64
}
