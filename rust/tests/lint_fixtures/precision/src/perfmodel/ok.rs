//! Fixture: all-f64 arithmetic and typed literals are clean.

pub fn cost(a: f64, b: f64) -> f64 {
    let scaled = a * 0.5;
    scaled + b
}

pub fn typed_literal() -> f32 {
    0.5f32
}
