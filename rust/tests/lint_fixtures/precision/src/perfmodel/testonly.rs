//! Fixture: #[cfg(test)] regions are exempt.

#[cfg(test)]
mod tests {
    #[test]
    fn quantization_roundoff_is_bounded() {
        let y = 1.000_000_1_f64;
        let x = y as f32;
        assert!((x as f64 - y).abs() < 1e-6);
    }
}
