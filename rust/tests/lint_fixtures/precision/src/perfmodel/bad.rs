//! Fixture: f32 precision laundered into f64 fires. The `as f64` sites
//! also sit in the unchecked-cast scope; those carry a cast allow so the
//! precision violation is isolated (an allow for one rule must not
//! suppress another on the same line).

pub fn tainted_let(y: f64) -> f64 {
    let x = y as f32;
    let clean = y * 2.0;
    x as f64 + clean // pallas-lint: allow(unchecked-cast)
}

pub fn tainted_param(w: f32, n: f64) -> f64 {
    w as f64 * n // pallas-lint: allow(unchecked-cast)
}

pub fn truncated_literal() -> f32 {
    0.1 as f32
}
