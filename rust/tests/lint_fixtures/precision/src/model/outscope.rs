//! Fixture: model/ is outside the precision-laundering scope.

pub fn quantized(y: f64) -> f64 {
    let x = y as f32;
    x as f64
}
