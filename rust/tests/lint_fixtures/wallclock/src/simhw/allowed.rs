// Fixture: the allow directive suppresses wall-clock violations.
use std::time::Instant;

pub fn stamp() -> Instant {
    // pallas-lint: allow(wall-clock-in-sim) — fixture-sanctioned exception
    Instant::now()
}

pub fn stamp_trailing() -> Instant {
    Instant::now() // pallas-lint: allow(wall-clock-in-sim)
}
