// Fixture: wall-clock reads in a virtual-clock module must fire.
use std::time::{Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    let a = Instant::now();
    let b = SystemTime::now();
    (a, b)
}

pub fn in_string_is_fine() -> &'static str {
    "Instant::now() mentioned in a string does not fire"
}
