// Fixture: the engine is real-time code — wall-clock reads are fine here.
use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
