//! Fixture: replica-local virtual clocks are the clean idiom.

pub struct Replica {
    pub now: f64,
}

pub fn advance(r: &mut Replica, pass_secs: f64) {
    r.now += pass_secs;
}

pub fn in_string_is_fine() -> &'static str {
    "Instant::now() mentioned in a string does not fire"
}
