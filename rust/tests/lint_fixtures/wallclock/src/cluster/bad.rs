//! Fixture: wall-clock reads in the cluster driver must fire — replica
//! clocks are virtual, and real time would break multi-replica replay.
use std::time::{Instant, SystemTime};

pub fn stamp_routing_decision() -> (Instant, SystemTime) {
    let a = Instant::now();
    let b = SystemTime::now();
    (a, b)
}
