// Fixture: allow suppresses the unordered-iteration rule.
// pallas-lint: allow(unordered-iteration) — membership-only set, never iterated
use std::collections::HashSet;

pub fn seen(ids: &[u64]) -> usize {
    let s: HashSet<u64> = ids.iter().copied().collect(); // pallas-lint: allow(unordered-iteration)
    s.len()
}
