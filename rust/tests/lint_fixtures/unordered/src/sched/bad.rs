// Fixture: unordered containers in a deterministic module must fire.
use std::collections::{HashMap, HashSet};

pub fn plan(ids: &[u64]) -> usize {
    let m: HashMap<u64, usize> = ids.iter().map(|&i| (i, 1)).collect();
    let s: HashSet<u64> = ids.iter().copied().collect();
    m.len() + s.len()
}
