// Fixture: engine is not a deterministic-replay module — no finding.
use std::collections::HashMap;

pub fn index(ids: &[u64]) -> HashMap<u64, usize> {
    ids.iter().enumerate().map(|(k, &i)| (i, k)).collect()
}
