// Fixture: tests must also iterate deterministically (golden traces).
use std::collections::HashSet;

fn ids() -> HashSet<u64> {
    (0..4).collect()
}
