//! Property tests: the four CPU attention tiers agree with an f64 oracle
//! (and each other) across shapes — odd head_dims, head_dim ≥ 128, GQA
//! groups {1, 4, 8}, context lengths hitting 8-lane tails and block
//! boundaries, empty/singleton batches — and the threaded rung stays
//! bit-identical to single-thread. The ISSUE-8 acceptance gate.

use moe_lens::cpuattn::{
    decode_attention, decode_attention_tuned, simd_available, AttnShape, AttnTuning,
    DecodeQuery, ThreadPool, Tier,
};
use moe_lens::kvcache::{KvLayout, PagedKvCache, SeqId};
use moe_lens::util::bf16::bf16_round;
use moe_lens::util::prop::check;
use moe_lens::util::rng::Rng;

const REL_TOL: f32 = 1e-4;

/// Pure-f64 flash-free reference (two-pass softmax), mirroring
/// `kernels/ref.py::ref_decode_attention`.
fn oracle(shape: AttnShape, q: &[f32], k_ctx: &[f32], v_ctx: &[f32], len: usize) -> Vec<f32> {
    let (nh, hd) = (shape.n_heads, shape.head_dim);
    let group = shape.gqa_group();
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = vec![0f32; nh * hd];
    for h in 0..nh {
        let kvh = h / group;
        let qh = &q[h * hd..(h + 1) * hd];
        let mut scores = vec![0f64; len];
        for t in 0..len {
            let kt = &k_ctx[t * shape.kv_dim() + kvh * hd..];
            let mut dot = 0f64;
            for d in 0..hd {
                dot += qh[d] as f64 * kt[d] as f64;
            }
            scores[t] = dot * scale;
        }
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0f64;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            denom += *s;
        }
        for t in 0..len {
            let vt = &v_ctx[t * shape.kv_dim() + kvh * hd..];
            let w = scores[t] / denom;
            for d in 0..hd {
                out[h * hd + d] += (w * vt[d] as f64) as f32;
            }
        }
    }
    out
}

/// Random paged cache + bf16-rounded dense mirror for the oracle.
fn build_cache(
    shape: AttnShape,
    lens: &[usize],
    block_size: usize,
    rng: &mut Rng,
) -> (PagedKvCache, Vec<(Vec<f32>, Vec<f32>)>) {
    let total_blocks: usize = lens.iter().map(|&l| l.div_ceil(block_size)).sum::<usize>() + 1;
    let mut cache =
        PagedKvCache::new(KvLayout::new(block_size, total_blocks), 1, shape.kv_dim());
    let mut dense = Vec::new();
    for (i, &len) in lens.iter().enumerate() {
        let id = i as SeqId;
        cache.register(id);
        cache.grow(id, len);
        let mut kd = Vec::new();
        let mut vd = Vec::new();
        for pos in 0..len {
            let k: Vec<f32> = (0..shape.kv_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let v: Vec<f32> = (0..shape.kv_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect();
            cache.write(id, 0, pos, &k, &v);
            kd.extend(k.iter().map(|&x| bf16_round(x)));
            vd.extend(v.iter().map(|&x| bf16_round(x)));
        }
        dense.push((kd, vd));
    }
    (cache, dense)
}

/// A shape from the required corpus: GQA groups {1, 4, 8}, head_dims
/// including odd (7, 33) and ≥ 128 (128, 160).
fn random_shape(rng: &mut Rng) -> AttnShape {
    let group = *rng.choose(&[1usize, 4, 8]);
    let n_kv_heads = *rng.choose(&[1usize, 2]);
    let head_dim = *rng.choose(&[7usize, 16, 33, 64, 128, 160]);
    AttnShape { n_heads: group * n_kv_heads, n_kv_heads, head_dim }
}

/// Context lengths around 8-lane tails and block boundaries.
fn random_lens(rng: &mut Rng, block_size: usize) -> Vec<usize> {
    let n_seq = rng.range(1, 4);
    (0..n_seq)
        .map(|_| match rng.range(0, 3) {
            0 => rng.range(1, 3 * block_size), // arbitrary
            1 => block_size * rng.range(1, 3), // exactly on a boundary
            2 => block_size * rng.range(1, 3) + 1, // one past
            _ => *rng.choose(&[1usize, 7, 8, 9, 15, 16, 17]), // lane tails
        })
        .collect()
}

fn random_queries(rng: &mut Rng, shape: AttnShape, n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..shape.q_dim()).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], ctx: &str) {
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= REL_TOL * b.abs().max(1.0),
            "{ctx} elem {i}: {a} vs {b}"
        );
    }
}

#[test]
fn all_tiers_match_f64_oracle() {
    check("cpuattn-tiers-vs-oracle", |rng| {
        let shape = random_shape(rng);
        let block_size = *rng.choose(&[4usize, 8, 16, 32]);
        let lens = random_lens(rng, block_size);
        let (cache, dense) = build_cache(shape, &lens, block_size, rng);
        let qs = random_queries(rng, shape, lens.len());
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();
        let tuning = AttnTuning { partition: *rng.choose(&[1usize, 5, 16, 512]) };
        for tier in [Tier::Scalar, Tier::Unrolled, Tier::Simd, Tier::Optimized] {
            let mut out = vec![0f32; queries.len() * shape.q_dim()];
            decode_attention_tuned(&cache, 0, shape, &queries, &mut out, tier, tuning);
            for (i, &len) in lens.iter().enumerate() {
                let (kd, vd) = &dense[i];
                let want = oracle(shape, &qs[i], kd, vd, len);
                let got = &out[i * shape.q_dim()..(i + 1) * shape.q_dim()];
                assert_close(got, &want, &format!("{tier:?} seq {i} len {len}"));
            }
        }
    });
}

#[test]
fn simd_dispatch_and_portable_fallback_agree() {
    // Tier::Unrolled IS the forced portable fallback; Tier::Simd takes
    // the intrinsics body where the host has AVX2+FMA. Running both under
    // one property covers both dispatch paths regardless of host CPU.
    check("cpuattn-simd-vs-unrolled", |rng| {
        let shape = random_shape(rng);
        let block_size = *rng.choose(&[8usize, 16]);
        let lens = random_lens(rng, block_size);
        let (cache, _) = build_cache(shape, &lens, block_size, rng);
        let qs = random_queries(rng, shape, lens.len());
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();
        let mut a = vec![0f32; queries.len() * shape.q_dim()];
        let mut b = vec![0f32; queries.len() * shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut a, Tier::Unrolled);
        decode_attention(&cache, 0, shape, &queries, &mut b, Tier::Simd);
        if !simd_available() {
            // Degenerate dispatch: both took the portable body.
            assert_eq!(a, b, "fallback must be the unrolled kernel itself");
        } else {
            assert_close(&b, &a, "simd vs unrolled");
        }
    });
}

#[test]
fn threaded_is_bit_identical_to_single_thread() {
    let pools: Vec<ThreadPool> = [1usize, 3, 0].iter().map(|&n| ThreadPool::new(n)).collect();
    check("cpuattn-threaded-bit-identity", |rng| {
        let shape = random_shape(rng);
        let block_size = *rng.choose(&[8usize, 16]);
        let lens = random_lens(rng, block_size);
        let (cache, _) = build_cache(shape, &lens, block_size, rng);
        let qs = random_queries(rng, shape, lens.len());
        let queries: Vec<DecodeQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| DecodeQuery { seq: i as SeqId, q })
            .collect();
        let mut single = vec![0f32; queries.len() * shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut single, Tier::Optimized);
        for pool in &pools {
            let mut out = vec![0f32; queries.len() * shape.q_dim()];
            pool.decode_attention(&cache, 0, shape, &queries, &mut out);
            assert_eq!(out, single, "pool of {} threads", pool.n_threads());
        }
    });
}

#[test]
fn empty_and_singleton_batches() {
    let shape = AttnShape { n_heads: 4, n_kv_heads: 1, head_dim: 7 };
    let mut rng = Rng::new(99);
    let (cache, dense) = build_cache(shape, &[1], 4, &mut rng);
    let pool = ThreadPool::new(2);

    // Empty batch: every entry point is a no-op.
    let mut empty: [f32; 0] = [];
    for tier in [Tier::Scalar, Tier::Unrolled, Tier::Simd, Tier::Optimized] {
        decode_attention(&cache, 0, shape, &[], &mut empty, tier);
    }
    pool.decode_attention(&cache, 0, shape, &[], &mut empty);

    // Singleton batch over a singleton context.
    let q: Vec<f32> = (0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect();
    let queries = [DecodeQuery { seq: 0, q: &q }];
    let (kd, vd) = &dense[0];
    let want = oracle(shape, &q, kd, vd, 1);
    for tier in [Tier::Scalar, Tier::Unrolled, Tier::Simd, Tier::Optimized] {
        let mut out = vec![0f32; shape.q_dim()];
        decode_attention(&cache, 0, shape, &queries, &mut out, tier);
        assert_close(&out, &want, &format!("singleton {tier:?}"));
    }
    let mut out = vec![0f32; shape.q_dim()];
    pool.decode_attention(&cache, 0, shape, &queries, &mut out);
    assert_close(&out, &want, "singleton threaded");
}
