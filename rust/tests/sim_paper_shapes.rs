//! Paper-shape integration over the simulated testbed: claims from §5/§8
//! asserted end to end through the scheduler + simulator (not just the
//! analytic models), including workload-generator-driven batches and the
//! EOS mode.

use moe_lens::config::{ModelSpec, AIME, MTBENCH, RAG};
use moe_lens::model::Request;
use moe_lens::simhw::{run_uniform, SimConfig, SimMachine};
use moe_lens::util::rng::Rng;
use moe_lens::workload::{eos_gen_len, WorkloadGen};

#[test]
fn generated_lengths_drive_simulated_time() {
    // EOS mode (§8.1): shorter effective generations must reduce wall
    // time for the same request count.
    let cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
    let mut rng = Rng::new(9);
    let full: Vec<Request> =
        (0..800).map(|i| Request::new(i, vec![1; 98], 128)).collect();
    let eos: Vec<Request> = (0..800)
        .map(|i| Request::new(i, vec![1; 98], eos_gen_len(128, 0.5, &mut rng)))
        .collect();
    let (_, r_full) = SimMachine::new(cfg.clone()).run(full);
    let (_, r_eos) = SimMachine::new(cfg).run(eos);
    assert!(
        r_eos.wall_secs < r_full.wall_secs,
        "EOS {} vs full {}",
        r_eos.wall_secs,
        r_full.wall_secs
    );
    assert!(r_eos.generated_tokens < r_full.generated_tokens);
}

#[test]
fn workload_generators_run_through_the_simulator() {
    // Table-3-shaped batches (lognormal prompt lengths) through the full
    // scheduler+simulator path; all requests finish, counts conserve.
    for (wl, g) in [(&MTBENCH, 64usize), (&RAG, 128), (&AIME, 512)] {
        let gen = WorkloadGen::new(wl, g, 32_000);
        let reqs = gen.batch(300, 0, 123);
        let budget: usize = reqs.iter().map(|r| r.max_gen).sum();
        let cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 210);
        let (_, report) = SimMachine::new(cfg).run(reqs);
        assert_eq!(report.requests, 300, "{}", wl.name);
        assert_eq!(report.generated_tokens, budget, "{}", wl.name);
        assert_eq!(report.preemptions, 0, "{}: 210 GB is ample for K=300", wl.name);
    }
}

#[test]
fn prefill_heavy_workloads_have_higher_processed_throughput() {
    // The PME ordering (Eq. 3) must survive the full system: RAG-shaped
    // batches convert memory into parallel tokens better than AIME-shaped.
    let (_, rag) = run_uniform(
        SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70),
        926,
        128,
        600,
    );
    let (_, aime) = run_uniform(
        SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70),
        128,
        512,
        600,
    );
    assert!(
        rag.processed_throughput > aime.processed_throughput,
        "rag {} vs aime {}",
        rag.processed_throughput,
        aime.processed_throughput
    );
}

#[test]
fn per_model_throughput_ordering_follows_model_size() {
    // Bigger weights -> longer δ -> lower throughput at the same KV (the
    // Fig. 11 cross-model ordering: 8x7B > DBRX ≈ 8x22B).
    let t = |m: ModelSpec| {
        run_uniform(SimConfig::moe_lens(m, 70), 98, 64, 1500).1.generation_throughput
    };
    let small = t(ModelSpec::mixtral_8x7b());
    let dbrx = t(ModelSpec::dbrx());
    let big = t(ModelSpec::mixtral_8x22b());
    assert!(small > dbrx && small > big, "{small} {dbrx} {big}");
}

#[test]
fn gpu_utilization_high_when_cache_ample_mtbench_g32() {
    // §8.2: "GPU utilization approaches around 90%" for g=32 with ample
    // cache. Our sim measures GPU-busy share of overlapped iterations.
    // K must oversubscribe so admission keeps the pipeline at its token
    // budget (the paper's 25k-request regime).
    let (trace, report) = run_uniform(
        SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 210),
        98,
        32,
        30_000,
    );
    assert_eq!(report.preemptions, 0);
    // Middle-of-run passes (steady state) should be GPU-busy. GPU busy =
    // exclusive GPU time plus the GPU/CPU-overlapped window.
    let n = trace.passes.len();
    let mid = &trace.passes[n / 3..2 * n / 3];
    let util: f64 =
        mid.iter().map(|p| p.gpu_busy() / p.duration).sum::<f64>() / mid.len() as f64;
    assert!(util > 0.5, "steady-state GPU utilization {util} too low");
}
