//! Scheduling-policy integration tests on the simulated paper testbed:
//! SLO-aware admission vs. FIFO under overload, weighted vs. newest-first
//! preemption victims, PR-1 equivalence of the defaults, and the
//! exclusive-lane trace invariant. Everything runs on the virtual clock,
//! so every assertion is exact and reproducible.

use moe_lens::config::ModelSpec;
use moe_lens::metrics::{LatencyStats, RunReport, Trace};
use moe_lens::model::Request;
use moe_lens::sched::{AdmissionPolicy, VictimPolicy};
use moe_lens::simhw::{SimConfig, SimMachine};
use moe_lens::util::rng::Rng;
use moe_lens::workload::{with_deadlines, ArrivalProcess};

fn poisson_arrivals(
    rate: f64,
    k: usize,
    p: usize,
    g: usize,
    seed: u64,
) -> Vec<(f64, Request)> {
    let mut rng = Rng::new(seed);
    ArrivalProcess::Poisson { rate }
        .times(k, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, t)| (t, Request::new(i as u64, vec![1; p], g)))
        .collect()
}

/// SLO-aware admission must strictly beat FIFO goodput when the arrival
/// stream runs far past the machine's saturation rate. Under FIFO the
/// queue grows without bound, so all but the earliest requests blow
/// through the deadline and the run drags on serving hopeless work;
/// shedding keeps the admitted set feasible.
#[test]
fn slo_admission_beats_fifo_goodput_under_overload() {
    let (p, g, k) = (98usize, 32usize, 20_000usize);
    // ~1.25x the predicted request service time (~155 s on this
    // machine): tight enough that queueing kills FIFO, loose enough that
    // an admitted request meets it comfortably.
    let slo = 195.0;
    // 500 req/s into a machine whose KV cache sustains a few dozen:
    // deep overload, arrivals all land within ~40 s.
    let arrivals = with_deadlines(poisson_arrivals(500.0, k, p, g, 21), slo);

    let run = |admission: AdmissionPolicy| -> (RunReport, LatencyStats) {
        let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        cfg.admission = admission;
        let (_, report, lat) =
            SimMachine::new(cfg).run_online(arrivals.clone(), slo);
        (report, lat)
    };

    let (fifo_report, fifo) = run(AdmissionPolicy::Fifo);
    let (slo_report, shed) = run(AdmissionPolicy::slo());

    // FIFO serves everything eventually; goodput only counts the early
    // window that met the deadline.
    assert_eq!(fifo.completed, k);
    assert_eq!(fifo.rejected + fifo.expired, 0);
    assert!(fifo.goodput_rps > 0.0);

    // SLO admission sheds the hopeless majority and finishes far sooner.
    assert!(shed.rejected > 0, "overload must shed");
    assert_eq!(shed.completed + shed.rejected + shed.expired, k);
    assert!(shed.completed < k);
    assert!(slo_report.wall_secs < fifo_report.wall_secs);

    assert!(
        shed.goodput_rps > fifo.goodput_rps,
        "SLO admission goodput {:.3} req/s must strictly beat FIFO {:.3} req/s \
         (fifo completed {} over {:.0} s; slo completed {} over {:.0} s)",
        shed.goodput_rps,
        fifo.goodput_rps,
        fifo.completed,
        fifo_report.wall_secs,
        shed.completed,
        slo_report.wall_secs,
    );
}

/// Weighted victim selection equalizes preemption delay across the
/// batch (a delayed sequence loses slack and is protected next time),
/// while newest-first concentrates every eviction on the most recently
/// admitted sequences. With online arrivals the concentrated variant
/// shows up directly as a fatter end-to-end tail.
#[test]
fn weighted_victims_lower_preemption_e2e_tail() {
    // A Poisson stream offered above the 2 GB cache's KV-bound service
    // rate (~0.06 req/s here): the cache stays saturated, so preemption
    // churn is sustained over hundreds of passes with a mixed-age decode
    // pool (arrivals spread over ~30 min of virtual time).
    let (p, g, k) = (98usize, 256usize, 120usize);
    let arrivals = with_deadlines(poisson_arrivals(0.07, k, p, g, 13), 5_000.0);

    let run = |victim: VictimPolicy| -> (RunReport, LatencyStats) {
        let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        cfg.kv_bytes = 2 << 30;
        cfg.victim = victim;
        let (_, report, lat) =
            SimMachine::new(cfg).run_online(arrivals.clone(), f64::INFINITY);
        (report, lat)
    };

    let (newest_report, newest) = run(VictimPolicy::Newest);
    let (weighted_report, weighted) = run(VictimPolicy::Weighted);

    // Same load, same completion guarantee, preemption active in both.
    assert_eq!(newest.completed, k);
    assert_eq!(weighted.completed, k);
    assert!(newest_report.preemptions > 0, "tight cache must preempt");
    assert!(weighted_report.preemptions > 0, "tight cache must preempt");

    assert!(
        weighted.e2e_p99 < newest.e2e_p99,
        "weighted victim e2e p99 {:.1} s must undercut newest-first {:.1} s \
         (preemptions: weighted {}, newest {})",
        weighted.e2e_p99,
        newest.e2e_p99,
        weighted_report.preemptions,
        newest_report.preemptions,
    );
}

/// The policy layer must be invisible at the defaults: a run with
/// explicitly configured `fifo`/`newest` policies — and with deadlines
/// attached — is pass-for-pass identical to the default configuration
/// without deadlines (PR-1 behavior).
#[test]
fn default_policies_are_byte_identical_to_pr1_behavior() {
    let (p, g, k) = (98usize, 32usize, 400usize);
    let bare = poisson_arrivals(50.0, k, p, g, 7);
    let with_slo = with_deadlines(bare.clone(), 120.0);

    let run = |arrivals: Vec<(f64, Request)>, explicit: bool| -> (Trace, LatencyStats) {
        let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        if explicit {
            cfg.admission = AdmissionPolicy::Fifo;
            cfg.victim = VictimPolicy::Newest;
        }
        let (trace, _, lat) = SimMachine::new(cfg).run_online(arrivals, 120.0);
        (trace, lat)
    };

    let (t_default, l_default) = run(bare, false);
    let (t_explicit, l_explicit) = run(with_slo.clone(), true);
    let (t_deadlined, l_deadlined) = run(with_slo, false);

    for (a, b) in [(&t_default, &t_explicit), (&t_default, &t_deadlined)] {
        assert_eq!(a.passes.len(), b.passes.len());
        for (x, y) in a.passes.iter().zip(&b.passes) {
            assert_eq!(x.pass_id, y.pass_id);
            assert_eq!(x.t_end, y.t_end, "pass {}", x.pass_id);
            assert_eq!(x.duration, y.duration, "pass {}", x.pass_id);
            assert_eq!(x.prefill_tokens, y.prefill_tokens, "pass {}", x.pass_id);
            assert_eq!(x.decode_tokens, y.decode_tokens, "pass {}", x.pass_id);
            assert_eq!(x.generated, y.generated, "pass {}", x.pass_id);
            assert_eq!(x.finished, y.finished, "pass {}", x.pass_id);
            assert_eq!(x.preempted, y.preempted, "pass {}", x.pass_id);
            assert_eq!(x.io_time, y.io_time, "pass {}", x.pass_id);
            assert_eq!(x.gpu_time, y.gpu_time, "pass {}", x.pass_id);
            assert_eq!(x.cpu_time, y.cpu_time, "pass {}", x.pass_id);
            assert_eq!(x.overlap_time, y.overlap_time, "pass {}", x.pass_id);
            assert_eq!(x.kv_blocks_used, y.kv_blocks_used, "pass {}", x.pass_id);
            assert_eq!(x.active_decode, y.active_decode, "pass {}", x.pass_id);
        }
    }
    for l in [&l_explicit, &l_deadlined] {
        assert_eq!(l.completed, l_default.completed);
        assert_eq!(l.rejected + l.expired, 0, "defaults never shed");
        assert_eq!(l.ttft_p50, l_default.ttft_p50);
        assert_eq!(l.e2e_p99, l_default.e2e_p99);
        assert_eq!(l.goodput_rps, l_default.goodput_rps);
    }
}

/// Acceptance invariant: every simulator-produced `PassRecord`
/// decomposes its duration into the four exclusive lanes, across all
/// policy configurations (including preemption-heavy and shedding runs).
#[test]
fn sim_pass_lanes_partition_duration_across_policies() {
    let configs: Vec<(AdmissionPolicy, VictimPolicy, u64, f64, usize, usize)> = vec![
        (AdmissionPolicy::Fifo, VictimPolicy::Newest, 70, 50.0, 98, 32),
        (AdmissionPolicy::slo(), VictimPolicy::Weighted, 70, 300.0, 98, 32),
        (AdmissionPolicy::Fifo, VictimPolicy::Weighted, 2, 20.0, 98, 128),
    ];
    for (admission, victim, kv_gb, rate, p, g) in configs {
        let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        cfg.kv_bytes = kv_gb << 30;
        cfg.admission = admission;
        cfg.victim = victim;
        let arrivals = with_deadlines(poisson_arrivals(rate, 300, p, g, 5), 400.0);
        let (trace, _, _) = SimMachine::new(cfg).run_online(arrivals, 400.0);
        assert!(!trace.passes.is_empty());
        for rec in &trace.passes {
            assert!(
                (rec.lanes_total() - rec.duration).abs() < 1e-9,
                "kv={kv_gb}GB rate={rate}: pass {} lanes_total {} vs duration {}",
                rec.pass_id,
                rec.lanes_total(),
                rec.duration
            );
        }
    }
}
