//! Pipelined-vs-synchronous equivalence on the simulated paper testbed
//! (virtual clock ⇒ every assertion is exact): the double-buffered pass
//! pipeline must change *when* work happens, never *what* work happens —
//! same yielded tokens, same finished set, and identical per-request
//! TTFT/TPOT orderings at pass granularity. Plus the shed-only
//! bookkeeping regression and the SLO-forces-replan rule.

use moe_lens::config::ModelSpec;
use moe_lens::metrics::{RequestTracker, Trace};
use moe_lens::model::Request;
use moe_lens::sched::AdmissionPolicy;
use moe_lens::simhw::{HostPlanCost, SimConfig, SimMachine};
use moe_lens::util::rng::Rng;
use moe_lens::workload::{with_deadlines, ArrivalProcess};

fn poisson_arrivals(rate: f64, k: usize, p: usize, g: usize, seed: u64) -> Vec<(f64, Request)> {
    let mut rng = Rng::new(seed);
    ArrivalProcess::Poisson { rate }
        .times(k, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, t)| (t, Request::new(i as u64, vec![1; p], g)))
        .collect()
}

fn sim(kv_gb: u64, depth: usize, host: HostPlanCost) -> SimMachine {
    let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), kv_gb);
    cfg.pipeline_depth = depth;
    cfg.host_plan = host;
    SimMachine::new(cfg)
}

/// Map a tracker timestamp back to the index of the pass that stamped it
/// (token/finish stamps are exactly a pass's `t_end` on the virtual
/// clock).
fn pass_index(trace: &Trace, t: f64) -> usize {
    trace
        .passes
        .iter()
        .position(|p| p.t_end == t)
        .unwrap_or_else(|| panic!("timestamp {t} is not a pass boundary"))
}

/// Per-request (first-token pass, finish pass, token count) fingerprint.
fn fingerprints(trace: &Trace, tracker: &RequestTracker, k: usize) -> Vec<(usize, usize, usize)> {
    (0..k as u64)
        .map(|id| {
            let t = tracker.timing(id).expect("tracked");
            (
                pass_index(trace, t.first_token.expect("served")),
                pass_index(trace, t.finish.expect("finished")),
                t.generated,
            )
        })
        .collect()
}

/// Online arrivals, mixed prefill/decode, preemption-free: with pipelining
/// on (and a real host cost), every request gets its first token in the
/// same pass, finishes in the same pass, and generates the same tokens as
/// the synchronous schedule — so TTFT and TPOT *orderings* are identical;
/// only the clock differs. Mid-pass arrivals joining planning one pass
/// later must not reorder anything under FIFO.
#[test]
fn pipelined_online_run_preserves_per_request_orderings() {
    let (p, g, k) = (98usize, 32usize, 600usize);
    let arrivals = poisson_arrivals(40.0, k, p, g, 17);

    let (t_sync, r_sync, l_sync, trk_sync) =
        sim(70, 0, HostPlanCost::default()).run_online_tracked(arrivals.clone(), f64::INFINITY);
    let (t_pipe, r_pipe, l_pipe, trk_pipe) = sim(70, 1, HostPlanCost::new(0.02, 1e-6))
        .run_online_tracked(arrivals, f64::INFINITY);

    assert_eq!(l_sync.completed, k);
    assert_eq!(l_pipe.completed, k);
    assert_eq!(r_sync.generated_tokens, r_pipe.generated_tokens);

    let f_sync = fingerprints(&t_sync, &trk_sync, k);
    let f_pipe = fingerprints(&t_pipe, &trk_pipe, k);
    for (id, (a, b)) in f_sync.iter().zip(&f_pipe).enumerate() {
        // Pipelined admission can lag by at most one pass for mid-pass
        // arrivals; orderings must survive exactly, so compare the
        // *relative* order rather than absolute pass ids.
        assert_eq!(a.2, b.2, "request {id}: token counts must match");
    }
    // TTFT ordering: requests sorted by (first-token pass, id) come out
    // in the same sequence.
    let order = |f: &[(usize, usize, usize)]| -> Vec<usize> {
        let mut ids: Vec<usize> = (0..f.len()).collect();
        ids.sort_by_key(|&i| (f[i].0, i));
        ids
    };
    assert_eq!(order(&f_sync), order(&f_pipe), "first-token order must match");
    // TPOT/finish ordering likewise.
    let forder = |f: &[(usize, usize, usize)]| -> Vec<usize> {
        let mut ids: Vec<usize> = (0..f.len()).collect();
        ids.sort_by_key(|&i| (f[i].1, i));
        ids
    };
    assert_eq!(forder(&f_sync), forder(&f_pipe), "finish order must match");
}

/// Same property through the preemption path: a tight cache churns
/// sequences through evict → re-prefill while the pipeline speculates;
/// completion and token accounting must be unaffected.
#[test]
fn pipelined_preemption_churn_conserves_work() {
    let (p, g, k) = (98usize, 128usize, 48usize);
    let arrivals = poisson_arrivals(20.0, k, p, g, 4);
    let run = |depth: usize| {
        let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        cfg.kv_bytes = 2 << 30;
        cfg.pipeline_depth = depth;
        cfg.host_plan = HostPlanCost::new(0.01, 0.0);
        SimMachine::new(cfg).run_online(arrivals.clone(), f64::INFINITY)
    };
    let (t0, r0, l0) = run(0);
    let (t1, r1, l1) = run(1);
    assert!(r0.preemptions > 0 && r1.preemptions > 0, "tight cache must preempt");
    assert_eq!(l0.completed, k);
    assert_eq!(l1.completed, k);
    assert_eq!(r0.generated_tokens, r1.generated_tokens);
    assert_eq!(t0.passes.last().unwrap().kv_blocks_used, 0);
    assert_eq!(t1.passes.last().unwrap().kv_blocks_used, 0);
    // Lane partition holds across the preemption-heavy pipelined trace.
    for rec in &t1.passes {
        assert!(
            (rec.lanes_total() - rec.duration).abs() < 1e-9,
            "pass {}: lanes {} vs duration {}",
            rec.pass_id,
            rec.lanes_total(),
            rec.duration
        );
    }
}

/// SLO admission is time-dependent, so the pipeline must take the
/// synchronous replan path: host cost stays fully exposed, nothing is
/// speculatively hidden, shed-only planning rounds leave the trace
/// timestamps monotone (the zero-duration bookkeeping regression), and
/// drop accounting still balances.
#[test]
fn slo_admission_pipelined_replans_and_keeps_trace_monotone() {
    let (p, g, k) = (98usize, 32usize, 3000usize);
    let slo = 195.0;
    let arrivals = with_deadlines(poisson_arrivals(500.0, k, p, g, 21), slo);
    let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
    cfg.admission = AdmissionPolicy::slo();
    cfg.pipeline_depth = 1;
    cfg.host_plan = HostPlanCost::new(0.02, 0.0);
    let (trace, _, lat) = SimMachine::new(cfg).run_online(arrivals, slo);

    assert!(lat.rejected > 0, "overload must shed");
    assert_eq!(lat.completed + lat.rejected + lat.expired, k);
    // No speculation under SLO: every pass pays its host cost in full and
    // hides nothing.
    for rec in &trace.passes {
        assert_eq!(rec.host_overlap_time, 0.0, "pass {}", rec.pass_id);
        assert!(rec.host_time > 0.0, "pass {}", rec.pass_id);
        assert!((rec.lanes_total() - rec.duration).abs() < 1e-9, "pass {}", rec.pass_id);
    }
    // Shed rounds produce no pass but must never break monotonicity of
    // what is recorded.
    for w in trace.passes.windows(2) {
        assert!(w[0].t_end <= w[1].t_end, "trace timestamps regressed");
    }
    // The downsampled Fig.-13 series stays monotone for every width.
    for n in [1usize, 7, 25, 100] {
        let s = trace.series(n, |p| p.kv_blocks_used as f64);
        for w in s.windows(2) {
            assert!(w[0].0 <= w[1].0, "series regressed at n={n}");
        }
    }
}
