//! Cross-layer integration: the Rust VSLPipe engine (PJRT executables +
//! paged BF16 KV cache + CPU decode attention) must reproduce the JAX
//! oracle's greedy generation token-for-token (DESIGN.md §5).
//!
//! Requires `make artifacts` (skipped silently otherwise, as in the unit
//! tests — CI always builds artifacts first).

use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::{Golden, Request};
use moe_lens::transfer::LinkTiming;

fn golden() -> Option<Golden> {
    std::path::Path::new("artifacts/golden_tiny.json")
        .exists()
        .then(|| Golden::load("artifacts", "golden_tiny.json").unwrap())
}

fn engine() -> ServingEngine {
    ServingEngine::load(EngineConfig::for_model("tiny")).unwrap()
}

#[test]
fn greedy_generation_matches_jax_oracle() {
    let Some(g) = golden() else { return };
    let mut eng = engine();
    let reqs: Vec<Request> = g
        .generation
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), g.generation.steps))
        .collect();
    let (_, report) = eng.run(reqs).unwrap();
    assert_eq!(report.requests, 3);

    let mut finished = eng.sched.take_finished();
    finished.sort_by_key(|s| s.id());
    for (i, seq) in finished.iter().enumerate() {
        assert_eq!(
            seq.generated, g.generation.tokens[i],
            "sequence {i}: engine vs JAX oracle"
        );
    }
}

#[test]
fn batched_serving_equals_sequential_serving() {
    let Some(g) = golden() else { return };
    // Concurrent batch must not perturb numerics vs one-at-a-time.
    let mut eng_all = engine();
    let reqs: Vec<Request> = g
        .generation
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), 4))
        .collect();
    eng_all.run(reqs).unwrap();
    let mut batch = eng_all.sched.take_finished();
    batch.sort_by_key(|s| s.id());

    for (i, p) in g.generation.prompts.iter().enumerate() {
        let mut eng = engine();
        eng.run(vec![Request::new(i as u64, p.clone(), 4)]).unwrap();
        let solo = eng.sched.take_finished();
        assert_eq!(solo[0].generated, batch[i].generated, "prompt {i}");
    }
}

#[test]
fn throttled_link_still_correct() {
    let Some(g) = golden() else { return };
    // Timing policy must never change numerics.
    let mut cfg = EngineConfig::for_model("tiny");
    cfg.timing = LinkTiming::Virtual(50e9);
    let mut eng = ServingEngine::load(cfg).unwrap();
    let reqs = vec![Request::new(0, g.generation.prompts[0].clone(), g.generation.steps)];
    eng.run(reqs).unwrap();
    let fin = eng.sched.take_finished();
    assert_eq!(fin[0].generated, g.generation.tokens[0]);
    assert!(eng.link().total_bytes() > 0, "weights must stream via the link");
}

#[test]
fn preemption_under_tight_cache_preserves_results() {
    let Some(g) = golden() else { return };
    // A tiny cache forces preemption + re-prefill; greedy determinism
    // means the tokens must still match the oracle (§6.2: preempted
    // sequences resume with their progress replayed).
    let mut cfg = EngineConfig::for_model("tiny");
    cfg.block_size = 4;
    cfg.kv_blocks = 9; // 36 token slots for 3 sequences of up to 13 tokens
    let mut eng = ServingEngine::load(cfg).unwrap();
    let reqs: Vec<Request> = g
        .generation
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), g.generation.steps))
        .collect();
    let (_, report) = eng.run(reqs).unwrap();
    let mut fin = eng.sched.take_finished();
    fin.sort_by_key(|s| s.id());
    for (i, seq) in fin.iter().enumerate() {
        assert_eq!(seq.generated, g.generation.tokens[i], "sequence {i}");
    }
    // the point of the test: the cache was actually tight
    assert!(
        report.preemptions > 0 || report.passes > g.generation.steps,
        "expected cache pressure (preemptions={}, passes={})",
        report.preemptions,
        report.passes
    );
}

#[test]
fn run_is_reproduced_by_manual_step_loop() {
    let Some(g) = golden() else { return };
    // The tentpole invariant: `run()` is exactly a step() loop over a
    // closed batch — same generated tokens, same pass structure.
    let reqs = |_: ()| -> Vec<Request> {
        g.generation
            .prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request::new(i as u64, p.clone(), g.generation.steps))
            .collect()
    };

    let mut eng_run = engine();
    let (trace_run, _) = eng_run.run(reqs(())).unwrap();
    let mut run_fin = eng_run.sched.take_finished();
    run_fin.sort_by_key(|s| s.id());

    let mut eng_step = engine();
    for r in reqs(()) {
        eng_step.submit(r).unwrap();
    }
    let mut trace_step = eng_step.begin_run();
    while !eng_step.sched.is_done() {
        let step = eng_step.step().unwrap();
        assert_eq!(step.yielded.len(), step.record.generated);
        trace_step.push(step.record);
    }
    let mut step_fin = eng_step.sched.take_finished();
    step_fin.sort_by_key(|s| s.id());

    assert_eq!(trace_run.passes.len(), trace_step.passes.len());
    for (a, b) in trace_run.passes.iter().zip(&trace_step.passes) {
        assert_eq!(a.prefill_tokens, b.prefill_tokens, "pass {}", a.pass_id);
        assert_eq!(a.decode_tokens, b.decode_tokens, "pass {}", a.pass_id);
        assert_eq!(a.generated, b.generated, "pass {}", a.pass_id);
        assert_eq!(a.finished, b.finished, "pass {}", a.pass_id);
        assert_eq!(a.preempted, b.preempted, "pass {}", a.pass_id);
    }
    assert_eq!(run_fin.len(), step_fin.len());
    for (a, b) in run_fin.iter().zip(&step_fin) {
        assert_eq!(a.generated, b.generated, "sequence {}", a.id());
    }
    // And both match the JAX oracle.
    for (i, seq) in step_fin.iter().enumerate() {
        assert_eq!(seq.generated, g.generation.tokens[i], "sequence {i}");
    }
}

#[test]
fn online_with_zero_arrivals_matches_closed_batch() {
    let Some(g) = golden() else { return };
    let reqs: Vec<Request> = g
        .generation
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), g.generation.steps))
        .collect();
    let mut eng = engine();
    let arrivals: Vec<(f64, Request)> =
        reqs.into_iter().map(|r| (0.0, r)).collect();
    let (_, report, latency) = eng.run_online(arrivals, f64::INFINITY).unwrap();
    assert_eq!(report.requests, 3);
    assert_eq!(latency.completed, 3);
    let mut fin = eng.sched.take_finished();
    fin.sort_by_key(|s| s.id());
    for (i, seq) in fin.iter().enumerate() {
        assert_eq!(seq.generated, g.generation.tokens[i], "sequence {i}");
    }
    // Latency sanity on the wall clock.
    assert!(latency.ttft_p50 > 0.0);
    assert!(latency.e2e_p99 >= latency.ttft_p50);
}

#[test]
fn pipelined_and_synchronous_runs_are_equivalent() {
    let Some(g) = golden() else { return };
    // The pipeline acceptance invariant: pipeline_depth = 0 takes the
    // pre-pipeline code path, and depth 1 must produce the same tokens,
    // the same finished set, and the same pass-by-pass work — the
    // speculative plan commits to exactly what a synchronous replan would
    // have produced (host embedding gather included).
    let run = |depth: usize| {
        let mut cfg = EngineConfig::for_model("tiny");
        cfg.pipeline_depth = depth;
        let mut eng = ServingEngine::load(cfg).unwrap();
        let reqs: Vec<Request> = g
            .generation
            .prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request::new(i as u64, p.clone(), g.generation.steps))
            .collect();
        let (trace, _) = eng.run(reqs).unwrap();
        let stats = eng.pipeline_stats();
        let mut fin = eng.sched.take_finished();
        fin.sort_by_key(|s| s.id());
        (trace, fin, stats)
    };
    let (t_sync, fin_sync, s_sync) = run(0);
    let (t_pipe, fin_pipe, s_pipe) = run(1);

    assert_eq!(s_sync.speculated, 0, "depth 0 must never speculate");
    assert!(s_pipe.speculated > 0, "depth 1 must speculate");
    assert!(s_pipe.committed > 0, "budget-only finishes must commit");
    assert_eq!(s_pipe.replanned, 0, "no EOS in this workload => no replans");

    assert_eq!(t_sync.passes.len(), t_pipe.passes.len());
    for (a, b) in t_sync.passes.iter().zip(&t_pipe.passes) {
        assert_eq!(a.prefill_tokens, b.prefill_tokens, "pass {}", a.pass_id);
        assert_eq!(a.decode_tokens, b.decode_tokens, "pass {}", a.pass_id);
        assert_eq!(a.generated, b.generated, "pass {}", a.pass_id);
        assert_eq!(a.finished, b.finished, "pass {}", a.pass_id);
        assert_eq!(a.preempted, b.preempted, "pass {}", a.pass_id);
        assert_eq!(a.kv_blocks_used, b.kv_blocks_used, "pass {}", a.pass_id);
    }
    assert_eq!(fin_sync.len(), fin_pipe.len());
    for (a, b) in fin_sync.iter().zip(&fin_pipe) {
        assert_eq!(a.id(), b.id());
        assert_eq!(a.generated, b.generated, "sequence {}", a.id());
    }
    // Both match the oracle (the pipelined host-side embedding gather is
    // bit-exact with the PJRT gather).
    for (i, seq) in fin_pipe.iter().enumerate() {
        assert_eq!(seq.generated, g.generation.tokens[i], "sequence {i}");
    }
    // Lane sanity with the pipeline on: exposed + hidden host lanes are
    // recorded, non-negative, and the five-lane sum stays within the
    // pass wall clock's bookkeeping slack.
    for p in &t_pipe.passes {
        assert!(p.host_time >= 0.0 && p.host_overlap_time >= 0.0);
        assert!(p.host_busy() >= 0.0);
    }
}

#[test]
fn pipelined_eos_replan_path_matches_oracle() {
    let Some(g) = golden() else { return };
    // An EOS finish is the one event the speculative planner cannot
    // predict: it must invalidate the committed pass and replan, and the
    // output must be unaffected. Use the oracle's first token as EOS so
    // the replan path actually fires.
    let eos = g.generation.tokens[0][0];
    let mut cfg = EngineConfig::for_model("tiny");
    cfg.pipeline_depth = 1;
    let mut eng = ServingEngine::load(cfg).unwrap();
    let mut reqs: Vec<Request> = g
        .generation
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64, p.clone(), g.generation.steps))
        .collect();
    reqs[0] = reqs[0].clone().with_eos(eos);
    eng.run(reqs).unwrap();
    assert!(eng.pipeline_stats().replanned > 0, "EOS must force a replan");
    let mut fin = eng.sched.take_finished();
    fin.sort_by_key(|s| s.id());
    assert_eq!(fin[0].generated, vec![eos], "EOS stops sequence 0 after one token");
    for (i, seq) in fin.iter().enumerate().skip(1) {
        assert_eq!(seq.generated, g.generation.tokens[i], "sequence {i}");
    }
}

#[test]
fn eos_termination_stops_early() {
    let Some(g) = golden() else { return };
    // Use the oracle's first generated token as a synthetic EOS: the
    // sequence must stop after exactly one token.
    let eos = g.generation.tokens[0][0];
    let mut eng = engine();
    let req = Request::new(0, g.generation.prompts[0].clone(), 8).with_eos(eos);
    eng.run(vec![req]).unwrap();
    let fin = eng.sched.take_finished();
    assert_eq!(fin[0].generated, vec![eos]);
}
