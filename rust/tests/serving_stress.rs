//! Stress/soak integration for the real engine: mixed request shapes,
//! EOS termination, determinism, and resource-conservation invariants
//! under KV pressure. (Skipped when artifacts are absent, as elsewhere.)

use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::{Request, SeqPhase};
use moe_lens::util::rng::Rng;

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn mixed_requests(n: usize, n_tok: usize, vocab: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let p = rng.range(1, n_tok / 2);
            let g = rng.range(1, n_tok - p);
            let prompt: Vec<i32> = (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            Request::new(i as u64, prompt, g)
        })
        .collect()
}

#[test]
fn mixed_batch_all_finish_with_exact_budgets() {
    if !have_artifacts() {
        return;
    }
    let mut eng = ServingEngine::load(EngineConfig::for_model("tiny")).unwrap();
    let reqs = mixed_requests(24, eng.n_tok(), eng.pjrt.config.vocab, 11);
    let budgets: Vec<usize> = reqs.iter().map(|r| r.max_gen).collect();
    let (_, report) = eng.run(reqs).unwrap();
    assert_eq!(report.requests, 24);
    let mut fin = eng.sched.take_finished();
    assert_eq!(fin.len(), 24, "every sequence must finish");
    fin.sort_by_key(|s| s.id());
    for (seq, budget) in fin.iter().zip(&budgets) {
        assert_eq!(seq.phase, SeqPhase::Finished);
        assert_eq!(seq.generated.len(), *budget, "no EOS -> exact budget");
        let vocab = eng.pjrt.config.vocab as i32;
        assert!(seq.generated.iter().all(|&t| (0..vocab).contains(&t)));
    }
    assert_eq!(
        report.generated_tokens,
        budgets.iter().sum::<usize>(),
        "generated-token accounting"
    );
}

#[test]
fn runs_are_deterministic() {
    if !have_artifacts() {
        return;
    }
    let out: Vec<Vec<Vec<i32>>> = (0..2)
        .map(|_| {
            let mut eng = ServingEngine::load(EngineConfig::for_model("tiny")).unwrap();
            let reqs = mixed_requests(10, eng.n_tok(), eng.pjrt.config.vocab, 77);
            eng.run(reqs).unwrap();
            let mut fin = eng.sched.take_finished();
            fin.sort_by_key(|s| s.id());
            fin.into_iter().map(|s| s.generated).collect()
        })
        .collect();
    assert_eq!(out[0], out[1], "same requests, same engine, same tokens");
}

#[test]
fn kv_pressure_soak_conserves_blocks() {
    if !have_artifacts() {
        return;
    }
    // Cache sized so only a fraction of the batch fits at once: forces
    // queueing, overlap, and (depending on shapes) preemption; everything
    // must still finish and release every block.
    let mut cfg = EngineConfig::for_model("tiny");
    cfg.block_size = 4;
    cfg.kv_blocks = 12; // 48 token slots
    let mut eng = ServingEngine::load(cfg).unwrap();
    let reqs = mixed_requests(20, eng.n_tok(), eng.pjrt.config.vocab, 5);
    let (trace, report) = eng.run(reqs).unwrap();
    assert_eq!(eng.sched.finished().len(), 20);
    let last = trace.passes.last().unwrap();
    assert_eq!(last.kv_blocks_used, 0, "all blocks released at the end");
    assert!(report.passes >= 20 / 2, "tight cache cannot do it in few passes");
}

#[test]
fn online_arrivals_finish_under_tight_kv() {
    if !have_artifacts() {
        return;
    }
    // Online admission against a cache too small for the whole stream:
    // requests arrive while earlier ones are mid-decode, the scheduler
    // queues/preempts as needed, and everything must still finish with
    // exact budgets and zero leaked blocks.
    let mut cfg = EngineConfig::for_model("tiny");
    cfg.block_size = 4;
    cfg.kv_blocks = 12; // 48 token slots
    let mut eng = ServingEngine::load(cfg).unwrap();
    let reqs = mixed_requests(16, eng.n_tok(), eng.pjrt.config.vocab, 21);
    let budgets: Vec<usize> = reqs.iter().map(|r| r.max_gen).collect();
    // Arrivals spread over ~80 ms: several passes' worth of stagger for
    // the tiny model, so admission genuinely happens mid-flight.
    let arrivals: Vec<(f64, moe_lens::model::Request)> = reqs
        .into_iter()
        .enumerate()
        .map(|(i, r)| (i as f64 * 0.005, r))
        .collect();
    let (trace, report, latency) = eng.run_online(arrivals, f64::INFINITY).unwrap();
    assert_eq!(report.requests, 16);
    assert_eq!(latency.completed, 16);
    let mut fin = eng.sched.take_finished();
    assert_eq!(fin.len(), 16, "every sequence must finish");
    fin.sort_by_key(|s| s.id());
    for (seq, budget) in fin.iter().zip(&budgets) {
        assert_eq!(seq.phase, SeqPhase::Finished);
        assert_eq!(seq.generated.len(), *budget);
    }
    assert_eq!(trace.passes.last().unwrap().kv_blocks_used, 0);
    // Latency records are coherent: TTFT <= e2e per percentile, and the
    // report's token accounting matches the budgets.
    assert!(latency.ttft_p50 <= latency.e2e_p50);
    assert!(latency.ttft_p99 <= latency.e2e_p99);
    assert_eq!(report.generated_tokens, budgets.iter().sum::<usize>());
}

#[test]
fn pass_lanes_decompose_duration() {
    if !have_artifacts() {
        return;
    }
    // The Fig.-13 accounting fix: io + gpu + cpu + overlap must decompose
    // the pass wall clock (within bookkeeping slack) instead of
    // double-counting the overlapped window into the GPU lane. Summed over
    // a whole run to smooth scheduler noise.
    let mut eng = ServingEngine::load(EngineConfig::for_model("tiny")).unwrap();
    let reqs = mixed_requests(24, eng.n_tok(), eng.pjrt.config.vocab, 31);
    let (trace, _) = eng.run(reqs).unwrap();
    let lanes: f64 = trace.passes.iter().map(|p| p.lanes_total()).sum();
    let duration: f64 = trace.passes.iter().map(|p| p.duration).sum();
    assert!(duration > 0.0);
    let rel = (duration - lanes).abs() / duration;
    assert!(
        rel < 0.05,
        "lane times must decompose pass duration: lanes {lanes:.6} vs \
         duration {duration:.6} (rel err {rel:.3})"
    );
    // The overlapped window exists and is not double-counted: GPU busy
    // (gpu + overlap) never exceeds the pass duration.
    for p in &trace.passes {
        assert!(
            p.gpu_busy() <= p.duration * 1.02 + 1e-6,
            "pass {}: gpu busy exceeds duration",
            p.pass_id
        );
    }
}

#[test]
fn eos_mixed_with_budget_termination() {
    if !have_artifacts() {
        return;
    }
    let mut eng = ServingEngine::load(EngineConfig::for_model("tiny")).unwrap();
    let vocab = eng.pjrt.config.vocab as i32;
    // Half the requests treat *every* token as EOS-eligible by setting an
    // impossible EOS (never fires); the rest use token 0 (may fire).
    let mut reqs = mixed_requests(12, eng.n_tok(), eng.pjrt.config.vocab, 3);
    for (i, r) in reqs.iter_mut().enumerate() {
        if i % 2 == 0 {
            r.eos = Some(0);
        } else {
            r.eos = Some(vocab); // out of range: never generated
        }
    }
    let budgets: Vec<(usize, Option<i32>)> =
        reqs.iter().map(|r| (r.max_gen, r.eos)).collect();
    eng.run(reqs).unwrap();
    let mut fin = eng.sched.take_finished();
    fin.sort_by_key(|s| s.id());
    for (seq, (budget, eos)) in fin.iter().zip(&budgets) {
        assert!(seq.generated.len() <= *budget);
        if seq.generated.len() < *budget {
            assert_eq!(seq.generated.last().copied(), eos.as_ref().copied().map(|e| e));
        }
    }
}
