//! Integration tests for `pallas-lint`: fixture corpus per rule, the
//! ratchet mechanics end-to-end, and a self-check that the committed
//! baseline matches the live tree (the same check CI runs).
//!
//! Note: this file itself is scanned by the linter (tests/ is in the
//! unordered-iteration scope), so it deliberately avoids the banned
//! collection idents in code position.

use std::path::{Path, PathBuf};

use moe_lens::analysis::{Baseline, BASELINE_FILE, collect_files, counts, Rule, scan_root};

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_root(group: &str) -> PathBuf {
    crate_root().join("tests").join("lint_fixtures").join(group)
}

/// Scan a fixture group and return (file, rule, detail) triples, sorted.
fn scan_group(group: &str) -> Vec<(String, Rule, String)> {
    let mut v: Vec<(String, Rule, String)> = scan_root(&fixture_root(group))
        .expect("fixture scan")
        .into_iter()
        .map(|v| (v.file, v.rule, v.detail))
        .collect();
    v.sort();
    v
}

#[test]
fn wallclock_fires_in_sim_modules_and_suppresses() {
    let got = scan_group("wallclock");
    // simhw/bad.rs and cluster/bad.rs each fire twice; allowed.rs (allow
    // directives), cluster/ok.rs (virtual clocks), and engine/ok.rs (out
    // of scope) contribute nothing.
    assert_eq!(got.len(), 4, "violations: {got:?}");
    for scoped in ["src/simhw/bad.rs", "src/cluster/bad.rs"] {
        let details: Vec<&str> = got
            .iter()
            .filter(|(f, _, _)| f == scoped)
            .map(|(_, _, d)| d.as_str())
            .collect();
        assert_eq!(details.len(), 2, "violations in {scoped}: {got:?}");
        assert!(details.contains(&"Instant::now"), "details: {details:?}");
        assert!(details.contains(&"SystemTime::now"), "details: {details:?}");
    }
    for (_, rule, _) in &got {
        assert_eq!(*rule, Rule::WallClockInSim);
    }
}

#[test]
fn unordered_fires_in_det_modules_and_tests_dir() {
    let got = scan_group("unordered");
    assert!(got.iter().all(|(_, r, _)| *r == Rule::UnorderedIteration), "violations: {got:?}");
    let in_bad = got.iter().filter(|(f, _, _)| f == "src/sched/bad.rs").count();
    let in_tests = got.iter().filter(|(f, _, _)| f == "tests/bad_in_tests.rs").count();
    // bad.rs: two idents on the `use` line plus one per field; the rule
    // also covers the crate's own tests/ tree.
    assert_eq!(in_bad, 4, "violations: {got:?}");
    assert_eq!(in_tests, 2, "violations: {got:?}");
    assert_eq!(got.len(), in_bad + in_tests, "allowed.rs / engine/ok.rs must be clean: {got:?}");
}

#[test]
fn lane_partition_catches_drift_in_both_functions() {
    let got = scan_group("lane");
    // The leaked lane is reported once per function it is missing from;
    // gpu_time is in the CSV row but unnamed in the header string.
    assert_eq!(got.len(), 3, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/metrics/bad.rs");
        assert_eq!(*rule, Rule::LanePartition);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&"leaked_time missing from lanes_total"), "details: {details:?}");
    assert!(details.contains(&"leaked_time missing from to_csv"), "details: {details:?}");
    assert!(details.contains(&"gpu_time missing from to_csv header"), "details: {details:?}");
}

#[test]
fn unchecked_cast_fires_outside_tests_only() {
    let got = scan_group("cast");
    // bad.rs has five narrowing casts; allowed.rs carries an allow,
    // testonly.rs casts only under #[cfg(test)], model/ is out of scope.
    assert_eq!(got.len(), 5, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/perfmodel/bad.rs");
        assert_eq!(*rule, Rule::UncheckedCast);
    }
}

#[test]
fn panic_policy_fires_on_unwrap_and_expect_only() {
    let got = scan_group("panic");
    // .unwrap() and .expect( fire; .unwrap_or(..) does not. The
    // #[cfg(test)] module and the allow-carrying site are exempt.
    assert_eq!(got.len(), 2, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/engine/bad.rs");
        assert_eq!(*rule, Rule::PanicPolicy);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&".unwrap()"), "details: {details:?}");
    assert!(details.contains(&".expect("), "details: {details:?}");
}

#[test]
fn float_eq_fires_on_literal_compares_not_strings() {
    let got = scan_group("floateq");
    // bad.rs compares against 0.0 and 0.5; strings_ok.rs mentions the
    // pattern only inside strings/comments and uses epsilon/integer
    // compares; allowed.rs carries a trailing allow.
    assert_eq!(got.len(), 2, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/model/bad.rs");
        assert_eq!(*rule, Rule::FloatEq);
    }
}

#[test]
fn undocumented_unsafe_fires_in_src_including_tests() {
    let got = scan_group("unsafedoc");
    // bad.rs: a bare block, an `unsafe impl`, and a block under
    // #[cfg(test)] (no test carve-out for this rule). ok.rs (documented
    // sites + decl-side unsafe), allowed.rs, and benches/outscope.rs
    // (rule scopes to src/) contribute nothing.
    assert_eq!(got.len(), 3, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/engine/bad.rs");
        assert_eq!(*rule, Rule::UndocumentedUnsafe);
    }
}

#[test]
fn atomic_ordering_requires_justification_comment() {
    let got = scan_group("atomic");
    // bad.rs: an undocumented Relaxed and an AcqRel whose neighboring
    // comment only *mentions* Ordering::AcqRel (path syntax is not a
    // doc). SeqCst, documented sites (ok.rs), the allow-carrying site,
    // the #[cfg(test)] region, and model/ (out of scope) are clean.
    assert_eq!(got.len(), 2, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/transfer/bad.rs");
        assert_eq!(*rule, Rule::AtomicOrdering);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&"Ordering::Relaxed without // Ordering:"), "details: {details:?}");
    assert!(details.contains(&"Ordering::AcqRel without // Ordering:"), "details: {details:?}");
}

#[test]
fn nondet_order_flags_hazards_not_pure_uses() {
    let got = scan_group("nondet");
    // sched/bad.rs and cluster/bad.rs each carry the same three hazards:
    // swap_remove, a float-keyed unstable sort, and a retain closure
    // with a side effect. The ok.rs files (order-preserving remove,
    // int-keyed or stable sorts, pure retain), allowed.rs, testonly.rs,
    // and model/ contribute nothing.
    assert_eq!(got.len(), 6, "violations: {got:?}");
    for scoped in ["src/sched/bad.rs", "src/cluster/bad.rs"] {
        let details: Vec<&str> = got
            .iter()
            .filter(|(f, _, _)| f == scoped)
            .map(|(_, _, d)| d.as_str())
            .collect();
        assert_eq!(details.len(), 3, "violations in {scoped}: {got:?}");
        assert!(details.contains(&"swap_remove reorders the tail"), "details: {details:?}");
        assert!(
            details.contains(&"float-keyed sort_unstable_by is unstable among ties"),
            "details: {details:?}"
        );
        assert!(
            details.contains(&"retain closure with side effects"),
            "details: {details:?}"
        );
    }
    for (_, rule, _) in &got {
        assert_eq!(*rule, Rule::NondeterministicOrder);
    }
}

#[test]
fn precision_laundering_tracks_taint_across_bindings() {
    let got = scan_group("precision");
    // bad.rs: a tainted let binding widened to f64, a tainted f32
    // parameter widened to f64, and a float literal truncated via `as
    // f32`. The cast allows on the widening lines must not suppress the
    // precision rule; the comma-list allow in allowed.rs suppresses
    // both; testonly.rs and model/ are exempt/out of scope.
    assert_eq!(got.len(), 3, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/perfmodel/bad.rs");
        assert_eq!(*rule, Rule::PrecisionLaundering);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&"f32 `x` widened to f64"), "details: {details:?}");
    assert!(details.contains(&"f32 `w` widened to f64"), "details: {details:?}");
    assert!(details.contains(&"float literal `0.1` truncated to f32"), "details: {details:?}");
}

#[test]
fn thread_spawn_blessed_only_in_worker_and_pool_impls() {
    let got = scan_group("spawn");
    // bad.rs: one ad-hoc spawn. The PlannerWorker/ThreadPool impls, the
    // scoped spawn, the allow-carrying site, the #[cfg(test)] helper
    // thread, and benches/ (rule scopes to src/) are clean.
    assert_eq!(got.len(), 1, "violations: {got:?}");
    assert_eq!(got[0].0, "src/engine/bad.rs");
    assert_eq!(got[0].1, Rule::ThreadSpawnPolicy);
    assert_eq!(got[0].2, "thread::spawn outside PlannerWorker/ThreadPool");
}

#[test]
fn unknown_rule_in_allow_directive_is_a_hard_error() {
    let err = scan_root(&fixture_root("badallow")).expect_err("typo'd allow must not scan clean");
    let msg = err.to_string();
    assert!(msg.contains("unknown rule 'flaot-eq'"), "message: {msg}");
    assert!(msg.contains("src/model/bad.rs:5"), "message: {msg}");
}

#[test]
fn fixture_corpus_is_excluded_from_the_default_scan() {
    let files = collect_files(crate_root()).expect("walk crate");
    assert!(!files.is_empty());
    for f in &files {
        let s = f.to_string_lossy();
        assert!(!s.contains("lint_fixtures"), "fixture leaked into scan: {s}");
    }
}

/// The check CI runs: the committed baseline must exactly match the live
/// tree — no new violations, no stale (overpaid) entries. As of the v2
/// burn-down the committed baseline is *empty*, so this doubles as a
/// zero-violations check over the whole tree (`--deny-baseline` enforces
/// the same in CI).
#[test]
fn committed_baseline_is_clean_against_live_tree() {
    let baseline = Baseline::load(&crate_root().join(BASELINE_FILE)).expect("load baseline");
    assert_eq!(
        baseline.total(),
        0,
        "the ratchet burned to zero in v2 and must stay there; carried debt: {:?}",
        baseline.files
    );
    let violations = scan_root(crate_root()).expect("scan crate");
    let actual = counts(&violations);
    let report = baseline.check(&actual);
    if !report.is_clean() {
        for v in &violations {
            eprintln!("{}:{}: {} ({})", v.file, v.line, v.rule.name(), v.detail);
        }
        panic!(
            "live tree has {} violation(s) over the empty baseline — fix them or \
             justify each site with `// pallas-lint: allow(<rule>)`",
            violations.len()
        );
    }
}

/// The committed empty-baseline file is byte-identical to what
/// `--update-baseline` would write, so a refresh is never a diff.
#[test]
fn committed_baseline_bytes_are_canonical() {
    let text = std::fs::read_to_string(crate_root().join(BASELINE_FILE)).expect("read baseline");
    let parsed = Baseline::parse(&text).expect("parse baseline");
    assert_eq!(text, parsed.to_pretty_json(), "baseline not in canonical serialized form");
}

/// `scan_root` through a `..`-laden path produces the same repo-relative
/// keys once the root is canonicalized (what the binary does for
/// `--root`), so baselines agree across invoking directories.
#[test]
fn canonical_root_normalizes_dotted_paths() {
    let dotted = fixture_root("spawn").join("..").join("spawn");
    let canon = moe_lens::analysis::canonical_root(&dotted).expect("canonicalize");
    assert_eq!(canon, moe_lens::analysis::canonical_root(&fixture_root("spawn")).unwrap());
    let via_dotted = counts(&scan_root(&canon).expect("scan"));
    let direct = counts(&scan_root(&fixture_root("spawn")).expect("scan"));
    assert_eq!(via_dotted, direct);
    assert!(via_dotted.keys().all(|k| k.starts_with("src/")), "keys: {via_dotted:?}");
}

/// Ratchet end-to-end: a synthetic new violation on top of the live tree
/// must fail `--check`, and `--update-baseline` must refuse to absorb it.
#[test]
fn synthetic_new_violation_fails_check_and_update() {
    let baseline = Baseline::load(&crate_root().join(BASELINE_FILE)).expect("load baseline");
    let mut actual = counts(&scan_root(crate_root()).expect("scan crate"));
    *actual
        .entry("src/engine/vslpipe.rs".to_string())
        .or_default()
        .entry("wall-clock-in-sim".to_string())
        .or_insert(0) += 1;
    let report = baseline.check(&actual);
    assert_eq!(report.regressions.len(), 1, "report: {report:?}");
    let r = &report.regressions[0];
    assert_eq!(r.file, "src/engine/vslpipe.rs");
    assert_eq!(r.rule, "wall-clock-in-sim");
    assert_eq!(r.actual, r.baseline + 1);
    assert!(baseline.updated(&actual).is_err(), "update must refuse to raise a count");
}

/// Ratchet end-to-end: paying down debt makes a baseline stale (check
/// fails) and `--update-baseline` burns it down. The committed baseline
/// is empty now, so this runs against a synthetic one carrying the
/// fixture corpus as its debt.
#[test]
fn paid_down_debt_goes_stale_and_updates_downward() {
    let actual = counts(&scan_root(&fixture_root("nondet")).expect("scan fixture group"));
    let baseline = Baseline::from_counts(&actual);
    assert!(baseline.total() > 0, "fixture group must carry debt for this test");
    assert!(baseline.check(&actual).is_clean());
    // Retire one violation.
    let (file, rule, old) = baseline
        .files
        .iter()
        .flat_map(|(f, m)| m.iter().map(move |(r, &n)| (f.clone(), r.clone(), n)))
        .next()
        .expect("baseline has debt");
    assert!(old > 0);
    let mut paid = actual.clone();
    paid.get_mut(&file).expect("debt file present in scan").insert(rule.clone(), old - 1);
    let report = baseline.check(&paid);
    assert!(report.regressions.is_empty(), "report: {report:?}");
    assert_eq!(report.stale.len(), 1, "report: {report:?}");
    let refreshed = baseline.updated(&paid).expect("downward update permitted");
    assert!(refreshed.total() < baseline.total());
    let new_count = refreshed.files.get(&file).and_then(|m| m.get(&rule)).copied().unwrap_or(0);
    assert_eq!(new_count, old - 1);
}
