//! Integration tests for `pallas-lint`: fixture corpus per rule, the
//! ratchet mechanics end-to-end, and a self-check that the committed
//! baseline matches the live tree (the same check CI runs).
//!
//! Note: this file itself is scanned by the linter (tests/ is in the
//! unordered-iteration scope), so it deliberately avoids the banned
//! collection idents in code position.

use std::path::{Path, PathBuf};

use moe_lens::analysis::{Baseline, BASELINE_FILE, collect_files, counts, Rule, scan_root};

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_root(group: &str) -> PathBuf {
    crate_root().join("tests").join("lint_fixtures").join(group)
}

/// Scan a fixture group and return (file, rule, detail) triples, sorted.
fn scan_group(group: &str) -> Vec<(String, Rule, String)> {
    let mut v: Vec<(String, Rule, String)> = scan_root(&fixture_root(group))
        .expect("fixture scan")
        .into_iter()
        .map(|v| (v.file, v.rule, v.detail))
        .collect();
    v.sort();
    v
}

#[test]
fn wallclock_fires_in_sim_modules_and_suppresses() {
    let got = scan_group("wallclock");
    // bad.rs fires twice; allowed.rs (allow directives) and engine/ok.rs
    // (out of scope) contribute nothing.
    assert_eq!(got.len(), 2, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/simhw/bad.rs");
        assert_eq!(*rule, Rule::WallClockInSim);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&"Instant::now"), "details: {details:?}");
    assert!(details.contains(&"SystemTime::now"), "details: {details:?}");
}

#[test]
fn unordered_fires_in_det_modules_and_tests_dir() {
    let got = scan_group("unordered");
    assert!(got.iter().all(|(_, r, _)| *r == Rule::UnorderedIteration), "violations: {got:?}");
    let in_bad = got.iter().filter(|(f, _, _)| f == "src/sched/bad.rs").count();
    let in_tests = got.iter().filter(|(f, _, _)| f == "tests/bad_in_tests.rs").count();
    // bad.rs: two idents on the `use` line plus one per field; the rule
    // also covers the crate's own tests/ tree.
    assert_eq!(in_bad, 4, "violations: {got:?}");
    assert_eq!(in_tests, 2, "violations: {got:?}");
    assert_eq!(got.len(), in_bad + in_tests, "allowed.rs / engine/ok.rs must be clean: {got:?}");
}

#[test]
fn lane_partition_catches_drift_in_both_functions() {
    let got = scan_group("lane");
    // The leaked lane is reported once per function it is missing from;
    // gpu_time is in the CSV row but unnamed in the header string.
    assert_eq!(got.len(), 3, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/metrics/bad.rs");
        assert_eq!(*rule, Rule::LanePartition);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&"leaked_time missing from lanes_total"), "details: {details:?}");
    assert!(details.contains(&"leaked_time missing from to_csv"), "details: {details:?}");
    assert!(details.contains(&"gpu_time missing from to_csv header"), "details: {details:?}");
}

#[test]
fn unchecked_cast_fires_outside_tests_only() {
    let got = scan_group("cast");
    // bad.rs has five narrowing casts; allowed.rs carries an allow,
    // testonly.rs casts only under #[cfg(test)], model/ is out of scope.
    assert_eq!(got.len(), 5, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/perfmodel/bad.rs");
        assert_eq!(*rule, Rule::UncheckedCast);
    }
}

#[test]
fn panic_policy_fires_on_unwrap_and_expect_only() {
    let got = scan_group("panic");
    // .unwrap() and .expect( fire; .unwrap_or(..) does not. The
    // #[cfg(test)] module and the allow-carrying site are exempt.
    assert_eq!(got.len(), 2, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/engine/bad.rs");
        assert_eq!(*rule, Rule::PanicPolicy);
    }
    let details: Vec<&str> = got.iter().map(|(_, _, d)| d.as_str()).collect();
    assert!(details.contains(&".unwrap()"), "details: {details:?}");
    assert!(details.contains(&".expect("), "details: {details:?}");
}

#[test]
fn float_eq_fires_on_literal_compares_not_strings() {
    let got = scan_group("floateq");
    // bad.rs compares against 0.0 and 0.5; strings_ok.rs mentions the
    // pattern only inside strings/comments and uses epsilon/integer
    // compares; allowed.rs carries a trailing allow.
    assert_eq!(got.len(), 2, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/model/bad.rs");
        assert_eq!(*rule, Rule::FloatEq);
    }
}

#[test]
fn undocumented_unsafe_fires_in_src_including_tests() {
    let got = scan_group("unsafedoc");
    // bad.rs: a bare block, an `unsafe impl`, and a block under
    // #[cfg(test)] (no test carve-out for this rule). ok.rs (documented
    // sites + decl-side unsafe), allowed.rs, and benches/outscope.rs
    // (rule scopes to src/) contribute nothing.
    assert_eq!(got.len(), 3, "violations: {got:?}");
    for (file, rule, _) in &got {
        assert_eq!(file, "src/engine/bad.rs");
        assert_eq!(*rule, Rule::UndocumentedUnsafe);
    }
}

#[test]
fn fixture_corpus_is_excluded_from_the_default_scan() {
    let files = collect_files(crate_root()).expect("walk crate");
    assert!(!files.is_empty());
    for f in &files {
        let s = f.to_string_lossy();
        assert!(!s.contains("lint_fixtures"), "fixture leaked into scan: {s}");
    }
}

/// The check CI runs: the committed baseline must exactly match the live
/// tree — no new violations, no stale (overpaid) entries.
#[test]
fn committed_baseline_is_clean_against_live_tree() {
    let baseline = Baseline::load(&crate_root().join(BASELINE_FILE)).expect("load baseline");
    let actual = counts(&scan_root(crate_root()).expect("scan crate"));
    let report = baseline.check(&actual);
    if !report.is_clean() {
        for r in report.regressions.iter().chain(&report.stale) {
            let kind = if r.actual > r.baseline { "regression" } else { "stale" };
            eprintln!("{kind}: {} {} baseline {} actual {}", r.file, r.rule, r.baseline, r.actual);
        }
        panic!(
            "lint baseline out of date ({} regressions, {} stale) — \
             run `cargo run --release --bin pallas-lint -- --update-baseline`",
            report.regressions.len(),
            report.stale.len()
        );
    }
}

/// Ratchet end-to-end: a synthetic new violation on top of the live tree
/// must fail `--check`, and `--update-baseline` must refuse to absorb it.
#[test]
fn synthetic_new_violation_fails_check_and_update() {
    let baseline = Baseline::load(&crate_root().join(BASELINE_FILE)).expect("load baseline");
    let mut actual = counts(&scan_root(crate_root()).expect("scan crate"));
    *actual
        .entry("src/engine/vslpipe.rs".to_string())
        .or_default()
        .entry("wall-clock-in-sim".to_string())
        .or_insert(0) += 1;
    let report = baseline.check(&actual);
    assert_eq!(report.regressions.len(), 1, "report: {report:?}");
    let r = &report.regressions[0];
    assert_eq!(r.file, "src/engine/vslpipe.rs");
    assert_eq!(r.rule, "wall-clock-in-sim");
    assert_eq!(r.actual, r.baseline + 1);
    assert!(baseline.updated(&actual).is_err(), "update must refuse to raise a count");
}

/// Ratchet end-to-end: paying down debt makes the committed baseline
/// stale (check fails) and `--update-baseline` burns it down.
#[test]
fn paid_down_debt_goes_stale_and_updates_downward() {
    let baseline = Baseline::load(&crate_root().join(BASELINE_FILE)).expect("load baseline");
    let mut actual = counts(&scan_root(crate_root()).expect("scan crate"));
    // The committed baseline carries real debt; retire one entry.
    let (file, rule, old) = baseline
        .files
        .iter()
        .flat_map(|(f, m)| m.iter().map(move |(r, &n)| (f.clone(), r.clone(), n)))
        .next()
        .expect("baseline has debt");
    assert!(old > 0);
    let m = actual.get_mut(&file).expect("debt file present in scan");
    m.insert(rule.clone(), old - 1);
    let report = baseline.check(&actual);
    assert!(report.regressions.is_empty(), "report: {report:?}");
    assert_eq!(report.stale.len(), 1, "report: {report:?}");
    let refreshed = baseline.updated(&actual).expect("downward update permitted");
    assert!(refreshed.total() < baseline.total());
    let new_count = refreshed.files.get(&file).and_then(|m| m.get(&rule)).copied().unwrap_or(0);
    assert_eq!(new_count, old - 1);
}
