//! Table 2: tokens + KV-cache size needed to saturate GPU compute
//! (Mixtral-8x7B, nominal PCIe 4.0 at B = 32 GB/s; Eq. 2).

use moe_lens::config::{GpuSpec, MachineSpec, ModelSpec};
use moe_lens::perfmodel::Stage1Model;
use moe_lens::util::bench::{banner, Table};

fn main() {
    banner("table2", "KV cache size needed to saturate GPU compute (Eq. 2)");
    // (gpu, paper TFLOPS, paper tokens, paper KV GB @256, @512)
    let rows = [
        (GpuSpec::a40(), 150.0, 19_200.0, 614.0, 1228.0),
        (GpuSpec::l40(), 181.0, 23_200.0, 741.0, 1482.0),
        (GpuSpec::a100(), 312.0, 40_000.0, 1277.0, 2554.0),
    ];
    let model = ModelSpec::mixtral_8x7b();
    let mut t = Table::new(&[
        "gpu", "TFLOPS", "tokens_paper", "tokens_ours", "kv256_paper_GB",
        "kv256_ours_GB", "kv512_paper_GB", "kv512_ours_GB",
    ]);
    for (gpu, tflops, tok_paper, kv256_paper, kv512_paper) in rows {
        let s1 = Stage1Model::new(MachineSpec::nominal(gpu.clone()), model.clone());
        let tok = s1.tokens_to_saturate();
        let kv256 = s1.kv_bytes_to_saturate(256) / 1e9;
        let kv512 = s1.kv_bytes_to_saturate(512) / 1e9;
        t.row(&[
            gpu.name.to_string(),
            format!("{tflops:.0}"),
            format!("{tok_paper:.0}"),
            format!("{tok:.0}"),
            format!("{kv256_paper:.0}"),
            format!("{kv256:.0}"),
            format!("{kv512_paper:.0}"),
            format!("{kv512:.0}"),
        ]);
        assert!((tok - tok_paper).abs() / tok_paper < 0.05, "{}", gpu.name);
        assert!((kv512 - kv512_paper).abs() / kv512_paper < 0.08, "{}", gpu.name);
    }
    t.print();
    t.print_csv("table2");
}
