//! Online-serving latency: TTFT / TPOT / e2e percentiles and goodput vs
//! Poisson arrival rate, MoE-Lens on the simulated paper testbed
//! (Mixtral-8x7B, MTBench shape, 70 GB KV cache).
//!
//! The closed-batch figures (fig11/fig12) measure throughput with every
//! request available up front; this bench measures what a *continuously
//! loaded* deployment sees. Expected shape: TTFT is flat while the system
//! is underloaded, then grows sharply past the saturation rate (the knee
//! is the paper's sustainable-throughput claim restated in latency terms);
//! TPOT degrades only mildly (decode iterations stretch under
//! memory-controller contention, §8.2); goodput rises ~linearly with load
//! and collapses once the queue outruns the SLO.

use moe_lens::config::ModelSpec;
use moe_lens::model::Request;
use moe_lens::simhw::{SimConfig, SimMachine};
use moe_lens::util::bench::{banner, Table};
use moe_lens::util::rng::Rng;
use moe_lens::workload::{ArrivalProcess, WorkloadGen, MTBENCH};

fn main() {
    banner(
        "latency_online",
        "online TTFT/TPOT/e2e vs Poisson arrival rate (sim clock, 70 GB KV)",
    );
    let (p, g, k) = (98usize, 32usize, 3000usize);
    let slo_e2e = 600.0; // seconds on the virtual clock

    let mut t = Table::new(&[
        "rate_req_s",
        "ttft_p50_s",
        "ttft_p99_s",
        "tpot_p50_ms",
        "tpot_p99_ms",
        "e2e_p50_s",
        "e2e_p99_s",
        "goodput_req_s",
        "gen_tok_s",
    ]);
    let mut ttft_by_rate: Vec<(f64, f64)> = Vec::new();
    for rate in [5.0f64, 20.0, 50.0, 100.0, 200.0, 400.0] {
        let mut rng = Rng::new(0x1A7E);
        let times = ArrivalProcess::Poisson { rate }.times(k, &mut rng);
        let arrivals: Vec<(f64, Request)> = times
            .into_iter()
            .enumerate()
            .map(|(i, t)| (t, Request::new(i as u64, vec![1; p], g)))
            .collect();
        let cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        let (_, report, lat) = SimMachine::new(cfg).run_online(arrivals, slo_e2e);
        assert_eq!(lat.completed, k, "every request finishes at rate {rate}");
        ttft_by_rate.push((rate, lat.ttft_p50));
        t.row(&[
            format!("{rate:.0}"),
            format!("{:.2}", lat.ttft_p50),
            format!("{:.2}", lat.ttft_p99),
            format!("{:.1}", lat.tpot_p50 * 1e3),
            format!("{:.1}", lat.tpot_p99 * 1e3),
            format!("{:.1}", lat.e2e_p50),
            format!("{:.1}", lat.e2e_p99),
            format!("{:.2}", lat.goodput_rps),
            format!("{:.0}", report.generation_throughput),
        ]);
    }
    t.print();
    t.print_csv("latency_online");
    // Shape check at the sweep's endpoints only: adjacent underloaded
    // rates draw independent Poisson samples whose p50s can wiggle, but
    // 5 vs 400 req/s (far past saturation) must separate decisively. The
    // exact per-rate monotonicity property is asserted in the simhw unit
    // tests where both runs share a regime.
    let (lo, hi) = (ttft_by_rate.first().unwrap(), ttft_by_rate.last().unwrap());
    assert!(
        hi.1 > lo.1,
        "TTFT p50 at {} req/s ({:.2}s) must exceed {} req/s ({:.2}s)",
        hi.0,
        hi.1,
        lo.0,
        lo.1
    );

    // Bursty arrivals at the same average rate: burstiness costs tail
    // latency, not median throughput.
    let mut t = Table::new(&["process", "ttft_p50_s", "ttft_p99_s", "goodput_req_s"]);
    for (name, process) in [
        ("poisson", ArrivalProcess::Poisson { rate: 100.0 }),
        ("burst x16", ArrivalProcess::Burst { rate: 100.0, size: 16 }),
    ] {
        let gen = WorkloadGen::new(&MTBENCH, g, 32_000);
        let arrivals = gen.arrivals(&process, k, 0, 0x1A7E);
        let cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
        let (_, _, lat) = SimMachine::new(cfg).run_online(arrivals, slo_e2e);
        t.row(&[
            name.into(),
            format!("{:.2}", lat.ttft_p50),
            format!("{:.2}", lat.ttft_p99),
            format!("{:.2}", lat.goodput_rps),
        ]);
    }
    t.print();
    t.print_csv("latency_online_burst");
}
