//! Fig. 7: the pipeline profiler's n_real search — analytic (paper
//! constants, Mixtral-8x7B on A40) and *live* on the real PJRT engine
//! (`small` model): GPU pass time is measured at several token counts,
//! a line is fitted, and the threshold where GPU compute covers the
//! per-layer weight transfer is reported.

use moe_lens::config::{GpuSpec, MachineSpec, ModelSpec};
use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::model::Request;
use moe_lens::sched::PipelineProfiler;
use moe_lens::transfer::LinkTiming;
use moe_lens::util::bench::{banner, Table};
use moe_lens::util::stats::line_fit;

fn main() -> anyhow::Result<()> {
    banner("fig7a", "analytic profile: Mixtral-8x7B on A40 (paper constants)");
    let fit = PipelineProfiler::analytic(
        &MachineSpec::nominal(GpuSpec::a40()),
        &ModelSpec::mixtral_8x7b(),
    );
    println!("  slope      : {:.3} us/token", fit.line.slope * 1e6);
    println!("  layer IO   : {:.2} ms", fit.layer_io_secs * 1e3);
    println!("  n_real     : {} tokens (paper's Eq.-2 estimate: ~19.2k)", fit.n_real);
    assert!((fit.n_real as f64 - 19_200.0).abs() / 19_200.0 < 0.25);

    banner("fig7b", "live profile: GPU pass time vs token count ('small' on PJRT)");
    // Measure whole prefill passes at 1..=4 buckets by serving pure-
    // prefill batches (g = 1) and reading the trace's per-pass GPU time.
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut t = Table::new(&["tokens", "buckets", "gpu_ms_per_pass"]);
    for buckets in 1usize..=4 {
        let mut cfg = EngineConfig::for_model("small");
        cfg.timing = LinkTiming::Unthrottled;
        cfg.token_budget = buckets * 64;
        cfg.kv_blocks = 512;
        let mut engine = ServingEngine::load(cfg)?;
        let n_tok = engine.n_tok();
        // `buckets` requests with (n_tok - 1)-token prompts, 1 generated
        // token: pass 0 is a pure prefill pass of `buckets` full buckets.
        let reqs: Vec<Request> = (0..buckets)
            .map(|i| Request::new(i as u64, vec![(i + 1) as i32; n_tok - 1], 1))
            .collect();
        let (trace, _) = engine.run(reqs)?;
        let gpu = trace.passes[0].gpu_time;
        let tokens = buckets * n_tok;
        t.row(&[
            tokens.to_string(),
            buckets.to_string(),
            format!("{:.1}", gpu * 1e3),
        ]);
        xs.push(tokens as f64);
        ys.push(gpu);
    }
    t.print();
    t.print_csv("fig7b");

    let live = line_fit(&xs, &ys);
    println!(
        "  live fit: gpu_ms = {:.3} us/token * n + {:.1} ms  (r2 = {:.3})",
        live.slope * 1e6,
        live.intercept * 1e3,
        live.r2
    );
    // At which token count would GPU time cover a layer transfer on a
    // 2 GB/s link? (the threshold the scheduler would use on this box)
    let spec = ModelSpec::small();
    let layer_io = spec.layer_bytes() as f64 / 2e9; // f32 weights, 2 GB/s
    let n_real = (layer_io - live.intercept) / live.slope;
    if n_real < 1.0 {
        println!(
            "  layer IO at 2 GB/s: {:.1} ms < pass floor {:.1} ms -> this box is \
             GPU-bound at any token count (n_real < 1 bucket); the scheduler \
             would cap passes at one bucket",
            layer_io * 1e3,
            live.intercept * 1e3
        );
    } else {
        println!(
            "  layer IO at 2 GB/s: {:.1} ms -> n_real ≈ {n_real:.0} tokens",
            layer_io * 1e3
        );
    }
    assert!(live.slope > 0.0, "GPU time must grow with tokens");
    Ok(())
}
