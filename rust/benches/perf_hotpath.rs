//! §Perf: hot-path microbenchmarks for the optimization pass — engine
//! pass latency breakdown, CPU attention kernel throughput, data-mover
//! achieved bandwidth, and scheduler/KV overhead. EXPERIMENTS.md §Perf
//! records the before/after iterations against these numbers.

use std::sync::Arc;

use moe_lens::cpuattn::{decode_attention, AttnShape, DecodeQuery, ThreadPool, Tier};
use moe_lens::engine::{EngineConfig, ServingEngine};
use moe_lens::kvcache::{KvLayout, PagedKvCache, PagedLayout, SeqId};
use moe_lens::model::Request;
use moe_lens::sched::{SchedConfig, Scheduler};
use moe_lens::transfer::{DataMover, LinkTiming, PcieLink, WeightBuffer, WeightFile};
use moe_lens::util::bench::{banner, bench, Table};
use moe_lens::util::rng::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    banner("perf", "hot-path microbenchmarks (this box, wall clock)");

    // --- 1. Engine pass latency breakdown (small model, 2 buckets).
    let mut cfg = EngineConfig::for_model("small");
    cfg.kv_blocks = 512;
    let mut engine = ServingEngine::load(cfg)?;
    let n_tok = engine.n_tok();
    let vocab = engine.pjrt.config.vocab;
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..16)
        .map(|i| {
            let p = n_tok / 2;
            let prompt: Vec<i32> = (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            Request::new(i as u64, prompt, n_tok / 4)
        })
        .collect();
    let (trace, report) = engine.run(reqs)?;
    let steady: Vec<_> = trace
        .passes
        .iter()
        .filter(|p| p.decode_tokens > 0 && p.prefill_tokens > 0)
        .collect();
    let mean = |f: &dyn Fn(&moe_lens::metrics::PassRecord) -> f64| -> f64 {
        if steady.is_empty() {
            return 0.0;
        }
        steady.iter().map(|p| f(p)).sum::<f64>() / steady.len() as f64
    };
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["passes".into(), report.passes.to_string()]);
    t.row(&["steady passes".into(), steady.len().to_string()]);
    t.row(&["mean pass".into(), format!("{:.1} ms", mean(&|p| p.duration) * 1e3)]);
    t.row(&["  gpu (PJRT)".into(), format!("{:.1} ms", mean(&|p| p.gpu_time) * 1e3)]);
    t.row(&["  cpu (attn/KV/merge)".into(), format!("{:.1} ms", mean(&|p| p.cpu_time) * 1e3)]);
    t.row(&["  overlap (gpu+cpu)".into(), format!("{:.1} ms", mean(&|p| p.overlap_time) * 1e3)]);
    t.row(&["  io wait".into(), format!("{:.1} ms", mean(&|p| p.io_time) * 1e3)]);
    let overhead = mean(&|p| p.duration - p.lanes_total());
    t.row(&["  other (bookkeeping)".into(), format!("{:.1} ms", overhead * 1e3)]);
    t.row(&[
        "overhead share".into(),
        format!("{:.1} %", 100.0 * overhead / mean(&|p| p.duration)),
    ]);
    t.print();
    t.print_csv("perf_engine");

    // --- 1b. Double-buffered pass pipeline: exposed (non-overlapped)
    // host time per run, pipelining on vs off on the same workload.
    // Measured identically for both modes as the step wall clock *not*
    // covered by the hardware lanes (io + gpu + cpu + overlap): for the
    // synchronous engine that is the inter-pass plan/pack/complete gap;
    // for the pipelined engine it is the booked host lane (snapshot,
    // replans, worker join tail, commit patching) plus bookkeeping slack.
    let pipeline_run = |depth: usize| -> anyhow::Result<(f64, f64, usize, usize)> {
        let mut cfg = EngineConfig::for_model("small");
        cfg.kv_blocks = 512;
        cfg.pipeline_depth = depth;
        let mut engine = ServingEngine::load(cfg)?;
        let n_tok = engine.n_tok();
        let vocab = engine.pjrt.config.vocab;
        let mut rng = Rng::new(1);
        for i in 0..16 {
            let p = n_tok / 2;
            let prompt: Vec<i32> =
                (0..p).map(|_| rng.range(1, vocab - 1) as i32).collect();
            engine.submit(Request::new(i as u64, prompt, n_tok / 4))?;
        }
        let mut trace = engine.begin_run();
        let mut step_wall = 0.0f64;
        while !engine.sched.is_done() {
            let t0 = std::time::Instant::now();
            let step = engine.step()?;
            step_wall += t0.elapsed().as_secs_f64();
            trace.push(step.record);
        }
        // One definition for both modes: wall clock the hardware lanes
        // don't cover. (Comparing sync's step-minus-body against pipe's
        // booked host lane would measure two different things.)
        let hw: f64 = trace
            .passes
            .iter()
            .map(|p| p.io_time + p.gpu_time + p.cpu_time + p.overlap_time)
            .sum();
        let exposed = (step_wall - hw).max(0.0);
        let stats = engine.pipeline_stats();
        Ok((exposed, step_wall, stats.committed, stats.replanned))
    };
    let (exposed_sync, wall_sync, _, _) = pipeline_run(0)?;
    let (exposed_pipe, wall_pipe, committed, replanned) = pipeline_run(1)?;
    let mut t = Table::new(&["mode", "exposed_host_ms", "run_wall_ms", "committed", "replanned"]);
    t.row(&[
        "synchronous".into(),
        format!("{:.3}", exposed_sync * 1e3),
        format!("{:.1}", wall_sync * 1e3),
        "-".into(),
        "-".into(),
    ]);
    t.row(&[
        "pipelined".into(),
        format!("{:.3}", exposed_pipe * 1e3),
        format!("{:.1}", wall_pipe * 1e3),
        committed.to_string(),
        replanned.to_string(),
    ]);
    t.print();
    t.print_csv("perf_pipeline");
    // Wall-clock numbers are reported, not asserted — on a loaded box or
    // a tiny layer loop the fixed speculation overhead (snapshot clone +
    // worker spawn) can exceed the gap it hides. The deterministic
    // virtual-clock case below is the asserted acceptance check.
    if exposed_pipe >= exposed_sync {
        println!(
            "WARN: pipelined exposed host {:.3} ms did not undercut \
             synchronous {:.3} ms on this run (wall-clock noise or \
             speculation overhead > hidden gap at this scale)",
            exposed_pipe * 1e3,
            exposed_sync * 1e3
        );
    }

    // Deterministic counterpart on the virtual clock (exact, no wall
    // noise): same workload, host plan cost modeled, exposed host time
    // strictly lower with the pipeline on.
    {
        use moe_lens::config::ModelSpec;
        use moe_lens::simhw::{HostPlanCost, SimConfig, SimMachine};
        let reqs: Vec<Request> =
            (0..200).map(|i| Request::new(i, vec![1; 98], 32)).collect();
        let sim_run = |depth: usize| {
            let mut cfg = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);
            cfg.pipeline_depth = depth;
            cfg.host_plan = HostPlanCost::new(0.05, 1e-5);
            let (trace, report) = SimMachine::new(cfg).run(reqs.clone());
            let exposed: f64 = trace.passes.iter().map(|p| p.host_time).sum();
            (exposed, report.wall_secs)
        };
        let (sim_sync, sim_sync_wall) = sim_run(0);
        let (sim_pipe, sim_pipe_wall) = sim_run(1);
        println!(
            "sim (virtual clock): exposed host {:.2}s -> {:.2}s, wall {:.1}s -> {:.1}s",
            sim_sync, sim_pipe, sim_sync_wall, sim_pipe_wall
        );
        assert!(sim_pipe < sim_sync, "sim: pipelining must hide host time");
        assert!(sim_pipe_wall < sim_sync_wall);
    }

    // --- 2. CPU attention kernel (Mixtral-8x7B geometry).
    let shape = AttnShape { n_heads: 32, n_kv_heads: 8, head_dim: 128 };
    let (n_seq, ctx) = (16usize, 256usize);
    let kv_dim = shape.kv_dim();
    let mut cache =
        PagedKvCache::new(KvLayout::new(16, n_seq * ctx / 16 + 1), 1, kv_dim);
    let mut qs = Vec::new();
    for i in 0..n_seq {
        cache.register(i as SeqId);
        cache.grow(i as SeqId, ctx);
        for pos in 0..ctx {
            let k: Vec<f32> = (0..kv_dim).map(|_| rng.f32() - 0.5).collect();
            cache.write(i as SeqId, 0, pos, &k, &k);
        }
        qs.push((0..shape.q_dim()).map(|_| rng.f32() - 0.5).collect::<Vec<f32>>());
    }
    let queries: Vec<DecodeQuery> =
        qs.iter().enumerate().map(|(i, q)| DecodeQuery { seq: i as SeqId, q }).collect();
    let mut out = vec![0f32; n_seq * shape.q_dim()];
    let mut t = Table::new(&["kernel", "Mtok/s/core", "GB/s (KV scan)"]);
    let ladder = [
        ("scalar", Tier::Scalar),
        ("unrolled", Tier::Unrolled),
        ("simd", Tier::Simd),
        ("dispatch", Tier::Optimized),
    ];
    for (name, tier) in ladder {
        let st = bench(1, Duration::from_millis(600), || {
            decode_attention(&cache, 0, shape, &queries, &mut out, tier)
        });
        let toks = (n_seq * ctx) as f64 / st.mean.as_secs_f64();
        let bytes = toks * (2 * kv_dim * 2) as f64;
        t.row(&[name.into(), format!("{:.2}", toks / 1e6), format!("{:.2}", bytes / 1e9)]);
    }
    {
        let pool = ThreadPool::new(0);
        let st = bench(1, Duration::from_millis(600), || {
            pool.decode_attention(&cache, 0, shape, &queries, &mut out)
        });
        let toks = (n_seq * ctx) as f64 / st.mean.as_secs_f64();
        let per_core = toks / pool.n_threads() as f64;
        let bytes = toks * (2 * kv_dim * 2) as f64;
        t.row(&[
            format!("threaded x{}", pool.n_threads()),
            format!("{:.2}", per_core / 1e6),
            format!("{:.2}", bytes / 1e9),
        ]);
    }
    t.print();
    t.print_csv("perf_attn");

    // --- 3. Data mover achieved bandwidth (unthrottled memcpy roof).
    let manifest = moe_lens::runtime::Manifest::load("artifacts")?;
    let wm = manifest.config("small")?;
    let weights = Arc::new(WeightFile::load("artifacts", &wm.weights)?);
    let layer_elems = weights.layer_data(0).len();
    let mut t = Table::new(&["packet_MB", "achieved_GB/s"]);
    for packet_mb in [1usize, 4, 16, 100] {
        let buffer = Arc::new(WeightBuffer::new(layer_elems));
        let link = Arc::new(PcieLink::new(LinkTiming::Unthrottled));
        let mover = DataMover::spawn(
            Arc::clone(&weights),
            Arc::clone(&buffer),
            Arc::clone(&link),
            packet_mb << 20,
        );
        let t0 = std::time::Instant::now();
        let reps = 3;
        for r in 0..reps {
            mover.reset();
            for l in 0..weights.n_layers() {
                mover.request(l);
            }
            for l in 0..weights.n_layers() {
                mover.wait_layer(l);
                mover.done_with(l);
            }
            let _ = r;
        }
        let bytes = (reps * weights.n_layers() * layer_elems * 4) as f64;
        t.row(&[
            packet_mb.to_string(),
            format!("{:.2}", bytes / t0.elapsed().as_secs_f64() / 1e9),
        ]);
    }
    t.print();
    t.print_csv("perf_mover");

    // --- 4. Scheduler + paged-KV planning overhead at paper scale.
    let mut sched = Scheduler::new(SchedConfig::new(30_000, 30_000));
    let mut layout = PagedLayout::new(KvLayout::new(16, 300_000));
    for i in 0..20_000u64 {
        sched.submit(Request::new(i, vec![1; 98], 32));
    }
    let mut passes = 0usize;
    let t0 = std::time::Instant::now();
    while !sched.is_done() && passes < 64 {
        let plan = sched.plan(&mut layout);
        let mut toks: Vec<_> = plan.decode.iter().map(|&(id, _)| (id, 1)).collect();
        toks.extend(plan.prefill.iter().filter(|c| c.completes).map(|c| (c.id, 1)));
        sched.complete(&toks, &mut layout);
        passes += 1;
    }
    println!(
        "scheduler: {passes} paper-scale passes planned+completed in {:.1} ms \
         ({:.2} ms/pass, {} active decode at end)",
        t0.elapsed().as_secs_f64() * 1e3,
        t0.elapsed().as_secs_f64() * 1e3 / passes as f64,
        sched.active_decode(),
    );
    Ok(())
}
