//! Fig. 4: predicted GPU utilization vs KV capacity for bounded request
//! batches K ∈ {25k, 50k, 100k, 200k} with paged KV (b = 16), p = 100,
//! g = 128 — converging to the Stage-1 bound as K → ∞ and b → 1.

use moe_lens::config::{MachineSpec, ModelSpec};
use moe_lens::perfmodel::{Stage1Model, Stage2Model};
use moe_lens::util::bench::{banner, Table};

fn main() {
    banner("fig4", "predicted GPU utilization vs request batch size (p=100, g=128)");
    let machine = MachineSpec::paper_testbed();
    let model = ModelSpec::mixtral_8x7b();
    let s1 = Stage1Model::new(machine.clone(), model.clone());
    let s2 = Stage2Model::new(machine.clone(), model.clone(), 16);
    let s2_b1 = Stage2Model::new(machine, model, 1);
    let (p, g) = (100usize, 128usize);
    let ks = [25_000.0, 50_000.0, 100_000.0, 200_000.0];

    let mut t = Table::new(&[
        "kv_GB", "K=25k", "K=50k", "K=100k", "K=200k", "K=inf_b1", "stage1",
    ]);
    for kv_gb in [25u64, 50, 100, 200, 400, 800, 1600] {
        let kv = kv_gb << 30;
        let mut row = vec![kv_gb.to_string()];
        for &k in &ks {
            row.push(format!("{:.3}", s2.predict(p, g, kv, k).gpu_utilization));
        }
        row.push(format!("{:.3}", s2_b1.predict(p, g, kv, 1e9).gpu_utilization));
        row.push(format!("{:.3}", s1.max_gpu_utilization(p, g, kv)));
        t.row(&row);
    }
    t.print();
    t.print_csv("fig4");

    // Shape assertions: larger K -> higher utilization at fixed KV; the
    // b=1, K->inf column converges to Stage 1; paging shifts the knee
    // right (paged util <= unpaged util).
    for kv_gb in [100u64, 400] {
        let kv = kv_gb << 30;
        let u25 = s2.predict(p, g, kv, 25_000.0).gpu_utilization;
        let u200 = s2.predict(p, g, kv, 200_000.0).gpu_utilization;
        assert!(u200 >= u25 - 1e-9, "batch size should help at {kv_gb} GB");
        let inf = s2_b1.predict(p, g, kv, 1e9).gpu_utilization;
        let st1 = s1.max_gpu_utilization(p, g, kv);
        assert!((inf - st1).abs() < 0.03, "convergence at {kv_gb} GB: {inf} vs {st1}");
        let paged = s2.predict(p, g, kv, 1e9).gpu_utilization;
        assert!(paged <= inf + 1e-9, "paging must not beat ideal");
    }
    println!("\nshape check: paged KV (b=16) needs more capacity for the same");
    println!("utilization; K=inf & b=1 reproduces the Stage-1 curve.");
}
