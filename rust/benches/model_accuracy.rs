//! §8.1 model accuracy: the Stage-2 performance model vs execution, over
//! every Fig. 11/12 cell (simulated machine) *and* the real PJRT engine
//! (link clock). Paper: 94% average accuracy.

use moe_lens::config::{ModelSpec, MachineSpec};
use moe_lens::perfmodel::Stage2Model;
use moe_lens::simhw::{run_uniform, SimConfig};
use moe_lens::util::bench::{banner, Table};
use moe_lens::util::stats::prediction_accuracy;

fn main() {
    banner("model_accuracy", "Stage-2 prediction vs execution (all eval cells)");
    let mut t = Table::new(&["workload", "model", "g", "kv_GB", "predicted", "measured", "acc_%"]);
    let mut accs = Vec::new();

    let cells: Vec<(&str, usize, usize)> = vec![
        ("mtbench", 98, 32),
        ("mtbench", 98, 64),
        ("mtbench", 98, 128),
        ("mtbench", 98, 256),
        ("rag", 926, 128),
        ("aime", 128, 512),
    ];
    for model in [ModelSpec::mixtral_8x7b(), ModelSpec::mixtral_8x22b(), ModelSpec::dbrx()] {
        for &(wl, p, g) in &cells {
            for kv_gb in [70u64, 210] {
                let s2 = Stage2Model::new(MachineSpec::paper_testbed(), model.clone(), 16);
                let k = ((5.0 * g as f64 * s2.q(p, g, kv_gb << 30)) as usize)
                    .clamp(200, 10_000);
                let (_, report) = run_uniform(SimConfig::moe_lens(model.clone(), kv_gb), p, g, k);
                let pred = s2.predict(p, g, kv_gb << 30, k as f64);
                let acc = prediction_accuracy(pred.throughput, report.generation_throughput);
                accs.push(acc);
                t.row(&[
                    wl.to_string(),
                    model.name.to_string(),
                    g.to_string(),
                    kv_gb.to_string(),
                    format!("{:.0}", pred.throughput),
                    format!("{:.0}", report.generation_throughput),
                    format!("{:.0}", acc * 100.0),
                ]);
            }
        }
    }
    t.print();
    t.print_csv("model_accuracy");

    let avg = accs.iter().sum::<f64>() / accs.len() as f64;
    let worst = accs.iter().cloned().fold(1.0f64, f64::min);
    println!("\n== summary over {} cells ==", accs.len());
    println!("  average accuracy : {:.0}% (paper: 94%)", avg * 100.0);
    println!("  worst cell       : {:.0}%", worst * 100.0);
    assert!(avg > 0.75, "average accuracy shape: {avg}");
}
