//! Fig. 11: overall generation throughput — MoE-Lens vs MoE-Lightning vs
//! vLLM on MTBench across three models, g ∈ {32, 64, 128, 256}, and KV
//! cache sizes {70, 210} GB, with the Stage-2 model's prediction overlay.
//!
//! Absolute numbers are simulator-clock values on the paper's hardware
//! constants; the *shape* — who wins, the rise-then-drop vs g at 210 GB,
//! larger speedups at larger KV — is the reproduction target.

use moe_lens::baselines::{MoeLightningSim, VllmSim};
use moe_lens::config::ModelSpec;
use moe_lens::perfmodel::Stage2Model;
use moe_lens::simhw::{run_uniform, SimConfig};
use moe_lens::util::bench::{banner, Table};
use moe_lens::util::stats::{geomean, prediction_accuracy};

fn main() {
    banner("fig11", "MTBench generation throughput (tok/s, sim clock) + model overlay");
    let models = [ModelSpec::mixtral_8x7b(), ModelSpec::mixtral_8x22b(), ModelSpec::dbrx()];
    let p = 98usize; // MTBench average prompt
    let mut speedups = Vec::new();
    let mut accs = Vec::new();

    for kv_gb in [70u64, 210] {
        println!("\n-- KV cache {kv_gb} GB --");
        let mut t = Table::new(&[
            "model", "g", "vllm", "lightning", "moe-lens", "predicted", "speedup", "acc_%",
        ]);
        for model in &models {
            let s2 = Stage2Model::new(
                moe_lens::config::MachineSpec::paper_testbed(),
                model.clone(),
                16,
            );
            let mut lens_by_g = Vec::new();
            for &g in &[32usize, 64, 128, 256] {
                let cfg = SimConfig::moe_lens(model.clone(), kv_gb);
                // §7: request batch 25k for g=32@70GB MTBench, else 5gq
                // (capped for bench runtime; throughput is steady-state).
                let k = ((5.0 * g as f64 * s2.q(p, g, kv_gb << 30)) as usize)
                    .clamp(500, 20_000);
                let (_, lens) = run_uniform(cfg, p, g, k);
                let (_, light) =
                    MoeLightningSim::new(model.clone(), kv_gb).run_uniform(p, g, 2500);
                let (_, vllm) =
                    VllmSim::new(model.clone(), kv_gb).run_uniform(p, g, 300);
                let pred = s2.predict(p, g, kv_gb << 30, k as f64);
                let speedup = lens.generation_throughput / light.generation_throughput;
                let acc =
                    prediction_accuracy(pred.throughput, lens.generation_throughput);
                speedups.push(speedup);
                accs.push(acc);
                lens_by_g.push(lens.generation_throughput);
                t.row(&[
                    model.name.to_string(),
                    g.to_string(),
                    format!("{:.0}", vllm.generation_throughput),
                    format!("{:.0}", light.generation_throughput),
                    format!("{:.0}", lens.generation_throughput),
                    format!("{:.0}", pred.throughput),
                    format!("{speedup:.1}x"),
                    format!("{:.0}", acc * 100.0),
                ]);
                assert!(
                    lens.generation_throughput > light.generation_throughput,
                    "{} g={g} kv={kv_gb}: MoE-Lens must win",
                    model.name
                );
                assert!(
                    light.generation_throughput > vllm.generation_throughput,
                    "{} g={g} kv={kv_gb}: lightning must beat vllm",
                    model.name
                );
            }
        }
        t.print();
        t.print_csv(&format!("fig11_kv{kv_gb}"));
    }

    println!("\n== summary ==");
    println!(
        "  geomean speedup vs MoE-Lightning: {:.1}x (paper: 4.6x avg, up to 12.4x on MTBench)",
        geomean(&speedups)
    );
    println!(
        "  Stage-2 model accuracy vs simulated MoE-Lens: {:.0}% (paper: 94%)",
        100.0 * accs.iter().sum::<f64>() / accs.len() as f64
    );
    assert!(geomean(&speedups) > 2.0, "average speedup shape");
}
