//! Ablations for the design choices DESIGN.md §6 calls out, each
//! isolating one MoE-Lens ingredient on the simulated testbed
//! (Mixtral-8x7B, MTBench-like p=98):
//!
//! 1. prefill/decode **overlap** alone (two-phase baseline given the
//!    *fast* attention kernel and full-memory plans);
//! 2. KV **block size** (the §5.5 paging term, executed not just modeled);
//! 3. CPU attention **kernel efficiency** inside the full system;
//! 4. memory-controller **contention** κ sensitivity (§8.2).

use moe_lens::baselines::MoeLightningSim;
use moe_lens::config::ModelSpec;
use moe_lens::simhw::{run_uniform, SimConfig};
use moe_lens::util::bench::{banner, Table};

fn main() {
    let model = ModelSpec::mixtral_8x7b();
    let (p, g, kv_gb, k) = (98usize, 64usize, 70u64, 10_000usize);

    banner("ablation1", "prefill/decode overlap isolated (fast attention everywhere)");
    let (_, lens) = run_uniform(SimConfig::moe_lens(model.clone(), kv_gb), p, g, k);
    let mut two_phase = MoeLightningSim::new(model.clone(), kv_gb);
    two_phase.cpu_attn_eff = 0.8; // same kernel as MoE-Lens
    let (_, tp) = two_phase.run_uniform(p, g, k);
    let mut t = Table::new(&["schedule", "gen_tok_s"]);
    t.row(&["overlapped (MoE-Lens)".into(), format!("{:.0}", lens.generation_throughput)]);
    t.row(&["two-phase, fast attention".into(), format!("{:.0}", tp.generation_throughput)]);
    t.print();
    assert!(
        lens.generation_throughput > tp.generation_throughput,
        "overlap alone must win: {} vs {}",
        lens.generation_throughput,
        tp.generation_throughput
    );

    banner("ablation2", "paged-KV block size (Eq. 8 executed)");
    let mut t = Table::new(&["block_size", "gen_tok_s", "preemptions"]);
    let mut by_block = Vec::new();
    for b in [1usize, 16, 64, 256] {
        let mut cfg = SimConfig::moe_lens(model.clone(), kv_gb);
        cfg.block_size = b;
        let (_, r) = run_uniform(cfg, p, g, k);
        t.row(&[
            b.to_string(),
            format!("{:.0}", r.generation_throughput),
            r.preemptions.to_string(),
        ]);
        by_block.push((b, r.generation_throughput));
    }
    t.print();
    t.print_csv("ablation_block");
    // Coarser blocks waste slot fragments -> throughput must not improve.
    assert!(
        by_block[0].1 >= by_block[3].1 * 0.98,
        "b=1 {} vs b=256 {}",
        by_block[0].1,
        by_block[3].1
    );

    banner("ablation3", "CPU attention kernel efficiency inside the full system");
    let mut t = Table::new(&["kernel_eff", "gen_tok_s"]);
    let mut by_eff = Vec::new();
    for (label, eff) in [("autovec 0.26", 0.8 / 3.1), ("optimized 0.80", 0.8)] {
        let mut cfg = SimConfig::moe_lens(model.clone(), kv_gb);
        cfg.cpu_attn_eff = eff;
        let (_, r) = run_uniform(cfg, p, g, k);
        t.row(&[label.into(), format!("{:.0}", r.generation_throughput)]);
        by_eff.push(r.generation_throughput);
    }
    t.print();
    assert!(by_eff[1] >= by_eff[0], "faster kernel must not hurt");

    banner("ablation4", "memory-controller contention sensitivity (§8.2)");
    // κ is a compile-time constant in simhw; show its effect via the lane
    // model directly (quiet vs heavy attention at κ = 0.25).
    let costs = moe_lens::simhw::CostModel {
        machine: &moe_lens::config::MachineSpec::paper_testbed(),
        model: &model,
        cpu_attn_eff: 0.8,
    };
    let mut t = Table::new(&["kv_tokens_scanned", "io_s", "io_contended_s"]);
    for kv_tokens in [0u64, 500_000, 2_000_000, 8_000_000] {
        let lanes = costs.overlapped_iter(10_000, kv_tokens);
        t.row(&[
            kv_tokens.to_string(),
            format!("{:.2}", lanes.io),
            format!("{:.2}", lanes.io_contended),
        ]);
    }
    t.print();
    println!("\n(κ = 0.25 reproduces §8.2's ~5 s → ~6 s weight-sweep stretch)");
}
