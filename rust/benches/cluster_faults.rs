//! Fault-tolerant cluster serving: crash-recovery goodput vs a
//! no-failover baseline, and scale-out goodput vs a single replica, on
//! the simulated paper testbed (Mixtral-8x7B, MTBench shape, 70 GB KV
//! cache per replica, virtual clock — fully deterministic).
//!
//! Two comparisons, each on its own deterministic arrival stream:
//!
//! * **Scale-out** — a stream in deep overload, served by one replica
//!   and by two behind round-robin. Two machines split the pass work, so
//!   the wall clock (and with it goodput) must improve.
//! * **Recovery** — an *under-loaded* two-replica cluster where replica 1
//!   crashes mid-stream. Under-load is the honest regime for this
//!   comparison: the wall clock is arrival-dominated in both runs, so
//!   goodput is proportional to completions — which re-routing strictly
//!   wins, because the no-failover baseline (max_retries = 0) abandons
//!   every request stranded on the crashed replica. (In deep overload a
//!   fail-fast baseline can *win* on goodput by shrinking the wall —
//!   failing work quickly is not fault tolerance.)
//!
//! Emits BENCH_cluster_faults.json at the repo root for plotting.
//!
//! ```text
//! cargo bench --bench cluster_faults              # full run + rewrite artifact
//! cargo bench --bench cluster_faults -- --check   # CI: assert >= committed floors
//! ```

use moe_lens::cluster::{Cluster, ClusterConfig, ClusterReport, FaultPlan, RouterPolicy};
use moe_lens::config::ModelSpec;
use moe_lens::model::Request;
use moe_lens::simhw::SimConfig;
use moe_lens::util::bench::{banner, Table};
use moe_lens::util::json::{obj, Json};
use moe_lens::workload::ArrivalProcess;

const ARTIFACT: &str = "BENCH_cluster_faults.json";

/// Regression floors for `--check`. Both runs are virtual-clock
/// deterministic; the floors gate direction ("recovery must beat
/// abandoning the work", "a second replica must help"), not percent-level
/// drift.
const BUDGETS: &[(&str, f64)] = &[
    ("recovery_over_nofailover_min", 1.0),
    ("scaleout_2x_over_1x_min", 1.0),
];

fn artifact_path() -> String {
    let root = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/.."))
        .unwrap_or_else(|_| "..".into());
    format!("{root}/{ARTIFACT}")
}

fn stream(k: usize, rate: f64, p: usize, g: usize, seed: u64) -> Vec<(f64, Request)> {
    let mut rng = moe_lens::util::rng::Rng::new(seed);
    let times = ArrivalProcess::Poisson { rate }.times(k, &mut rng);
    times
        .into_iter()
        .enumerate()
        .map(|(i, t)| (t, Request::new(moe_lens::util::cast::usize_u64(i), vec![1; p], g)))
        .collect()
}

fn run(cfg: ClusterConfig, arrivals: &[(f64, Request)]) -> ClusterReport {
    Cluster::new(cfg).run_online(arrivals.to_vec(), f64::INFINITY)
}

fn main() {
    let check_mode = std::env::args().any(|a| a == "--check");
    banner(
        "cluster_faults",
        "crash-recovery goodput vs no-failover, scale-out goodput vs one replica",
    );
    let (p, g) = (98usize, 32usize);
    let base = SimConfig::moe_lens(ModelSpec::mixtral_8x7b(), 70);

    let mut t = Table::new(&[
        "scenario",
        "replicas",
        "completed",
        "rerouted",
        "replayed",
        "failed",
        "wall_s",
        "goodput_req_s",
    ]);
    let mut rows_json: Vec<Json> = Vec::new();
    let record = |t: &mut Table, rows: &mut Vec<Json>, name: &str, rep: &ClusterReport| {
        let wall = rep.traces.iter().map(|tr| tr.wall_secs()).fold(0.0f64, f64::max);
        t.row(&[
            name.into(),
            format!("{}", rep.reports.len()),
            format!("{}", rep.stats.completed),
            format!("{}", rep.stats.rerouted),
            format!("{}", rep.stats.replayed),
            format!("{}", rep.stats.failed),
            format!("{wall:.0}"),
            format!("{:.3}", rep.stats.goodput_rps),
        ]);
        rows.push(obj(vec![
            ("scenario", Json::Str(name.into())),
            ("replicas", Json::Num(rep.reports.len() as f64)),
            ("completed", Json::Num(rep.stats.completed as f64)),
            ("rerouted", Json::Num(rep.stats.rerouted as f64)),
            ("replayed", Json::Num(rep.stats.replayed as f64)),
            ("failed", Json::Num(rep.stats.failed as f64)),
            ("wall_s", Json::Num(wall)),
            ("goodput_req_s", Json::Num(rep.stats.goodput_rps)),
        ]));
    };

    // --- scale-out: deep overload, one replica vs two ------------------
    let k_over = 2_000usize;
    let overload = stream(k_over, 500.0, p, g, 0xC1);
    let one = run(ClusterConfig::new(base.clone(), 1), &overload);
    let two = run(ClusterConfig::new(base.clone(), 2), &overload);
    record(&mut t, &mut rows_json, "overload-1x", &one);
    record(&mut t, &mut rows_json, "overload-2x", &two);
    assert_eq!(one.stats.completed, k_over, "no deadlines: everything completes");
    assert_eq!(two.stats.completed, k_over, "no deadlines: everything completes");
    let scaleout = two.stats.goodput_rps / one.stats.goodput_rps.max(1e-12);
    assert!(
        scaleout > 1.0,
        "two replicas must beat one on overload goodput ({:.3} vs {:.3})",
        two.stats.goodput_rps,
        one.stats.goodput_rps
    );

    // --- recovery: under-loaded pair, replica 1 crashes mid-stream -----
    let k_rec = 400usize;
    let underload = stream(k_rec, 2.0, p, g, 0xFA);
    let faulted = |retries: usize| {
        let mut cfg = ClusterConfig::new(base.clone(), 2)
            .with_router(RouterPolicy::Deadline)
            .with_faults(FaultPlan::parse("crash@100:r1").expect("valid fault spec"));
        cfg.max_retries = retries;
        cfg
    };
    let recovered = run(faulted(2), &underload);
    let nofail = run(faulted(0), &underload);
    record(&mut t, &mut rows_json, "crash-recovered", &recovered);
    record(&mut t, &mut rows_json, "crash-nofailover", &nofail);
    t.print();
    t.print_csv("cluster_faults");

    assert!(
        nofail.stats.failed > 0,
        "the crash must strand work for the comparison to mean anything"
    );
    assert_eq!(
        recovered.stats.completed, k_rec,
        "with retries and no deadlines, every stranded request must recover"
    );
    assert!(
        recovered.stats.rerouted + recovered.stats.replayed > 0,
        "recovery must actually re-route"
    );
    let recovery = recovered.stats.goodput_rps / nofail.stats.goodput_rps.max(1e-12);
    assert!(
        recovery > 1.0,
        "re-route recovery goodput {:.3} must strictly beat no-failover {:.3}",
        recovered.stats.goodput_rps,
        nofail.stats.goodput_rps
    );
    println!(
        "\nrecovery goodput gain over no-failover: {recovery:.3}x; \
         2-replica scale-out over 1: {scaleout:.3}x"
    );

    // --- artifact: check against the committed floors, or rewrite -----
    let path = artifact_path();
    if check_mode {
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {path}: {e} — commit the bench artifact"));
        let doc = Json::parse(&text).expect("parse committed artifact");
        let budgets = doc.req("budgets");
        let measured = [
            ("recovery_over_nofailover_min", recovery),
            ("scaleout_2x_over_1x_min", scaleout),
        ];
        for (key, got) in measured {
            let floor = budgets.req(key).as_f64().expect("budget is a number");
            assert!(
                got >= floor,
                "budget {key}: measured {got:.4} under committed floor {floor:.4}"
            );
            println!("check {key}: {got:.3} >= floor {floor:.3}  ok");
        }
        println!("--check passed against {path}");
        return;
    }

    let doc = obj(vec![
        ("bench", Json::Str("cluster_faults".into())),
        ("version", Json::Num(1.0)),
        ("model", Json::Str(ModelSpec::mixtral_8x7b().name.to_string())),
        ("p", Json::Num(p as f64)),
        ("g", Json::Num(g as f64)),
        ("rows", Json::Arr(rows_json)),
        (
            "budgets",
            obj(BUDGETS.iter().map(|&(bk, v)| (bk, Json::Num(v))).collect()),
        ),
        (
            "note",
            Json::Str(
                "refresh with `cargo bench --bench cluster_faults` from rust/; \
                 both comparisons are virtual-clock deterministic, budgets gate \
                 direction (recovery and scale-out must win), not percent-level \
                 drift"
                    .into(),
            ),
        ),
    ]);
    std::fs::write(&path, format!("{doc}\n")).expect("write bench artifact");
    println!("wrote {path}");
}
